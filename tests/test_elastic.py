"""Elastic scaling: mesh planning + checkpoint reshard across meshes
(subprocess with 8 forced devices)."""
import os
import subprocess
import sys
import textwrap

from repro.launch.elastic import plan_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_plan_mesh_shapes():
    assert plan_mesh(512, pods=2) == ((2, 16, 16), ("pod", "data", "model"))
    assert plan_mesh(256) == ((16, 16), ("data", "model"))
    assert plan_mesh(64) == ((4, 16), ("data", "model"))
    assert plan_mesh(8, tp=4) == ((2, 4), ("data", "model"))


def test_restore_across_mesh_sizes():
    """Save on a (2,4) mesh, restore onto (4,2) — elasticity end-to-end."""
    body = """
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager

        devs = jax.devices()
        mesh_a = Mesh(np.array(devs).reshape(2, 4), ('data', 'model'))
        mesh_b = Mesh(np.array(devs).reshape(4, 2), ('data', 'model'))
        w = jnp.arange(64.0).reshape(8, 8)
        wa = jax.device_put(w, NamedSharding(mesh_a, P('data', 'model')))
        d = tempfile.mkdtemp()
        cm = CheckpointManager(d)
        cm.save(1, {'w': wa})
        got, _ = cm.restore(1, {'w': w},
                            shardings={'w': NamedSharding(mesh_b, P('data', 'model'))})
        np.testing.assert_array_equal(np.asarray(got['w']), np.asarray(w))
        assert got['w'].sharding.mesh.shape['data'] == 4
        print('OK')
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
