"""Shared cross-engine FL parity harness (not a test module).

The engine-parity suites — ``test_fl_batched.py`` (sequential vs
batched), ``test_fl_streaming.py`` (batched vs streaming),
``test_fl_arena.py`` (dict vs arena store) and ``test_fl_async.py``
(streaming vs async at staleness -> 0) — all drive the same tiny
image task through :class:`repro.fl.FLServer` and assert the same
contract. This module holds the single copy of that machinery:

  * :func:`get_task` — module-cached dataset + dirichlet partition
    (one build for the whole pytest session, every suite shares it),
  * :func:`make_model` — the 256-64-10 fedpara/pfedpara MLP,
  * :func:`run_server` — construct + run one configured ``FLServer``,
  * :func:`assert_parity` — the parity contract, store-agnostic: it
    reads client state through ``client_state_of``/``resident_of`` so
    a dict-store reference checks against an arena-store run as-is,
  * :func:`state_bytes` / :func:`hist_key` — the bitwise crash/resume
    fingerprints (``test_fl_resume.py``, ``test_fl_async.py``),
  * a ``hypothesis`` import shim so property tests degrade to skips
    when hypothesis is not installed.

Tolerance policy: engines reassociate the same fp32 weighted sum, so
params agree only to accumulation-order tolerance — ``DEFAULT_ATOL =
1e-4`` for the unnormalized streaming/async/arena accumulators,
``5e-5`` for the batched-vs-sequential pair which normalizes earlier
(each suite picks its bound). Everything discrete must match exactly:
arrival masks are bitwise, wire bytes to 1e-12 GB, losses to 1e-4.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParamCfg
from repro.data import dirichlet_partition, make_image_dataset, train_test_split
from repro.fl import ClientConfig, FLServer, ServerConfig, make_strategy
from repro.nn import recurrent as rec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # only the property tests need hypothesis
    HAVE_HYPOTHESIS = False

    def given(**kw):          # no-op decorators so modules still load
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    settings = given

    class st:  # noqa: N801
        sampled_from = staticmethod(lambda *a, **k: None)
        integers = staticmethod(lambda *a, **k: None)

DEFAULT_ATOL = 1e-4   # fp32 accumulation-order tolerance (running sums)

N_CLIENTS = 8

_TASK = {}


def get_task():
    """The shared parity task: 1200-sample synthetic image classification
    flattened to 256 features, split 8 ways by a dirichlet(0.5) draw.
    Cached at module level — the first suite to ask builds it, every
    later suite (and hypothesis re-entry) reuses the same arrays."""
    if not _TASK:
        ds = make_image_dataset(1200, 10, size=16, channels=1, noise=0.3)
        data = {"x": ds["x"].reshape(len(ds["y"]), -1), "y": ds["y"]}
        tr, te = train_test_split(data)
        _TASK.update(tr=tr, te=te,
                     parts=dirichlet_partition(tr["y"], N_CLIENTS, 0.5))
    return _TASK


def make_model(kind):
    """The parity model: a 256-64-10 MLP under the given factorization
    (``fedpara`` / ``pfedpara`` / ...). Returns (cfg, params, loss_fn);
    init is keyed on PRNGKey(0) so every engine starts identically."""
    cfg = rec.MLPConfig(in_dim=256, hidden=64, classes=10,
                        param=ParamCfg(kind=kind, gamma=0.3,
                                       min_dim_for_factorization=8))
    params = rec.init_mlp_model(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, b):
        return rec.mlp_loss(p, cfg, b)

    return cfg, params, loss_fn


def run_server(task, engine, *, chunk=None, strategy="fedavg",
               personalization="none", rounds=2, participation=0.5,
               lr=0.1, batch=16, epochs=1, eval_fn=None, **server_kw):
    """Construct one FLServer on the shared task and run it to
    completion. ``chunk=None`` leaves ``client_chunk`` at its default
    (the sequential/batched engines ignore it); extra ``server_kw``
    forward to :class:`ServerConfig`."""
    kind = "pfedpara" if personalization == "pfedpara" else "fedpara"
    cfg, params, loss_fn = make_model(kind)
    if chunk is not None:
        server_kw.setdefault("client_chunk", chunk)
    srv = FLServer(loss_fn, params, task["tr"], task["parts"],
                   make_strategy(strategy),
                   ClientConfig(lr=lr, batch=batch, epochs=epochs),
                   ServerConfig(clients=N_CLIENTS, participation=participation,
                                rounds=rounds, engine=engine,
                                personalization=personalization,
                                **server_kw),
                   eval_fn=eval_fn)
    srv.run()
    return srv


def maxdiff(a, b):
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b))
    return max(leaves) if leaves else 0.0


def assert_parity(ref, got, *, check_residents=False, atol=DEFAULT_ATOL):
    """The cross-engine parity contract.

    ``ref`` must be a dict-store server (its ``client_states`` /
    ``local_trees`` dicts drive the iteration); ``got`` may use any
    state store — client state is read through the store-agnostic
    ``client_state_of`` / ``resident_of`` accessors. Masks and wire
    bytes are exact, params fp32-tolerance, losses to 1e-4.
    ``check_residents`` additionally requires dict-store resident key
    sets to coincide (arena rows exist for every client by design).
    """
    assert ([r.get("arrived_mask") for r in ref.history]
            == [r.get("arrived_mask") for r in got.history])
    assert maxdiff(ref.global_params, got.global_params) < atol
    assert maxdiff(ref.server_state, got.server_state) < atol
    if ref.arena is None and got.arena is None:
        assert set(ref.client_states) == set(got.client_states)
    for cid in ref.client_states:
        assert maxdiff(ref.client_states[cid],
                       got.client_state_of(cid)) < atol, cid
    if check_residents and ref.arena is None and got.arena is None:
        assert set(ref.local_trees) == set(got.local_trees)
    for cid in ref.local_trees:
        resident = got.resident_of(cid)
        assert resident is not None, cid
        assert maxdiff(ref.local_trees[cid], resident) < atol, cid
    for rr, rg in zip(ref.history, got.history):
        assert abs(rr["mean_loss"] - rg["mean_loss"]) < 1e-4
        assert abs(rr["comm_gb"] - rg["comm_gb"]) < 1e-12


# ----------------------------------------------------- resume fingerprints
def state_bytes(srv):
    """Every aggregate-relevant array, as one bytes blob (bitwise)."""
    trees = [srv.global_params, srv.server_state]
    for cid in sorted(srv.client_states):
        trees.append(srv.client_states[cid])
    for cid in sorted(srv.local_trees):
        trees.append(srv.local_trees[cid])
    if srv.arena is not None:
        trees += [srv.arena.state, srv.arena.participation,
                  srv.arena.versions]
        if srv.arena.residents is not None:
            trees.append(srv.arena.residents)
    return b"".join(np.asarray(x).tobytes()
                    for t in trees for x in jax.tree.leaves(t))


def hist_key(hist):
    return [(r["round"], r["mean_loss"], r.get("down_bytes"),
             r.get("up_bytes"), tuple(r.get("arrived_mask", ())),
             r.get("rejected"), r.get("retries")) for r in hist]
