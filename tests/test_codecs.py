"""Codec pipeline unit tests: per-stage round trips, exact wire-byte
accounting, error-feedback bias reduction, vmap-vs-per-client parity,
split/merge placeholder alignment, and the downlink-application
regression (downlink quantization used to be a silent no-op)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import codecs, comm
from repro.fl.codecs import make_codec, measured_bytes
from repro.fl.strategies import tree_sub, tree_zeros


@pytest.fixture()
def payload():
    key = jax.random.PRNGKey(7)
    ka, kb, kc = jax.random.split(key, 3)
    return {
        "fc1": {"x1": jax.random.normal(ka, (40, 6)),
                "y1": jax.random.normal(kb, (30, 6))},
        "b1": jax.random.normal(kc, (30,)),
    }


def _maxdiff(a, b):
    return max(jax.tree.leaves(
        jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b)))


# ------------------------------------------------------------ stage trips

def test_identity_codec_is_noop(payload):
    codec = make_codec("fp32")
    assert codec.is_identity and not codec.has_ef
    dec, ef = codec.encode_decode(payload)
    assert dec is payload and ef is None
    assert codec.wire_bytes(payload) == comm.tree_bytes(payload)


def test_fp16_roundtrip(payload):
    dec, _ = make_codec("fp16").encode_decode(payload)
    assert _maxdiff(dec, payload) < 2e-3
    assert jax.tree.leaves(dec)[0].dtype == jnp.float32


def test_int8_roundtrip(payload):
    dec, _ = make_codec("int8").encode_decode(payload, key=jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(dec), jax.tree.leaves(payload)):
        scale = float(jnp.abs(b).max())
        assert float(jnp.abs(a - b).max()) < scale / 64


def test_delta_roundtrip_is_exact(payload):
    ref = jax.tree.map(lambda x: x + 0.5, payload)
    codec = make_codec("delta")
    dec, _ = codec.encode_decode(payload, ref=ref)
    assert _maxdiff(dec, payload) < 1e-6
    # the wire carries the difference, not the payload
    wire, _ = codec.encode(payload, ref=ref)
    assert _maxdiff(wire, tree_sub(payload, ref)) == 0.0


def test_topk_keeps_exactly_k_largest(payload):
    frac = 0.2
    codec = make_codec(f"topk{frac}")
    wire, ef = codec.encode(payload, ef=codec.ef_init(payload))
    for w, x in zip(jax.tree.leaves(wire), jax.tree.leaves(payload)):
        k = max(1, math.ceil(frac * x.size))
        nz = int((np.asarray(w) != 0).sum())
        assert nz == k
        kept = np.sort(np.abs(np.asarray(w).ravel()))[-k:]
        top = np.sort(np.abs(np.asarray(x).ravel()))[-k:]
        np.testing.assert_allclose(kept, top, atol=1e-7)
    # residual = input - wire (error feedback)
    assert _maxdiff(ef, tree_sub(payload, wire)) == 0.0


def test_lowrank_reconstructs_lowrank_input():
    a = jax.random.normal(jax.random.PRNGKey(0), (24, 3))
    b = jax.random.normal(jax.random.PRNGKey(1), (3, 18))
    x = {"w": a @ b}   # true rank 3
    dec, _ = make_codec("lowrank3").encode_decode(x)
    assert _maxdiff(dec, x) < 1e-4
    # rank-1 truncation of a rank-3 matrix must lose energy
    dec1, _ = make_codec("lowrank1").encode_decode(x)
    assert _maxdiff(dec1, x) > 1e-2


def test_lowrank_fractional_rank_and_ineligible_leaves(payload):
    codec = make_codec("lowrank0.25")
    wire, _ = codec.encode(payload)
    assert codecs._is_lr_node(wire["fc1"]["x1"])
    # 1-D bias passes through untouched
    np.testing.assert_array_equal(np.asarray(wire["b1"]),
                                  np.asarray(payload["b1"]))


# --------------------------------------------------------------- parsing

def test_spec_validation():
    assert make_codec("").is_identity
    assert make_codec("delta|topk0.1|int8").has_ef
    with pytest.raises(ValueError):
        make_codec("int8|delta")          # wrong order
    with pytest.raises(ValueError):
        make_codec("topk0.1|lowrank4")    # mutually exclusive sparsifiers
    with pytest.raises(ValueError):
        make_codec("topk0.1|topk0.2")     # duplicate category
    with pytest.raises(ValueError):
        make_codec("gzip")                # unknown stage
    with pytest.raises(ValueError):
        make_codec("topk1.5")             # fraction out of range


# ------------------------------------------------------------ wire bytes

def test_wire_bytes_exact(payload):
    sizes = {k: int(np.prod(v.shape)) for k, v in
             [("x1", payload["fc1"]["x1"]), ("y1", payload["fc1"]["y1"]),
              ("b1", payload["b1"])]}
    n = sum(sizes.values())
    assert make_codec("fp32").wire_bytes(payload) == 4 * n
    assert make_codec("fp16").wire_bytes(payload) == 2 * n
    assert make_codec("int8").wire_bytes(payload) == n + 4 * 3  # 3 scales
    # delta|topk0.1|int8: per leaf k int8 values + 4B indices + 4B scale
    expect = sum(
        (lambda k: k * 1 + 4 * k + 4)(max(1, math.ceil(0.1 * s)))
        for s in sizes.values())
    assert make_codec("delta|topk0.1|int8").wire_bytes(payload) == expect
    # delta|lowrank2|int8: eligible 2-D leaves carry r*(m+n) int8 factor
    # entries + 2 scales; the 1-D bias stays a plain int8 leaf + 1 scale
    r = 2
    expect_lr = ((r * (40 + 6) + 8) + (r * (30 + 6) + 8)
                 + (sizes["b1"] + 4))
    assert make_codec("delta|lowrank2|int8").wire_bytes(payload) == expect_lr


def test_measured_bytes_matches_wire_bytes(payload):
    key = jax.random.PRNGKey(3)
    ref = tree_zeros(payload)
    for spec, kw in [("int8", {}), ("fp16", {}), ("delta|lowrank2|int8", {}),
                     ("delta|topk0.1|int8", {"topk_frac": 0.1}),
                     ("topk0.3", {"topk_frac": 0.3})]:
        codec = make_codec(spec)
        wire, _ = codec.encode(payload, ref=ref, ef=codec.ef_init(payload),
                               key=key)
        assert measured_bytes(wire, **kw) == codec.wire_bytes(payload), spec


# ------------------------------------------------------- error feedback

def test_error_feedback_reduces_longrun_bias():
    """Accumulated EF-top-k transmissions converge to the true signal;
    naive top-k keeps dropping the same small coordinates forever."""
    x = {"g": jnp.asarray(np.linspace(0.1, 1.0, 50, dtype=np.float32))}
    codec = make_codec("topk0.2")
    T = 20
    naive = tree_zeros(x)
    with_ef = tree_zeros(x)
    ef = codec.ef_init(x)
    for _ in range(T):
        dec_naive, _ = codec.encode_decode(x)          # no accumulator
        naive = jax.tree.map(jnp.add, naive, dec_naive)
        dec_ef, ef = codec.encode_decode(x, ef=ef)
        with_ef = jax.tree.map(jnp.add, with_ef, dec_ef)
    target = jax.tree.map(lambda a: T * a, x)
    bias_naive = _maxdiff(naive, target) / T
    bias_ef = _maxdiff(with_ef, target) / T
    assert bias_naive > 0.05          # small coords never transmitted
    assert bias_ef < bias_naive / 5   # EF amortizes the truncation away


# ----------------------------------------------------- vmap == per-client

def test_vmap_path_matches_per_client(payload):
    C = 3
    codec = make_codec("delta|topk0.25|int8")
    keys = jax.random.split(jax.random.PRNGKey(5), C)
    stacked = jax.tree.map(
        lambda x: jnp.stack([x * (i + 1) for i in range(C)]), payload)
    ref = jax.tree.map(lambda x: 0.5 * x, payload)
    ef0 = codec.ef_init(payload)
    stacked_ef = jax.tree.map(lambda x: jnp.stack([x] * C), ef0)

    dec_v, ef_v = jax.vmap(
        lambda u, e, k: codec.encode_decode(u, ref=ref, ef=e, key=k)
    )(stacked, stacked_ef, keys)

    for i in range(C):
        one = jax.tree.map(lambda x: x[i], stacked)
        dec_i, ef_i = codec.encode_decode(one, ref=ref, ef=ef0, key=keys[i])
        assert _maxdiff(jax.tree.map(lambda x: x[i], dec_v), dec_i) < 1e-6
        assert _maxdiff(jax.tree.map(lambda x: x[i], ef_v), ef_i) < 1e-6


# ------------------------------------------------- approx top-k backend

def test_approx_topk_flag_parity(payload):
    """Routing _topk through jax.lax.approx_max_k (flag-forced) must
    stay within the EF-codec's tolerance of the exact lax.top_k path:
    approx selection with recall_target r keeps ≥ r·k of the true
    top-k mass, so the decoded payload error is bounded by the mass of
    the (1-r)·k swapped coordinates. On CPU the lowering is exact, so
    the two paths coincide; the bound below holds on every backend."""
    codec = make_codec("topk0.25")
    try:
        codecs.set_approx_topk(False)
        exact, _ = codec.encode_decode(payload)
        codecs.set_approx_topk(True)
        approx, _ = codec.encode_decode(payload)
    finally:
        codecs.set_approx_topk(None)
    # identical support size either way
    for e, a in zip(jax.tree.leaves(exact), jax.tree.leaves(approx)):
        assert int((e != 0).sum()) == int((a != 0).sum())
    # decoded mass within the recall bound of the exact path
    num = sum(float(jnp.sum(jnp.abs(e - a)))
              for e, a in zip(jax.tree.leaves(exact),
                              jax.tree.leaves(approx)))
    den = sum(float(jnp.sum(jnp.abs(e))) for e in jax.tree.leaves(exact))
    assert num <= 2 * (1 - codecs._APPROX_RECALL) * den + 1e-6


def test_approx_topk_flag_resolution(monkeypatch):
    codecs.set_approx_topk(True)
    assert codecs.use_approx_topk()
    codecs.set_approx_topk(False)
    assert not codecs.use_approx_topk()
    codecs.set_approx_topk(None)
    monkeypatch.setenv("REPRO_APPROX_TOPK", "1")
    assert codecs.use_approx_topk()
    monkeypatch.setenv("REPRO_APPROX_TOPK", "0")
    assert not codecs.use_approx_topk()
    monkeypatch.delenv("REPRO_APPROX_TOPK")
    # auto: accelerator backends only
    assert codecs.use_approx_topk() == (
        jax.default_backend() in ("tpu", "gpu"))


# --------------------------------------------- encoded-form aggregation

def test_encode_for_agg_linear_codecs(payload):
    """decode(wire) == linear(agg_wire) + delta-ref for every
    non-lowrank codec: the streaming accumulator can weighted-sum
    agg wires and add the reference once at the end."""
    ref = jax.tree.map(lambda x: 0.3 * x, payload)
    key = jax.random.PRNGKey(9)
    for spec in ("int8", "fp16", "delta|int8", "delta|topk0.3|int8",
                 "topk0.5"):
        codec = make_codec(spec)
        assert codec.agg_linear
        ef = codec.ef_init(payload)
        wire, _ = codec.encode_for_agg(payload, ref=ref, ef=ef, key=key)
        dec, _ = codec.encode_decode(payload, ref=ref, ef=ef, key=key)
        if "int8" in spec:
            lin = comm.dequantize_int8(wire)
        elif "fp16" in spec:
            lin = comm.dequantize_fp16(wire)
        else:
            lin = wire
        lin = codec.agg_finalize(lin, ref=ref)
        assert _maxdiff(lin, dec) < 1e-5, spec


def test_encode_for_agg_lowrank_composes_per_client(payload):
    """Bilinear stages are composed back per client by encode_for_agg;
    only the delta offset is left to the aggregator."""
    ref = tree_zeros(payload)
    codec = make_codec("delta|lowrank2|int8")
    assert not codec.agg_linear
    wire, _ = codec.encode_for_agg(payload, ref=ref,
                                   key=jax.random.PRNGKey(1))
    # dense leaves only — no {"lr_u","lr_v"} or {"q","scale"} nodes left
    def no_nodes(n):
        if isinstance(n, dict):
            assert set(n) not in ({"q", "scale"}, {"lr_u", "lr_v"})
            for v in n.values():
                no_nodes(v)
    no_nodes(wire)
    dec, _ = codec.encode_decode(payload, ref=ref,
                                 key=jax.random.PRNGKey(1))
    assert _maxdiff(codec.agg_finalize(wire, ref=ref), dec) < 1e-5


# ------------------------------------- split/merge placeholder alignment

def test_split_merge_preserves_sequence_placeholders():
    """Regression: list/tuple nodes used to drop None entries on the
    local side, so merge zipped misaligned sequences and silently
    replaced leaves."""
    key = jax.random.PRNGKey(0)
    leaf = lambda s: jax.random.normal(key, s)
    p = {
        "blocks": [
            {"x1": leaf((8, 2)), "y1": leaf((6, 2)),
             "x2": leaf((8, 2)), "y2": leaf((6, 2))},
            {"w": leaf((6, 6))},                      # dense block
        ],
        "pair": (leaf((4,)), {"x2": leaf((3, 2)), "x1": leaf((3, 2))}),
        "head": {"w": leaf((6, 3))},
    }
    g, l = comm.split_pfedpara(p)
    assert len(g["blocks"]) == 2                      # placeholders kept
    assert len(l["blocks"]) == 2 and l["blocks"][1] is None
    merged = comm.merge_pfedpara(g, l)
    flat_p = jax.tree_util.tree_flatten_with_path(p)[0]
    flat_m = jax.tree_util.tree_flatten_with_path(merged)[0]
    assert len(flat_p) == len(flat_m)
    for (ka, va), (kb, vb) in zip(sorted(flat_p, key=str),
                                  sorted(flat_m, key=str)):
        assert str(ka) == str(kb)
        np.testing.assert_array_equal(va, vb)


def test_split_merge_roundtrip_property():
    """Randomized nested dict/list/tuple trees round-trip exactly."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    rng = np.random.RandomState(0)

    def leaves():
        return st.builds(lambda s: rng.randn(s).astype(np.float32),
                         st.integers(1, 4))

    def trees(depth=3):
        if depth == 0:
            return leaves()
        sub = trees(depth - 1)
        fed = st.fixed_dictionaries(
            {"x1": leaves(), "y1": leaves(), "x2": leaves(), "y2": leaves()})
        return st.one_of(
            leaves(), fed,
            st.dictionaries(st.sampled_from(["a", "b", "w"]), sub,
                            min_size=1, max_size=2),
            st.lists(sub, min_size=1, max_size=3),
            st.lists(sub, min_size=1, max_size=3).map(tuple),
        )

    @given(trees())
    @settings(max_examples=30, deadline=None)
    def check(tree):
        g, l = comm.split_pfedpara(tree)
        merged = comm.merge_pfedpara(g, l)
        fa = jax.tree_util.tree_flatten_with_path(tree)[0]
        fb = jax.tree_util.tree_flatten_with_path(merged)[0]
        assert [str(k) for k, _ in fa] == [str(k) for k, _ in fb]
        for (_, va), (_, vb) in zip(fa, fb):
            np.testing.assert_array_equal(va, vb)

    check()
