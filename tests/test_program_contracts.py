"""fedlint Layer 2: donation aliasing, wire-dtype, and host-callback
contracts on the engines' real compiled round programs — plus negative
controls proving each detector actually detects.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import program_check as pc


# ------------------------------------------------------- real programs

def test_donation_aliases_in_compiled_hlo():
    for r in pc.check_donation():
        assert r.ok, r.render()


def test_wire_payloads_stay_at_wire_dtype():
    for r in pc.check_wire_dtype():
        assert r.ok, r.render()


def test_exactly_the_registered_callbacks():
    for r in pc.check_callbacks():
        assert r.ok, r.render()


@pytest.mark.slow
def test_cli_fast_mode_passes():
    assert pc.main(["--fast"]) == 0


# ---------------------------------------------------- negative controls

def test_widening_detector_catches_host_side_dequant():
    # the anti-design: int8 wire payload widened to f32 OUTSIDE any
    # kernel — exactly what the fused dequant-accumulate path avoids
    def bad_agg(q, coeff):
        return (q.astype(jnp.float32) * coeff[:, None]).sum(0)

    jaxpr = jax.make_jaxpr(bad_agg)(
        jax.ShapeDtypeStruct((4, 64), jnp.int8),
        jax.ShapeDtypeStruct((4,), jnp.float32)).jaxpr
    found = pc.widening_converts(jaxpr)
    assert len(found) == 1 and "int8" in found[0]


def test_widening_detector_ignores_non_wire_dtypes():
    def ok(x):
        return x.astype(jnp.float32).sum()

    jaxpr = jax.make_jaxpr(ok)(
        jax.ShapeDtypeStruct((8,), jnp.bfloat16)).jaxpr
    assert pc.widening_converts(jaxpr) == []


def test_alias_detector_requires_donation():
    def f(x):
        return x + 1.0

    x = jax.ShapeDtypeStruct((128,), jnp.float32)
    plain = jax.jit(f).lower(x).compile().as_text()
    donated = jax.jit(f, donate_argnums=(0,)).lower(x).compile().as_text()
    assert pc.hlo_aliases(plain) == []
    assert pc.hlo_aliases(donated) != []


def test_callback_detector_names_the_callee():
    def fetch(i):
        return np.zeros((3,), np.float32)

    def prog(i):
        return jax.pure_callback(
            fetch, jax.ShapeDtypeStruct((3,), jnp.float32), i)

    jaxpr = jax.make_jaxpr(prog)(jnp.int32(0)).jaxpr
    names = pc.callback_callees(jaxpr)
    assert len(names) == 1 and names[0].endswith("fetch")


def test_compile_counter_counts_fresh_compiles_only():
    @jax.jit
    def g(x):
        return x * 2.0

    with pc.CompileCounter() as cc:
        g(jnp.ones((4,)))       # fresh compile
        g(jnp.ones((4,)))       # cache hit
    assert cc.count == 1
    with pc.CompileCounter() as cc2:
        g(jnp.ones((4,)))       # still cached
    assert cc2.count == 0


@pytest.mark.slow
def test_serve_decode_compiles_once_and_keeps_int8_narrow():
    """The serve contracts: 16 decode steps over 2 user cohorts reuse
    ONE compilation (position + user rows traced, cache donated), and
    the int8 weight cache is never widened outside a pallas_call."""
    for r in pc.check_serve():
        assert r.ok, r.render()
