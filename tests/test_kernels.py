"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops


def _mats(key, B, m, n, r, dtype):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, m), dtype)
    f = [jax.random.normal(k, (d, r), jnp.float32) * 0.2
         for k, d in zip(ks[1:], (m, n, m, n))]
    return x, f


SHAPES = [
    (8, 64, 64, 4),
    (17, 100, 50, 3),      # non-aligned everything
    (128, 256, 256, 16),   # MXU-aligned
    (1, 384, 128, 32),     # single row
    (33, 128, 300, 7),
]


@pytest.mark.parametrize("B,m,n,r", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedpara_matmul_sweep(B, m, n, r, dtype):
    key = jax.random.PRNGKey(B * 1000 + m + n + r)
    x, (x1, y1, x2, y2) = _mats(key, B, m, n, r, dtype)
    got = ops.fedpara_matmul(x, x1, y1, x2, y2, interpret=True,
                             block_b=32, block_m=128, block_n=128)
    want = ops.fedpara_matmul_ref(x, x1, y1, x2, y2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("m,n,r", [(64, 64, 4), (100, 52, 3), (256, 256, 16),
                                   (300, 128, 9)])
@pytest.mark.parametrize("variant", ["plain", "tanh", "pfedpara"])
def test_fedpara_compose_sweep(m, n, r, variant):
    key = jax.random.PRNGKey(m + n + r)
    _, (x1, y1, x2, y2) = _mats(key, 1, m, n, r, jnp.float32)
    if variant == "plain":
        got = ops.fedpara_compose(x1, y1, x2, y2, interpret=True,
                                  block_m=128, block_n=128)
        want = ops.fedpara_compose_ref(x1, y1, x2, y2)
    elif variant == "tanh":
        got = ops.fedpara_compose(x1, y1, x2, y2, use_tanh=True, interpret=True,
                                  block_m=128, block_n=128)
        want = ops.fedpara_compose_ref(x1, y1, x2, y2, use_tanh=True)
    else:
        got = ops.pfedpara_compose(x1, y1, x2, y2, interpret=True,
                                   block_m=128, block_n=128)
        want = ops.pfedpara_compose_ref(x1, y1, x2, y2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 48), m=st.integers(8, 160), n=st.integers(8, 160),
       r=st.integers(1, 12), seed=st.integers(0, 2**30))
def test_fedpara_matmul_property(B, m, n, r, seed):
    key = jax.random.PRNGKey(seed)
    x, (x1, y1, x2, y2) = _mats(key, B, m, n, r, jnp.float32)
    got = ops.fedpara_matmul(x, x1, y1, x2, y2, interpret=True,
                             block_b=16, block_m=64, block_n=64)
    want = ops.fedpara_matmul_ref(x, x1, y1, x2, y2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_kernel_matches_layer_dense():
    """The fused kernel path must agree with the materialize-then-matmul
    layer path used by the models."""
    from repro.configs.base import ParamCfg
    from repro.nn.layers import dense, init_dense

    key = jax.random.PRNGKey(0)
    pcfg = ParamCfg(kind="fedpara", gamma=0.3, min_dim_for_factorization=8)
    sub = init_dense(key, 96, 160, pcfg)
    x = jax.random.normal(key, (4, 7, 96), jnp.float32)
    y_ref = dense(sub, x, pcfg, jnp.float32, use_pallas=False)
    y_ker = dense(sub, x, pcfg, jnp.float32, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
