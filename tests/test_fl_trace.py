"""Fleet availability-trace model: pinned statistics + server wiring.

``FleetTrace`` is the O(cohort) replacement for the server's O(fleet)
sampling path, so its statistics have to be pinned: tier proportions
must track ``tier_mix`` to within a percent at fleet scale, the diurnal
participation curve must modulate around ``1 - dropout`` with the
configured amplitude/phase spread, cohorts must be distinct in-range ids
reproducible from the round seed alone, and ``spawn_seeds`` must never
collide. The integration tests drive a real trace-configured server and
check the realized participation statistics.
"""
import numpy as np
import pytest

from repro.fl import FleetTrace, spawn_seeds
from repro.fl.trace import _id_hash


# ---------------------------------------------------------------- tier mix
def test_tier_mix_proportions_at_scale():
    mix = (0.5, 0.3, 0.2)
    trace = FleetTrace(clients=100_000, tier_mix=mix, seed=4)
    tiers = trace.tiers_of(np.arange(100_000))
    frac = np.bincount(tiers, minlength=3) / 100_000.0
    np.testing.assert_allclose(frac, mix, atol=0.01)
    np.testing.assert_array_equal(trace.tier_counts(), [50_000, 30_000,
                                                        20_000])


def test_tier_assignment_deterministic_and_seed_sensitive():
    cids = np.arange(1000)
    a = FleetTrace(clients=1000, tier_mix=(0.5, 0.5), seed=1)
    b = FleetTrace(clients=1000, tier_mix=(0.5, 0.5), seed=1)
    c = FleetTrace(clients=1000, tier_mix=(0.5, 0.5), seed=2)
    np.testing.assert_array_equal(a.tiers_of(cids), b.tiers_of(cids))
    assert (a.tiers_of(cids) != c.tiers_of(cids)).any()


def test_tiers_uncorrelated_with_phase():
    """The two id hashes use different irrational multipliers: a
    client's time zone must say nothing about its capacity tier."""
    trace = FleetTrace(clients=50_000, tier_mix=(0.5, 0.5), seed=0)
    cids = np.arange(50_000)
    phase = trace.client_phase(cids)
    tiers = trace.tiers_of(cids)
    # mean phase per tier both ~0.5 (independent uniforms)
    for t in (0, 1):
        assert abs(phase[tiers == t].mean() - 0.5) < 0.01


def test_homogeneous_trace_tiers_are_zero():
    trace = FleetTrace(clients=100)
    np.testing.assert_array_equal(trace.tiers_of(np.arange(5)), 0)
    np.testing.assert_array_equal(trace.tier_counts(), [100])


# ------------------------------------------------------------- availability
def test_availability_flat_without_diurnal():
    trace = FleetTrace(clients=1000, dropout=0.25)
    av = trace.availability(np.arange(100), round_idx=7)
    np.testing.assert_allclose(av, 0.75)


def test_availability_diurnal_pinned():
    """phase_spread=0 puts the whole fleet on one cycle: at a quarter
    period the sine peaks, availability = base * (1 + amplitude)."""
    trace = FleetTrace(clients=1000, dropout=0.2, diurnal_amplitude=0.2,
                       diurnal_period=24, phase_spread=0.0, seed=0)
    cids = np.arange(10)
    peak = trace.availability(cids, round_idx=6)    # t = 6/24 -> sin = 1
    trough = trace.availability(cids, round_idx=18)  # sin = -1
    np.testing.assert_allclose(peak, 0.8 * 1.2, atol=1e-9)
    np.testing.assert_allclose(trough, 0.8 * 0.8, atol=1e-9)


def test_availability_bounded_and_mean_reverting():
    trace = FleetTrace(clients=10_000, dropout=0.3, diurnal_amplitude=0.4,
                       diurnal_period=24, phase_spread=1.0, seed=3)
    cids = np.arange(10_000)
    means = []
    for r in range(24):
        av = trace.availability(cids, r)
        assert av.min() >= 0.0 and av.max() <= 1.0
        means.append(av.mean())
    # across a full simulated day the (unclipped) wave averages out
    assert abs(np.mean(means) - 0.7) < 0.02


def test_id_hash_equidistributed():
    u = _id_hash(np.arange(100_000), 0.6180339887498949, seed=5)
    hist, _ = np.histogram(u, bins=10, range=(0, 1))
    np.testing.assert_allclose(hist / 100_000.0, 0.1, atol=0.01)


# ------------------------------------------------------------------ cohorts
def test_sample_cohort_distinct_in_range_deterministic():
    trace = FleetTrace(clients=1_000_000, seed=11)
    a = trace.sample_cohort(trace.round_rng(3), 10_000)
    b = trace.sample_cohort(trace.round_rng(3), 10_000)
    c = trace.sample_cohort(trace.round_rng(4), 10_000)
    np.testing.assert_array_equal(a, b)        # replayable per round
    assert (a != c).any()                      # re-keyed per round
    assert len(a) == 10_000 == len(np.unique(a))
    assert a.min() >= 0 and a.max() < 1_000_000


@pytest.mark.parametrize("k", [1, 5, 8, 10])  # rejection / dense / full
def test_sample_cohort_small_fleet_paths(k):
    trace = FleetTrace(clients=10, seed=0)
    got = trace.sample_cohort(trace.round_rng(0), k)
    assert len(got) == k == len(np.unique(got))
    assert got.min() >= 0 and got.max() < 10


# ------------------------------------------------------------------ seeding
def test_spawn_seeds_unique_and_keyed():
    a = spawn_seeds(0, 0, 50_000)
    assert a.dtype == np.uint64
    assert len(np.unique(a)) == 50_000          # no birthday collisions
    np.testing.assert_array_equal(a, spawn_seeds(0, 0, 50_000))
    assert (a != spawn_seeds(0, 1, 50_000)).any()
    assert (a != spawn_seeds(1, 0, 50_000)).any()
    np.testing.assert_array_equal(
        FleetTrace(clients=10, seed=9).local_seeds(2, 8),
        spawn_seeds(9, 2, 8))


def test_trace_validation():
    with pytest.raises(ValueError):
        FleetTrace(clients=0)
    with pytest.raises(ValueError):
        FleetTrace(clients=10, tier_mix=(0.5, 0.4))


# -------------------------------------------------------------- integration
N_CLIENTS = 64


def _trace_server(trace, rounds=4, **server_kw):
    import jax

    from repro.configs.base import ParamCfg
    from repro.data import (dirichlet_partition, make_image_dataset,
                            train_test_split)
    from repro.fl import ClientConfig, FLServer, ServerConfig, make_strategy
    from repro.nn import recurrent as rec

    ds = make_image_dataset(500, 10, size=8, channels=1, noise=0.3)
    data = {"x": ds["x"].reshape(len(ds["y"]), -1), "y": ds["y"]}
    tr, _ = train_test_split(data)
    parts = dirichlet_partition(tr["y"], N_CLIENTS, 0.5)
    cfg = rec.MLPConfig(in_dim=64, hidden=32, classes=10,
                        param=ParamCfg(kind="fedpara", gamma=0.3,
                                       min_dim_for_factorization=8))
    params = rec.init_mlp_model(jax.random.PRNGKey(0), cfg)
    srv = FLServer(lambda p, b: rec.mlp_loss(p, cfg, b), params, tr, parts,
                   make_strategy("fedavg"),
                   ClientConfig(lr=0.1, batch=16, epochs=1),
                   ServerConfig(clients=N_CLIENTS, participation=0.25,
                                rounds=rounds, engine="streaming",
                                client_chunk=4, trace=trace, **server_kw))
    srv.run()
    return srv


def test_trace_server_participation_statistics():
    """A dropout-0.3 trace realizes ~70% arrivals of each sampled
    cohort, reproducibly (all randomness keyed on the trace seed)."""
    trace = FleetTrace(clients=N_CLIENTS, dropout=0.3, seed=21)
    srv = _trace_server(trace, rounds=6, state_store="arena",
                        data_stream="chunked")
    sampled = sum(len(r["sampled"]) for r in srv.history)
    arrived = sum(sum(r["arrived_mask"]) for r in srv.history)
    assert sampled == 6 * 16
    assert 0.45 < arrived / sampled < 0.95     # ~0.7 ± binomial noise
    assert arrived == srv.participation_counts().sum()
    # same trace seed -> bitwise-identical cohorts and masks
    rerun = _trace_server(FleetTrace(clients=N_CLIENTS, dropout=0.3,
                                     seed=21),
                          rounds=6, state_store="arena",
                          data_stream="chunked")
    assert ([r["sampled"] for r in srv.history]
            == [r["sampled"] for r in rerun.history])
    assert ([r["arrived_mask"] for r in srv.history]
            == [r["arrived_mask"] for r in rerun.history])


def test_trace_tier_mix_drives_hetero_pricing():
    """tier_mix pairs positionally with gamma_tiers: the run works with
    NO O(fleet) tier table (server.tier_of stays None) and still prices
    per-tier wire bytes."""
    trace = FleetTrace(clients=N_CLIENTS, tier_mix=(0.5, 0.3, 0.2), seed=5)
    srv = _trace_server(trace, rounds=2, gamma_tiers=(0.1, 0.2, 0.3),
                        state_store="arena")
    assert srv.tier_of is None
    assert srv.history[-1]["comm_gb"] > 0
    with pytest.raises(ValueError):
        _trace_server(FleetTrace(clients=N_CLIENTS, tier_mix=(0.5, 0.5),
                                 seed=5),
                      rounds=1, gamma_tiers=(0.1, 0.2, 0.3))
