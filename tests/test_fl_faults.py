"""Chaos-grade federation: deterministic fault injection + defenses.

The fault schedule (`repro.fl.faults.FaultPlan`) must be a pure
function of (seed, round, attempt) — identical across engines and
replayable — and the compiled upload defenses must (a) reject the
injected corruption, (b) keep the global model finite where
defense='none' lets NaNs poison it, and (c) agree across the
sequential / batched / streaming engines when the streaming chunk
covers the whole cohort (same gate statistics block).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.program_check import make_mini_server
from repro.fl import faults as faults_lib
from repro.fl.faults import FAULT_KINDS, FaultPlan
from repro.fl.strategies import tree_trimmed_wmean_stacked

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # only the property test needs hypothesis
    HAVE_HYPOTHESIS = False

    def given(**kw):          # no-op decorators so the module still loads
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    settings = given

    class st:  # noqa: N801
        integers = staticmethod(lambda **kw: None)
        floats = staticmethod(lambda **kw: None)


def _glob(srv):
    return np.concatenate([np.asarray(x).ravel()
                           for x in jax.tree.leaves(srv.global_params)])


# ------------------------------------------------------------ fault plans

def test_fault_plan_deterministic():
    plan = FaultPlan(rate=0.5, seed=3)
    a = plan.draw(7, 16)
    b = plan.draw(7, 16)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    # rounds are independently keyed: some round must differ
    c = plan.draw(8, 16)
    assert any(not np.array_equal(a[k], c[k]) for k in a)
    # recovery attempts open a fresh stream without touching attempt 0
    d = plan.draw(7, 16, attempt=1)
    assert any(not np.array_equal(a[k], d[k]) for k in a)
    np.testing.assert_array_equal(plan.draw(7, 16)["kind"], a["kind"])


def test_fault_plan_rate_zero_and_kinds():
    clean = FaultPlan(rate=0.0, seed=0).draw(0, 32)
    assert not clean["crash"].any()
    assert (clean["kind"] == -1).all()
    assert (clean["byz"] == 1.0).all()
    only_crash = FaultPlan(rate=1.0, kinds=("crash",), seed=0).draw(0, 32)
    assert only_crash["crash"].all()
    assert (only_crash["kind"]
            == FAULT_KINDS.index("crash")).all()


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       round_idx=st.integers(min_value=0, max_value=10_000),
       rate=st.floats(min_value=0.0, max_value=1.0))
def test_fault_plan_draw_is_pure(seed, round_idx, rate):
    """Property: draw(round) is bitwise replayable and internally
    consistent (exactly the drawn kinds set their per-kind mask)."""
    plan = FaultPlan(rate=rate, seed=seed)
    a, b = plan.draw(round_idx, 8), plan.draw(round_idx, 8)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    kind = a["kind"]
    np.testing.assert_array_equal(
        a["crash"], kind == FAULT_KINDS.index("crash"))
    np.testing.assert_array_equal(
        a["nan"] > 0, kind == FAULT_KINDS.index("nan"))
    np.testing.assert_array_equal(
        a["flip"] > 0, kind == FAULT_KINDS.index("bitflip"))
    np.testing.assert_array_equal(
        a["stale"] > 0, kind == FAULT_KINDS.index("stale"))
    np.testing.assert_array_equal(
        a["byz"] != 1.0, kind == FAULT_KINDS.index("byzantine"))


def test_fault_plan_draw_is_pure_seeded():
    """Deterministic fallback for the hypothesis property above."""
    for seed, round_idx, rate in [(0, 0, 0.3), (7, 123, 0.9), (42, 5, 0.05)]:
        plan = FaultPlan(rate=rate, seed=seed)
        a, b = plan.draw(round_idx, 8), plan.draw(round_idx, 8)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])
        kind = a["kind"]
        np.testing.assert_array_equal(
            a["crash"], kind == FAULT_KINDS.index("crash"))
        np.testing.assert_array_equal(
            a["nan"] > 0, kind == FAULT_KINDS.index("nan"))


# ------------------------------------------------------ injection helpers

def test_poison_clean_client_is_bitwise_noop():
    """A clean client's payload must pass through injection BIT-exactly
    (fault=None and fault-with-clean-draw paths must agree)."""
    key = jax.random.PRNGKey(0)
    u = {"w": jax.random.normal(key, (5, 4)), "b": jnp.ones((4,))}
    r = jax.tree.map(lambda x: x * 0.5, u)
    s = jax.tree.map(lambda x: x * 0.25, u)
    out = faults_lib.poison_upload_one(
        u, r, s, jnp.float32(0.0), jnp.float32(np.nan),
        jnp.float32(1.0), jnp.float32(0.0))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(u)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_flip_wire_bits_targets_int8_only():
    wire = {"q": jnp.zeros((64,), jnp.int8), "scale": jnp.float32(0.1)}
    key = jnp.asarray([1, 2], jnp.uint32)
    off = faults_lib.flip_wire_bits(wire, jnp.float32(0.0), key, 4)
    assert np.asarray(off["q"]).tobytes() == bytes(64)
    on = faults_lib.flip_wire_bits(wire, jnp.float32(1.0), key, 4)
    assert np.asarray(on["q"]).any()              # bits actually flipped
    assert float(on["scale"]) == float(wire["scale"])   # non-int8 untouched
    # deterministic in the key
    on2 = faults_lib.flip_wire_bits(wire, jnp.float32(1.0), key, 4)
    np.testing.assert_array_equal(np.asarray(on["q"]), np.asarray(on2["q"]))


def test_validity_gate_rejects_nan_and_outlier():
    inliers = 1.0 + 0.01 * np.arange(15, dtype=np.float32)
    norms = jnp.asarray(np.concatenate([inliers, [50.0, np.nan]]
                                       ).reshape(-1, 1), jnp.float32)
    finite = jnp.isfinite(norms).all(axis=1)
    cand = jnp.ones(17, jnp.float32)
    valid = np.asarray(faults_lib.validity_gate(norms, finite, cand, 3.0))
    assert valid[16] == 0.0         # non-finite always rejected
    assert valid[15] == 0.0         # 50x norm is far outside 3 sigma
    assert valid[:15].all()
    # degenerate blocks (<= 3 candidates): finite-only gate
    small = np.asarray(faults_lib.validity_gate(
        norms[:2], finite[:2], jnp.ones(2, jnp.float32), 3.0))
    assert small.all()


# ----------------------------------------------------- trimmed aggregation

def test_trimmed_mean_drops_outliers():
    vals = jnp.asarray([[1.0], [2.0], [3.0], [4.0], [100.0]], jnp.float32)
    w = jnp.ones(5, jnp.float32)
    fallback = {"x": jnp.zeros(())}
    out = tree_trimmed_wmean_stacked({"x": vals}, w, None,
                                     {"x": jnp.zeros((1,))}, trim=0.2)
    # k = floor(0.2 * 5) = 1 trimmed from each side: mean(2, 3, 4)
    np.testing.assert_allclose(np.asarray(out["x"]), [3.0], rtol=1e-6)
    # zero-weight members never participate
    w0 = jnp.asarray([1, 1, 1, 1, 0], jnp.float32)
    out0 = tree_trimmed_wmean_stacked({"x": vals}, w0, None,
                                      {"x": jnp.zeros((1,))}, trim=0.0)
    np.testing.assert_allclose(np.asarray(out0["x"]), [2.5], rtol=1e-6)
    # no surviving members -> fallback value
    outf = tree_trimmed_wmean_stacked({"x": vals},
                                      jnp.zeros(5, jnp.float32), None,
                                      {"x": jnp.full((1,), 7.0)}, trim=0.0)
    np.testing.assert_allclose(np.asarray(outf["x"]), [7.0], rtol=1e-6)


def test_trimmed_defense_statically_rejected_off_batched():
    for engine in ("sequential", "streaming"):
        with pytest.raises(ValueError, match="batched engine"):
            make_mini_server(engine, "dict", defense="trimmed")


# --------------------------------------------------- cross-engine identity

def test_cross_engine_fault_identity():
    """With client_chunk >= cohort the three engines share the same gate
    statistics block, so fault draws, rejections AND the defended global
    must agree (fp32 accumulation-order tolerance)."""
    results = {}
    for engine in ("sequential", "batched", "streaming"):
        srv = make_mini_server(engine, "dict", defense="clip",
                               fault_rate=0.4, uplink_codec="int8",
                               client_chunk=8)
        hist = [srv.run_round() for _ in range(3)]
        results[engine] = (srv, hist)
    ref_srv, ref_hist = results["sequential"]
    for engine in ("batched", "streaming"):
        srv, hist = results[engine]
        assert [r["rejected"] for r in hist] == \
            [r["rejected"] for r in ref_hist]
        assert [r["fault_kinds"] for r in hist] == \
            [r["fault_kinds"] for r in ref_hist]
        assert [r["arrived_mask"] for r in hist] == \
            [r["arrived_mask"] for r in ref_hist]
        assert np.abs(_glob(srv) - _glob(ref_srv)).max() < 5e-5


def test_defense_keeps_global_finite_under_nan_faults():
    """defense='none' lets one NaN client poison the aggregate; the
    clip gate rejects it and stays within a small loss gap of the
    fault-free run."""
    from repro.fl.faults import FaultPlan as FP

    def run(defense, rate):
        srv = make_mini_server("batched", "dict", defense=defense)
        if rate:
            srv.scfg.faults = FP(rate=rate, kinds=("nan", "byzantine"),
                                 seed=1)
        hist = [srv.run_round() for _ in range(4)]
        return srv, hist

    clean, hist_clean = run("none", 0.0)
    undefended, _ = run("none", 0.25)
    defended, hist_def = run("clip", 0.25)
    assert not np.isfinite(_glob(undefended)).all()
    assert np.isfinite(_glob(defended)).all()
    gap = abs(hist_def[-1]["mean_loss"] - hist_clean[-1]["mean_loss"])
    assert gap < 0.25, f"defended loss gap {gap:.3f} too large"
    assert sum(r["rejected"] for r in hist_def) > 0


def test_recovery_resamples_cohort():
    """When crashed + rejected clients exceed recover_frac, the round
    re-samples a replacement cohort from a salted stream (bounded by
    recover_retries) and records the attempt count."""
    from repro.fl.faults import FaultPlan as FP

    srv = make_mini_server("batched", "dict", defense="clip",
                           recover_retries=2, recover_frac=0.3)
    srv.scfg.faults = FP(rate=0.8, kinds=("crash", "nan"), seed=0)
    hist = [srv.run_round() for _ in range(3)]
    assert any(r["retries"] > 0 for r in hist)
    for r in hist:
        assert set(r["fault_kinds"]) <= {"crash", "nan"}
        assert r["retries"] <= 2
        assert np.isfinite(_glob(srv)).all()
    # recovery must not disturb the legacy RNG stream: a fault-free
    # server's post-run selection state is what a no-retry run produces
    srv_plain = make_mini_server("batched", "dict")
    srv_plain.run(rounds=3)
    s0 = srv.rng.get_state()
    s1 = srv_plain.rng.get_state()
    np.testing.assert_array_equal(s0[1], s1[1])
    assert s0[2] == s1[2]


def test_mean_loss_ignores_nonfinite_clients():
    """One NaN-loss client must not poison the round's mean_loss; the
    record keeps the non-finite count for diagnosis."""
    from repro.fl.server import _loss_stats

    mean, bad = _loss_stats([1.0, float("nan"), 3.0])
    assert mean == 2.0 and bad == 1
    mean, bad = _loss_stats([float("inf")])
    assert np.isnan(mean) and bad == 1
    mean, bad = _loss_stats([1.0, 3.0])
    assert mean == 2.0 and bad == 0  # all-finite: plain mean, bitwise
