"""End-to-end behaviour tests for the paper's system.

1. FedPara vs low-rank vs original at matched budgets (Table 2's claim,
   miniature): FedPara accuracy >= low-rank accuracy at equal params.
2. Communication: FedPara transfers ~gamma-controlled fraction of the
   original payload (Fig. 3's mechanism).
3. pFedPara personalization beats FedAvg on highly-skewed clients
   (Fig. 5 scenario 3, miniature).
4. Jacobian correction + tanh variants run and stay finite (supp. B).
5. Pods mode: train.py runs a full local-SGD round loop with checkpoint
   resume (fault tolerance).
"""
import functools
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParamCfg
from repro.core.parameterization import num_params
from repro.core.regularization import fedpara_loss_with_jacobian_correction
from repro.data import (
    dirichlet_partition,
    make_image_dataset,
    train_test_split,
    two_class_partition,
)
from repro.fl import ClientConfig, FLServer, ServerConfig, make_strategy
from repro.nn import recurrent as rec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# multi-round FL training loops + subprocess train.py runs: minutes, not
# seconds — excluded from the PR CI job (see pytest.ini / ci.yml)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def image_task():
    ds = make_image_dataset(2400, 10, size=16, channels=1, noise=0.5, seed=0)
    data = {"x": ds["x"].reshape(len(ds["y"]), -1), "y": ds["y"]}
    return train_test_split(data)


def _train(kind, gamma, tr, te, rounds=4, parts=None, personalization="none",
           clients=10, participation=0.5, lr=0.05):
    cfg = rec.MLPConfig(in_dim=256, hidden=128, classes=10,
                        param=ParamCfg(kind=kind, gamma=gamma,
                                       min_dim_for_factorization=8))
    params = rec.init_mlp_model(jax.random.PRNGKey(0), cfg)
    if parts is None:
        parts = dirichlet_partition(tr["y"], clients, 0.5)

    def loss_fn(p, b):
        return rec.mlp_loss(p, cfg, b)

    def eval_fn(p):
        return float(rec.mlp_accuracy(p, cfg, {"x": te["x"][:400],
                                               "y": te["y"][:400]}))

    srv = FLServer(loss_fn, params, tr, parts, make_strategy("fedavg"),
                   ClientConfig(lr=lr, batch=32, epochs=2),
                   ServerConfig(clients=clients, participation=participation,
                                rounds=rounds, personalization=personalization),
                   eval_fn=eval_fn)
    hist = srv.run()
    return srv, hist, cfg, params


def test_fedpara_capacity_vs_lowrank(image_task):
    """Table 2 mechanism, sanity margin only: a 4-round miniature is
    seed-noisy, so assert matched budgets + FedPara within a wide margin
    and learning. The full capacity comparison (longer runs) lives in
    benchmarks table2; the deterministic rank-superiority claim is in
    test_rank_properties.py::test_fedpara_beats_lowrank_rank_at_parity."""
    tr, te = image_task
    _, h_fp, cfg_fp, p_fp = _train("fedpara", 0.3, tr, te)
    _, h_lr, cfg_lr, p_lr = _train("lowrank", 0.3, tr, te)
    n_fp, n_lr = num_params(p_fp), num_params(p_lr)
    assert abs(n_fp - n_lr) < 0.15 * n_lr  # matched budgets by construction
    assert h_fp[-1]["eval"] > 0.3          # learns well above chance
    assert h_fp[-1]["eval"] >= h_lr[-1]["eval"] - 0.25


def test_comm_reduction_vs_original(image_task):
    """FedPara transfers a strict fraction of the original payload."""
    tr, te = image_task
    srv_fp, _, _, p_fp = _train("fedpara", 0.1, tr, te, rounds=2)
    srv_or, _, _, p_or = _train("original", 0.0, tr, te, rounds=2)
    ratio = srv_fp.comm_log.total_gb / srv_or.comm_log.total_gb
    assert ratio < 0.6, f"comm ratio {ratio}"
    assert ratio == pytest.approx(num_params(p_fp) / num_params(p_or), rel=0.05)


def test_pfedpara_beats_fedavg_on_skewed_clients(image_task):
    """Fig. 5 scenario 3 (highly-skewed two-class clients), miniature.

    The paper's comparison is at MATCHED COMMUNICATION (Fig. 5's x-axis
    is transfer cost): pFedPara uploads only the global halves (x1/y1 —
    half the factor payload), so the FedAvg baseline gets half the
    rounds at its full payload. Deterministic: data/model/server seeds
    are pinned, participation is full (every client's personal half
    trains every round), and the observed margin (~+0.15 across server
    seeds 0-2) is asserted with a wide safety gap.
    """
    tr, te = image_task
    parts = two_class_partition(tr["y"], 10)
    srv_p, _, cfg_p, _ = _train("pfedpara", 0.5, tr, te, rounds=4, parts=parts,
                                personalization="pfedpara",
                                participation=1.0, lr=0.1)
    srv_g, hist_g, cfg_g, _ = _train("fedpara", 0.5, tr, te, rounds=2,
                                     parts=parts, participation=1.0, lr=0.1)
    # the two runs really transfer the same uplink bytes (±5% for the
    # model's unfactorized leaves, which pFedPara also uploads)
    assert srv_p.comm_log.up_bytes == pytest.approx(
        srv_g.comm_log.up_bytes, rel=0.05)

    def ev(cfg):
        def fn(p, cid):
            idx = parts[cid][:60]
            return rec.mlp_accuracy(p, cfg, {"x": tr["x"][idx], "y": tr["y"][idx]})
        return fn

    acc_p = np.mean(srv_p.personalized_eval(ev(cfg_p)))
    acc_g = np.mean(srv_g.personalized_eval(ev(cfg_g)))
    assert acc_p > acc_g + 0.05, (acc_p, acc_g)
    assert acc_p > 0.5


def test_jacobian_correction_runs_and_reduces_mismatch():
    key = jax.random.PRNGKey(0)
    from repro.core.parameterization import init_fedpara

    params = init_fedpara(key, 32, 24, 6)
    target = jax.random.normal(key, (32, 24)) * 0.05

    def loss_of_w(w):
        return jnp.mean((w - target) ** 2)

    total = fedpara_loss_with_jacobian_correction(loss_of_w, params,
                                                  lam=1.0, eta=0.05)
    base = loss_of_w((params["x1"] @ params["y1"].T) * (params["x2"] @ params["y2"].T))
    assert float(total) >= float(base)  # penalty is nonnegative
    g = jax.grad(lambda p: fedpara_loss_with_jacobian_correction(
        loss_of_w, p, lam=1.0, eta=0.05))(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_tanh_variant_trains(image_task):
    tr, te = image_task
    _, hist, _, _ = _train("fedpara_tanh", 0.3, tr, te, rounds=3)
    assert np.isfinite(hist[-1]["mean_loss"])
    assert hist[-1]["eval"] > 0.15


def test_pods_training_with_checkpoint_resume():
    """train.py --mode pods: run 6 steps, kill, resume from checkpoint."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    with tempfile.TemporaryDirectory() as d:
        args = [sys.executable, "-m", "repro.launch.train", "--mode", "pods",
                "--arch", "xlstm-125m", "--preset", "cpu-small",
                "--seq", "32", "--batch", "4", "--steps", "6",
                "--ckpt-dir", d, "--ckpt-every", "3", "--log-every", "2"]
        out1 = subprocess.run(args, capture_output=True, text=True, env=env,
                              cwd=REPO, timeout=1200)
        assert out1.returncode == 0, out1.stderr[-2000:]
        assert "step 0 loss" in out1.stdout
        # resume: steps start from the checkpoint
        args2 = args[:args.index("--steps") + 1] + ["8"] + \
            args[args.index("--steps") + 2:]
        out2 = subprocess.run(args2, capture_output=True, text=True, env=env,
                              cwd=REPO, timeout=1200)
        assert out2.returncode == 0, out2.stderr[-2000:]
        assert "step 6 loss" in out2.stdout  # resumed past step 6
        assert "step 0 loss" not in out2.stdout
