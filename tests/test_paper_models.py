"""Paper model tests: VGG16 (Prop 3 convs + Table 5 counts), char-LSTM,
and the FC-pair MLP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParamCfg
from repro.core.parameterization import num_params
from repro.nn.recurrent import (
    LSTMConfig,
    MLPConfig,
    init_lstm,
    init_mlp_model,
    lstm_accuracy,
    lstm_apply,
    lstm_loss,
    mlp_loss,
)
from repro.nn.vision import (
    VGG_SMALL_PLAN,
    VGGConfig,
    init_vgg,
    vgg_accuracy,
    vgg_apply,
    vgg_loss,
)


def test_vgg16_param_counts_match_table5():
    """Paper Table 5: original 15.25M; FedPara gamma=0.1 -> 1.55M (10 cls)."""
    k = jax.random.PRNGKey(0)
    orig = init_vgg(k, VGGConfig(param=ParamCfg(kind="original")))
    fp = init_vgg(k, VGGConfig(param=ParamCfg(kind="fedpara", gamma=0.1)))
    assert abs(num_params(orig) / 1e6 - 15.25) < 0.1
    assert abs(num_params(fp) / 1e6 - 1.55) < 0.1
    # gamma monotone in params (Fig. 4 x-axis)
    sizes = [num_params(init_vgg(k, VGGConfig(param=ParamCfg(kind="fedpara",
                                                             gamma=g))))
             for g in (0.1, 0.4, 0.7)]
    assert sizes == sorted(sizes)


@pytest.mark.parametrize("kind", ["original", "lowrank", "fedpara"])
def test_vgg_small_trains_one_step(kind):
    k = jax.random.PRNGKey(0)
    cfg = VGGConfig(plan=VGG_SMALL_PLAN, fc_dims=(64,),
                    param=ParamCfg(kind=kind, gamma=0.2))
    p = init_vgg(k, cfg)
    x = jax.random.normal(k, (8, 32, 32, 3))
    y = jnp.arange(8) % 10
    loss, g = jax.value_and_grad(vgg_loss)(p, cfg, {"x": x, "y": y})
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
    logits = vgg_apply(p, cfg, x)
    assert logits.shape == (8, 10)


def test_lstm_compression_and_forward():
    k = jax.random.PRNGKey(0)
    fp = init_lstm(k, LSTMConfig())
    orig = init_lstm(k, LSTMConfig(param=ParamCfg(kind="original")))
    ratio = num_params(fp) / num_params(orig)
    assert 0.1 < ratio < 0.35  # paper reports ~19%
    cfg = LSTMConfig()
    tokens = jax.random.randint(k, (4, 33), 0, cfg.vocab)
    loss = lstm_loss(fp, cfg, {"tokens": tokens})
    assert np.isfinite(float(loss))
    logits = lstm_apply(fp, cfg, tokens[:, :-1])
    assert logits.shape == (4, 32, cfg.vocab)


def test_lstm_learns_markov_structure():
    from repro.data import make_char_corpus
    from repro.optim import adam, apply_updates

    cfg = LSTMConfig(vocab=20, embed=8, hidden=32,
                     param=ParamCfg(kind="fedpara", gamma=0.3,
                                    min_dim_for_factorization=8))
    k = jax.random.PRNGKey(0)
    p = init_lstm(k, cfg)
    data = make_char_corpus(64, 33, vocab=20, seed=0)
    opt = adam(1e-2)
    st = opt.init(p)
    batch = {"tokens": jnp.asarray(data)}
    l0 = float(lstm_loss(p, cfg, batch))
    step = jax.jit(lambda p, st: _step(p, st, cfg, batch, opt))
    for _ in range(30):
        p, st, loss = step(p, st)
    assert float(loss) < l0 - 0.3  # clear learning signal


def _step(p, st, cfg, batch, opt):
    loss, g = jax.value_and_grad(lstm_loss)(p, cfg, batch)
    u, st = opt.update(g, st, p)
    return apply_updates_local(p, u), st, loss


def apply_updates_local(p, u):
    return jax.tree.map(lambda a, b: a + b, p, u)


def test_mlp_pfedpara_structure():
    cfg = MLPConfig(param=ParamCfg(kind="pfedpara", gamma=0.5,
                                   min_dim_for_factorization=8))
    p = init_mlp_model(jax.random.PRNGKey(0), cfg)
    assert set(p["fc1"]) == {"x1", "y1", "x2", "y2"}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 784))
    loss = mlp_loss(p, cfg, {"x": x, "y": jnp.array([0, 1, 2, 3])})
    assert np.isfinite(float(loss))
