"""FL runtime tests: strategies, pFedPara split/merge, comm accounting,
quantization, straggler/dropout fault tolerance."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParamCfg
from repro.data import dirichlet_partition, make_image_dataset, train_test_split
from repro.fl import (
    ClientConfig,
    FLServer,
    ServerConfig,
    make_strategy,
    merge_pfedpara,
    split_pfedpara,
)
from repro.fl import comm
from repro.nn import recurrent as rec


@pytest.fixture(scope="module")
def task():
    ds = make_image_dataset(2000, 10, size=16, channels=1, noise=0.3)
    data = {"x": ds["x"].reshape(len(ds["y"]), -1), "y": ds["y"]}
    tr, te = train_test_split(data)
    cfg = rec.MLPConfig(in_dim=256, hidden=64, classes=10,
                        param=ParamCfg(kind="fedpara", gamma=0.3,
                                       min_dim_for_factorization=8))
    params = rec.init_mlp_model(jax.random.PRNGKey(0), cfg)
    parts = dirichlet_partition(tr["y"], 12, 0.5)

    def loss_fn(p, b):
        return rec.mlp_loss(p, cfg, b)

    def eval_fn(p):
        return float(rec.mlp_accuracy(p, cfg, {"x": te["x"][:300],
                                               "y": te["y"][:300]}))

    return dict(tr=tr, cfg=cfg, params=params, parts=parts,
                loss_fn=loss_fn, eval_fn=eval_fn)


@pytest.mark.slow  # 4-round FL loop per strategy; parity is covered fast
@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "scaffold",
                                      "feddyn", "fedadam"])
def test_strategies_learn(task, strategy):
    srv = FLServer(task["loss_fn"], task["params"], task["tr"], task["parts"],
                   make_strategy(strategy),
                   ClientConfig(lr=0.1, batch=32, epochs=2),
                   ServerConfig(clients=12, participation=0.5, rounds=4),
                   eval_fn=task["eval_fn"])
    hist = srv.run()
    assert hist[-1]["eval"] > hist[0]["eval"]
    assert hist[-1]["eval"] > 0.35  # well above 0.1 chance after 4 rounds


def test_pfedpara_split_merge_roundtrip(task):
    cfg = task["cfg"]
    p = rec.init_mlp_model(jax.random.PRNGKey(1),
                           rec.MLPConfig(in_dim=256, hidden=64, classes=10,
                                         param=ParamCfg(kind="pfedpara", gamma=0.5,
                                                        min_dim_for_factorization=8)))
    g, l = split_pfedpara(p)
    # the transferred half carries no x2/y2 leaves
    def keys(tree, acc=()):
        out = []
        for k, v in tree.items():
            if isinstance(v, dict):
                out += keys(v, acc + (k,))
            else:
                out.append(acc + (k,))
        return out
    assert not any(k[-1] in ("x2", "y2") for k in keys(g))
    assert all(k[-1] in ("x2", "y2") for k in keys(l))
    merged = merge_pfedpara(g, l)
    for (ka, va), (kb, vb) in zip(
            sorted(jax.tree_util.tree_flatten_with_path(p)[0], key=str),
            sorted(jax.tree_util.tree_flatten_with_path(merged)[0], key=str)):
        assert str(ka) == str(kb)
        np.testing.assert_array_equal(va, vb)
    # payload halves (paper: "only a half of each layer's parameters")
    from repro.core.parameterization import num_params
    factor_total = sum(num_params(v) for v in [p["fc1"], p["fc2"]])
    factor_global = sum(num_params(v) for v in [g["fc1"], g["fc2"]])
    assert abs(factor_global - factor_total / 2) < 2


def test_comm_accounting_matches_paper_formula(task):
    srv = FLServer(task["loss_fn"], task["params"], task["tr"], task["parts"],
                   make_strategy("fedavg"),
                   ClientConfig(lr=0.05, batch=32, epochs=1),
                   ServerConfig(clients=12, participation=0.5, rounds=2))
    srv.run()
    from repro.core.parameterization import num_params
    expected = 2 * 6 * num_params(task["params"]) * 4 * 2  # 2 dirs x 6 cl x 2 rounds
    assert abs(srv.comm_log.up_bytes + srv.comm_log.down_bytes - expected) < 0.01 * expected


def test_quantization_roundtrip():
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (64, 32)), "b": jax.random.normal(key, (7,))}
    q = comm.quantize_int8(tree, key)
    deq = comm.dequantize_int8(q)
    for k in tree:
        err = float(jnp.abs(deq[k] - tree[k]).max())
        scale = float(jnp.abs(tree[k]).max())
        assert err < scale / 64  # int8 grid
    assert comm.quantized_bytes(tree, "int8") < comm.quantized_bytes(tree, "fp32") / 3.5


def test_straggler_and_dropout_fault_tolerance(task):
    srv = FLServer(task["loss_fn"], task["params"], task["tr"], task["parts"],
                   make_strategy("fedavg"),
                   ClientConfig(lr=0.05, batch=32, epochs=1),
                   ServerConfig(clients=12, participation=0.5, rounds=3,
                                oversample=0.5, deadline_quantile=0.5,
                                dropout_prob=0.3, seed=3))
    hist = srv.run()
    assert len(hist) == 3  # no crash despite drops
    for rec_ in hist:
        assert rec_["participants"] <= 6


def test_total_dropout_skips_round(task):
    srv = FLServer(task["loss_fn"], task["params"], task["tr"], task["parts"],
                   make_strategy("fedavg"),
                   ClientConfig(lr=0.05, batch=32, epochs=1),
                   ServerConfig(clients=12, participation=0.5, rounds=1,
                                dropout_prob=1.0))
    rec_ = srv.run_round()
    assert rec_.get("skipped") and rec_["participants"] == 0


def test_fedpaq_uplink_quantization_runs(task):
    srv = FLServer(task["loss_fn"], task["params"], task["tr"], task["parts"],
                   make_strategy("fedavg"),
                   ClientConfig(lr=0.05, batch=32, epochs=1),
                   ServerConfig(clients=12, participation=0.5, rounds=2,
                                uplink_quant="int8"), eval_fn=task["eval_fn"])
    hist = srv.run()
    assert np.isfinite(hist[-1]["mean_loss"])


def _one_round_server(task, **server_kw):
    srv = FLServer(task["loss_fn"], task["params"], task["tr"], task["parts"],
                   make_strategy("fedavg"),
                   ClientConfig(lr=0.05, batch=32, epochs=1),
                   ServerConfig(clients=12, participation=0.5, rounds=1,
                                **server_kw))
    srv.run()
    return srv


@pytest.mark.parametrize("engine", ["sequential", "batched"])
def test_downlink_quantization_is_applied(task, engine):
    """Regression: downlink_quant used to be charged to CommLog but
    never applied to the payload clients trained on. An int8 downlink
    must change the client training inputs — and therefore the
    aggregated global params — in BOTH engines."""
    srv_fp32 = _one_round_server(task, engine=engine)
    srv_int8 = _one_round_server(task, engine=engine, downlink_quant="int8")
    diffs = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()),
        srv_fp32.global_params, srv_int8.global_params))
    assert max(diffs) > 1e-6, "int8 downlink did not change training"
    # and the decoded broadcast itself differs from the raw payload
    down_dec, _ = srv_int8._encode_downlink(srv_int8._download_payload(0))
    raw = srv_int8._download_payload(0)
    assert max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), down_dec, raw))) > 0


def test_commlog_bytes_equal_measured_encoded_bytes(task):
    """CommLog accounting must equal the bytes of the actually-encoded
    wire trees, not scheme-priced dense payloads."""
    from repro.fl import codecs

    srv = _one_round_server(task, uplink_quant="int8", downlink_quant="int8")
    n = srv.history[-1]["participants"]
    payload = srv._download_payload(0)
    codec = srv.downlink_codec
    wire, _ = codec.encode(payload, key=jax.random.PRNGKey(0))
    measured = codecs.measured_bytes(wire)
    assert srv.comm_log.down_bytes == n * measured
    assert srv.comm_log.up_bytes == n * measured  # same structure both links
    assert measured == codec.wire_bytes(payload)


def test_straggler_mask_keeps_first_arrivals():
    """Regression: the mask used to keep the first n_target in
    *sampling* order; it must keep the n_target earliest *arrivals*."""
    from repro.fl.server import arrival_mask

    lat = np.array([5.0, 1.0, 4.0, 2.0, 3.0])
    ok = np.array([True, True, True, True, True])
    np.testing.assert_array_equal(
        arrival_mask(ok, lat, 3), [False, True, False, True, True])
    # dropped-out clients never count toward the target
    ok2 = np.array([True, False, True, True, True])
    np.testing.assert_array_equal(
        arrival_mask(ok2, lat, 2), [False, False, False, True, True])
    # ties broken stably by sampling position
    lat3 = np.array([2.0, 1.0, 1.0])
    np.testing.assert_array_equal(
        arrival_mask(np.ones(3, bool), lat3, 2), [False, True, True])
