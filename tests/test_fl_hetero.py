"""Heterogeneous-capacity federation: per-client rank tiers.

Contracts under test (see docs/hetero.md):
  * uniform tiers at the model's own gamma reproduce the homogeneous
    engines exactly — bitwise arrival masks, fp32-tolerance params,
    identical wire bytes — across sequential/batched/streaming and
    non-identity codecs;
  * heterogeneous runs agree across all three engines on the same
    round selections;
  * per-tier uplink wire bytes are strictly lower for lower-gamma
    tiers (exact shape algebra, both links);
  * aggregation is per-column arrival-weighted: columns beyond a
    client's tier contribute zero WEIGHT (not zero value), and columns
    no arrived client covers keep the current global value;
  * slice/mask/embed helpers agree: embed(slice(p)) == mask * p.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParamCfg
from repro.core import parameterization as P
from repro.core import rank_policy
from repro.data import dirichlet_partition, make_image_dataset, train_test_split
from repro.fl import ClientConfig, FLServer, ServerConfig, make_strategy
from repro.fl.strategies import tree_hetero_wmean_stacked, tree_wmean_stacked
from repro.nn import recurrent as rec

ATOL = 1e-4
N_CLIENTS = 8
TIERS = (0.0, 0.1, 0.3)
MODEL_GAMMA = 0.3

_TASK = {}


def _get_task():
    if not _TASK:
        ds = make_image_dataset(1000, 10, size=16, channels=1, noise=0.3)
        data = {"x": ds["x"].reshape(len(ds["y"]), -1), "y": ds["y"]}
        tr, te = train_test_split(data)
        _TASK.update(tr=tr, te=te,
                     parts=dirichlet_partition(tr["y"], N_CLIENTS, 0.5))
    return _TASK


@pytest.fixture(scope="module")
def task():
    return _get_task()


def _make(kind="fedpara"):
    cfg = rec.MLPConfig(in_dim=256, hidden=64, classes=10,
                        param=ParamCfg(kind=kind, gamma=MODEL_GAMMA,
                                       min_dim_for_factorization=8))
    params = rec.init_mlp_model(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, b):
        return rec.mlp_loss(p, cfg, b)

    return cfg, params, loss_fn


def _run(task, engine, tiers, *, strategy="fedavg", personalization="none",
         rounds=2, chunk=3, participation=0.5, **server_kw):
    kind = "pfedpara" if personalization == "pfedpara" else "fedpara"
    cfg, params, loss_fn = _make(kind)
    srv = FLServer(loss_fn, params, task["tr"], task["parts"],
                   make_strategy(strategy),
                   ClientConfig(lr=0.1, batch=16, epochs=1),
                   ServerConfig(clients=N_CLIENTS, participation=participation,
                                rounds=rounds, engine=engine,
                                client_chunk=chunk, gamma_tiers=tiers,
                                personalization=personalization,
                                **server_kw))
    srv.run()
    return srv


def _maxdiff(a, b):
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b))
    return max(leaves) if leaves else 0.0


def _assert_parity(ref, got):
    assert ([r.get("arrived_mask") for r in ref.history]
            == [r.get("arrived_mask") for r in got.history])
    assert _maxdiff(ref.global_params, got.global_params) < ATOL
    assert _maxdiff(ref.server_state, got.server_state) < ATOL
    for cid in ref.client_states:
        assert _maxdiff(ref.client_states[cid],
                        got.client_states.get(cid, {})) < ATOL
    for rr, rg in zip(ref.history, got.history):
        assert rr["down_bytes"] == rg["down_bytes"]
        assert rr["up_bytes"] == rg["up_bytes"]


# ------------------------------------------------- helper-level contracts

def test_slice_mask_embed_roundtrip():
    _, params, _ = _make()
    for g in (0.0, 0.05, 0.3, 1.0):
        sliced = P.slice_factor_tree(params, g)
        masks = P.rank_mask_tree(params, g)
        emb = P.embed_factor_tree(sliced, params)
        masked = P.apply_rank_mask(params, masks)
        assert _maxdiff(emb, masked) == 0.0


def test_factor_spec_detection():
    _, params, _ = _make()
    spec = P.factor_spec(params["fc1"])
    assert spec["kind"] == "matrix"
    assert (spec["m"], spec["n"]) == (256, 64)
    assert spec["r"] == params["fc1"]["x1"].shape[1]
    assert P.factor_spec({"w": params["b1"]}) is None
    assert P.factor_spec(params) is None          # whole model: not a node
    # pfedpara split halves are still recognized
    assert P.factor_spec({k: params["fc1"][k] for k in ("x1", "y1")}) is not None
    assert P.factor_spec({k: params["fc1"][k] for k in ("x2", "y2")}) is not None


def test_conv_factor_masks():
    from repro.core.tensor_fedpara import init_conv_fedpara

    node = init_conv_fedpara(jax.random.PRNGKey(0), 32, 16, 3, 3, gamma=0.5)
    spec = P.factor_spec(node)
    assert spec["kind"] == "conv" and spec["k1"] == spec["k2"] == 3
    r_full = spec["r"]
    sliced = P.slice_factor_tree(node, 0.0)
    r_t = sliced["x1"].shape[1]
    assert r_t <= r_full
    assert sliced["t1"].shape == (r_t, r_t, 3, 3)
    emb = P.embed_factor_tree(sliced, node)
    masked = P.apply_rank_mask(node, P.rank_mask_tree(node, 0.0))
    assert _maxdiff(emb, masked) == 0.0


def test_tier_rank_floor_and_cap():
    # tiny layer where r_max < r_min: every tier floors at r_min
    m = n = 4
    assert rank_policy.matrix_rmax(m, n) < rank_policy.matrix_rmin(m, n)
    for g in (0.0, 0.5, 1.0):
        assert rank_policy.matrix_tier_rank(m, n, 2, g) == 2  # capped at r_full
    # gamma=1 tier never exceeds the materialized rank
    assert rank_policy.matrix_tier_rank(256, 64, 13, 1.0) == 13
    # gamma=0 tier floors at r_min even when r_full is larger
    assert (rank_policy.matrix_tier_rank(256, 64, 13, 0.0)
            == rank_policy.matrix_rmin(256, 64))


def test_tier_assignment_rules():
    sched = rank_policy.TierSchedule((0.05, 0.1, 0.3), "round_robin")
    assert list(sched.assign(6)) == [0, 1, 2, 0, 1, 2]
    rand = rank_policy.TierSchedule((0.05, 0.1, 0.3), "random")
    a1, a2 = rand.assign(50, seed=1), rand.assign(50, seed=1)
    assert (a1 == a2).all() and set(a1) <= {0, 1, 2}
    size = rank_policy.TierSchedule((0.3, 0.05), "size")  # unsorted gammas
    tiers = size.assign(4, sizes=[10, 100, 20, 200])
    # largest datasets land on the largest gamma (index 0 here)
    assert tiers[3] == 0 and tiers[1] == 0 and tiers[0] == 1 and tiers[2] == 1
    with pytest.raises(ValueError):
        rank_policy.TierSchedule((), "round_robin")
    with pytest.raises(ValueError):
        rank_policy.TierSchedule((0.1,), "nope")


def test_hetero_wmean_per_column_semantics():
    # 3 clients, leaf (2, 4): client tiers cover 2, 3 and 0 columns
    x = jnp.arange(24, dtype=jnp.float32).reshape(3, 2, 4)
    col = lambda k: (jnp.arange(4) < k).astype(jnp.float32)[None, :]
    masks = jnp.stack([col(2), col(3), col(0)])           # (3, 1, 4)
    w = jnp.array([1.0, 3.0, 5.0])
    tgt = jnp.full((2, 4), -7.0)
    out = tree_hetero_wmean_stacked(x, w, masks, tgt)
    # col 0-1: mean over clients 0, 1; col 2: client 1 only; col 3: nobody
    expect01 = (1 * x[0, :, :2] + 3 * x[1, :, :2]) / 4.0
    assert jnp.allclose(out[:, :2], expect01)
    assert jnp.allclose(out[:, 2], x[1, :, 2])
    assert jnp.allclose(out[:, 3], tgt[:, 3])             # uncovered: target
    # all-ones masks reduce to the homogeneous weighted mean
    ones = jnp.ones((3, 1, 4))
    assert jnp.allclose(tree_hetero_wmean_stacked(x, w, ones, tgt),
                        tree_wmean_stacked(x, w), atol=1e-6)


# ------------------------------------------------------ engine contracts

@pytest.mark.parametrize("engine", ["sequential", "batched", "streaming"])
@pytest.mark.parametrize("codec", ["", "int8", "delta|topk0.2|int8"])
def test_uniform_tier_reproduces_homogeneous(task, engine, codec):
    base = _run(task, engine, (), uplink_codec=codec)
    uni = _run(task, engine, (MODEL_GAMMA,), uplink_codec=codec)
    assert ([r.get("arrived_mask") for r in base.history]
            == [r.get("arrived_mask") for r in uni.history])
    assert _maxdiff(base.global_params, uni.global_params) < ATOL
    assert base.comm_log.up_bytes == uni.comm_log.up_bytes
    assert base.comm_log.down_bytes == uni.comm_log.down_bytes


@pytest.mark.parametrize("codec", ["", "int8", "delta|topk0.2|int8", "fp16"])
def test_hetero_engine_parity_codecs(task, codec):
    ref = _run(task, "sequential", TIERS, uplink_codec=codec)
    for engine in ("batched", "streaming"):
        got = _run(task, engine, TIERS, uplink_codec=codec)
        _assert_parity(ref, got)


@pytest.mark.parametrize("strategy", ["scaffold", "feddyn"])
def test_hetero_engine_parity_strategies(task, strategy):
    ref = _run(task, "sequential", TIERS, strategy=strategy)
    for engine in ("batched", "streaming"):
        _assert_parity(ref, _run(task, engine, TIERS, strategy=strategy))


@pytest.mark.parametrize("mode", ["pfedpara", "fedper", "local"])
def test_hetero_engine_parity_personalization(task, mode):
    ref = _run(task, "sequential", TIERS, personalization=mode)
    for engine in ("batched", "streaming"):
        got = _run(task, engine, TIERS, personalization=mode)
        _assert_parity(ref, got)
        for cid in ref.local_trees:
            assert _maxdiff(ref.local_trees[cid],
                            got.local_trees[cid]) < ATOL


def test_tier_bytes_strictly_lower(task):
    """Exact shape algebra: lower-gamma tiers upload strictly fewer wire
    bytes, and the hetero run charges strictly less than homogeneous."""
    srv = _run(task, "batched", TIERS, uplink_codec="int8",
               downlink_codec="int8")
    info = srv.tier_bytes()
    up = [t["up_bytes"] for t in info]
    down = [t["down_bytes"] for t in info]
    assert up[0] < up[1] < up[2]
    assert down[0] < down[1] < down[2]
    # exact: bytes equal the codec's pricing of the sliced payload
    probe = srv._download_payload(0)
    for t, g in enumerate(TIERS):
        sliced = P.slice_factor_tree(probe, g)
        assert up[t] == srv.uplink_codec.wire_bytes(sliced)
    homog = _run(task, "batched", (), uplink_codec="int8",
                 downlink_codec="int8")
    assert srv.comm_log.up_bytes < homog.comm_log.up_bytes
    assert srv.comm_log.down_bytes < homog.comm_log.down_bytes


def test_masked_columns_stay_zero_through_training(task):
    """A low-tier client's factor columns beyond its rank see only zero
    signals (masked broadcast, masked strategy state) and remain exactly
    zero through local SGD — the invariant that makes the masked program
    equal to physically sliced training. Verified on the personalization
    residents of a ``local``-mode run, which ARE the trained params."""
    srv = _run(task, "sequential", TIERS, rounds=1, participation=1.0,
               personalization="local")
    masks = srv._tier_cache["full_masks"]
    for cid, trained in srv.local_trees.items():
        mask = jax.tree.map(lambda m: m[int(srv.tier_of[cid])], masks)
        leftover = _maxdiff(trained, P.apply_rank_mask(trained, mask))
        assert leftover == 0.0, (cid, leftover)


def test_uncovered_columns_keep_global(task):
    """With every tier below the model gamma, trailing factor columns
    are covered by nobody and must keep their current global values."""
    cfg, params, loss_fn = _make()
    srv = FLServer(loss_fn, params, task["tr"], task["parts"],
                   make_strategy("fedavg"),
                   ClientConfig(lr=0.1, batch=16, epochs=1),
                   ServerConfig(clients=N_CLIENTS, participation=1.0,
                                rounds=1, engine="batched",
                                gamma_tiers=(0.0,)))  # everyone at r_min
    srv.run()
    mask = jax.tree.map(lambda m: m[0], srv._tier_cache["payload_masks"])
    for key in ("fc1", "fc2"):
        m = np.asarray(mask[key]["x1"])[0]
        covered = m > 0
        new = np.asarray(srv.global_params[key]["x1"])
        old = np.asarray(params[key]["x1"])
        assert not np.allclose(new[:, covered], old[:, covered])
        np.testing.assert_array_equal(new[:, ~covered], old[:, ~covered])
