"""Fused dequant-accumulate kernel vs the dense jnp oracle.

Acceptance: the kernel must match decode-then-reduce over non-aligned
shapes (client axis and flat length both off the tile grid), masked /
zero-weight clients, int8 ``{"q", "scale"}`` trees with per-client
scales, and mixed dense/fp16 leaves — and the two-level shard_map path
must equal the single-pass reduction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import comm
from repro.kernels import agg, blocks, ref

ATOL = 1e-4


def _rand_q(key, shape):
    return jax.random.randint(key, shape, -127, 128, jnp.int8)


@pytest.mark.parametrize("C,L", [(1, 7), (5, 37), (16, 512), (33, 600),
                                 (8, 4097), (64, 130)])
def test_dequant_acc_matches_oracle(C, L):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(C * 1000 + L), 3)
    q = _rand_q(k1, (C, L))
    coeff = jax.random.normal(k2, (C,))
    acc = jax.random.normal(k3, (L,))
    out = agg.dequant_acc(acc, q, coeff, interpret=True)
    want = ref.dequant_acc_ref(acc, q, coeff)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=ATOL)


def test_dequant_acc_masked_clients_contribute_zero():
    key = jax.random.PRNGKey(0)
    q = _rand_q(key, (6, 200))
    coeff = jnp.array([1.0, 0.0, 2.0, 0.0, 0.0, 0.5])
    acc = jnp.zeros((200,))
    out = agg.dequant_acc(acc, q, coeff, interpret=True)
    want = ref.dequant_acc_ref(acc, q[jnp.array([0, 2, 5])],
                               coeff[jnp.array([0, 2, 5])])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=ATOL)


def test_dequant_acc_fp_dtypes():
    key = jax.random.PRNGKey(1)
    for dtype in (jnp.float32, jnp.float16):
        x = jax.random.normal(key, (9, 333)).astype(dtype)
        coeff = jnp.abs(jax.random.normal(key, (9,)))
        acc = jnp.ones((333,))
        out = agg.dequant_acc(acc, x, coeff, interpret=True)
        want = ref.dequant_acc_ref(acc, x, coeff)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=ATOL)


def test_tree_dequant_acc_int8_scale_tree():
    """Stacked {"q", "scale"} nodes: the per-client scale folds into the
    coefficient (dequant is linear), nested dict/list structure walks."""
    key = jax.random.PRNGKey(2)
    C = 7
    ks = jax.random.split(key, 4)
    payload = {"w": jax.random.normal(ks[0], (C, 6, 9)),
               "sub": [jax.random.normal(ks[1], (C, 11)),
                       jax.random.normal(ks[2], (C,))]}
    wire = jax.vmap(lambda t, k: comm.quantize_int8(t, k))(
        payload, jax.random.split(ks[3], C))
    w = jnp.abs(jax.random.normal(key, (C,)))
    out = agg.tree_dequant_acc(agg.acc_zeros_like(wire), wire, w,
                               interpret=True)
    want = ref.tree_dequant_acc_ref(agg.acc_zeros_like(wire), wire, w)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=ATOL)
    # accumulator structure mirrors the payload, not the wire
    assert out["w"].shape == (6, 9) and out["sub"][1].shape == ()


def test_tree_dequant_acc_mixed_wire():
    """int8 nodes and dense fp16/fp32 leaves in one wire tree (what a
    "topk|fp16"-style codec hands the streaming aggregator)."""
    key = jax.random.PRNGKey(3)
    C = 5
    wire = {
        "a": jax.vmap(lambda x, k: comm.quantize_int8(x, k))(
            jax.random.normal(key, (C, 24)), jax.random.split(key, C)),
        "b": jax.random.normal(key, (C, 4, 6)).astype(jnp.float16),
        "c": jax.random.normal(key, (C, 3)),
    }
    w = jnp.array([2.0, 0.0, 1.0, 3.0, 0.5])
    out = agg.tree_dequant_acc(agg.acc_zeros_like(wire), wire, w,
                               interpret=True)
    want = ref.tree_dequant_acc_ref(agg.acc_zeros_like(wire), wire, w)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=ATOL)


def test_tree_dequant_acc_running_accumulation():
    """Chunked folding: two tree_dequant_acc calls over client halves
    equal one call over the full stack (chunk-size invariance at the
    kernel level)."""
    key = jax.random.PRNGKey(4)
    C = 8
    x = jax.random.normal(key, (C, 50))
    w = jnp.abs(jax.random.normal(key, (C,)))
    full = agg.tree_dequant_acc(jnp.zeros((50,)), x, w, interpret=True)
    half = agg.tree_dequant_acc(jnp.zeros((50,)), x[:4], w[:4],
                                interpret=True)
    half = agg.tree_dequant_acc(half, x[4:], w[4:], interpret=True)
    np.testing.assert_allclose(np.asarray(half), np.asarray(full), rtol=1e-4, atol=ATOL)


def test_select_agg_blocks_regimes():
    for L, want_l in [(100, 512), (1 << 13, 2048), (1 << 17, 8192),
                      (1 << 21, 16384)]:
        bc, bl = blocks.select_agg_blocks(16, L)
        assert bc == 32 and bl == want_l


def test_acc_zeros_like_structures():
    wire = {"q8": {"q": jnp.zeros((3, 4, 5), jnp.int8),
                   "scale": jnp.zeros((3,))},
            "dense": jnp.zeros((3, 7))}
    acc = agg.acc_zeros_like(wire)
    assert acc["q8"].shape == (4, 5) and acc["q8"].dtype == jnp.float32
    assert acc["dense"].shape == (7,)
