"""Distributed runtime tests — run in subprocesses with 8 forced host
devices (device count is locked at first jax init, so in-process tests
can't change it)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.collectives import (
    plan_buckets,
    powersgd_compress,
    powersgd_decompress,
    powersgd_init,
)
from repro.distributed.fedpod import sync_mask
from repro.analysis import hlo

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_powersgd_error_feedback_bounded():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (64, 32))
    st = powersgd_init(g.shape, 4, key)
    sent = jnp.zeros_like(g)
    for _ in range(30):
        p, q, st = powersgd_compress(g, st)
        sent = sent + powersgd_decompress(p, q)
    rel = float(jnp.linalg.norm(sent - 30 * g) / jnp.linalg.norm(30 * g))
    assert rel < 0.5  # cumulative transmitted ~ cumulative gradient
    # full-rank compression is exact (up to fp32 QR/matmul roundoff)
    st2 = powersgd_init(g.shape, 32, key)
    p, q, st2 = powersgd_compress(g, st2)
    rel_full = float(jnp.linalg.norm(powersgd_decompress(p, q) - g)
                     / jnp.linalg.norm(g))
    assert rel_full < 1e-3


def test_bucket_plan_respects_size():
    tree = {f"w{i}": jnp.zeros((1024,)) for i in range(10)}  # 4KB each
    buckets = plan_buckets(tree, bucket_bytes=8192)
    assert all(len(b) <= 2 for b in buckets)
    assert sum(len(b) for b in buckets) == 10


def test_sync_mask_keeps_embeddings_local():
    params = {"embed": {"w": jnp.zeros((8, 4))},
              "layers": {"wq": {"x1": jnp.zeros((4, 2))}},
              "unembed": {"w": jnp.zeros((4, 8))}}
    mask = sync_mask(params, "factors")
    assert mask["embed"]["w"] is False
    assert mask["unembed"]["w"] is False
    assert mask["layers"]["wq"]["x1"] is True
    mask_full = sync_mask(params, "full")
    assert all(jax.tree.leaves(mask_full))


def test_fedpod_round_semantics():
    """2 pods diverge during local steps; after FedAvg the synced leaves
    are equal across pods and equal to the mean, embeddings stay local."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed.fedpod import make_fed_round, stack_for_pods, sync_mask
        from repro.optim import sgd

        def loss_fn(params, batch):
            h = batch['x'] @ params['wq']['x1']
            h = h @ params['embed']['w']
            return jnp.mean((h - batch['y'])**2)

        params = {'wq': {'x1': jnp.ones((4, 3))},
                  'embed': {'w': jnp.ones((3, 2)) * 0.5}}
        opt = sgd(0.05)
        mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4, 1),
                    ('pod', 'data', 'model'))
        stacked = stack_for_pods(params, 2)
        opt_state = jax.tree.map(lambda a: jnp.stack([a, a]),
                                 opt.init(params))
        K, B = 3, 8
        key = jax.random.PRNGKey(0)
        batches = {'x': jax.random.normal(key, (2, K, B, 4)),
                   'y': jax.random.normal(key, (2, K, B, 2))}
        step = make_fed_round(loss_fn, opt, local_steps=K, sync='factors')
        with mesh:
            new_params, opt_state, loss = jax.jit(step)(stacked, opt_state, batches)
        x1 = np.asarray(new_params['wq']['x1'])
        emb = np.asarray(new_params['embed']['w'])
        assert np.allclose(x1[0], x1[1]), 'factors must be pod-synced'
        assert not np.allclose(emb[0], emb[1]), 'embeddings stay pod-local'
        print('OK', float(loss))
    """)


def test_quick_dryrun_cell_via_subprocess():
    """End-to-end dryrun machinery on a small mesh (8 devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
         "--shape", "decode_32k", "--mesh", "multi", "--quick",
         "--skip-cost", "--out", "/tmp/dryrun_pytest"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    import json
    art = json.load(open("/tmp/dryrun_pytest/xlstm-125m_decode_32k_multi.json"))
    assert "memory" in art and art["memory"]["argument_bytes"] > 0


def test_batched_local_update_pads_nondivisible_client_batch():
    """Regression: C not divisible by the mesh axis used to warn and
    silently fall back to single-device vmap; now the batch is padded
    with masked dummies, stays on the shard_map path, and matches the
    plain vmap result exactly."""
    run_sub("""
        import warnings
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.fl.batch_engine import batched_local_update
        from repro.fl.client import ClientConfig

        def loss_fn(p, b):
            return jnp.mean((b['x'] @ p['w'] - b['y']) ** 2)

        C, S, B = 6, 3, 4          # 6 clients on an 8-device axis
        key = jax.random.PRNGKey(0)
        params = {'w': jax.random.normal(key, (C, 5, 2))}
        batches = {'x': jax.random.normal(key, (C, S, B, 5)),
                   'y': jax.random.normal(key, (C, S, B, 2))}
        smask = jnp.ones((C, S), jnp.float32).at[2, 2:].set(0.0)
        cfg = ClientConfig(lr=0.1)
        args = (params, {}, batches, smask, loss_fn, cfg, 'fedavg', 0.1)

        ref = batched_local_update(*args)            # single-device vmap
        mesh = Mesh(np.array(jax.devices()[:8]), ('clients',))
        with warnings.catch_warnings():
            warnings.simplefilter('error')           # no fallback warning
            out = batched_local_update(*args, mesh=mesh)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
            assert a.shape == b.shape
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
        print('OK padded shard_map matches vmap')
    """)


def test_sharded_dequant_acc_two_level():
    """Two-level streaming aggregation: per-shard fused partial sums +
    one psum must equal the dense oracle over the full client stack."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.fl import comm
        from repro.kernels import agg, ref

        C = 8
        key = jax.random.PRNGKey(0)
        payload = {'w': jax.random.normal(key, (C, 12, 5)),
                   'b': jax.random.normal(key, (C, 7))}
        wire = jax.vmap(comm.quantize_int8)(
            payload, jax.random.split(key, C))
        w = jnp.abs(jax.random.normal(key, (C,)))
        mesh = Mesh(np.array(jax.devices()[:8]), ('clients',))
        with mesh:
            out = jax.jit(lambda t, ww: agg.sharded_tree_dequant_acc(
                t, ww, mesh, 'clients', interpret=True))(wire, w)
        want = ref.tree_dequant_acc_ref(agg.acc_zeros_like(wire), wire, w)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)
        print('OK two-level')
    """)


def test_streaming_engine_on_client_mesh():
    """Full streaming round on a ('clients',) mesh: chunk sharded over
    devices, two-level aggregation — must match the meshless run."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs.base import ParamCfg
        from repro.data import iid_partition, make_image_dataset, \
            train_test_split
        from repro.fl import ClientConfig, FLServer, ServerConfig, \
            make_strategy
        from repro.nn import recurrent as rec

        ds = make_image_dataset(640, 10, size=8, channels=1, noise=0.3)
        data = {'x': ds['x'].reshape(len(ds['y']), -1), 'y': ds['y']}
        tr, _ = train_test_split(data)
        cfg = rec.MLPConfig(in_dim=64, hidden=32, classes=10,
                            param=ParamCfg(kind='fedpara', gamma=0.3,
                                           min_dim_for_factorization=8))
        params = rec.init_mlp_model(jax.random.PRNGKey(0), cfg)
        parts = iid_partition(len(tr['y']), 8, 0)
        def loss_fn(p, b):
            return rec.mlp_loss(p, cfg, b)
        def build(mesh):
            return FLServer(loss_fn, params, tr, parts,
                            make_strategy('fedavg'),
                            ClientConfig(lr=0.1, batch=16, epochs=1),
                            ServerConfig(clients=8, participation=1.0,
                                         rounds=1, engine='streaming',
                                         client_chunk=8,
                                         uplink_codec='int8'),
                            mesh=mesh)
        srv0 = build(None); srv0.run()
        mesh = Mesh(np.array(jax.devices()[:8]), ('clients',))
        srv1 = build(mesh); srv1.run()
        d = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(srv0.global_params),
            jax.tree.leaves(srv1.global_params)))
        assert d < 1e-4, d
        print('OK mesh streaming', d)
    """)


def test_bucketed_pmean_subprocess():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed.collectives import bucketed_pmean
        mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4),
                    ('pod', 'data'))
        tree = {'a': jnp.arange(8.0), 'b': jnp.ones((3, 3))}
        with mesh:
            out = jax.jit(lambda t: bucketed_pmean(t, mesh, 'pod'))(tree)
        np.testing.assert_allclose(np.asarray(out['a']), np.arange(8.0))
        print('OK')
    """)


# ---------------------------------------------------------------- HLO parse

SAMPLE_HLO = """
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={{0,1},{2,3}}, to_apply=%add
  %ag = bf16[16,512]{1,0} all-gather(%agin), dimensions={1}, replica_groups=[2,4]<=[8]
  %agin = bf16[16,128]{1,0} parameter(1)
  %rs = f32[4,32]{1,0} reduce-scatter(%p0), dimensions={1}, replica_groups={{0,1,2,3}}
  %cp = f32[8]{0} collective-permute(%cpi), source_target_pairs={{0,1},{1,0}}
  %cpi = f32[8]{0} parameter(2)
"""


def test_collective_stats_operand_accounting():
    st = hlo.collective_stats(SAMPLE_HLO, pod_size=0)
    # all-reduce operand = 16*128*4 = 8192
    assert st["all-reduce:intra_pod"]["bytes"] == 8192
    # all-gather operand resolved through defs: bf16 16*128*2 = 4096
    assert st["all-gather:intra_pod"]["bytes"] == 4096
    # reduce-scatter operand = full f32 input 8192
    assert st["reduce-scatter:intra_pod"]["bytes"] == 8192
    assert st["collective-permute:intra_pod"]["bytes"] == 32
    assert st["total"]["count"] == 4


def test_replica_group_formats_and_domain():
    groups = hlo.parse_replica_groups("[2,4]<=[8]")
    assert groups == [[0, 1, 2, 3], [4, 5, 6, 7]]
    gt = hlo.parse_replica_groups("[4,2]<=[2,4]T(1,0)")
    assert gt == [[0, 4], [1, 5], [2, 6], [3, 7]]
    assert hlo.classify_domain([[0, 4]], pod_size=4) == "cross_pod"
    assert hlo.classify_domain([[0, 1, 2, 3]], pod_size=4) == "intra_pod"


def test_extrapolation_linear():
    u1 = {"total": {"bytes": 10, "ring_bytes": 5.0, "count": 2}}
    u2 = {"total": {"bytes": 16, "ring_bytes": 8.0, "count": 3}}
    out = hlo.extrapolate(u1, u2, periods=10)
    assert out["total"]["bytes"] == 10 + 9 * 6
    assert out["total"]["count"] == 2 + 9 * 1
