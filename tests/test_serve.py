"""Serve engine: cost-model decisions, the user arena, the serve-params
cache rewrite, engine-level mode parity, and the checkpoint->serve
roundtrip from a REAL (miniature) pFedPara federation.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ParamCfg
from repro.data import iid_partition, make_token_lm_dataset
from repro.fl import comm
from repro.nn.layers import init_dense
from repro.nn.transformer import ModelOptions, build_model
from repro.serve import (ServeEngine, UserArena, build_serve_params,
                         crossover_batch, decide, inject_users,
                         load_fl_checkpoint, mode_costs, plan_params)


def _tiny_cfg(kind="pfedpara"):
    cfg = get_arch("qwen3-8b").reduced()
    return dataclasses.replace(cfg, n_layers=2, param=dataclasses.replace(
        cfg.param, kind=kind, min_dim_for_factorization=8, gamma=0.5))


_OPTS = ModelOptions(attn_chunk=8, ssm_chunk=8, logit_chunk=16,
                     dtype=jnp.float32)


# ----------------------------------------------------------- cost model

def test_fused_reads_fewer_bytes_at_decode_batch():
    # the headline regime: at B=1 the fused path streams factor bytes
    # (16r(m+n)) against precompose's weight-cache bytes (~mn)
    c = mode_costs(1024, 4096, 32, 1)
    assert c["fused"]["bytes"] < c["precompose"]["bytes"]


def test_crossover_shrinks_with_rank():
    # larger rank -> more per-row fused work -> precompose wins earlier
    assert (crossover_batch(1024, 4096, 128)
            <= crossover_batch(1024, 4096, 32)
            <= crossover_batch(1024, 4096, 8))


def test_decide_forced_modes_and_impls():
    for mode, impl in (("precompose", "w8"), ("fused", None)):
        d = decide("p", 256, 512, 16, batch=1, mode=mode)
        assert d.mode == mode
        if impl:
            assert d.impl == impl
    d = decide("p", 256, 512, 16, batch=1, mode="precompose",
               weight_dtype="fp16")
    assert d.impl == "einsum"


def test_tanh_never_takes_the_gram_identity():
    c = mode_costs(512, 512, 32, 1, kind="fedpara_tanh")
    assert c["fused"]["impl"] == "tile"


def test_auto_picks_the_measured_faster_branch():
    # pinned cases straddling the crossover: tiny batch favors fused,
    # wide batch favors precompose — auto must take whichever branch
    # its own measurements rank first, on every case
    for batch in (1, 64):
        d = decide("p", 256, 512, 8, batch=batch, mode="auto", measure=True)
        assert set(d.measured_us) == {"precompose", "fused"}
        assert d.mode == min(d.measured_us, key=d.measured_us.get)


def test_pfedpara_user_costs_compare_cache_vs_gram():
    c = mode_costs(256, 512, 8, 4, users=4, kind="pfedpara")
    assert c["precompose"]["impl"] == "cache_residual"
    assert c["fused"]["impl"] == "gram"


def test_plan_params_walks_factors_and_dense():
    cfg = _tiny_cfg("fedpara")
    model = build_model(cfg, _OPTS)
    params = model.init_params(jax.random.PRNGKey(0))
    plan = plan_params(params, "fedpara", batch=1, mode="auto")
    modes = {d.mode for d in plan.values()}
    assert "dense" in modes                      # embed / unembed
    assert modes - {"dense"}                     # factorized layers too
    assert all(d.r > 0 for d in plan.values() if d.mode != "dense")


# ----------------------------------------------------------- user arena

def _local_tree(key, m, n, r):
    k1, k2 = jax.random.split(key)
    return {"lin": {"x2": jax.random.normal(k1, (m, r)) * 0.2,
                    "y2": jax.random.normal(k2, (n, r)) * 0.2}}


def test_arena_rows_and_gather():
    trees = {uid: _local_tree(jax.random.PRNGKey(uid), 8, 12, 2)
             for uid in (3, 7, 11)}
    arena = UserArena.create(trees)
    assert arena.n_users == 3
    rows = arena.rows_for([7, 3, 99])   # unknown uid -> row 0
    assert rows.tolist() == [1, 0, 0]
    g = arena.gather(rows)
    np.testing.assert_array_equal(np.asarray(g["lin"]["x2"][0]),
                                  np.asarray(trees[7]["lin"]["x2"]))
    assert g["lin"]["y2"].shape == (3, 12, 2)
    assert arena.nbytes() == sum(x.size * 4 for x in jax.tree.leaves(
        arena.tree))


def test_inject_users_overlays_and_orients():
    sp = {"lin": {"x1": jnp.zeros((8, 2)), "y1": jnp.zeros((12, 2))},
          "scan": {"x1": jnp.zeros((4, 8, 2)), "y1": jnp.zeros((4, 12, 2))},
          "embed": {"w": jnp.zeros((5, 8))}}
    gathered = {
        "lin": {"x2": jnp.ones((3, 8, 2)), "y2": jnp.ones((3, 12, 2))},
        "scan": {"x2": jnp.ones((3, 4, 8, 2)), "y2": jnp.ones((3, 4, 12, 2))},
    }
    out = inject_users(sp, gathered)
    assert out["lin"]["ux2"].shape == (3, 8, 2)      # users leading
    assert out["scan"]["ux2"].shape == (4, 3, 8, 2)  # layers back leading
    assert "x1" in out["lin"] and "w" in out["embed"]
    assert "ux2" not in sp["lin"]                    # input untouched


# ------------------------------------------------------ cache rewrite

def test_build_serve_params_per_plan():
    key = jax.random.PRNGKey(0)
    pcfg = ParamCfg(kind="fedpara", gamma=0.4, min_dim_for_factorization=8)
    params = {"a": init_dense(key, 64, 96, pcfg),
              "b": init_dense(key, 64, 96, pcfg)}
    plan = {"a": decide("a", 64, 96, params["a"]["x1"].shape[1],
                        batch=1, mode="precompose"),
            "b": decide("b", 64, 96, params["b"]["x1"].shape[1],
                        batch=1, mode="fused")}
    sp = build_serve_params(params, "fedpara", plan, "int8")
    assert sp["a"]["w_q"].dtype == jnp.int8 and "scale" in sp["a"]
    assert set(sp["b"]) == set(params["b"])  # fused: factors verbatim
    sp16 = build_serve_params(params, "fedpara", plan, "fp16")
    assert sp16["a"]["w"].dtype == jnp.float16


def test_build_serve_params_personalized_shares_w1():
    key = jax.random.PRNGKey(1)
    pcfg = ParamCfg(kind="pfedpara", gamma=0.4, min_dim_for_factorization=8)
    node = init_dense(key, 64, 96, pcfg)
    glob = {k: v for k, v in node.items() if k in ("x1", "y1")}
    r = glob["x1"].shape[1]
    plan = {"a": decide("a", 64, 96, r, batch=2, kind="pfedpara",
                        mode="precompose", users=3)}
    sp = build_serve_params({"a": glob}, "pfedpara", plan, "int8")
    # the shared W1 cache, NOT a composed per-user W
    assert sp["a"]["w1_q"].shape == (64, 96)
    assert sp["a"]["w1_q"].dtype == jnp.int8


# -------------------------------------------- engine-level mode parity

def test_engine_modes_match_dense_baseline_fedpara():
    """fused vs precomposed vs the plain training-path model (which
    materializes W): same checkpoint-free tiny model, same logits."""
    cfg = _tiny_cfg("fedpara")
    model = build_model(cfg, _OPTS)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts = jnp.asarray(make_token_lm_dataset(2, 8, cfg.vocab_size,
                                                seed=1))
    cache = model.init_cache(2, 8)
    _, base = jax.jit(model.prefill)(params, prompts, cache)
    base = np.asarray(base)
    tol = {"fused": 1e-4, "precompose/fp16": 5e-3, "precompose/int8": 8e-2}
    for mode, dt in (("fused", "int8"), ("precompose", "fp16"),
                     ("precompose", "int8")):
        eng = ServeEngine(cfg, params, mode=mode, cache_dtype=dt,
                          batch=2, use_pallas=False, opts=_OPTS)
        _, logits = eng.prefill(prompts, eng.init_cache(2, 8))
        rel = (np.abs(np.asarray(logits) - base).max()
               / (np.abs(base).max() + 1e-9))
        key = mode if mode == "fused" else f"{mode}/{dt}"
        assert rel < tol[key], (mode, dt, rel)


# --------------------------------- checkpoint -> serve roundtrip (slow)

@pytest.fixture(scope="module")
def trained_pfedpara(tmp_path_factory):
    """A real 2-round pFedPara federation + its checkpoint directory."""
    from repro.checkpoint import CheckpointManager
    from repro.fl.client import ClientConfig
    from repro.fl.server import FLServer, ServerConfig
    from repro.fl.strategies import make_strategy

    cfg = _tiny_cfg("pfedpara")
    model = build_model(cfg, _OPTS)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = make_token_lm_dataset(36, 16, cfg.vocab_size, seed=0)
    parts = iid_partition(len(toks), 3)
    srv = FLServer(lambda p, b: model.loss(p, b), params,
                   {"tokens": toks}, parts, make_strategy("fedavg"),
                   ClientConfig(lr=0.05, batch=8, epochs=1),
                   ServerConfig(clients=3, participation=1.0, rounds=2,
                                personalization="pfedpara"))
    srv.run()
    d = str(tmp_path_factory.mktemp("ckpt"))
    srv.save_checkpoint(CheckpointManager(d))
    return d, cfg, model, srv


@pytest.mark.slow
def test_checkpoint_loader_rebuilds_all_trees(trained_pfedpara):
    d, cfg, model, srv = trained_pfedpara
    glob, locals_, _extra, _step = load_fl_checkpoint(d)
    assert sorted(locals_) == sorted(srv.local_trees)
    for cid, tree in srv.local_trees.items():
        for a, b in zip(jax.tree.leaves(tree),
                        jax.tree.leaves(locals_[cid])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # loader returns the checkpointed global tree verbatim
    for a, b in zip(jax.tree.leaves(srv.global_params),
                    jax.tree.leaves(glob)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
@pytest.mark.parametrize("mode,cache_dtype,tol", [
    ("fused", "int8", 1e-4),
    ("precompose", "fp16", 5e-3),
    ("precompose", "int8", 8e-2),
])
def test_checkpoint_to_serve_per_user_parity(trained_pfedpara, mode,
                                             cache_dtype, tol):
    """Serve each trained user from the checkpoint and match the oracle:
    merge that user's personal half into the global tree and run the
    plain training-path model."""
    d, cfg, model, srv = trained_pfedpara
    eng = ServeEngine.from_checkpoint(d, cfg, mode=mode,
                                      cache_dtype=cache_dtype, batch=3,
                                      use_pallas=False, opts=_OPTS)
    uids = sorted(srv.local_trees)
    prompts = jnp.asarray(make_token_lm_dataset(3, 8, cfg.vocab_size,
                                                seed=2))
    cache = eng.init_cache(3, 12)
    cache, logits = eng.prefill(prompts, cache, user_ids=uids)
    glob = comm.split_pfedpara(srv.global_params)[0]
    for i, u in enumerate(uids):
        full = comm.merge_pfedpara(glob, srv.local_trees[u])
        c2 = model.init_cache(1, 12)
        _, want = jax.jit(model.prefill)(full, prompts[i:i + 1], c2)
        rel = (np.abs(np.asarray(logits[i]) - np.asarray(want[0])).max()
               / (np.abs(np.asarray(want)).max() + 1e-9))
        assert rel < tol, (mode, cache_dtype, u, rel)
    # and decode advances without error for a rotating cohort
    tok = jnp.argmax(logits, -1)[:, None]
    for i in range(3):
        logits, cache = eng.decode_step(cache, tok, 8 + i,
                                        user_ids=uids[::-1])
        tok = jnp.argmax(logits, -1)[:, None]
    assert np.asarray(logits).shape == (3, cfg.vocab_size)
