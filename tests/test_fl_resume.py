"""Bitwise crash/resume for all three engines + checkpoint atomicity.

An FL run killed at a round boundary and resumed from its checkpoint
must reproduce the uninterrupted run BITWISE: global params, per-client
state, comm totals and the history records — for every engine x state
store, including error-feedback codecs (the EF accumulator is client
state and must survive the round trip) and fault/defense rounds.

Plus the CheckpointManager contracts the resume guarantee rests on:
async save failures surface on the caller thread instead of dying with
the daemon thread, and a crash mid-save never corrupts (or publishes)
a step directory.
"""
import os

import jax
import numpy as np
import pytest

from parity import hist_key as _hist_key
from parity import state_bytes as _state_bytes
from repro.analysis.program_check import make_mini_server
from repro.checkpoint import CheckpointManager

EF_CODEC = "delta|topk0.5|int8"


MATRIX = [
    ("sequential", "dict", EF_CODEC, "fedavg"),
    ("batched", "dict", EF_CODEC, "scaffold"),
    ("batched", "arena", EF_CODEC, "fedavg"),
    ("streaming", "dict", EF_CODEC, "fedadam"),
    ("streaming", "arena", EF_CODEC, "scaffold"),
]


@pytest.mark.parametrize("engine,store,codec,strategy", MATRIX)
def test_resume_is_bitwise(tmp_path, engine, store, codec, strategy):
    kw = dict(participation=0.75, uplink_codec=codec, strategy=strategy,
              defense="clip", fault_rate=0.3)

    srv_a = make_mini_server(engine, store, **kw)
    hist_a = srv_a.run(rounds=4)

    d = str(tmp_path / "ck")
    srv_b = make_mini_server(engine, store, **kw)
    srv_b.run(rounds=2, ckpt=CheckpointManager(d))
    del srv_b   # "kill" after round 2: only the checkpoint survives

    srv_c = make_mini_server(engine, store, **kw)
    step = srv_c.restore_checkpoint(CheckpointManager(d))
    assert step == 2
    hist_c = srv_c.run(rounds=4, ckpt=CheckpointManager(d))

    assert _hist_key(hist_a) == _hist_key(hist_c)
    assert _state_bytes(srv_a) == _state_bytes(srv_c)
    assert srv_a.comm_log.up_bytes == srv_c.comm_log.up_bytes
    assert srv_a.comm_log.down_bytes == srv_c.comm_log.down_bytes
    assert srv_a.round_idx == srv_c.round_idx


def test_resume_restores_downlink_codec_state(tmp_path):
    """Delta downlink refs + server-side EF must survive the round trip
    (they shift every later broadcast if lost)."""
    kw = dict(downlink_codec="delta|int8", participation=0.75)
    srv_a = make_mini_server("batched", "dict", **kw)
    srv_a.run(rounds=4)
    d = str(tmp_path / "ck")
    srv_b = make_mini_server("batched", "dict", **kw)
    srv_b.run(rounds=2, ckpt=CheckpointManager(d))
    srv_c = make_mini_server("batched", "dict", **kw)
    srv_c.restore_checkpoint(CheckpointManager(d))
    srv_c.run(rounds=4, ckpt=CheckpointManager(d))
    assert np.concatenate(
        [np.asarray(x).ravel() for x in jax.tree.leaves(
            srv_a.global_params)]).tobytes() == np.concatenate(
        [np.asarray(x).ravel() for x in jax.tree.leaves(
            srv_c.global_params)]).tobytes()


def test_run_checkpoints_every_k(tmp_path):
    d = str(tmp_path / "ck")
    srv = make_mini_server("batched", "dict")
    mgr = CheckpointManager(d, keep=0)
    srv.run(rounds=4, ckpt=mgr, ckpt_every=2)
    assert mgr.all_steps() == [2, 4]


# ------------------------------------------------ manager failure modes

def test_async_save_error_surfaces(tmp_path, monkeypatch):
    """An async save that fails must raise on the NEXT wait()/save(),
    not die silently with the daemon thread."""
    mgr = CheckpointManager(str(tmp_path / "ck"), async_save=True)

    def boom(step, host_tree, extra):
        raise OSError("disk full")

    monkeypatch.setattr(mgr, "_write", boom)
    mgr.save(0, {"x": np.zeros(3)})
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    # the error is consumed: the manager is usable again
    mgr.wait()

    mgr2 = CheckpointManager(str(tmp_path / "ck2"), async_save=True)
    monkeypatch.setattr(mgr2, "_write", boom)
    mgr2.save(0, {"x": np.zeros(3)})
    with pytest.raises(OSError, match="disk full"):
        mgr2.save(1, {"x": np.zeros(3)})   # save() re-raises via wait()


def test_kill_mid_save_never_corrupts(tmp_path, monkeypatch):
    """A crash between the tmp-dir write and the atomic rename leaves no
    step_* directory behind: the previous checkpoint stays the latest
    restorable one."""
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d)
    tree = {"x": np.arange(5, dtype=np.float32)}
    mgr.save(1, tree, extra={"round_idx": 1})

    real_savez = np.savez

    def dying_savez(path, **arrays):
        real_savez(path, **arrays)   # partial artifacts land in tmp
        raise KeyboardInterrupt("killed mid-save")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(KeyboardInterrupt):
        mgr.save(2, {"x": np.full(5, 9.0, np.float32)})
    monkeypatch.setattr(np, "savez", real_savez)

    # the half-written step never published; step 1 is intact
    assert mgr.all_steps() == [1]
    assert not os.path.exists(os.path.join(d, "step_0000000002"))
    restored, extra = mgr.restore(None, tree)
    np.testing.assert_array_equal(restored["x"], tree["x"])
    assert extra["round_idx"] == 1
    # and a later save of the same step succeeds over the stale tmp dir
    mgr.save(2, {"x": np.full(5, 9.0, np.float32)})
    assert mgr.all_steps() == [1, 2]


def test_restore_items_structure_free(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    tree = {"a": {"b": np.arange(4, dtype=np.int32)},
            "c": np.float32(2.5)}
    mgr.save(3, tree, extra={"k": "v"})
    by_path, extra, step = mgr.restore_items()
    assert step == 3
    assert extra == {"k": "v"}
    np.testing.assert_array_equal(by_path["a/b"], tree["a"]["b"])
    np.testing.assert_array_equal(by_path["c"], np.float32(2.5))
