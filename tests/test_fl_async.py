"""Async buffered federation (FedBuff-style): parity, properties, resume.

The async engine (``repro.fl.async_engine`` + ``engine="async"``) is
the event-driven fourth engine; its contracts against the sync family:

  * **staleness -> 0 parity** — with instant arrivals and ``buffer_k``
    = the participation target, one dispatch fills exactly one buffer
    at ``tau = 0`` where every staleness spec weighs 1.0, so the async
    fold must reproduce the STREAMING engine bitwise in the arrival
    masks / wire bytes and to fp32 accumulation-order tolerance in
    params — across strategies, codecs (incl. error feedback), rank
    tiers, personalization, defenses and both state stores.
  * **fold order-invariance** — the buffered accumulator is a weighted
    sum: folding the same arrivals in any order changes nothing but
    fp32 reassociation (hypothesis property over permutations).
  * **version-pinned refs** — a delta-codec upload re-attaches the
    broadcast its client trained against; with a single live dispatch
    the re-attach coefficient is EXACTLY 1.0 (same host-float sums in
    numerator and denominator), reproducing ``Codec.agg_finalize``
    bitwise.
  * **bitwise crash/resume mid-buffer** — killing the server with
    uploads still in flight and restoring from the checkpoint replays
    the uninterrupted run bit-for-bit (heap, wires, refs, clock).
  * **trace re-keying** — ``FleetTrace.arrival_stream`` replays from
    ``(seed, round, salt)`` alone, independent of prior draws.

Shared harness: ``tests/parity.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from parity import (
    assert_parity,
    get_task,
    given,
    hist_key,
    maxdiff,
    run_server,
    settings,
    st,
    state_bytes,
)
from repro.analysis.program_check import make_mini_server
from repro.checkpoint import CheckpointManager
from repro.fl import ClientConfig, make_strategy
from repro.fl.arrivals import (
    arrival_events,
    arrival_mask,
    arrival_order,
    fold_crashes,
)
from repro.fl.async_engine import (
    AsyncDispatch,
    finalize_buffer,
    fold_arrival,
    make_staleness,
)
from repro.fl.codecs import Codec, make_codec
from repro.fl.trace import FleetTrace

EF_CODEC = "delta|topk0.5|int8"


@pytest.fixture(scope="module")
def task():
    return get_task()


# ----------------------------------------------------- staleness -> 0 parity
PARITY_CELLS = [
    pytest.param(dict(), id="fedavg"),
    pytest.param(dict(strategy="scaffold"), id="scaffold"),
    pytest.param(dict(strategy="feddyn"), id="feddyn"),
    pytest.param(dict(uplink_codec="delta|topk0.1|int8",
                      downlink_codec="delta|topk0.1|int8", rounds=3),
                 id="ef-both-links"),
    pytest.param(dict(gamma_tiers=(0.2, 0.4)), id="hetero-tiers"),
    pytest.param(dict(state_store="arena",
                      uplink_codec="delta|topk0.2|int8"), id="arena-delta"),
    pytest.param(dict(personalization="pfedpara"), id="pfedpara"),
    pytest.param(dict(defense="clip"), id="clip-defense"),
    pytest.param(dict(uplink_codec="delta|lowrank2|int8"), id="lowrank"),
]


@pytest.mark.parametrize("kw", PARITY_CELLS)
def test_staleness_zero_parity(task, kw):
    """Acceptance: the async engine with instant arrivals reproduces the
    streaming engine — bitwise arrival masks and wire bytes, fp32-tol
    params — for every cell of the strategy × codec × tier × store ×
    personalization matrix. ``buffer_k=0`` defaults K to the sync
    participation target; the default (deadline-free) config admits the
    whole cohort so one dispatch fills exactly one buffer at tau=0."""
    kw = dict(kw)
    mode = kw.get("personalization", "none")
    ref = run_server(task, "streaming", chunk=3, **kw)
    got = run_server(task, "async", chunk=3, **kw)
    assert_parity(ref, got, check_residents=(mode != "none"))
    for r in got.history:
        assert r["version"] + 1 == r["round"]
        assert r["dispatches"] == 1 and r["in_flight"] == 0
        assert set(r["staleness_hist"]) == {"0"}   # nothing ever stale


@pytest.mark.parametrize("spec", ["constant", "poly:0.5", "hinge:4"])
def test_staleness_zero_parity_any_spec(task, spec):
    """Every staleness family weighs tau=0 arrivals at exactly 1.0, so
    the parity contract is spec-independent."""
    ref = run_server(task, "streaming", chunk=3)
    got = run_server(task, "async", chunk=3, staleness=spec)
    assert_parity(ref, got)


# ------------------------------------------------------ fold order-invariance
_K = 6


def _toy_wires(seed):
    """A dispatch wire stack with both leaf kinds the fused fold
    handles: an int8 {"q","scale"} node and a dense fp32 leaf."""
    rng = np.random.default_rng(seed)
    wires = {
        "w": {"q": jnp.asarray(
                  rng.integers(-127, 128, size=(_K, 8, 6)), jnp.int8),
              "scale": jnp.asarray(
                  rng.uniform(0.01, 0.1, size=(_K,)), jnp.float32)},
        "b": jnp.asarray(rng.normal(size=(_K, 6)), jnp.float32),
    }
    weights = rng.uniform(0.5, 2.0, size=_K)
    return wires, weights


def _fold_in_order(wires, weights, order):
    acc = {"w": jnp.zeros((8, 6), jnp.float32),
           "b": jnp.zeros((6,), jnp.float32)}
    for p in order:
        acc = fold_arrival(acc, wires, int(p), float(weights[p]))
    return acc


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), perm_seed=st.integers(0, 1000))
def test_fold_order_invariance(seed, perm_seed):
    """Property: folding one buffer's arrivals in ANY order gives the
    same accumulator up to fp32 reassociation — and matches the dense
    numpy reference sum(w_c * dequant(wire_c))."""
    wires, weights = _toy_wires(seed)
    order = np.random.default_rng(perm_seed).permutation(_K)
    fwd = _fold_in_order(wires, weights, range(_K))
    perm = _fold_in_order(wires, weights, order)
    assert maxdiff(fwd, perm) < 1e-5
    q = np.asarray(wires["w"]["q"], np.float64)
    s = np.asarray(wires["w"]["scale"], np.float64)
    ref_w = np.einsum("c,ckl->kl", weights * s, q)
    ref_b = np.einsum("c,ck->k", weights, np.asarray(wires["b"], np.float64))
    np.testing.assert_allclose(np.asarray(fwd["w"]), ref_w, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fwd["b"]), ref_b, atol=1e-4)


# ------------------------------------------------------- version-pinned refs
def test_agg_finalize_pinned_matches_manual():
    mean = {"a": jnp.full((3,), 2.0, jnp.float32)}
    refs = {0: {"a": jnp.arange(3, dtype=jnp.float32)},
            2: {"a": jnp.full((3,), -1.0, jnp.float32)}}
    # dispatch 1 has zero coefficient and NO ref entry: must be skipped
    out = Codec.agg_finalize_pinned(mean, refs, {0: 0.25, 1: 0.0, 2: 0.5})
    want = 2.0 + 0.25 * np.arange(3) + 0.5 * (-1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), want, rtol=1e-6)


def test_single_dispatch_ref_coefficient_is_bitwise():
    """With one live dispatch the pinned re-attach coefficient is built
    from the SAME host-float sum as the mean's denominator, so it is
    exactly 1.0 and ``finalize_buffer`` equals ``Codec.agg_finalize``
    bit-for-bit — the mechanism behind the staleness->0 parity."""
    rng = np.random.default_rng(0)
    codec = make_codec("delta|int8")
    acc = {"a": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)}
    ref = {"a": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)}
    w = 0.1 + 3.6  # a non-trivial host-float accumulation
    out = finalize_buffer([acc], [w], [{7: w}], {7: ref}, codec=codec,
                          agg_target={"a": jnp.zeros((4, 5), jnp.float32)})
    mean = jax.tree.map(lambda a: a / jnp.float32(w), acc)
    want = codec.agg_finalize(mean, ref=ref)
    assert np.asarray(out["a"]).tobytes() == np.asarray(want["a"]).tobytes()


def test_finalize_empty_buffer_keeps_target():
    """Zero accepted weight (fully-rejected buffer) must return the
    aggregation target unchanged, never a zeroed model."""
    tgt = {"a": jnp.asarray([[1.5, -2.0]], jnp.float32)}
    acc = {"a": jnp.zeros((1, 2), jnp.float32)}
    out = finalize_buffer([acc], [0.0], [{}], {}, codec=make_codec(""),
                          agg_target=tgt)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tgt["a"]))


# -------------------------------------------------------- staleness weights
def test_make_staleness_specs():
    assert make_staleness("constant")(0) == 1.0
    assert make_staleness("constant")(9) == 1.0
    assert make_staleness("poly:1.0")(3) == pytest.approx(0.25)
    assert make_staleness("poly")(0) == 1.0          # default a = 0.5
    assert make_staleness("poly")(3) == pytest.approx(0.5)
    hinge = make_staleness("hinge")                  # default b = 4
    assert hinge(0) == 1.0 and hinge(4) == 1.0
    assert hinge(6) == pytest.approx(1.0 / 3.0)
    # tau = 0 weighs exactly 1.0 under EVERY family (the parity anchor)
    for spec in ("constant", "poly:0.3", "poly:2", "hinge:1", "hinge:8"):
        assert make_staleness(spec)(0) == 1.0
    with pytest.raises(ValueError, match="staleness"):
        make_staleness("warp")


# --------------------------------------------------- configuration rejection
def test_async_config_rejections():
    with pytest.raises(ValueError, match="defense"):
        make_mini_server("async", defense="trimmed")
    with pytest.raises(ValueError, match="staleness_mix"):
        make_mini_server("async", staleness_mix=0.5)
    with pytest.raises(ValueError, match="recover_retries"):
        make_mini_server("async", recover_retries=1)
    with pytest.raises(ValueError, match="buffer_k"):
        make_mini_server("async", buffer_k=-1)
    with pytest.raises(ValueError, match="staleness"):
        make_mini_server("async", staleness="warp")


def test_async_dispatch_rejects_order_statistic_defense():
    with pytest.raises(ValueError, match="clip"):
        AsyncDispatch(loss_fn=lambda p, b: 0.0,
                      strategy=make_strategy("fedavg"),
                      client_cfg=ClientConfig(), defense="trimmed")


# ----------------------------------------- genuinely-async history accounting
_ASYNC_KW = dict(participation=1.0, uplink_codec=EF_CODEC, buffer_k=2,
                 straggler_sigma=1.0, staleness="poly:0.5")


def test_async_history_accounting():
    """The per-version history row's shape algebra: every popped arrival
    lands in the staleness histogram as either a fold or a stale drop,
    wire bytes reconcile with the comm log, and the virtual clock is the
    running sum of the per-version latencies."""
    srv = make_mini_server("async", "dict", **_ASYNC_KW)
    hist = srv.run(rounds=4)
    hist = [r for r in hist if not r.get("skipped")]
    assert hist
    for r in hist:
        assert sum(r["staleness_hist"].values()) == (
            r["folded"] + r["dropped_stale"])
        assert all(isinstance(k, str) and int(k) >= 0
                   for k in r["staleness_hist"])
        assert r["folded"] >= 1
        assert r["round_latency"] >= 0.0
    assert [r["version"] for r in hist] == list(range(len(hist)))
    vt = [r["virtual_time"] for r in hist]
    assert vt == sorted(vt)
    assert vt[-1] == pytest.approx(sum(r["round_latency"] for r in hist))
    # per-version wire bytes reconcile with the cumulative comm log
    assert sum(r["up_bytes"] for r in hist) == srv.comm_log.up_bytes
    assert sum(r["down_bytes"] for r in hist) == srv.comm_log.down_bytes
    # buffer_k < cohort: some uploads straddle a version bump
    assert any(int(k) > 0 for r in hist for k in r["staleness_hist"])
    versions = srv.client_versions()
    assert versions.shape == (srv.scfg.clients,)
    assert versions.max() >= 0 and versions.max() < srv.round_idx
    assert np.isfinite(np.concatenate(
        [np.asarray(x, np.float64).ravel()
         for x in jax.tree.leaves(srv.global_params)])).all()


def test_max_staleness_drops_arrivals():
    srv = make_mini_server("async", "dict", max_staleness=0, **_ASYNC_KW)
    hist = [r for r in srv.run(rounds=4) if not r.get("skipped")]
    assert sum(r["dropped_stale"] for r in hist) > 0
    # dropped arrivals still pay uplink bytes but never fold
    for r in hist:
        assert sum(r["staleness_hist"].values()) == (
            r["folded"] + r["dropped_stale"])
    assert np.isfinite(np.concatenate(
        [np.asarray(x, np.float64).ravel()
         for x in jax.tree.leaves(srv.global_params)])).all()


# ------------------------------------------------ bitwise crash/resume
@pytest.mark.parametrize("store", ["dict", "arena"])
def test_async_resume_is_bitwise_mid_buffer(tmp_path, store):
    """Kill the async server at a version boundary with uploads still in
    flight (pending heap, pinned wires/refs, fractional clock) and
    resume: the continuation must be bitwise — state, history, comm
    totals and the per-client version pins."""
    kw = dict(participation=0.75, uplink_codec=EF_CODEC, strategy="fedavg",
              defense="clip", fault_rate=0.3, buffer_k=4,
              straggler_sigma=1.0, staleness="poly:0.5")
    srv_a = make_mini_server("async", store, **kw)
    hist_a = srv_a.run(rounds=5)

    d = str(tmp_path / "ck")
    srv_b = make_mini_server("async", store, **kw)
    srv_b.run(rounds=3, ckpt=CheckpointManager(d))
    assert srv_b._async.pending   # mid-buffer: uploads in flight at save
    del srv_b

    srv_c = make_mini_server("async", store, **kw)
    assert srv_c.restore_checkpoint(CheckpointManager(d)) == 3
    hist_c = srv_c.run(rounds=5, ckpt=CheckpointManager(d))

    assert hist_key(hist_a) == hist_key(hist_c)
    assert state_bytes(srv_a) == state_bytes(srv_c)
    np.testing.assert_array_equal(srv_a.client_versions(),
                                  srv_c.client_versions())
    assert srv_a.comm_log.up_bytes == srv_c.comm_log.up_bytes
    assert srv_a.comm_log.down_bytes == srv_c.comm_log.down_bytes
    assert srv_a.round_idx == srv_c.round_idx


# ------------------------------------------------------- arrival machinery
def test_arrival_helpers_consistency():
    lat = np.array([3.0, 1.0, 2.0, 1.0, 5.0])
    ok = np.ones(5, bool)
    order = arrival_order(lat)
    np.testing.assert_array_equal(order, [1, 3, 2, 0, 4])  # stable tie 1<3
    mask = arrival_mask(ok, lat, 3)
    np.testing.assert_array_equal(mask, [False, True, True, True, False])
    # the first n_target events ARE the arrival_mask clients
    events = arrival_events(ok, lat, t0=10.0)
    assert [p for _, p in events] == list(order)
    assert [t for t, _ in events] == [10.0 + lat[p] for p in order]
    assert set(p for _, p in events[:3]) == set(np.where(mask)[0])
    # masked-out clients never produce events
    some = arrival_events(mask, lat)
    assert [p for _, p in some] == [1, 3, 2]
    # crash folding: a crashed client never arrives; None is a no-op
    crash = np.array([False, True, False, False, False])
    eff = fold_crashes(mask, crash)
    np.testing.assert_array_equal(eff, [False, False, True, True, False])
    assert fold_crashes(mask, None) is mask


def test_trace_arrival_stream_rekeying():
    """``arrival_stream`` replays from (seed, round, salt) alone: a
    fresh trace that made unrelated draws first produces the identical
    cohort AND event stream — the crash/resume determinism contract —
    and it decomposes into exactly the ``_select_round`` draw order
    (sample -> latency -> availability)."""
    def mk():
        return FleetTrace(clients=64, seed=9, dropout=0.2,
                          diurnal_amplitude=0.3)
    t1 = mk()
    cohort_a, ev_a = t1.arrival_stream(5, 12, 3000.0, 1.0, 10.0, t0=2.5)
    t2 = mk()
    t2.round_rng(0).random(1000)   # unrelated draws must not matter
    _ = t2.arrival_stream(4, 12, 3000.0, 1.0, 10.0)
    cohort_b, ev_b = t2.arrival_stream(5, 12, 3000.0, 1.0, 10.0, t0=2.5)
    np.testing.assert_array_equal(cohort_a, cohort_b)
    assert ev_a == ev_b
    # stream shape: sorted times, distinct valid positions, offset by t0
    times = [t for t, _ in ev_a]
    assert times == sorted(times) and all(t >= 2.5 for t in times)
    pos = [p for _, p in ev_a]
    assert len(set(pos)) == len(pos) and all(0 <= p < 12 for p in pos)
    # draw-order contract: identical to _select_round's trace path
    rng = mk().round_rng(5)
    cohort_m = mk().sample_cohort(rng, 12)
    lat = mk().latency(rng, 3000.0, 12, 1.0, 10.0)
    alive = rng.random(12) < mk().availability(cohort_m, 5)
    np.testing.assert_array_equal(cohort_a, cohort_m)
    assert ev_a == arrival_events(alive, lat, t0=2.5)
    # a salt opens a genuinely different stream at the same round
    _, ev_s = mk().arrival_stream(5, 12, 3000.0, 1.0, 10.0, t0=2.5, salt=1)
    assert ev_s != ev_a
