# Intentionally minimal. Do NOT set --xla_force_host_platform_device_count
# here: smoke tests and benchmarks must see the real (single) device.
# Multi-device behaviour is tested via subprocesses in test_distributed.py
# and by repro.launch.dryrun (which sets its own XLA_FLAGS before jax init).
import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)
