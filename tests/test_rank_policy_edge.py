"""Edge-case/property coverage for ``repro.core.rank_policy``.

* tiny layers where parameter parity sits below the full-rank point
  (``r_max < r_min``): the policy degrades to ``r_min`` for every gamma;
* the ``gamma ∈ {0, 1}`` endpoints hit ``r_min`` / ``max(r_min, r_max)``
  exactly;
* ``matrix_rank_for_gamma`` is monotone non-decreasing in gamma;
* the parameter-parity bound ``2r(m+n) <= mn`` holds at ``r_max``
  whenever parity is achievable at all;
* tier clamping: ``tier_rank`` stays inside
  ``[min(r_min, r_full), r_full]`` for every gamma.

Hypothesis-gated like the other property suites.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import rank_policy

DIM = st.integers(min_value=2, max_value=512)
TINY = st.integers(min_value=2, max_value=7)
GAMMA = st.floats(min_value=0.0, max_value=1.0,
                  allow_nan=False, allow_infinity=False)


@settings(max_examples=60, deadline=None)
@given(m=TINY, n=TINY, g=GAMMA)
def test_tiny_layers_degrade_to_rmin(m, n, g):
    """When 2r(m+n) > mn already at the full-rank floor, the policy
    returns r_min for every gamma instead of an inverted interval."""
    rmin, rmax = rank_policy.matrix_rmin(m, n), rank_policy.matrix_rmax(m, n)
    r = rank_policy.matrix_rank_for_gamma(m, n, g)
    if rmax < rmin:
        assert r == rmin
    assert r >= 1


@settings(max_examples=60, deadline=None)
@given(m=DIM, n=DIM)
def test_gamma_endpoints(m, n):
    rmin, rmax = rank_policy.matrix_rmin(m, n), rank_policy.matrix_rmax(m, n)
    assert rank_policy.matrix_rank_for_gamma(m, n, 0.0) == rmin
    assert rank_policy.matrix_rank_for_gamma(m, n, 1.0) == max(rmin, rmax)


@settings(max_examples=60, deadline=None)
@given(m=DIM, n=DIM, g1=GAMMA, g2=GAMMA)
def test_rank_monotone_in_gamma(m, n, g1, g2):
    lo, hi = sorted((g1, g2))
    assert (rank_policy.matrix_rank_for_gamma(m, n, lo)
            <= rank_policy.matrix_rank_for_gamma(m, n, hi))


@settings(max_examples=60, deadline=None)
@given(m=DIM, n=DIM)
def test_param_parity_bound_at_rmax(m, n):
    """2r(m+n) <= mn at r_max — parameter parity with the dense layer —
    whenever ANY rank satisfies parity (i.e. mn >= 2(m+n))."""
    rmax = rank_policy.matrix_rmax(m, n)
    if m * n >= 2 * (m + n):
        assert rank_policy.matrix_param_count(m, n, rmax) <= m * n
        # and rmax is maximal: one more rank unit breaks parity
        assert rank_policy.matrix_param_count(m, n, rmax + 1) > m * n
    else:
        assert rmax == 1   # clamped floor for degenerate tiny layers


@settings(max_examples=60, deadline=None)
@given(m=DIM, n=DIM, g=GAMMA, r_full=st.integers(1, 64))
def test_tier_rank_clamped(m, n, g, r_full):
    rmin = rank_policy.matrix_rmin(m, n)
    r = rank_policy.matrix_tier_rank(m, n, r_full, g)
    assert min(rmin, r_full) <= r <= r_full
    # a tier at gamma=1 saturates the materialized rank whenever the
    # policy rank reaches it
    if rank_policy.matrix_rank_for_gamma(m, n, 1.0) >= r_full:
        assert rank_policy.matrix_tier_rank(m, n, r_full, 1.0) == r_full


@settings(max_examples=40, deadline=None)
@given(o=st.integers(2, 128), i=st.integers(2, 128),
       k=st.sampled_from([1, 3, 5]), g=GAMMA, r_full=st.integers(1, 32))
def test_conv_tier_rank_clamped(o, i, k, g, r_full):
    rmin = rank_policy.conv_rmin(o, i)
    r = rank_policy.conv_tier_rank(o, i, k, k, r_full, g)
    assert min(rmin, r_full) <= r <= r_full


@settings(max_examples=40, deadline=None)
@given(o=st.integers(4, 128), i=st.integers(4, 128),
       k=st.sampled_from([1, 3, 5]))
def test_conv_rmax_parity(o, i, k):
    """Prop.-3 parity: 2R(O+I+R·K1K2) <= OIK1K2 at r_max (when
    achievable), and r_max+1 breaks it."""
    rmax = rank_policy.conv_rmax(o, i, k, k)
    dense = o * i * k * k
    if rank_policy.conv_param_count(o, i, k, k, 1) <= dense:
        assert rank_policy.conv_param_count(o, i, k, k, rmax) <= dense
        assert rank_policy.conv_param_count(o, i, k, k, rmax + 1) > dense
