"""Property tests for the paper's propositions (hypothesis + numpy).

Prop 1: rank(W) <= r1*r2 for W = (X1Y1t) o (X2Y2t).
Prop 2: r1 = r2 = R uniquely minimizes (r1+r2)(m+n) s.t. r1 r2 >= R^2.
Cor 1:  R^2 >= min(m,n) iff full rank achievable; r_min = ceil(sqrt(min)).
Prop 3: rank of the 1st unfolding of the conv kernel <= R^2.
Fig 6:  random FedPara at r_min spans full rank (100% of trials).
Table 1: exact parameter counts.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    compose_conv_fedpara,
    compose_fedpara,
    compose_lowrank,
    init_conv,
    init_fedpara,
    init_lowrank,
    rank_policy,
)

DIM = st.integers(min_value=4, max_value=96)
RANK = st.integers(min_value=1, max_value=8)


@settings(max_examples=30, deadline=None)
@given(m=DIM, n=DIM, r1=RANK, r2=RANK, seed=st.integers(0, 2**30))
def test_prop1_rank_bound(m, n, r1, r2, seed):
    rng = np.random.RandomState(seed)
    x1, y1 = rng.randn(m, r1), rng.randn(n, r1)
    x2, y2 = rng.randn(m, r2), rng.randn(n, r2)
    w = (x1 @ y1.T) * (x2 @ y2.T)
    assert np.linalg.matrix_rank(w) <= min(r1 * r2, m, n)


@settings(max_examples=30, deadline=None)
@given(m=DIM, n=DIM, big_r=st.integers(1, 12))
def test_prop2_unique_optimum(m, n, big_r):
    """Exhaustively verify r1=r2=R is the unique integral minimizer."""
    best = 2 * big_r * (m + n)
    for r1 in range(1, 3 * big_r + 1):
        for r2 in range(1, 3 * big_r + 1):
            if r1 * r2 >= big_r * big_r and (r1, r2) != (big_r, big_r):
                assert (r1 + r2) * (m + n) >= best
                if (r1 + r2) * (m + n) == best:
                    # ties only possible when r1+r2 == 2R with r1r2 >= R^2
                    # => AM-GM forces r1 == r2 == R: contradiction
                    assert r1 + r2 > 2 * big_r


@settings(max_examples=40, deadline=None)
@given(m=DIM, n=DIM)
def test_corollary1_rmin(m, n):
    rmin = rank_policy.matrix_rmin(m, n)
    assert rmin * rmin >= min(m, n)
    assert (rmin - 1) * (rmin - 1) < min(m, n) or rmin == 1
    assert rmin == math.isqrt(min(m, n) - 1) + 1 if min(m, n) > 1 else rmin == 1


@settings(max_examples=15, deadline=None)
@given(o=st.integers(4, 32), i=st.integers(4, 32), r=st.integers(1, 5),
       seed=st.integers(0, 2**30))
def test_prop3_conv_unfolding_rank(o, i, r, seed):
    rng = np.random.RandomState(seed)
    t1, t2 = rng.randn(r, r, 3, 3), rng.randn(r, r, 3, 3)
    x1, x2 = rng.randn(o, r), rng.randn(o, r)
    y1, y2 = rng.randn(i, r), rng.randn(i, r)
    w1 = np.einsum("oa,ib,abhw->oihw", x1, y1, t1)
    w2 = np.einsum("oa,ib,abhw->oihw", x2, y2, t2)
    w = w1 * w2
    unfold1 = w.reshape(o, -1)                       # 1st unfolding
    unfold2 = np.moveaxis(w, 1, 0).reshape(i, -1)    # 2nd unfolding
    assert np.linalg.matrix_rank(unfold1) <= r * r
    assert np.linalg.matrix_rank(unfold2) <= r * r


def test_fig6_full_rank_sampling():
    """Paper Fig. 6: W in R^{100x100} with r1=r2=10 achieves rank 100 in
    every one of (here) 100 random trials."""
    m = n = 100
    rmin = rank_policy.matrix_rmin(m, n)
    assert rmin == 10
    rng = np.random.RandomState(0)
    for _ in range(100):
        x1, y1 = rng.randn(m, rmin), rng.randn(n, rmin)
        x2, y2 = rng.randn(m, rmin), rng.randn(n, rmin)
        w = (x1 @ y1.T) * (x2 @ y2.T)
        assert np.linalg.matrix_rank(w) == 100


def test_table1_exact_counts():
    """Table 1 reference example: m=n=O=I=256, K=3, R=16."""
    assert 256 * 256 == 65536                                   # FC original
    assert rank_policy.matrix_param_count(256, 256, 16) == 16384  # FC FedPara
    assert rank_policy.conv_param_count(256, 256, 3, 3, 16) == 20992   # Prop 3
    assert rank_policy.conv_reshape_param_count(256, 256, 3, 3, 16) == 81920  # Prop 1
    assert 256 * 256 * 9 == 589824                              # conv original


@settings(max_examples=20, deadline=None)
@given(m=st.integers(32, 256), n=st.integers(32, 256))
def test_gamma_interpolation_monotone(m, n):
    rs = [rank_policy.matrix_rank_for_gamma(m, n, g) for g in (0.0, 0.3, 0.6, 1.0)]
    assert rs == sorted(rs)
    assert rs[0] == rank_policy.matrix_rmin(m, n)
    assert rs[-1] == rank_policy.matrix_rmax(m, n)
    # parameter parity: r_max keeps us at or under the dense count
    assert rank_policy.matrix_param_count(m, n, rs[-1]) <= m * n


def test_init_variance_matches_he():
    key = jax.random.PRNGKey(0)
    m = n = 512
    r = rank_policy.matrix_rmin(m, n)
    w = compose_fedpara(init_fedpara(key, m, n, r))
    assert abs(float(w.var()) - 2.0 / m) < 0.3 * (2.0 / m)
    wl = compose_lowrank(init_lowrank(key, m, n, 2 * r))
    assert abs(float(wl.var()) - 2.0 / m) < 0.3 * (2.0 / m)
    pc = init_conv(key, 128, 128, 3, 3, kind="fedpara", gamma=0.0)
    wc = compose_conv_fedpara(pc)
    tgt = 2.0 / (128 * 9)
    assert abs(float(wc.var()) - tgt) < 0.35 * tgt


def test_fedpara_beats_lowrank_rank_at_parity():
    """Same parameter count: FedPara max rank R^2 vs low-rank 2R (Fig 1)."""
    m = n = 256
    r = 16
    rng = np.random.RandomState(1)
    w_fp = (rng.randn(m, r) @ rng.randn(n, r).T) * (rng.randn(m, r) @ rng.randn(n, r).T)
    w_lr = rng.randn(m, 2 * r) @ rng.randn(n, 2 * r).T
    assert np.linalg.matrix_rank(w_fp) == min(r * r, m)  # full 256
    assert np.linalg.matrix_rank(w_lr) == 2 * r          # stuck at 32
