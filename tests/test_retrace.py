"""Zero-retrace contract: at a fixed cohort shape, rounds 2..3 compile
NOTHING new, for every engine × state-store combination.

This is the regression the per-round lr decay once caused (a static lr
argument recompiled the local step every round) and the reason
``_local_step`` now takes lr traced. The counter hooks jax's dispatch
logger, so a failure names exactly which program recompiled.
"""
import pytest

from repro.analysis import program_check as pc


@pytest.mark.parametrize("engine,store", pc.RETRACE_MATRIX,
                         ids=[f"{e}-{s}" for e, s in pc.RETRACE_MATRIX])
def test_fixed_shape_rounds_compile_nothing(engine, store):
    events = pc.count_retrace(engine, store)
    assert events == [], (
        f"{engine}/{store}: rounds 2-3 recompiled {sorted(set(events))}")


def test_lr_decay_does_not_retrace():
    # lr changes every round (0.1 * decay**t); it must be traced, not
    # baked into the compile cache key.
    def factory():
        srv = pc.make_mini_server("sequential", "dict")
        srv.scfg.lr_decay = 0.9
        return srv

    events = pc.count_retrace("sequential", "dict", server_factory=factory)
    assert events == [], f"lr decay retraced: {sorted(set(events))}"


def test_client_chunk_change_recompiles_round_program_once():
    srv = pc.make_mini_server("streaming", "dict")
    srv.run_round()
    srv.run_round()

    srv.scfg.client_chunk = 2
    with pc.CompileCounter() as cc:
        srv.run_round()
    round_prog = [e for e in cc.events if "_round_program" in e]
    assert len(round_prog) == 1, (
        f"chunk change should recompile the round program exactly once, "
        f"got {cc.events}")

    # and the new shape is cached: the next round is clean again
    with pc.CompileCounter() as cc2:
        srv.run_round()
    assert cc2.events == [], f"post-rechunk round recompiled: {cc2.events}"


def test_strategy_state_does_not_retrace():
    # scaffold threads per-client control variates through every round;
    # the state tree must stay shape-stable.
    def factory():
        return pc.make_mini_server("streaming", "dict", strategy="scaffold")

    events = pc.count_retrace("streaming", "dict", server_factory=factory)
    assert events == [], f"scaffold state retraced: {sorted(set(events))}"
