"""Arena-vs-dict client-state parity + fleet data-path invariants.

The device-resident arena (``repro.fl.arena``) must be an invisible
substrate swap: gather → local-update → scatter round-trips have to
reproduce the dict-based engines bitwise-masked and fp32-tol in params
for every strategy × personalization mode × codec (error feedback
threaded through the stacked rows), with identical wire bytes. The
streamed data path (``ChunkBatchSource``) must materialize bit-identical
batches to the eager full-cohort stack, and the pre-sized pad slots must
equal what the old concatenate path produced.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParamCfg
from repro.data import (
    ChunkBatchSource,
    VirtualPartitions,
    dirichlet_partition,
    make_image_dataset,
    stack_client_epochs,
    train_test_split,
)
from repro.fl import ClientConfig, FLServer, ServerConfig, make_strategy
from repro.nn import recurrent as rec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # only the property test needs hypothesis
    HAVE_HYPOTHESIS = False

    def given(**kw):          # no-op decorators so the module still loads
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    settings = given

    class st:  # noqa: N801
        sampled_from = staticmethod(lambda *a: None)

ATOL = 1e-4

N_CLIENTS = 8


_TASK = {}


def _get_task():
    if not _TASK:
        ds = make_image_dataset(1200, 10, size=16, channels=1, noise=0.3)
        data = {"x": ds["x"].reshape(len(ds["y"]), -1), "y": ds["y"]}
        tr, te = train_test_split(data)
        _TASK.update(tr=tr, te=te,
                     parts=dirichlet_partition(tr["y"], N_CLIENTS, 0.5))
    return _TASK


@pytest.fixture(scope="module")
def task():
    return _get_task()


def _make(kind):
    cfg = rec.MLPConfig(in_dim=256, hidden=64, classes=10,
                        param=ParamCfg(kind=kind, gamma=0.3,
                                       min_dim_for_factorization=8))
    params = rec.init_mlp_model(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, b):
        return rec.mlp_loss(p, cfg, b)

    return cfg, params, loss_fn


def _run(task, engine, *, chunk=3, strategy="fedavg", personalization="none",
         rounds=2, **server_kw):
    kind = "pfedpara" if personalization == "pfedpara" else "fedpara"
    cfg, params, loss_fn = _make(kind)
    srv = FLServer(loss_fn, params, task["tr"], task["parts"],
                   make_strategy(strategy),
                   ClientConfig(lr=0.1, batch=16, epochs=1),
                   ServerConfig(clients=N_CLIENTS, participation=0.5,
                                rounds=rounds, engine=engine,
                                client_chunk=chunk,
                                personalization=personalization,
                                **server_kw))
    srv.run()
    return srv


def _maxdiff(a, b):
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b))
    return max(leaves) if leaves else 0.0


def _assert_substrate_parity(ref, got):
    """ref = dict-store engine, got = same engine on the arena."""
    assert ([r.get("arrived_mask") for r in ref.history]
            == [r.get("arrived_mask") for r in got.history])
    assert _maxdiff(ref.global_params, got.global_params) < ATOL
    assert _maxdiff(ref.server_state, got.server_state) < ATOL
    for cid in ref.client_states:
        assert _maxdiff(ref.client_states[cid],
                        got.client_state_of(cid)) < ATOL, cid
    for cid in ref.local_trees:
        assert _maxdiff(ref.local_trees[cid], got.resident_of(cid)) < ATOL
    for rr, rg in zip(ref.history, got.history):
        assert abs(rr["mean_loss"] - rg["mean_loss"]) < 1e-4
        assert abs(rr["comm_gb"] - rg["comm_gb"]) < 1e-12


# ------------------------------------------------------------------ tentpole
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=8, deadline=None)
@given(engine=st.sampled_from(["batched", "streaming"]),
       strategy=st.sampled_from(["fedavg", "fedprox", "scaffold", "feddyn"]),
       mode=st.sampled_from(["none", "pfedpara", "fedper", "local"]),
       codec=st.sampled_from(["", "int8", "delta|topk0.1|int8"]))
def test_arena_roundtrip_property(engine, strategy, mode, codec):
    """Acceptance: gather → local-update → scatter equals the dict path
    for random strategy × personalization × codec draws, EF accumulators
    threaded through the stacked arena rows."""
    task = _get_task()
    kw = dict(strategy=strategy, personalization=mode, uplink_codec=codec)
    ref = _run(task, engine, **kw)
    got = _run(task, engine, state_store="arena", **kw)
    _assert_substrate_parity(ref, got)


@pytest.mark.parametrize("engine,strategy,mode,codec", [
    ("batched", "feddyn", "none", "int8"),
    ("streaming", "scaffold", "pfedpara", ""),
    ("streaming", "fedprox", "fedper", "delta|topk0.1|int8"),
    ("batched", "scaffold", "local", "int8"),
])
def test_arena_roundtrip_matrix(task, engine, strategy, mode, codec):
    """Pinned strategy × mode × codec cells (runs with or without
    hypothesis — the property test above widens the same check)."""
    kw = dict(strategy=strategy, personalization=mode, uplink_codec=codec)
    ref = _run(task, engine, **kw)
    got = _run(task, engine, state_store="arena", **kw)
    _assert_substrate_parity(ref, got)


@pytest.mark.parametrize("engine", ["batched", "streaming"])
def test_arena_parity_ef_both_links(task, engine):
    """Non-identity codecs on BOTH links, multi-round, EF threaded."""
    kw = dict(uplink_codec="delta|topk0.1|int8",
              downlink_codec="delta|topk0.1|int8", rounds=3)
    ref = _run(task, engine, **kw)
    got = _run(task, engine, state_store="arena", **kw)
    _assert_substrate_parity(ref, got)


def test_arena_parity_hetero_tiers(task):
    """Rank tiers price and mask identically off the arena."""
    kw = dict(gamma_tiers=(0.1, 0.2, 0.3), strategy="scaffold")
    for engine in ("batched", "streaming"):
        ref = _run(task, engine, **kw)
        got = _run(task, engine, state_store="arena", **kw)
        _assert_substrate_parity(ref, got)


def test_arena_participation_counters(task):
    """The int32 counter row equals a host tally of the arrival masks."""
    srv = _run(task, "streaming", state_store="arena", rounds=3,
               strategy="scaffold")
    tally = np.zeros(N_CLIENTS, np.int64)
    for r in srv.history:
        for cid, hit in zip(r["sampled"], r["arrived_mask"]):
            tally[cid] += hit
    np.testing.assert_array_equal(srv.participation_counts(), tally)
    # the scratch row absorbs pad-slot scatters but never a real arrival
    assert int(np.asarray(srv.arena.participation)[-1]) == 0


def test_arena_scratch_row_stays_pristine(task):
    """chunk=3 over cohorts of 4 forces pad slots every round; the
    scratch row they all address must keep its template value."""
    srv = _run(task, "streaming", state_store="arena", chunk=3,
               strategy="scaffold", rounds=3)
    tmpl = srv.arena.client_state(0)  # row 0 mutated; compare structure
    scratch = srv.arena.client_state(srv.arena.scratch_row)
    for leaf in jax.tree.leaves(scratch):   # scaffold init = all zeros
        assert not np.asarray(leaf).any()
    assert set(scratch) == set(tmpl)


# ---------------------------------------------------------------- data path
def test_chunked_data_stream_bitwise(task):
    """Lazy per-chunk materialization is bit-identical to the eager
    full-cohort stack (shared row-fill helper), dict and arena stores."""
    ref = _run(task, "streaming", rounds=3)
    for kw in (dict(data_stream="chunked"),
               dict(data_stream="chunked", state_store="arena")):
        got = _run(task, "streaming", rounds=3, **kw)
        assert ([r.get("arrived_mask") for r in ref.history]
                == [r.get("arrived_mask") for r in got.history])
        assert _maxdiff(ref.global_params, got.global_params) == 0.0


def test_chunk_batch_source_matches_eager_stack(task):
    """fetch(i) rows == the eager stack's rows, bitwise, pads included."""
    tr, parts = task["tr"], task["parts"]
    cids = [1, 3, 4, 6, 7]
    seeds = [11, 22, 33, 44, 55]
    chunk, n_chunks, pad = 2, 3, 1
    batches, step_mask = stack_client_epochs(
        tr, parts, cids, batch=16, epochs=1, seeds=seeds,
        pad_steps=None, pad_clients=pad)
    S = step_mask.shape[1]
    src = ChunkBatchSource(tr, parts, cids, batch=16, epochs=1, seeds=seeds,
                           chunk=chunk, n_chunks=n_chunks, pad_steps=S)
    np.testing.assert_array_equal(src.step_mask(), step_mask)
    for ci in range(n_chunks):
        got = src.fetch(ci)
        for k in batches:
            np.testing.assert_array_equal(
                got[k], batches[k][ci * chunk:(ci + 1) * chunk])
    struct = src.chunk_struct()
    for k in batches:
        assert struct[k].shape == (chunk,) + batches[k].shape[1:]
        assert struct[k].dtype == batches[k].dtype


def test_stack_pad_clients_presized(task):
    """pad_clients pre-sizes the allocation: leading rows match the
    unpadded stack bitwise, pad rows are zero batches + zero mask."""
    tr, parts = task["tr"], task["parts"]
    cids, seeds = [0, 2, 5], [7, 8, 9]
    plain, mask = stack_client_epochs(tr, parts, cids, 16, 1, seeds)
    padded, pmask = stack_client_epochs(tr, parts, cids, 16, 1, seeds,
                                        pad_clients=2)
    for k in plain:
        np.testing.assert_array_equal(plain[k], padded[k][:3])
        assert not padded[k][3:].any()
    np.testing.assert_array_equal(mask, pmask[:3])
    assert not pmask[3:].any()


def test_virtual_partitions_deterministic():
    """O(1)-per-client views: stable across instances, distinct sorted
    sample ids in range, scalar indexing only."""
    a = VirtualPartitions(pool_size=10_000, clients=1_000_000,
                          samples_per_client=32, seed=3)
    b = VirtualPartitions(pool_size=10_000, clients=1_000_000,
                          samples_per_client=32, seed=3)
    assert len(a) == 1_000_000
    for cid in (0, 999_999, 123_456):
        idx = a[cid]
        np.testing.assert_array_equal(idx, b[cid])
        assert len(idx) == 32 == len(set(int(i) for i in idx))
        assert idx.min() >= 0 and idx.max() < 10_000
        assert np.all(np.diff(idx) > 0)
    assert not np.array_equal(a[0], a[1])
    assert np.array_equal(a[-1], a[999_999])
    np.testing.assert_array_equal(a.sizes([4, 5]), [32, 32])
    with pytest.raises(TypeError):
        a[[0, 1]]
    with pytest.raises(IndexError):
        a[1_000_000]


# ----------------------------------------------------------------- seeding
def test_quant_keys_vmap_matches_fold_in_loop(task):
    """The vectorized per-client quantization keys are value-identical
    to the historical per-client fold_in loop."""
    srv = _run(task, "batched", rounds=1)
    got = srv._quant_keys(7)
    base = jax.random.PRNGKey(srv.round_idx)
    want = jnp.stack([jax.random.fold_in(base, i) for i in range(7)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
