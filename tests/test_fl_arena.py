"""Arena-vs-dict client-state parity + fleet data-path invariants.

The device-resident arena (``repro.fl.arena``) must be an invisible
substrate swap: gather → local-update → scatter round-trips have to
reproduce the dict-based engines bitwise-masked and fp32-tol in params
for every strategy × personalization mode × codec (error feedback
threaded through the stacked rows), with identical wire bytes. The
streamed data path (``ChunkBatchSource``) must materialize bit-identical
batches to the eager full-cohort stack, and the pre-sized pad slots must
equal what the old concatenate path produced. Shared harness:
``tests/parity.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from parity import (
    HAVE_HYPOTHESIS,
    N_CLIENTS,
    assert_parity,
    get_task,
    given,
    maxdiff,
    run_server,
    settings,
    st,
)
from repro.data import (
    ChunkBatchSource,
    VirtualPartitions,
    stack_client_epochs,
)


@pytest.fixture(scope="module")
def task():
    return get_task()


def _run(task, engine, *, chunk=3, **kw):
    return run_server(task, engine, chunk=chunk, **kw)


# ------------------------------------------------------------------ tentpole
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=8, deadline=None)
@given(engine=st.sampled_from(["batched", "streaming"]),
       strategy=st.sampled_from(["fedavg", "fedprox", "scaffold", "feddyn"]),
       mode=st.sampled_from(["none", "pfedpara", "fedper", "local"]),
       codec=st.sampled_from(["", "int8", "delta|topk0.1|int8"]))
def test_arena_roundtrip_property(engine, strategy, mode, codec):
    """Acceptance: gather → local-update → scatter equals the dict path
    for random strategy × personalization × codec draws, EF accumulators
    threaded through the stacked arena rows."""
    task = get_task()
    kw = dict(strategy=strategy, personalization=mode, uplink_codec=codec)
    ref = _run(task, engine, **kw)
    got = _run(task, engine, state_store="arena", **kw)
    assert_parity(ref, got)


@pytest.mark.parametrize("engine,strategy,mode,codec", [
    ("batched", "feddyn", "none", "int8"),
    ("streaming", "scaffold", "pfedpara", ""),
    ("streaming", "fedprox", "fedper", "delta|topk0.1|int8"),
    ("batched", "scaffold", "local", "int8"),
])
def test_arena_roundtrip_matrix(task, engine, strategy, mode, codec):
    """Pinned strategy × mode × codec cells (runs with or without
    hypothesis — the property test above widens the same check)."""
    kw = dict(strategy=strategy, personalization=mode, uplink_codec=codec)
    ref = _run(task, engine, **kw)
    got = _run(task, engine, state_store="arena", **kw)
    assert_parity(ref, got)


@pytest.mark.parametrize("engine", ["batched", "streaming"])
def test_arena_parity_ef_both_links(task, engine):
    """Non-identity codecs on BOTH links, multi-round, EF threaded."""
    kw = dict(uplink_codec="delta|topk0.1|int8",
              downlink_codec="delta|topk0.1|int8", rounds=3)
    ref = _run(task, engine, **kw)
    got = _run(task, engine, state_store="arena", **kw)
    assert_parity(ref, got)


def test_arena_parity_hetero_tiers(task):
    """Rank tiers price and mask identically off the arena."""
    kw = dict(gamma_tiers=(0.1, 0.2, 0.3), strategy="scaffold")
    for engine in ("batched", "streaming"):
        ref = _run(task, engine, **kw)
        got = _run(task, engine, state_store="arena", **kw)
        assert_parity(ref, got)


def test_arena_participation_counters(task):
    """The int32 counter row equals a host tally of the arrival masks."""
    srv = _run(task, "streaming", state_store="arena", rounds=3,
               strategy="scaffold")
    tally = np.zeros(N_CLIENTS, np.int64)
    for r in srv.history:
        for cid, hit in zip(r["sampled"], r["arrived_mask"]):
            tally[cid] += hit
    np.testing.assert_array_equal(srv.participation_counts(), tally)
    # the scratch row absorbs pad-slot scatters but never a real arrival
    assert int(np.asarray(srv.arena.participation)[-1]) == 0


def test_arena_scratch_row_stays_pristine(task):
    """chunk=3 over cohorts of 4 forces pad slots every round; the
    scratch row they all address must keep its template value."""
    srv = _run(task, "streaming", state_store="arena", chunk=3,
               strategy="scaffold", rounds=3)
    tmpl = srv.arena.client_state(0)  # row 0 mutated; compare structure
    scratch = srv.arena.client_state(srv.arena.scratch_row)
    for leaf in jax.tree.leaves(scratch):   # scaffold init = all zeros
        assert not np.asarray(leaf).any()
    assert set(scratch) == set(tmpl)


# ---------------------------------------------------------------- data path
def test_chunked_data_stream_bitwise(task):
    """Lazy per-chunk materialization is bit-identical to the eager
    full-cohort stack (shared row-fill helper), dict and arena stores."""
    ref = _run(task, "streaming", rounds=3)
    for kw in (dict(data_stream="chunked"),
               dict(data_stream="chunked", state_store="arena")):
        got = _run(task, "streaming", rounds=3, **kw)
        assert ([r.get("arrived_mask") for r in ref.history]
                == [r.get("arrived_mask") for r in got.history])
        assert maxdiff(ref.global_params, got.global_params) == 0.0


def test_chunk_batch_source_matches_eager_stack(task):
    """fetch(i) rows == the eager stack's rows, bitwise, pads included."""
    tr, parts = task["tr"], task["parts"]
    cids = [1, 3, 4, 6, 7]
    seeds = [11, 22, 33, 44, 55]
    chunk, n_chunks, pad = 2, 3, 1
    batches, step_mask = stack_client_epochs(
        tr, parts, cids, batch=16, epochs=1, seeds=seeds,
        pad_steps=None, pad_clients=pad)
    S = step_mask.shape[1]
    src = ChunkBatchSource(tr, parts, cids, batch=16, epochs=1, seeds=seeds,
                           chunk=chunk, n_chunks=n_chunks, pad_steps=S)
    np.testing.assert_array_equal(src.step_mask(), step_mask)
    for ci in range(n_chunks):
        got = src.fetch(ci)
        for k in batches:
            np.testing.assert_array_equal(
                got[k], batches[k][ci * chunk:(ci + 1) * chunk])
    struct = src.chunk_struct()
    for k in batches:
        assert struct[k].shape == (chunk,) + batches[k].shape[1:]
        assert struct[k].dtype == batches[k].dtype


def test_stack_pad_clients_presized(task):
    """pad_clients pre-sizes the allocation: leading rows match the
    unpadded stack bitwise, pad rows are zero batches + zero mask."""
    tr, parts = task["tr"], task["parts"]
    cids, seeds = [0, 2, 5], [7, 8, 9]
    plain, mask = stack_client_epochs(tr, parts, cids, 16, 1, seeds)
    padded, pmask = stack_client_epochs(tr, parts, cids, 16, 1, seeds,
                                        pad_clients=2)
    for k in plain:
        np.testing.assert_array_equal(plain[k], padded[k][:3])
        assert not padded[k][3:].any()
    np.testing.assert_array_equal(mask, pmask[:3])
    assert not pmask[3:].any()


def test_virtual_partitions_deterministic():
    """O(1)-per-client views: stable across instances, distinct sorted
    sample ids in range, scalar indexing only."""
    a = VirtualPartitions(pool_size=10_000, clients=1_000_000,
                          samples_per_client=32, seed=3)
    b = VirtualPartitions(pool_size=10_000, clients=1_000_000,
                          samples_per_client=32, seed=3)
    assert len(a) == 1_000_000
    for cid in (0, 999_999, 123_456):
        idx = a[cid]
        np.testing.assert_array_equal(idx, b[cid])
        assert len(idx) == 32 == len(set(int(i) for i in idx))
        assert idx.min() >= 0 and idx.max() < 10_000
        assert np.all(np.diff(idx) > 0)
    assert not np.array_equal(a[0], a[1])
    assert np.array_equal(a[-1], a[999_999])
    np.testing.assert_array_equal(a.sizes([4, 5]), [32, 32])
    with pytest.raises(TypeError):
        a[[0, 1]]
    with pytest.raises(IndexError):
        a[1_000_000]


# ----------------------------------------------------------------- seeding
def test_quant_keys_vmap_matches_fold_in_loop(task):
    """The vectorized per-client quantization keys are value-identical
    to the historical per-client fold_in loop."""
    srv = _run(task, "batched", rounds=1)
    got = srv._quant_keys(7)
    base = jax.random.PRNGKey(srv.round_idx)
    want = jnp.stack([jax.random.fold_in(base, i) for i in range(7)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
