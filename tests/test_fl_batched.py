"""Batched-vs-sequential FL engine parity.

The client-batched engine (`repro.fl.batch_engine`) must reproduce the
sequential reference: bitwise-identical aggregation masks (both derive
them from the same host RNG draws) and fp32-tolerance-identical global
params / client residents, for every strategy and personalization mode,
including straggler/dropout masking and quantized uplinks. Shared
harness: ``tests/parity.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from parity import (
    N_CLIENTS,
    assert_parity,
    get_task,
    make_model,
    maxdiff,
    run_server,
)
from repro.configs.base import ParamCfg
from repro.data import dirichlet_partition, iid_partition
from repro.data.loader import client_epochs, stack_client_epochs
from repro.fl import ClientConfig, FLServer, ServerConfig, make_strategy
from repro.nn import recurrent as rec

ATOL = 5e-5  # fp32 accumulation-order tolerance


@pytest.fixture(scope="module")
def task():
    return get_task()


def _run_pair(task, *, rounds=1, **kw):
    return [run_server(task, engine, rounds=rounds, **kw)
            for engine in ("sequential", "batched")]


@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "scaffold",
                                      "feddyn"])
def test_strategy_parity(task, strategy):
    seq, bat = _run_pair(task, strategy=strategy)
    assert_parity(seq, bat, atol=ATOL)


@pytest.mark.parametrize("mode", ["none", "pfedpara", "fedper"])
def test_personalization_parity(task, mode):
    seq, bat = _run_pair(task, personalization=mode, rounds=2)
    assert_parity(seq, bat, check_residents=(mode != "none"), atol=ATOL)


def test_straggler_masking_parity(task):
    seq, bat = _run_pair(task, rounds=3, oversample=0.5,
                         deadline_quantile=0.5, dropout_prob=0.3, seed=3)
    assert_parity(seq, bat, atol=ATOL)
    masks = [r["arrived_mask"] for r in bat.history]
    assert any(0 in m for m in masks)  # masking actually exercised


def test_quantized_uplink_parity(task):
    seq, bat = _run_pair(task, uplink_quant="int8")
    assert_parity(seq, bat, atol=ATOL)


def test_full_codec_stack_parity(task):
    """Acceptance: sequential and batched agree under
    "delta|topk0.1|int8" on BOTH links — including the client-stacked
    error-feedback accumulators threaded through client_states."""
    seq, bat = _run_pair(task, rounds=3,
                         uplink_codec="delta|topk0.1|int8",
                         downlink_codec="delta|topk0.1|int8")
    assert_parity(seq, bat, atol=ATOL)
    # error feedback is live: accumulators exist and are non-zero
    efs = [st["_ef_up"] for st in seq.client_states.values()]
    assert efs and any(float(jnp.abs(l).max()) > 0
                       for e in efs for l in jax.tree.leaves(e))


def test_codec_parity_with_personalization(task):
    seq, bat = _run_pair(task, rounds=2, personalization="pfedpara",
                         uplink_codec="delta|topk0.2|int8",
                         downlink_codec="fp16")
    assert_parity(seq, bat, check_residents=True, atol=ATOL)


def test_batched_engine_learns(task):
    cfg, _, _ = make_model("fedpara")
    te = task["te"]

    def eval_fn(p):
        return float(rec.mlp_accuracy(p, cfg, {"x": te["x"][:300],
                                               "y": te["y"][:300]}))

    srv = run_server(task, "batched", rounds=4, epochs=2, eval_fn=eval_fn)
    hist = srv.history
    assert hist[-1]["eval"] > hist[0]["eval"]
    assert hist[-1]["eval"] > 0.3


def test_stack_client_epochs_matches_generator(task):
    tr = task["tr"]
    parts = dirichlet_partition(tr["y"], 6, 0.5)
    cids, seeds = [0, 2, 5], [11, 22, 33]
    batches, mask = stack_client_epochs(tr, parts, cids, 16, 2, seeds)
    assert mask.shape[0] == 3 and batches["x"].shape[:2] == mask.shape
    for c, (cid, seed) in enumerate(zip(cids, seeds)):
        ref = list(client_epochs(tr, parts[cid], 16, 2, seed))
        assert int(mask[c].sum()) == len(ref)
        for s, b in enumerate(ref):
            if len(b["x"]) == 16:  # full batches replicated exactly
                np.testing.assert_array_equal(batches["x"][c, s], b["x"])
                np.testing.assert_array_equal(batches["y"][c, s], b["y"])


def test_batched_personalized_eval_matches_sequential(task):
    from repro.fl.batch_engine import batched_personalized_eval

    seq, bat = _run_pair(task, personalization="fedper", rounds=2)
    cfg, _, _ = make_model("fedpara")
    tr = task["tr"]
    parts = iid_partition(len(tr["y"]), N_CLIENTS, 0)

    def metric(p, batch):
        return rec.mlp_accuracy(p, cfg, batch)

    eval_data = {k: np.stack([v[parts[c][:40]] for c in range(N_CLIENTS)])
                 for k, v in tr.items()}

    def batch_eval(stacked, cids):
        return batched_personalized_eval(stacked, eval_data, metric)

    scores_b = bat.personalized_eval(batch_eval_fn=batch_eval)
    scores_s = bat.personalized_eval(
        eval_fn=lambda p, cid: metric(p, {k: v[cid] for k, v in eval_data.items()}))
    np.testing.assert_allclose(scores_b, scores_s, atol=1e-5)


def test_batched_compose_kernel_matches_reference():
    key = jax.random.PRNGKey(0)
    from repro.kernels.fedpara_compose import fedpara_compose

    C, m, n, r = 2, 96, 130, 4
    ks = jax.random.split(key, 4)
    x1, x2 = (jax.random.normal(k, (C, m, r)) for k in ks[:2])
    y1, y2 = (jax.random.normal(k, (C, n, r)) for k in ks[2:])
    out = fedpara_compose(x1, y1, x2, y2, block_m=128, block_n=128,
                          interpret=True)
    ref = (jnp.einsum("cmr,cnr->cmn", x1, y1)
           * jnp.einsum("cmr,cnr->cmn", x2, y2))
    assert out.shape == (C, m, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_use_pallas_parity_both_engines(task):
    """Acceptance: with the fused custom-VJP kernels in the loss
    (``ParamCfg(use_pallas=True)``) BOTH engines produce global params
    parity-tolerant with the materialize path — and with each other."""
    parts = iid_partition(len(task["tr"]["y"]), 4)
    results = {}
    for engine in ("sequential", "batched"):
        for pallas in (False, True):
            cfg = rec.MLPConfig(in_dim=256, hidden=64, classes=10,
                                param=ParamCfg(kind="fedpara", gamma=0.3,
                                               min_dim_for_factorization=8,
                                               use_pallas=pallas))
            params = rec.init_mlp_model(jax.random.PRNGKey(0), cfg)

            def loss_fn(p, b, cfg=cfg):
                return rec.mlp_loss(p, cfg, b)

            srv = FLServer(loss_fn, params, task["tr"], parts,
                           make_strategy("fedavg"),
                           ClientConfig(lr=0.1, batch=64, epochs=1),
                           ServerConfig(clients=4, participation=1.0,
                                        rounds=1, engine=engine))
            srv.run()
            results[(engine, pallas)] = srv.global_params
    # fused-vs-materialize: fp32 tile-accumulation-order tolerance
    for engine in ("sequential", "batched"):
        assert maxdiff(results[(engine, False)],
                       results[(engine, True)]) < 2e-3, engine
    # engine-vs-engine on the fused path: the usual parity contract
    assert maxdiff(results[("sequential", True)],
                   results[("batched", True)]) < 2e-3
