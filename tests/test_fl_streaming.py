"""Streaming-vs-batched FL engine parity + chunk-size invariance.

The streaming engine (`repro.fl.stream_engine`) must reproduce the
batched engine on identical round selections (bitwise-equal arrival
masks — both derive them from the same host RNG draws) to fp32
accumulation-order tolerance, for every personalization mode and for
non-identity uplink codecs with error feedback threaded across chunks.
Aggregation must be invariant to the chunk size: chunking only
reassociates the fp32 weighted sum. Shared harness: ``tests/parity.py``.
"""
import jax
import jax.numpy as jnp
import pytest

from parity import (
    N_CLIENTS,
    assert_parity,
    get_task,
    given,
    make_model,
    run_server,
    settings,
    st,
)
from repro.fl import ClientConfig, FLServer, ServerConfig, make_strategy
from repro.nn import recurrent as rec


@pytest.fixture(scope="module")
def task():
    return get_task()


def _run(task, engine, *, chunk=3, **kw):
    return run_server(task, engine, chunk=chunk, **kw)


@pytest.mark.parametrize("strategy", ["fedavg", "scaffold", "feddyn"])
def test_strategy_parity(task, strategy):
    bat = _run(task, "batched", strategy=strategy)
    stream = _run(task, "streaming", strategy=strategy)
    assert_parity(bat, stream)


@pytest.mark.parametrize("mode", ["none", "pfedpara", "fedper", "local"])
def test_personalization_parity(task, mode):
    bat = _run(task, "batched", personalization=mode)
    stream = _run(task, "streaming", personalization=mode)
    assert_parity(bat, stream, check_residents=(mode != "none"))


def test_codec_with_error_feedback_parity(task):
    """Acceptance: a non-identity uplink codec with error feedback —
    the EF accumulators thread through the chunked client state exactly
    as through the batched stacked state, across multiple rounds."""
    kw = dict(uplink_codec="delta|topk0.1|int8",
              downlink_codec="delta|topk0.1|int8", rounds=3)
    bat = _run(task, "batched", **kw)
    stream = _run(task, "streaming", **kw)
    assert_parity(bat, stream)
    efs = [s["_ef_up"] for s in stream.client_states.values()]
    assert efs and any(float(jnp.abs(l).max()) > 0
                       for e in efs for l in jax.tree.leaves(e))


def test_lowrank_codec_parity(task):
    """Bilinear (low-rank) stages fall back to per-client composition
    inside the chunk — still never a (C, model) stack."""
    bat = _run(task, "batched", uplink_codec="delta|lowrank2|int8")
    stream = _run(task, "streaming", uplink_codec="delta|lowrank2|int8")
    assert_parity(bat, stream)


def test_straggler_masking_parity(task):
    # Looser atol than the single-trajectory contract: across 3 rounds
    # the carried ~1e-7 accumulation-order difference re-enters local
    # SGD and can amplify through ReLU boundary flips (seeding both
    # engines with identical round-3 inputs brings them back to ~1e-7,
    # so the masking/aggregation logic itself is exact).
    kw = dict(rounds=3, oversample=0.5, deadline_quantile=0.5,
              dropout_prob=0.3, seed=3)
    bat = _run(task, "batched", **kw)
    stream = _run(task, "streaming", **kw)
    assert_parity(bat, stream, atol=1e-3)
    assert any(0 in r["arrived_mask"] for r in stream.history)


@pytest.mark.parametrize("chunk", [1, 3, N_CLIENTS])
def test_chunk_sizes_match_batched(task, chunk):
    """chunk ∈ {1, 3, C}: every chunking matches the batched engine on
    identical selections, with the EF-bearing codec stack active."""
    kw = dict(uplink_codec="delta|topk0.2|int8", rounds=2)
    bat = _run(task, "batched", **kw)
    stream = _run(task, "streaming", chunk=chunk, **kw)
    assert_parity(bat, stream)


_INVARIANCE_REF = {}


@settings(max_examples=6, deadline=None)
@given(chunk=st.sampled_from([1, 3, N_CLIENTS]),
       codec=st.sampled_from(["", "int8", "delta|topk0.2|int8"]))
def test_chunk_size_invariance(chunk, codec):
    """Property: the streamed aggregate is chunk-size invariant — any
    chunking of the same round selection gives the same global params,
    client states and EF accumulators to fp32 tolerance (chunking only
    reassociates the weighted sum). The chunk=2 run doubles as the
    batched-engine cross-check baseline."""
    task = get_task()
    if codec not in _INVARIANCE_REF:
        bat = _run(task, "batched", uplink_codec=codec)
        assert_parity(bat, _run(task, "streaming", chunk=2,
                                uplink_codec=codec))
        _INVARIANCE_REF[codec] = bat
    got = _run(task, "streaming", chunk=chunk, uplink_codec=codec)
    assert_parity(_INVARIANCE_REF[codec], got)


def test_streaming_engine_learns(task):
    cfg, _, _ = make_model("fedpara")
    te = task["te"]

    def eval_fn(p):
        return float(rec.mlp_accuracy(p, cfg, {"x": te["x"][:300],
                                               "y": te["y"][:300]}))

    srv = run_server(task, "streaming", chunk=2, rounds=4, epochs=2,
                     eval_fn=eval_fn)
    hist = srv.history
    assert hist[-1]["eval"] > hist[0]["eval"]
    assert hist[-1]["chunks"] == 2 and hist[-1]["client_chunk"] == 2


def test_unknown_engine_rejected(task):
    cfg, params, loss_fn = make_model("fedpara")
    with pytest.raises(ValueError, match="unknown engine"):
        FLServer(loss_fn, params, task["tr"], task["parts"],
                 make_strategy("fedavg"), ClientConfig(),
                 ServerConfig(engine="warp"))
