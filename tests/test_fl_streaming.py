"""Streaming-vs-batched FL engine parity + chunk-size invariance.

The streaming engine (`repro.fl.stream_engine`) must reproduce the
batched engine on identical round selections (bitwise-equal arrival
masks — both derive them from the same host RNG draws) to fp32
accumulation-order tolerance, for every personalization mode and for
non-identity uplink codecs with error feedback threaded across chunks.
Aggregation must be invariant to the chunk size: chunking only
reassociates the fp32 weighted sum.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParamCfg
from repro.data import dirichlet_partition, make_image_dataset, train_test_split
from repro.fl import ClientConfig, FLServer, ServerConfig, make_strategy
from repro.nn import recurrent as rec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # only the property test needs hypothesis
    HAVE_HYPOTHESIS = False

    def given(**kw):          # no-op decorators so the module still loads
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    settings = given

    class st:  # noqa: N801
        sampled_from = staticmethod(lambda *a: None)

ATOL = 1e-4  # fp32 accumulation-order tolerance (unnormalized running
             # sums peak higher than the batched engine's normalized mean)

N_CLIENTS = 8


_TASK = {}


def _get_task():
    if not _TASK:
        ds = make_image_dataset(1200, 10, size=16, channels=1, noise=0.3)
        data = {"x": ds["x"].reshape(len(ds["y"]), -1), "y": ds["y"]}
        tr, te = train_test_split(data)
        _TASK.update(tr=tr, te=te,
                     parts=dirichlet_partition(tr["y"], N_CLIENTS, 0.5))
    return _TASK


@pytest.fixture(scope="module")
def task():
    return _get_task()


def _make(kind):
    cfg = rec.MLPConfig(in_dim=256, hidden=64, classes=10,
                        param=ParamCfg(kind=kind, gamma=0.3,
                                       min_dim_for_factorization=8))
    params = rec.init_mlp_model(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, b):
        return rec.mlp_loss(p, cfg, b)

    return cfg, params, loss_fn


def _run(task, engine, *, chunk=3, strategy="fedavg", personalization="none",
         rounds=2, **server_kw):
    kind = "pfedpara" if personalization == "pfedpara" else "fedpara"
    cfg, params, loss_fn = _make(kind)
    srv = FLServer(loss_fn, params, task["tr"], task["parts"],
                   make_strategy(strategy),
                   ClientConfig(lr=0.1, batch=16, epochs=1),
                   ServerConfig(clients=N_CLIENTS, participation=0.5,
                                rounds=rounds, engine=engine,
                                client_chunk=chunk,
                                personalization=personalization,
                                **server_kw))
    srv.run()
    return srv


def _maxdiff(a, b):
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b))
    return max(leaves) if leaves else 0.0


def _assert_parity(ref, got, check_residents=False, atol=ATOL):
    assert ([r.get("arrived_mask") for r in ref.history]
            == [r.get("arrived_mask") for r in got.history])
    assert _maxdiff(ref.global_params, got.global_params) < atol
    assert _maxdiff(ref.server_state, got.server_state) < atol
    assert set(ref.client_states) == set(got.client_states)
    for cid in ref.client_states:
        assert _maxdiff(ref.client_states[cid],
                        got.client_states.get(cid, {})) < atol
    if check_residents:
        assert set(ref.local_trees) == set(got.local_trees)
        for cid in ref.local_trees:
            assert _maxdiff(ref.local_trees[cid], got.local_trees[cid]) < atol
    for rr, rg in zip(ref.history, got.history):
        assert abs(rr["mean_loss"] - rg["mean_loss"]) < 1e-4
        assert abs(rr["comm_gb"] - rg["comm_gb"]) < 1e-12


@pytest.mark.parametrize("strategy", ["fedavg", "scaffold", "feddyn"])
def test_strategy_parity(task, strategy):
    bat = _run(task, "batched", strategy=strategy)
    stream = _run(task, "streaming", strategy=strategy)
    _assert_parity(bat, stream)


@pytest.mark.parametrize("mode", ["none", "pfedpara", "fedper", "local"])
def test_personalization_parity(task, mode):
    bat = _run(task, "batched", personalization=mode)
    stream = _run(task, "streaming", personalization=mode)
    _assert_parity(bat, stream, check_residents=(mode != "none"))


def test_codec_with_error_feedback_parity(task):
    """Acceptance: a non-identity uplink codec with error feedback —
    the EF accumulators thread through the chunked client state exactly
    as through the batched stacked state, across multiple rounds."""
    kw = dict(uplink_codec="delta|topk0.1|int8",
              downlink_codec="delta|topk0.1|int8", rounds=3)
    bat = _run(task, "batched", **kw)
    stream = _run(task, "streaming", **kw)
    _assert_parity(bat, stream)
    efs = [s["_ef_up"] for s in stream.client_states.values()]
    assert efs and any(float(jnp.abs(l).max()) > 0
                       for e in efs for l in jax.tree.leaves(e))


def test_lowrank_codec_parity(task):
    """Bilinear (low-rank) stages fall back to per-client composition
    inside the chunk — still never a (C, model) stack."""
    bat = _run(task, "batched", uplink_codec="delta|lowrank2|int8")
    stream = _run(task, "streaming", uplink_codec="delta|lowrank2|int8")
    _assert_parity(bat, stream)


def test_straggler_masking_parity(task):
    # Looser atol than the single-trajectory contract: across 3 rounds
    # the carried ~1e-7 accumulation-order difference re-enters local
    # SGD and can amplify through ReLU boundary flips (seeding both
    # engines with identical round-3 inputs brings them back to ~1e-7,
    # so the masking/aggregation logic itself is exact).
    kw = dict(rounds=3, oversample=0.5, deadline_quantile=0.5,
              dropout_prob=0.3, seed=3)
    bat = _run(task, "batched", **kw)
    stream = _run(task, "streaming", **kw)
    _assert_parity(bat, stream, atol=1e-3)
    assert any(0 in r["arrived_mask"] for r in stream.history)


@pytest.mark.parametrize("chunk", [1, 3, N_CLIENTS])
def test_chunk_sizes_match_batched(task, chunk):
    """chunk ∈ {1, 3, C}: every chunking matches the batched engine on
    identical selections, with the EF-bearing codec stack active."""
    kw = dict(uplink_codec="delta|topk0.2|int8", rounds=2)
    bat = _run(task, "batched", **kw)
    stream = _run(task, "streaming", chunk=chunk, **kw)
    _assert_parity(bat, stream)


_INVARIANCE_REF = {}


@settings(max_examples=6, deadline=None)
@given(chunk=st.sampled_from([1, 3, N_CLIENTS]),
       codec=st.sampled_from(["", "int8", "delta|topk0.2|int8"]))
def test_chunk_size_invariance(chunk, codec):
    """Property: the streamed aggregate is chunk-size invariant — any
    chunking of the same round selection gives the same global params,
    client states and EF accumulators to fp32 tolerance (chunking only
    reassociates the weighted sum). The chunk=2 run doubles as the
    batched-engine cross-check baseline."""
    task = _get_task()
    if codec not in _INVARIANCE_REF:
        bat = _run(task, "batched", uplink_codec=codec)
        _assert_parity(bat, _run(task, "streaming", chunk=2,
                                 uplink_codec=codec))
        _INVARIANCE_REF[codec] = bat
    got = _run(task, "streaming", chunk=chunk, uplink_codec=codec)
    _assert_parity(_INVARIANCE_REF[codec], got)


def test_streaming_engine_learns(task):
    cfg, params, loss_fn = _make("fedpara")
    te = task["te"]

    def eval_fn(p):
        return float(rec.mlp_accuracy(p, cfg, {"x": te["x"][:300],
                                               "y": te["y"][:300]}))

    srv = FLServer(loss_fn, params, task["tr"], task["parts"],
                   make_strategy("fedavg"),
                   ClientConfig(lr=0.1, batch=16, epochs=2),
                   ServerConfig(clients=N_CLIENTS, participation=0.5,
                                rounds=4, engine="streaming",
                                client_chunk=2), eval_fn=eval_fn)
    hist = srv.run()
    assert hist[-1]["eval"] > hist[0]["eval"]
    assert hist[-1]["chunks"] == 2 and hist[-1]["client_chunk"] == 2


def test_unknown_engine_rejected(task):
    cfg, params, loss_fn = _make("fedpara")
    with pytest.raises(ValueError, match="unknown engine"):
        FLServer(loss_fn, params, task["tr"], task["parts"],
                 make_strategy("fedavg"), ClientConfig(),
                 ServerConfig(engine="warp"))
