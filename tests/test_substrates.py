"""Optimizers, schedules, data pipeline, checkpoint manager."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import (
    ShardedBatcher,
    dirichlet_partition,
    iid_partition,
    make_char_corpus,
    make_image_dataset,
    two_class_partition,
)
from repro.data.partition import partition_stats
from repro.optim import (
    adam,
    adamw,
    apply_updates,
    chain_clip,
    cosine_decay,
    exponential_decay,
    global_norm,
    sgd,
    warmup_cosine,
)


@pytest.mark.parametrize("make_opt", [lambda: sgd(0.1, momentum=0.9),
                                      lambda: adam(0.05),
                                      lambda: adamw(0.05, weight_decay=0.0)])
def test_optimizers_converge_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.full((8,), 3.0)}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        u, state = opt.update(g, state, params)
        params = apply_updates(params, u)
    assert float(loss(params)) < 1e-3


def test_clip_bounds_update():
    opt = chain_clip(sgd(1.0), max_norm=0.5)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    u, _ = opt.update(huge, state, params)
    assert float(global_norm(u)) <= 0.5 + 1e-5


def test_schedules():
    s1 = exponential_decay(0.1, 0.992)
    assert abs(float(s1(jnp.int32(0))) - 0.1) < 1e-7
    assert float(s1(jnp.int32(100))) < 0.1 * 0.992 ** 99
    s2 = cosine_decay(1.0, 100)
    assert float(s2(jnp.int32(0))) == 1.0
    assert abs(float(s2(jnp.int32(100))) - 0.1) < 1e-6
    s3 = warmup_cosine(1.0, 10, 100)
    assert float(s3(jnp.int32(5))) == 0.5


def test_dirichlet_partition_covers_all():
    labels = np.random.RandomState(0).randint(0, 10, 2000)
    parts = dirichlet_partition(labels, 20, alpha=0.5)
    joined = np.concatenate(parts)
    assert len(joined) == 2000 and len(np.unique(joined)) == 2000
    stats = partition_stats(labels, parts)
    # non-IID: at least one client misses at least one class
    assert (stats["class_hist"] == 0).any()


def test_two_class_partition_is_highly_skewed():
    labels = np.random.RandomState(0).randint(0, 10, 1000)
    parts = two_class_partition(labels, 50)
    stats = partition_stats(labels, parts)
    assert stats["max_classes_per_client"] <= 3  # ~2 shards -> <=2-3 classes


def test_iid_partition_balanced():
    parts = iid_partition(1000, 10)
    assert all(abs(len(p) - 100) <= 1 for p in parts)


def test_batcher_resume_determinism():
    data = {"x": np.arange(100).reshape(100, 1)}
    b1 = ShardedBatcher(data, 16, seed=7)
    seq1 = [b1.next_batch()["x"][:, 0].tolist() for _ in range(10)]
    pos = None
    b2 = ShardedBatcher(data, 16, seed=7)
    out = []
    for i in range(10):
        if i == 4:
            pos = b2.position()
            b3 = ShardedBatcher(data, 16, seed=7)
            b3.restore(pos)
            assert b3.next_batch()["x"][:, 0].tolist() == seq1[4]
        out.append(b2.next_batch()["x"][:, 0].tolist())
    assert out == seq1


def test_char_corpus_learnable():
    """Markov corpus: bigram statistics beat uniform by a margin."""
    seqs = make_char_corpus(64, 256, vocab=40, seed=1)
    trans = np.zeros((40, 40))
    for row in seqs:
        for a, b in zip(row[:-1], row[1:]):
            trans[a, b] += 1
    top1 = trans.max(1).sum() / max(1, trans.sum())
    assert top1 > 0.2  # >> 1/40 chance


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "nest": [{"b": jnp.ones((4,), jnp.bfloat16)}]}
        for s in (1, 2, 3):
            cm.save(s, tree, extra={"step": s, "pos": {"epoch": 1}})
        assert cm.all_steps() == [2, 3]
        got, extra = cm.restore(None, tree)
        assert extra["step"] == 3
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
        assert got["nest"][0]["b"].dtype == jnp.bfloat16


def test_checkpoint_async_and_missing_leaf_error():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=1, async_save=True)
        cm.save(5, {"a": jnp.zeros((3,))})
        cm.wait()
        with pytest.raises(KeyError):
            cm.restore(5, {"a": jnp.zeros((3,)), "new": jnp.zeros((1,))})
        with pytest.raises(ValueError):
            cm.restore(5, {"a": jnp.zeros((4,))})


def test_checkpoint_reshard_on_load():
    """Elasticity: restore with explicit (single-device) shardings."""
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        cm.save(1, tree)
        sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        got, _ = cm.restore(1, tree, shardings={"w": sharding})
        assert got["w"].sharding == sharding
