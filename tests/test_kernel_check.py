"""Kernel block-table contract: every layer shape every shipped config
produces must resolve to a valid tile, and the set of shapes the table
can NOT serve within the VMEM budget is pinned here — an xfail-style
report, not a silent fallback.
"""
import pytest

from repro.analysis import kernel_check as kc
from repro.configs import ASSIGNED

# The known over-VMEM shapes: llama3-405B's 16384x53248 FFN matrices in
# the dY-factor backward body (~18.6 MiB > 16 MiB). Adding a block-table
# regime for them shrinks this set; adding a new config may grow it —
# either way, deliberately, here.
KNOWN_UNCOVERED = {
    ("llama3-405b", 16384, 53248, "dfy"),
    ("llama3-405b", 53248, 16384, "dfy"),
}


def _key(entry):
    return (entry.config, entry.m, entry.n, entry.body)


@pytest.fixture(scope="module")
def results():
    return kc.check_all()


def test_every_config_enumerates_factor_layers(results):
    covered = {r.config for r in results}
    assert covered == set(ASSIGNED)
    assert all(any(r.config == c and r.body == "fwd" for r in results)
               for c in ASSIGNED), "a config produced no factor layers"


def test_no_invalid_tiles(results):
    bad = kc.invalid(results)
    assert bad == [], "\n".join(r.render() for r in bad)


def test_uncovered_set_is_exactly_the_known_one(results):
    over = {_key(r) for r in kc.uncovered(results)}
    report = "\n".join(r.render() for r in kc.uncovered(results))
    assert over == KNOWN_UNCOVERED, (
        f"uncovered-shape report changed:\n{report}\n"
        f"update KNOWN_UNCOVERED deliberately if the block table or a "
        f"config changed")


def test_aggregation_tiles_always_fit(results):
    agg = [r for r in results if r.body == "agg"]
    assert agg, "no aggregation entries enumerated"
    assert all(r.valid and r.fits for r in agg), "\n".join(
        r.render() for r in agg if not (r.valid and r.fits))


def test_vmem_model_matches_hand_count():
    # fwd body, blocks (8, 32, 128), r=16: streamed = x(8x32) +
    # factors 2*(32+128)*16 + out(8x128); scratch = 8x128 — all fp32.
    streamed = 8 * 32 + 2 * (32 * 16 + 128 * 16) + 8 * 128
    expect = (2 * streamed + 8 * 128) * 4
    assert kc.kernel_vmem("fwd", 8, 32, 128, 16) == expect


def test_selected_blocks_cover_every_factor_shape():
    from repro.kernels import blocks

    for name in ASSIGNED:
        for path, m, n, r in kc.factor_shapes(kc.enumerate_config(name)):
            bb, bm, bn = blocks.select_blocks(m, n, r)
            assert bb > 0 and bm > 0 and bn > 0, (name, path)
            # padded grid covers the operand
            assert -(-m // bm) * bm >= m and -(-n // bn) * bn >= n


def test_cli_reports_without_failing():
    assert kc.main([]) == 0


def test_cli_strict_fails_on_the_known_uncovered():
    assert kc.main(["--strict", "llama3-405b"]) == 1
    assert kc.main(["--strict", "qwen3-8b"]) == 0


def test_serve_bodies_enumerated_for_every_config(results):
    sv = [r for r in results if r.body in kc.SERVE_BODIES]
    assert {r.config for r in sv} == set(ASSIGNED)
    assert {r.body for r in sv} == set(kc.SERVE_BODIES)


def test_serve_tiles_always_fit():
    # unlike the dfy training body, the serve tile table covers every
    # shipped shape within VMEM — no serve entry may join
    # KNOWN_UNCOVERED without a deliberate pin here
    sv = [r for r in kc.check_all() if r.body in kc.SERVE_BODIES]
    bad = [r for r in sv if not (r.valid and r.fits)]
    assert bad == [], "\n".join(r.render() for r in bad)


def test_serve_vmem_model_matches_hand_count():
    # w8 body, blocks (8, 32, 128): int8 weight tile streams at 1 B/elt
    # next to fp32 x/scale/out; scratch = fp32 acc + widened weight copy
    stream = 4 * 8 * 32 + 32 * 128 + 4 * 128 + 4 * 8 * 128
    scratch = 4 * 8 * 128 + 4 * 32 * 128
    assert kc.serve_kernel_vmem("w8", 8, 32, 128, 0) == (
        kc.DOUBLE_BUFFER * stream + scratch)
    # resid adds the per-user factor slices (fp32) and a second scratch
    resid_stream = stream + 4 * (32 * 4 + 128 * 4)
    resid_scratch = scratch + 4 * 32 * 128
    assert kc.serve_kernel_vmem("resid", 8, 32, 128, 4) == (
        kc.DOUBLE_BUFFER * resid_stream + resid_scratch)
