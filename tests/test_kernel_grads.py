"""Gradient parity of the fused custom-VJP Pallas kernels vs ``jax.grad``
of the dense oracles (interpret mode executes the kernel bodies on CPU).

Mirrors the forward sweeps in test_kernels.py: shapes (incl. non-aligned
m/n/r padding), dtypes, all three variants (fedpara / fedpara_tanh /
pfedpara), direct-VJP-vs-oracle, and vmap over a client axis — the exact
composition the client-batched FL engine traces (jit(vmap(grad(loss)))).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

KINDS = ["fedpara", "fedpara_tanh", "pfedpara"]
# small blocks keep interpret-mode grids multi-tile so padding and the
# sequential accumulation axes are actually exercised
BLK = dict(interpret=True, block_b=16, block_m=64, block_n=64)


def _mats(key, B, m, n, r, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, m), dtype)
    f = [jax.random.normal(k, (d, r), jnp.float32) * 0.2
         for k, d in zip(ks[1:], (m, n, m, n))]
    return x, f


def _loss_through(matmul, kind):
    def loss(x, x1, y1, x2, y2):
        y = matmul(x, x1, y1, x2, y2)
        return jnp.sum(jnp.sin(y.astype(jnp.float32)))
    return loss


def _grads(matmul, kind, args):
    return jax.grad(_loss_through(matmul, kind), argnums=(0, 1, 2, 3, 4))(*args)


SHAPES = [
    (8, 64, 64, 4),
    (17, 100, 50, 3),      # non-aligned everything
    (1, 384, 128, 32),     # single row
    (33, 128, 300, 7),
]


@pytest.mark.parametrize("B,m,n,r", SHAPES)
@pytest.mark.parametrize("kind", KINDS)
def test_grad_parity_sweep(B, m, n, r, kind):
    key = jax.random.PRNGKey(B * 1000 + m + n + r)
    x, (x1, y1, x2, y2) = _mats(key, B, m, n, r)
    args = (x, x1, y1, x2, y2)
    got = _grads(lambda *a: ops.fedpara_matmul(*a, kind=kind, **BLK),
                 kind, args)
    want = _grads(lambda *a: ops.fedpara_matmul_ref(*a, kind=kind),
                  kind, args)
    for g, w, nm in zip(got, want, ("dx", "dx1", "dy1", "dx2", "dy2")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=5e-4, rtol=5e-4,
                                   err_msg=f"{kind} {(B, m, n, r)} {nm}")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kind", KINDS)
def test_grad_parity_dtypes(dtype, kind):
    key = jax.random.PRNGKey(7)
    x, (x1, y1, x2, y2) = _mats(key, 24, 96, 72, 6, dtype)
    args = (x, x1, y1, x2, y2)
    got = _grads(lambda *a: ops.fedpara_matmul(*a, kind=kind, **BLK),
                 kind, args)
    want = _grads(lambda *a: ops.fedpara_matmul_ref(*a, kind=kind),
                  kind, args)
    # bf16 inputs: the kernel contracts bf16 operands with fp32
    # accumulation while the oracle upcasts first — a few-ULP spread
    tol = 1e-1 if dtype == jnp.bfloat16 else 5e-4
    for g, w, nm in zip(got, want, ("dx", "dx1", "dy1", "dx2", "dy2")):
        assert g.dtype == w.dtype, nm   # cotangents keep primal dtypes
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   atol=tol, rtol=tol, err_msg=f"{kind} {nm}")


@pytest.mark.parametrize("kind", KINDS)
def test_direct_vjp_matches_closed_form_oracle(kind):
    """ops.fedpara_matmul_vjp (raw backward kernels) vs the dense
    closed-form oracle in ref.py — isolates the kernels from custom_vjp
    plumbing."""
    key = jax.random.PRNGKey(11)
    x, (x1, y1, x2, y2) = _mats(key, 13, 70, 90, 5)
    dy = jax.random.normal(jax.random.PRNGKey(12), (13, 90), jnp.float32)
    got = ops.fedpara_matmul_vjp(x, x1, y1, x2, y2, dy, kind=kind, **BLK)
    want = ops.fedpara_matmul_vjp_ref(x, x1, y1, x2, y2, dy, kind=kind)
    for g, w, nm in zip(got, want, ("dx", "dx1", "dy1", "dx2", "dy2")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=5e-4, rtol=5e-4,
                                   err_msg=f"{kind} {nm}")


@pytest.mark.parametrize("kind", KINDS)
def test_grad_parity_vmap_client_axis(kind):
    """jit(vmap(grad(loss))) over a leading client axis — the exact
    composition the batched FL engine traces. Pallas' batching rule
    folds the client axis into the kernel grids (one launch/layer)."""
    C, B, m, n, r = 3, 9, 48, 80, 5
    ks = jax.random.split(jax.random.PRNGKey(21), 5)
    x = jax.random.normal(ks[0], (C, B, m), jnp.float32)
    x1, y1, x2, y2 = [jax.random.normal(k, (C, d, r), jnp.float32) * 0.2
                      for k, d in zip(ks[1:], (m, n, m, n))]

    def loss(xc, a1, b1, a2, b2):
        y = ops.fedpara_matmul(xc, a1, b1, a2, b2, kind=kind,
                               interpret=True, block_b=16, block_m=32,
                               block_n=32)
        return jnp.sum(jnp.sin(y))

    def loss_ref(xc, a1, b1, a2, b2):
        return jnp.sum(jnp.sin(ops.fedpara_matmul_ref(xc, a1, b1, a2, b2,
                                                      kind=kind)))

    got = jax.jit(jax.vmap(jax.grad(loss, argnums=(0, 1, 2, 3, 4))))(
        x, x1, y1, x2, y2)
    want = jax.vmap(jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4)))(
        x, x1, y1, x2, y2)
    for g, w, nm in zip(got, want, ("dx", "dx1", "dy1", "dx2", "dy2")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=5e-4, rtol=5e-4,
                                   err_msg=f"{kind} vmap {nm}")


@pytest.mark.parametrize("kind", KINDS)
def test_stacked_client_batched_grids(kind):
    """Direct (C, ...) stacked calls select the explicit batched grids,
    forward and backward, and match the per-client loop."""
    C, B, m, n, r = 2, 11, 40, 56, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (C, B, m), jnp.float32)
    x1, y1, x2, y2 = [jax.random.normal(k, (C, d, r), jnp.float32) * 0.2
                      for k, d in zip(ks[1:], (m, n, m, n))]
    kw = dict(kind=kind, interpret=True, block_b=16, block_m=32, block_n=32)

    y = ops.fedpara_matmul(x, x1, y1, x2, y2, **kw)
    y_ref = jnp.stack([ops.fedpara_matmul_ref(x[c], x1[c], y1[c], x2[c],
                                              y2[c], kind=kind)
                       for c in range(C)])
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)

    got = jax.grad(lambda *a: jnp.sum(jnp.sin(
        ops.fedpara_matmul(*a, **kw))), argnums=(0, 1, 2, 3, 4))(
        x, x1, y1, x2, y2)
    want = jax.vmap(jax.grad(lambda *a: jnp.sum(jnp.sin(
        ops.fedpara_matmul_ref(*a, kind=kind))), argnums=(0, 1, 2, 3, 4)))(
        x, x1, y1, x2, y2)
    for g, w, nm in zip(got, want, ("dx", "dx1", "dy1", "dx2", "dy2")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=5e-4, rtol=5e-4,
                                   err_msg=f"{kind} stacked {nm}")


def test_layer_dense_pfedpara_pallas_path():
    """dense() no longer excludes kind='pfedpara' from the Pallas path,
    and its gradients match the materialize path."""
    from repro.configs.base import ParamCfg
    from repro.nn.layers import dense, init_dense

    key = jax.random.PRNGKey(0)
    pcfg = ParamCfg(kind="pfedpara", gamma=0.3, min_dim_for_factorization=8)
    sub = init_dense(key, 96, 160, pcfg)
    assert "x1" in sub
    x = jax.random.normal(key, (4, 7, 96), jnp.float32)

    y_ref = dense(sub, x, pcfg, jnp.float32, use_pallas=False)
    y_ker = dense(sub, x, pcfg, jnp.float32, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)

    def loss(sub, use_pallas):
        return jnp.sum(dense(sub, x, pcfg, jnp.float32,
                             use_pallas=use_pallas) ** 2)

    g_ker = jax.grad(loss)(sub, True)
    g_ref = jax.grad(loss)(sub, False)
    for k in sub:
        np.testing.assert_allclose(np.asarray(g_ker[k]), np.asarray(g_ref[k]),
                                   atol=2e-3, rtol=2e-3, err_msg=k)


def test_paramcfg_use_pallas_threads_through_models():
    """ParamCfg(use_pallas=True) flips the MLP loss/grads onto the fused
    kernels with identical numerics."""
    from dataclasses import replace

    from repro.configs.base import ParamCfg
    from repro.nn import recurrent as rec

    cfg = rec.MLPConfig(in_dim=64, hidden=48, classes=10,
                        param=ParamCfg(kind="fedpara", gamma=0.5,
                                       min_dim_for_factorization=8))
    cfg_pl = replace(cfg, param=replace(cfg.param, use_pallas=True))
    params = rec.init_mlp_model(jax.random.PRNGKey(3), cfg)
    batch = {"x": jax.random.normal(jax.random.PRNGKey(4), (16, 64)),
             "y": jax.random.randint(jax.random.PRNGKey(5), (16,), 0, 10)}

    l_ref, g_ref = jax.value_and_grad(rec.mlp_loss)(params, cfg, batch)
    l_ker, g_ker = jax.value_and_grad(rec.mlp_loss)(params, cfg_pl, batch)
    np.testing.assert_allclose(float(l_ker), float(l_ref), atol=1e-4, rtol=1e-4)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-3),
        g_ker, g_ref)


def test_block_table_shared_fwd_bwd():
    """select_blocks returns sane tiles across the (m, n, r) regimes and
    is what both forward and backward default to."""
    for (m, n, r) in [(64, 64, 4), (256, 512, 16), (4096, 4096, 64),
                      (16384, 53248, 128)]:
        bb, bm, bn = ops.select_blocks(m, n, r)
        assert bb > 0 and bm % 128 == 0 and bn % 128 == 0, (m, n, r)
    # large layers get wider n tiles than small ones
    assert ops.select_blocks(16384, 53248, 128)[2] >= \
        ops.select_blocks(64, 64, 4)[2]
