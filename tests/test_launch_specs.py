"""Launch-layer unit tests that don't need a big mesh: input specs,
partition rules, period extrapolation config math."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, SHAPES, get_arch
from repro.distributed.sharding import AxisRules, param_spec, tree_param_specs
from repro.launch import specs as specs_mod
from repro.launch.dryrun import arch_period, with_periods
from repro.nn.transformer import ModelOptions, build_model


def test_batch_specs_all_cells_defined():
    for arch in ASSIGNED:
        cfg = get_arch(arch)
        for sname, shape in SHAPES.items():
            bs = specs_mod.batch_specs(cfg, shape)
            if shape.kind == "train":
                assert bs["tokens"].shape == (shape.global_batch,
                                              shape.seq_len + 1)
            elif shape.kind == "prefill":
                assert bs["tokens"].shape == (shape.global_batch, shape.seq_len)
            else:
                assert bs["token"].shape == (shape.global_batch, 1)
            if cfg.is_encdec and shape.kind != "decode":
                assert bs["frames"].shape[1] == cfg.encoder_seq


def test_param_rules_paths():
    rules = AxisRules(None)  # no mesh: divisibility check passes axes thru? -> None
    # with no mesh all sizes are 1 -> spec falls back to None everywhere,
    # so test the PATH matching with a fake mesh via direct rule table
    from repro.distributed.sharding import _param_rules

    table = _param_rules()

    def logical_for(path):
        for rx, axes in table:
            if rx.search(path):
                return axes
        return None

    assert logical_for("layers/attn/wq/x1") == ("fsdp2", None)
    assert logical_for("layers/attn/wq/y2") == ("tp2", None)
    assert logical_for("layers/attn/wo/x1") == ("tp2", None)
    assert logical_for("layers/mlp/w_down/y1") == ("fsdp2", None)
    assert logical_for("layers/moe/experts/w_gate/x1") == ("experts", "fsdp2", None)
    assert logical_for("embed/w") == ("embed_vocab", "tp")
    assert logical_for("unembed/w") == ("embed", "vocab")
    assert logical_for("layers/attn/wq/w") == ("fsdp", "tp")
    assert logical_for("layers/attn/wq/w_q") == ("fsdp", "tp")
    assert logical_for("final_norm/scale") is None


def test_period_config_math():
    for arch, period in [("llama3-405b", 1), ("gemma3-12b", 6),
                         ("zamba2-2.7b", 6), ("xlstm-125m", 4),
                         ("whisper-small", 1)]:
        cfg = get_arch(arch)
        assert arch_period(cfg) == period
        assert cfg.n_layers % period == 0
        c2 = with_periods(cfg, 2)
        assert c2.n_layers == 2 * period
        if cfg.encoder_layers:
            assert c2.encoder_layers == 2


def test_long500k_gate_matches_design():
    runs = {a for a in ASSIGNED if get_arch(a).subquadratic}
    assert runs == {"mixtral-8x22b", "gemma3-12b", "zamba2-2.7b", "xlstm-125m"}


def test_cache_specs_structure():
    cfg = get_arch("qwen3-8b").reduced()
    model = build_model(cfg, ModelOptions())
    cache = jax.eval_shape(lambda: model.init_cache(4, 64))
    rules = AxisRules(None)
    specs = specs_mod.cache_partition_specs(cfg, cache, rules)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)
    assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P)) \
        == jax.tree.structure(cache)
