"""Serve Pallas kernel validation (interpret mode executes the kernel
bodies on CPU): the int8/fp16 weight-cache matmul, the pFedPara
cache+residual kernel (single- and many-user), and the Hadamard-Gram
decode identity — each against its dense oracle, aligned and
non-aligned shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops


def _mats(key, B, m, n, r, dtype):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, m), dtype)
    f = [jax.random.normal(k, (d, r), jnp.float32) * 0.2
         for k, d in zip(ks[1:], (m, n, m, n))]
    return x, f


SERVE_SHAPES = [
    (8, 64, 64, 4),
    (17, 100, 50, 3),      # non-aligned everything
    (1, 384, 128, 32),     # single decode row
    (33, 128, 300, 7),
]


def _quant(w):
    from repro.nn.layers import quantize_int8

    node = quantize_int8(w)
    return node["w_q"], node["scale"]


@pytest.mark.parametrize("B,m,n,r", SERVE_SHAPES)
@pytest.mark.parametrize("quant", [True, False])
def test_w8_matmul_sweep(B, m, n, r, quant):
    from repro.kernels import ref

    key = jax.random.PRNGKey(B + m + n)
    x, (x1, y1, x2, y2) = _mats(key, B, m, n, r, jnp.float32)
    w = ops.fedpara_compose_ref(x1, y1, x2, y2, out_dtype=jnp.float32)
    if quant:
        wq, scale = _quant(w)
    else:
        wq, scale = w.astype(jnp.float16), None
    got = ops.w8_matmul(x, wq, scale, interpret=True,
                        out_dtype=jnp.float32)
    want = ref.w8_matmul_ref(x, wq, scale, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,m,n,r", SERVE_SHAPES)
@pytest.mark.parametrize("quant", [True, False])
def test_cache_residual_single_user(B, m, n, r, quant):
    from repro.kernels import ref

    key = jax.random.PRNGKey(7 * B + m)
    x, (x1, y1, x2, y2) = _mats(key, B, m, n, r, jnp.float32)
    w1 = jnp.einsum("mr,nr->mn", x1, y1)
    if quant:
        wq, scale = _quant(w1)
    else:
        wq, scale = w1.astype(jnp.float16), None
    got = ops.cache_residual_matmul(x, wq, scale, x2, y2, interpret=True,
                                    out_dtype=jnp.float32)
    want = ref.cache_residual_ref(x, wq, scale, x2, y2,
                                  out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("U,t", [(1, 1), (3, 2), (5, 1)])
def test_cache_residual_many_user_vs_merge_oracle(U, t):
    """The many-user kernel vs the TRUE oracle: merge each user's
    pFedPara factors into a dense W_u = W1 ⊙ (X2ᵤY2ᵤᵀ + 1) and
    contract — the per-user weight the kernel never materializes."""
    m, n, r = 96, 130, 5
    key = jax.random.PRNGKey(U * 10 + t)
    ks = jax.random.split(key, 5)
    x1 = jax.random.normal(ks[0], (m, r), jnp.float32) * 0.2
    y1 = jax.random.normal(ks[1], (n, r), jnp.float32) * 0.2
    ux2 = jax.random.normal(ks[2], (U, m, r), jnp.float32) * 0.2
    uy2 = jax.random.normal(ks[3], (U, n, r), jnp.float32) * 0.2
    x = jax.random.normal(ks[4], (U, t, m), jnp.float32)
    w1 = jnp.einsum("mr,nr->mn", x1, y1)
    got = ops.cache_residual_matmul(x, w1.astype(jnp.float16), None,
                                    ux2, uy2, interpret=True,
                                    out_dtype=jnp.float32)
    for u in range(U):
        wu = w1.astype(jnp.float16).astype(jnp.float32) * (
            ux2[u] @ uy2[u].T + 1.0)
        want_u = x[u] @ wu
        np.testing.assert_allclose(np.asarray(got[u]), np.asarray(want_u),
                                   atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,m,n,r", SERVE_SHAPES)
@pytest.mark.parametrize("kind", ["fedpara", "pfedpara"])
def test_gram_decode_matches_dense(B, m, n, r, kind):
    """The Hadamard-Gram decode identity vs compose-then-dense."""
    key = jax.random.PRNGKey(B + n + r)
    x, (x1, y1, x2, y2) = _mats(key, B, m, n, r, jnp.float32)
    got = ops.fedpara_gram_decode(x, x1, y1, x2, y2, kind=kind,
                                  out_dtype=jnp.float32)
    w = ops.fedpara_compose_ref(x1, y1, x2, y2, kind=kind,
                                out_dtype=jnp.float32)
    want = x @ w
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_gram_decode_many_user_per_user_weights():
    U, t, m, n, r = 4, 2, 64, 96, 6
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x1 = jax.random.normal(ks[0], (m, r), jnp.float32) * 0.2
    y1 = jax.random.normal(ks[1], (n, r), jnp.float32) * 0.2
    ux2 = jax.random.normal(ks[2], (U, m, r), jnp.float32) * 0.2
    uy2 = jax.random.normal(ks[3], (U, n, r), jnp.float32) * 0.2
    x = jax.random.normal(ks[4], (U, t, m), jnp.float32)
    got = ops.fedpara_gram_decode(x, x1, y1, ux2, uy2, kind="pfedpara",
                                  out_dtype=jnp.float32)
    for u in range(U):
        w = ops.fedpara_compose_ref(x1, y1, ux2[u], uy2[u],
                                    kind="pfedpara", out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got[u]),
                                   np.asarray(x[u] @ w),
                                   atol=2e-4, rtol=2e-4)


def test_gram_decode_rejects_tanh():
    x, (x1, y1, x2, y2) = _mats(jax.random.PRNGKey(0), 2, 16, 16, 2,
                                jnp.float32)
    with pytest.raises(ValueError):
        ops.fedpara_gram_decode(x, x1, y1, x2, y2, kind="fedpara_tanh")
