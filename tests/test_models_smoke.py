"""Per-architecture smoke tests (deliverable f): reduced config of the
same family, one forward/train step on CPU, asserting output shapes and
finiteness — plus decode-vs-prefill consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch
from repro.nn.transformer import ModelOptions, build_model
from repro.optim import adamw, apply_updates

OPTS = ModelOptions(attn_chunk=8, ssm_chunk=8, logit_chunk=16, dtype=jnp.float32)


def _batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, OPTS)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) < 2.0 * np.log(cfg.vocab_size)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), arch
    opt = adamw(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    new_params = apply_updates(params, updates)
    loss2 = model.loss(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_prefill(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, OPTS)
    key = jax.random.PRNGKey(1)
    params = model.init_params(key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    cache = model.init_cache(B, 64)
    if cfg.is_encdec:
        frames = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
        cache, logits_pre = model.prefill(params, {"frames": frames,
                                                   "tokens": tokens}, cache)
    else:
        cache, logits_pre = model.prefill(params, tokens, cache)
    assert logits_pre.shape == (B, cfg.vocab_size)
    nxt = jnp.argmax(logits_pre, -1)[:, None]
    logits_dec, cache = model.decode_step(params, cache, nxt, jnp.int32(S))
    assert logits_dec.shape == (B, cfg.vocab_size)

    tokens2 = jnp.concatenate([tokens, nxt], 1)
    cache2 = model.init_cache(B, 64)
    if cfg.is_encdec:
        _, logits_ref = model.prefill(params, {"frames": frames,
                                               "tokens": tokens2}, cache2)
    else:
        _, logits_ref = model.prefill(params, tokens2, cache2)
    scale = float(jnp.abs(logits_ref).max()) + 1e-9
    assert float(jnp.abs(logits_dec - logits_ref).max()) / scale < 2e-3, arch


@pytest.mark.parametrize("arch", ["qwen3-8b", "mixtral-8x22b", "zamba2-2.7b",
                                  "xlstm-125m"])
def test_precompose_equivalence(arch):
    """Serving with pre-composed dense weights == serving with factors."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, OPTS)
    key = jax.random.PRNGKey(2)
    params = model.init_params(key)
    composed = model.precompose(params)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    c1, l1 = model.prefill(params, tokens, model.init_cache(2, 32))
    c2, l2 = model.prefill(composed, tokens, model.init_cache(2, 32))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3,
                               rtol=2e-3)


def test_gemma_local_global_pattern():
    cfg = get_arch("gemma3-12b").reduced()
    model = build_model(cfg, OPTS)
    w = np.asarray(model.layer_windows(0))
    assert (w == 0).sum() == cfg.n_layers // cfg.local_global_period
    assert all(x in (0, cfg.local_window) for x in w)


def test_sliding_window_cache_is_ring():
    """mixtral: decode cache allocates window slots, not the full seq."""
    cfg = get_arch("mixtral-8x22b").reduced()
    model = build_model(cfg, OPTS)
    cache = model.init_cache(2, 4096)
    assert cache["k"].shape[2] == cfg.sliding_window


def test_scan_vs_unrolled_equivalence():
    """scan_layers=False (dry-run cost variants) must compute the same
    function as the scanned model."""
    cfg = get_arch("qwen3-8b").reduced()
    key = jax.random.PRNGKey(3)
    m_scan = build_model(cfg, OPTS)
    m_unroll = build_model(cfg, ModelOptions(attn_chunk=8, ssm_chunk=8,
                                             logit_chunk=16, dtype=jnp.float32,
                                             scan_layers=False))
    params = m_scan.init_params(key)
    batch = _batch(cfg, key)
    l1 = m_scan.loss(params, batch)
    l2 = m_unroll.loss(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_int8_kv_cache_decode_close():
    """§Perf B2: int8 KV cache decode stays within ~2% of the bf16 cache."""
    cfg = get_arch("qwen3-8b").reduced()
    m8 = build_model(cfg, ModelOptions(attn_chunk=8, ssm_chunk=8,
                                       logit_chunk=16, dtype=jnp.float32,
                                       int8_kv=True))
    m = build_model(cfg, OPTS)
    key = jax.random.PRNGKey(4)
    params = m.init_params(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    c8, l8 = m8.prefill(params, tokens, m8.init_cache(2, 64))
    c, l = m.prefill(params, tokens, m.init_cache(2, 64))
    assert c8["k_q"].dtype == jnp.int8
    nxt = jnp.argmax(l8, -1)[:, None]
    d8, _ = m8.decode_step(params, c8, nxt, jnp.int32(16))
    d, _ = m.decode_step(params, c, nxt, jnp.int32(16))
    rel = float(jnp.abs(d8 - d).max() / (jnp.abs(d).max() + 1e-9))
    assert rel < 0.05, rel
