"""fedlint layer 1: rule engine, baseline, CLI, and the repo itself.

The seeded-violation fixture is the negative control the acceptance
criteria ask for: a tiny fake repo whose one module violates FED001-006
and whose docs contain a dead link — ``--check`` must exit non-zero on
it, and exit zero on this repository.
"""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.lint import (check_doc_links, load_baseline, run_lint,
                                 write_baseline)
from repro.analysis.lint.__main__ import main as lint_main
from repro.analysis.lint.rules import RULES, Project

REPO = Path(__file__).resolve().parents[1]

# One violation per AST rule; parses cleanly, never executed.
_BAD_SRC = '''\
import functools
import jax
import numpy as np


@jax.jit
def bad_step(x):
    noise = np.random.normal(size=3)
    scale = float(x)
    host = np.asarray(x)
    return x * scale + noise.sum() + host.sum()


@functools.partial(jax.jit, static_argnames=("missing",))
def bad_static(x, flag):
    return x


step = jax.jit(lambda a, b: (a + b, b), donate_argnums=(0,))


def loop(a, b):
    out, b = step(a, b)
    return out + a


def run_cb(x):
    return jax.pure_callback(lambda v: v, x, x)


def build(keys):
    return {k: 0 for k in set(keys)}
'''


@pytest.fixture
def violation_repo(tmp_path):
    pkg = tmp_path / "src" / "pkg"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "bad.py").write_text(_BAD_SRC)
    docs = tmp_path / "docs"
    docs.mkdir()
    (docs / "guide.md").write_text(
        "See [the missing page](nonexistent.md) and "
        "[the web](https://example.com).\n")
    return tmp_path


# ------------------------------------------------------------ rule engine

def test_fixture_trips_every_ast_rule(violation_repo):
    result = run_lint(repo_root=violation_repo)
    hit = {f.rule for f in result.findings}
    assert hit == {"FED001", "FED002", "FED003", "FED004", "FED005",
                   "FED006"}, sorted(f.render() for f in result.findings)
    assert not result.ok


def test_fed002_counts_each_sync_site(violation_repo):
    result = run_lint(repo_root=violation_repo, select={"FED002"})
    # float(x) and np.asarray(x) are separate findings
    assert len(result.findings) == 2


def test_fed004_names_the_donated_argument(violation_repo):
    result = run_lint(repo_root=violation_repo, select={"FED004"})
    (f,) = result.findings
    assert "`a`" in f.message and "position 0" in f.message


def test_doc_link_rule(violation_repo):
    findings = check_doc_links(
        [violation_repo / "docs" / "guide.md"], violation_repo)
    assert [f.rule for f in findings] == ["FED007"]
    assert "nonexistent.md" in findings[0].message


def test_rebind_on_call_line_kills_fed004_taint(tmp_path):
    # `x, mu = step(x, mu)` is the donation-safe idiom every engine uses:
    # the store on the call's own line rebinds the name to the NEW output.
    pkg = tmp_path / "src" / "m"
    pkg.mkdir(parents=True)
    (pkg / "ok.py").write_text(
        "import jax\n"
        "step = jax.jit(lambda a, b: (a + b, b), donate_argnums=(0,))\n"
        "def loop(a, b):\n"
        "    a, b = step(a, b)\n"
        "    return a + b\n")
    result = run_lint(repo_root=tmp_path, select={"FED004"})
    assert result.ok, [f.render() for f in result.findings]


def test_traced_propagation_is_cross_module(tmp_path):
    # helper() is only traced because a jitted body in another module
    # imports and calls it — the project-wide call graph must see that.
    pkg = tmp_path / "src" / "p"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "helper.py").write_text(
        "import numpy as np\n"
        "def helper(x):\n"
        "    return x + np.random.uniform()\n")
    (pkg / "engine.py").write_text(
        "import jax\n"
        "from p.helper import helper\n"
        "@jax.jit\n"
        "def round_program(x):\n"
        "    return helper(x)\n")
    result = run_lint(repo_root=tmp_path, select={"FED001"})
    assert [f.symbol for f in result.findings] == ["helper"]


def test_host_callback_callee_is_exempt(tmp_path):
    # A pure_callback callee runs host-side: host RNG there is fine.
    pkg = tmp_path / "src" / "p"
    pkg.mkdir(parents=True)
    (pkg / "m.py").write_text(
        "import jax\n"
        "import numpy as np\n"
        "def fetch(i):\n"
        "    return np.random.normal(size=3)\n"
        "@jax.jit\n"
        "def prog(i, spec):\n"
        "    return jax.pure_callback(fetch, spec, i)\n")
    result = run_lint(repo_root=tmp_path, select={"FED001"})
    assert result.ok, [f.render() for f in result.findings]


# -------------------------------------------------- suppression mechanisms

def test_inline_disable_suppresses(tmp_path):
    pkg = tmp_path / "src" / "m"
    pkg.mkdir(parents=True)
    (pkg / "a.py").write_text(
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return float(x)  # fedlint: disable=FED002\n")
    result = run_lint(repo_root=tmp_path)
    assert result.ok and len(result.suppressed) == 1


def test_baseline_roundtrip_and_staleness(violation_repo):
    live = run_lint(repo_root=violation_repo)
    bl = violation_repo / "fedlint_baseline.json"
    write_baseline(bl, live.findings)
    assert len(load_baseline(bl)) == len({f.key for f in live.findings})

    again = run_lint(repo_root=violation_repo)
    assert again.ok and len(again.suppressed) == len(live.findings)
    assert again.stale_baseline == []

    # remove the offending module: every entry must be reported stale
    (violation_repo / "src" / "pkg" / "bad.py").write_text("x = 1\n")
    stale = run_lint(repo_root=violation_repo)
    assert stale.ok and len(stale.stale_baseline) == len(live.findings)


def test_baseline_key_survives_line_shift(violation_repo):
    live = run_lint(repo_root=violation_repo)
    write_baseline(violation_repo / "fedlint_baseline.json", live.findings)
    bad = violation_repo / "src" / "pkg" / "bad.py"
    bad.write_text("# a new leading comment shifts every line\n"
                   + bad.read_text())
    shifted = run_lint(repo_root=violation_repo)
    assert shifted.ok, [f.render() for f in shifted.findings]


# ------------------------------------------------------------------- CLI

def test_cli_check_fails_on_fixture(violation_repo):
    rc = lint_main(["--check", "-q", "--repo-root", str(violation_repo)])
    assert rc == 1


def test_cli_check_includes_fixture_docs(violation_repo):
    rc = lint_main(["--check", "-q", "--docs-only",
                    "--repo-root", str(violation_repo)])
    assert rc == 1


def test_cli_rejects_unknown_rule(violation_repo, capsys):
    rc = lint_main(["--select", "FED999",
                    "--repo-root", str(violation_repo)])
    assert rc == 2


def test_cli_write_baseline_then_check_passes(violation_repo):
    assert lint_main(["--write-baseline", "-q",
                      "--repo-root", str(violation_repo)]) == 0
    assert lint_main(["--check", "-q",
                      "--repo-root", str(violation_repo)]) == 0


def test_cli_check_passes_on_this_repo():
    # Acceptance criterion: the shipped source tree is clean under its
    # committed baseline. Run as a real subprocess = the CI lint job.
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--check", "--docs"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_repo_baseline_has_no_stale_entries():
    result = run_lint(repo_root=REPO, include_docs=True)
    assert result.ok, [f.render() for f in result.findings]
    assert result.stale_baseline == []
    # every committed suppression carries a real justification
    for just in load_baseline(REPO / "fedlint_baseline.json").values():
        assert just and "TODO" not in just


def test_rule_catalog_is_documented():
    catalog = (REPO / "docs" / "analysis.md").read_text()
    for rule in RULES:
        assert rule in catalog, f"{rule} missing from docs/analysis.md"


def test_project_reports_parse_errors(tmp_path):
    pkg = tmp_path / "src" / "m"
    pkg.mkdir(parents=True)
    (pkg / "broken.py").write_text("def f(:\n")
    proj = Project([pkg / "broken.py"], tmp_path)
    assert any(f.rule == "PARSE" for f in proj.run())
