"""Documentation health checks (the fast-CI ``docs`` job).

* Every public symbol exported by ``repro.fl``, ``repro.kernels.ops``
  and ``repro.core`` carries a non-empty docstring (classes checked
  with their public methods).
* Every fenced ```python`` block in ``docs/*.md`` and ``README.md``
  compiles (``compile()`` smoke — syntax rot fails CI, execution is
  not attempted).
* Every relative markdown link in ``docs/*.md`` and ``README.md``
  points at a file that exists (dead links fail the job).
"""
import inspect
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = sorted(REPO.glob("docs/*.md")) + [REPO / "README.md"]

PUBLIC_MODULES = ("repro.fl", "repro.kernels.ops", "repro.core")


def _public_symbols(mod):
    names = getattr(mod, "__all__", None)
    if names is None:
        names = [n for n in dir(mod) if not n.startswith("_")]
    for n in names:
        obj = getattr(mod, n)
        if inspect.ismodule(obj):
            # re-exported submodules of this package count; foreign
            # modules (jax, numpy) leaking through dir() do not
            if obj.__name__.startswith("repro"):
                yield f"{mod.__name__}.{n}", obj
            continue
        if inspect.isfunction(obj) or inspect.isclass(obj):
            yield f"{mod.__name__}.{n}", obj
            if inspect.isclass(obj):
                for mn, m in inspect.getmembers(obj, inspect.isfunction):
                    if not mn.startswith("_"):
                        yield f"{mod.__name__}.{n}.{mn}", m


@pytest.mark.parametrize("modname", PUBLIC_MODULES)
def test_public_api_has_docstrings(modname):
    mod = __import__(modname, fromlist=["_"])
    assert inspect.getdoc(mod), f"{modname} has no module docstring"
    missing = [name for name, obj in _public_symbols(mod)
               if not (inspect.getdoc(obj) or "").strip()]
    assert not missing, f"public symbols without docstrings: {missing}"


def _python_blocks(text):
    return re.findall(r"```python\n(.*?)```", text, flags=re.S)


def test_docs_exist():
    for required in ("architecture.md", "engines.md", "codecs.md",
                     "kernels.md", "benchmarks.md", "hetero.md"):
        assert (REPO / "docs" / required).is_file(), f"docs/{required} missing"


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_code_blocks_compile(path):
    for i, block in enumerate(_python_blocks(path.read_text())):
        try:
            compile(block, f"{path.name}:block{i}", "exec")
        except SyntaxError as e:
            raise AssertionError(
                f"{path} python block #{i} does not compile: {e}\n{block}"
            ) from e


_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_relative_links_resolve(path):
    dead = []
    for target in _LINK.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if not rel:
            continue
        if not (path.parent / rel).resolve().exists():
            dead.append(target)
    assert not dead, f"{path}: dead relative links {dead}"
