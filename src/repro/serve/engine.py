"""The serve engine: checkpoint -> per-layer plan -> jitted decode.

``ServeEngine`` glues the serving stack together:

1. **Load** — ``load_fl_checkpoint`` rebuilds the FL server's trees
   from a :class:`CheckpointManager` step without a target structure
   (``unflatten_paths``): the trained ``global_params`` plus, for
   pFedPara runs, every client's personal ``local_trees/<cid>`` half.
2. **Plan** — ``cost_model.plan_params`` walks the factor nodes and
   decides precompose-vs-fused per layer (measured or analytic roofline;
   ``mode`` forces either branch). The table is queryable
   (:meth:`decision_table`) and shipped with benchmark artifacts.
3. **Cache** — ``cache.build_serve_params`` rewrites the tree per the
   plan (int8/fp16 composed caches, verbatim factors, shared pFedPara
   W1 cache). Per-user factors stack into a :class:`UserArena`.
4. **Serve** — one jitted prefill and one jitted decode step. Position
   AND user-row indices are traced arguments, so decoding 16 steps over
   rotating user cohorts compiles exactly once; the KV cache is donated
   so decode updates it in place.

Many-user decode: ``decode_step(..., user_ids=[...])`` gathers the
cohort's (X2, Y2) rows from the arena with one ``jnp.take`` and injects
them as ``ux2/uy2`` (``inject_users``), which
``repro.nn.layers.dense`` routes into the fused cache+residual kernel
or the per-user Gram path — B distinct users served in one launch with
zero per-user W materialization.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, unflatten_paths
from repro.configs.base import ArchConfig
from repro.nn.transformer import ModelOptions, build_model
from repro.serve import cost_model
from repro.serve.cache import build_serve_params, serve_state_bytes
from repro.serve.user_arena import UserArena, inject_users


def load_fl_checkpoint(path: str, step: Optional[int] = None
                       ) -> Tuple[Any, Dict[int, Any], Dict, int]:
    """Restore an FL training checkpoint for serving.

    Returns ``(global_params, local_trees, extra, step)``:
    ``global_params`` is the trained model (pFedPara: the global half
    only), ``local_trees`` maps client id -> personal factor tree
    (empty for non-personalized runs). Client ids are discovered from
    the checkpoint's paths — no target structure needed.
    """
    mgr = CheckpointManager(path)
    by_path, extra, step = mgr.restore_items(step)
    global_params = unflatten_paths(by_path, prefix="global_params")
    if global_params is None or global_params == {}:
        raise ValueError(f"checkpoint at {path} has no global_params")
    cids = sorted({p.split("/")[1] for p in by_path
                   if p.startswith("local_trees/")}, key=int)
    local_trees = {
        int(c): unflatten_paths(by_path, prefix=f"local_trees/{c}")
        for c in cids}
    to_dev = lambda t: jax.tree.map(jnp.asarray, t)  # noqa: E731
    return to_dev(global_params), to_dev(local_trees), extra, step


class ServeEngine:
    """Decode engine over a planned serve-params tree (module docstring).

    Args:
        cfg: the architecture the checkpoint was trained with.
        global_params: trained global tree (factor nodes intact).
        local_trees: optional ``{uid: personal_tree}`` (pFedPara).
        mode: ``precompose`` | ``fused`` | ``auto`` — per-layer layout
            (auto ranks by measured µs when ``measure`` else roofline).
        cache_dtype: ``int8`` | ``fp16`` precomposed-cache precision.
        batch: decode batch the plan optimizes for (and the cohort
            width when users are resident).
        use_pallas: route matmuls through the serve Pallas kernels
            (default: only on TPU — interpret emulation elsewhere is
            orders slower; the XLA fallbacks are numerically identical).
        measure: time both branches per distinct (m, n, r) for ``auto``.
        opts: ModelOptions overrides (dtype, chunks, scan_layers...).
    """

    def __init__(self, cfg: ArchConfig, global_params: Any,
                 local_trees: Optional[Dict[Any, Any]] = None, *,
                 mode: str = "auto", cache_dtype: str = "int8",
                 batch: int = 1, use_pallas: Optional[bool] = None,
                 measure: bool = False,
                 opts: Optional[ModelOptions] = None):
        if mode not in ("precompose", "fused", "auto"):
            raise ValueError(f"mode must be precompose|fused|auto, got {mode}")
        kind = cfg.param.kind
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.mode = mode
        self.cache_dtype = cache_dtype
        self.batch = int(batch)
        self.arena = UserArena.create(local_trees) if local_trees else None
        if kind == "pfedpara" and self.arena is not None:
            # the checkpoint's global_params carries the SERVER's own
            # x2/y2 copy (merge_pfedpara keeps the tree whole between
            # rounds) — personalized serving replaces it per user, so
            # the serve tree starts from the global half only
            from repro.fl import comm

            global_params = comm.split_pfedpara(global_params)[0]

        self.plan = cost_model.plan_params(
            global_params, kind, batch=self.batch, mode=mode,
            weight_dtype=cache_dtype,
            users=self.arena.n_users if self.arena else 0, measure=measure)
        self.serve_params = jax.jit(
            lambda p: build_serve_params(p, kind, self.plan, cache_dtype)
        )(global_params)

        # gram_batch: decode rows route fused layers through the Gram
        # identity whenever the plan picked it (per-batch, so the knob
        # equals the planned batch; prefill's larger row counts still
        # take the tile path)
        gram = any(d.mode == "fused" and d.impl == "gram"
                   for d in self.plan.values())
        cfg = dataclasses.replace(
            cfg, param=dataclasses.replace(
                cfg.param, gram_batch=self.batch if gram else 0))
        self.cfg = cfg
        base = opts or ModelOptions(attn_chunk=64, ssm_chunk=32,
                                    logit_chunk=64)
        self.opts = dataclasses.replace(base, use_pallas=use_pallas)
        self.model = build_model(cfg, self.opts)

        model = self.model

        def _with_users(sp, arena_tree, rows):
            if arena_tree is None:
                return sp
            gathered = jax.tree.map(lambda a: jnp.take(a, rows, axis=0),
                                    arena_tree)
            return inject_users(sp, gathered)

        def _prefill(sp, arena_tree, cache, tokens, rows):
            return model.prefill(_with_users(sp, arena_tree, rows),
                                 tokens, cache)

        def _decode(sp, arena_tree, cache, token, pos, rows):
            return model.decode_step(_with_users(sp, arena_tree, rows),
                                     cache, token, pos)

        self._jit_prefill = jax.jit(_prefill)
        self._jit_decode = jax.jit(_decode, donate_argnums=(2,))

    # ------------------------------------------------------------- loading
    @classmethod
    def from_checkpoint(cls, path: str, cfg: ArchConfig, *,
                        step: Optional[int] = None, **kw) -> "ServeEngine":
        """Build an engine straight from an FL training checkpoint
        directory (keyword args forwarded to the constructor)."""
        global_params, local_trees, _extra, _step = load_fl_checkpoint(
            path, step)
        return cls(cfg, global_params, local_trees or None, **kw)

    # -------------------------------------------------------------- compute
    def _rows(self, user_ids: Optional[Sequence[Any]], batch: int):
        if self.arena is None:
            return None if user_ids is None else None
        if user_ids is None:
            user_ids = [self.arena.uids[0]] * batch
        return self.arena.rows_for(user_ids)

    def init_cache(self, batch: int, max_seq: int):
        return self.model.init_cache(batch, max_seq)

    def prefill(self, tokens, cache, user_ids: Optional[Sequence] = None):
        """Run the prompt through the model; returns (cache, logits)."""
        rows = self._rows(user_ids, jnp.shape(tokens)[0])
        return self._jit_prefill(
            self.serve_params, self.arena.tree if self.arena else None,
            cache, tokens, rows)

    def decode_step(self, cache, token, pos,
                    user_ids: Optional[Sequence] = None):
        """One decode step. ``pos`` and the cohort's user rows are
        traced — steps and cohorts reuse one compilation; the cache is
        donated and updated in place. Returns (logits, cache)."""
        rows = self._rows(user_ids, jnp.shape(token)[0])
        return self._jit_decode(
            self.serve_params, self.arena.tree if self.arena else None,
            cache, token, jnp.int32(pos), rows)

    def generate(self, prompts, gen_len: int,
                 user_ids: Optional[Sequence] = None) -> np.ndarray:
        """Greedy-decode ``gen_len`` tokens after prefilling
        ``prompts`` (B, S); returns (B, gen_len) token ids."""
        tokens = jnp.asarray(prompts)
        B, S = tokens.shape
        cache = self.init_cache(B, S + gen_len)
        cache, logits = self.prefill(tokens, cache, user_ids)
        out: List[np.ndarray] = []
        tok = jnp.argmax(logits, -1)[:, None]
        for i in range(gen_len):
            out.append(np.asarray(tok)[:, 0])
            logits, cache = self.decode_step(cache, tok, S + i, user_ids)
            tok = jnp.argmax(logits, -1)[:, None]
        return np.stack(out, 1)

    # ------------------------------------------------------------ accounting
    def decision_table(self) -> List[Dict[str, Any]]:
        """Per-layer decision rows (path, dims, mode, impl, predicted /
        measured µs, analytic crossover batch)."""
        return cost_model.decision_table(self.plan)

    def state_bytes(self) -> int:
        """Device bytes of the shared serve weights (excludes the
        per-user factor arena — see :meth:`arena_bytes`)."""
        return serve_state_bytes(self.serve_params)

    def arena_bytes(self) -> int:
        """Device bytes of the stacked per-user factors (grows linearly
        in residents at 2r(m+n) floats per layer per user; the shared
        half stays flat — the many-user memory claim)."""
        return self.arena.nbytes() if self.arena else 0
