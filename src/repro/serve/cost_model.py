"""Per-layer precompose-vs-fused decision model for the serve engine.

For each factorized layer (m, n, r) at a given decode batch B, two
weight layouts compete:

precompose
    W composed once at load time and cached — fp16 (2mn bytes/step) or
    int8 with per-channel scales (mn bytes/step, the serve w8 kernel).
    Step FLOPs are the dense 2Bmn.

fused
    Only factors live in HBM. Two implementations: the tile kernel
    (compose (bm, bn) tiles in VMEM; ~4mnr compose FLOPs per bb-slab of
    rows) and the Hadamard-Gram identity (O(r²(m+n)) FLOPs per token, no
    (m, n) object anywhere — see ``repro.kernels.serve_matmul``). The
    cost model picks the cheaper implementation per batch.

Costs are rooflines — time = max(bytes/BW, flops/peak) — keyed on
(m, n, r, batch), with optional direct measurement (jit, warm up, then
median-of-k timing of the exact op each mode runs). ``auto`` takes the
measured branch when measurements exist, the analytic one otherwise.
The resulting per-layer decisions are recorded as a table (serialized
into ``BENCH_serve.json`` and printed by ``launch/serve.py``).

pFedPara layers with resident users compare the shared-cache + residual
kernel ("precompose": one int8 W1 for every user, per-user factors
streamed through VMEM) against the fully-fused per-user Gram path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

# Roofline constants (TPU v5e: 819 GB/s HBM, ~197 bf16 TFLOP/s) — the
# analytic model ranks modes by max(bytes/BW, flops/peak); absolute
# numbers only matter relatively, so CPU runs still pick sane modes.
HBM_GBPS = 819.0
PEAK_TFLOPS = 197.0

MODES = ("precompose", "fused")


def predict_us(bytes_: float, flops: float, *, hbm_gbps: float = HBM_GBPS,
               peak_tflops: float = PEAK_TFLOPS) -> float:
    """Roofline latency (µs) for a step moving ``bytes_`` and doing
    ``flops``."""
    return max(bytes_ / (hbm_gbps * 1e3), flops / (peak_tflops * 1e6))


def mode_costs(m: int, n: int, r: int, batch: int, *, kind: str = "fedpara",
               weight_dtype: str = "int8", users: int = 0,
               block_b: int = 64) -> Dict[str, Dict[str, float]]:
    """{mode: {bytes, flops, impl}} for one layer at one decode batch.

    ``users`` > 0 marks a personalized pFedPara layer serving that many
    distinct users per step (batch rows are user rows).
    """
    act = 2.0 * batch * (m + n)  # bf16 activations in + out
    wbytes = m * n * (1 if weight_dtype == "int8" else 2) + 4 * n
    fbytes = 4.0 * 4 * r * (m + n)  # four fp32 factor panels
    out: Dict[str, Dict[str, float]] = {}
    if users > 0 and kind == "pfedpara":
        ufac = 2.0 * 4 * r * (m + n) * users  # gathered (X2, Y2) slices
        # cache+residual kernel: the shared W1 tile stream repeats per
        # user (outermost grid axis); residual compose ~2mnr per user.
        out["precompose"] = {
            "bytes": users * wbytes + ufac + act,
            "flops": users * (2.0 * m * n * (r + 1)) + 2.0 * batch * m * n,
            "impl": "cache_residual",
        }
        out["fused"] = {
            "bytes": fbytes + ufac + 8.0 * batch * r * (m + n),
            "flops": 2.0 * batch * (r * r + r) * (m + n),
            "impl": "gram",
        }
        return out
    out["precompose"] = {
        "bytes": wbytes + act,
        "flops": 2.0 * batch * m * n,
        "impl": "w8" if weight_dtype == "int8" else "einsum",
    }
    # fused: gram (when the variant allows it) vs tile kernel
    slabs = -(-batch // block_b)
    tile = {
        "bytes": fbytes * slabs + act,
        "flops": slabs * 4.0 * m * n * r + 2.0 * batch * m * n,
        "impl": "tile",
    }
    if kind == "fedpara_tanh":
        out["fused"] = tile
        return out
    gram = {
        # factors + (B, m, r)/(B, n, r) intermediates written and read
        "bytes": fbytes + 8.0 * batch * r * (m + n) + act,
        "flops": 2.0 * batch * r * r * (m + n)
        + (2.0 * batch * r * (m + n) if kind == "pfedpara" else 0.0),
        "impl": "gram",
    }
    out["fused"] = min((gram, tile), key=lambda c: predict_us(c["bytes"],
                                                              c["flops"]))
    return out


def crossover_batch(m: int, n: int, r: int, *, kind: str = "fedpara",
                    weight_dtype: str = "int8", max_batch: int = 4096) -> int:
    """Smallest batch where precompose's roofline beats fused (doubling
    scan; ``max_batch`` when fused wins everywhere)."""
    b = 1
    while b <= max_batch:
        c = mode_costs(m, n, r, b, kind=kind, weight_dtype=weight_dtype)
        if (predict_us(**_bf(c["precompose"]))
                < predict_us(**_bf(c["fused"]))):
            return b
        b *= 2
    return max_batch


def _bf(c):
    return {"bytes_": c["bytes"], "flops": c["flops"]}


# ------------------------------------------------------------- measurement

def _median_time_us(fn, args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))  # warm-up / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def measure_modes(m: int, n: int, r: int, batch: int, *,
                  kind: str = "fedpara", weight_dtype: str = "int8",
                  users: int = 0, dtype=jnp.bfloat16,
                  reps: int = 5) -> Dict[str, float]:
    """Measured µs per mode: jit + run the exact single-layer op each
    serving mode would execute, median of ``reps``."""
    from repro.kernels import ops
    from repro.nn.layers import quantize_int8

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    fac = [(jax.random.normal(ks[i], s) * 0.1).astype(jnp.float32)
           for i, s in zip(range(1, 5), ((m, r), (n, r), (m, r), (n, r)))]
    x1, y1, x2, y2 = fac
    costs = mode_costs(m, n, r, batch, kind=kind, weight_dtype=weight_dtype,
                       users=users)
    out: Dict[str, float] = {}

    if users > 0 and kind == "pfedpara":
        w1 = jnp.einsum("mr,nr->mn", x1, y1)
        node = quantize_int8(w1) if weight_dtype == "int8" else {
            "w": w1.astype(jnp.float16)}
        w = node.get("w_q", node.get("w"))
        s = node.get("scale")
        t = max(1, batch // users)
        xs = jax.random.normal(ks[5], (users, t, m)).astype(dtype)
        ux2 = jnp.broadcast_to(x2, (users, m, r)) + 0.0
        uy2 = jnp.broadcast_to(y2, (users, n, r)) + 0.0
        pre = jax.jit(lambda a, b, c: ops.cache_residual_matmul(
            a, w, s, b, c, out_dtype=dtype))
        out["precompose"] = _median_time_us(pre, (xs, ux2, uy2), reps)
        fus = jax.jit(lambda a, b, c: ops.fedpara_gram_decode(
            a, x1, y1, b, c, kind="pfedpara", out_dtype=dtype))
        out["fused"] = _median_time_us(fus, (xs, ux2, uy2), reps)
        return out

    xs = jax.random.normal(ks[5], (batch, m)).astype(dtype)
    wd = ops.fedpara_compose_ref(x1, y1, x2, y2, kind=kind,
                                 out_dtype=jnp.float32)
    if weight_dtype == "int8":
        node = quantize_int8(wd)
        pre = jax.jit(lambda a: ops.w8_matmul(a, node["w_q"], node["scale"],
                                              out_dtype=dtype))
    else:
        wh = wd.astype(jnp.float16)
        pre = jax.jit(lambda a: jnp.einsum(
            "bm,mn->bn", a.astype(dtype), wh.astype(dtype)))
    out["precompose"] = _median_time_us(pre, (xs,), reps)

    impl = costs["fused"]["impl"]
    if impl == "tile" and jax.default_backend() != "tpu":
        # off-TPU the tile kernel only exists as interpret emulation —
        # timing it measures the emulator, not serving. Measure what the
        # backend would actually run: the Gram identity, or (tanh) the
        # compose-then-einsum fallback.
        impl = "gram" if kind != "fedpara_tanh" else "einsum"
    if impl == "gram":
        fus = jax.jit(lambda a: ops.fedpara_gram_decode(
            a, x1, y1, x2, y2, kind=kind, out_dtype=dtype))
    elif impl == "tile":
        fus = jax.jit(lambda a: ops.fedpara_matmul(
            a, x1, y1, x2, y2, kind=kind, out_dtype=dtype))
    else:
        fus = jax.jit(lambda a: jnp.einsum(
            "bm,mn->bn", a.astype(dtype),
            ops.fedpara_compose_ref(x1, y1, x2, y2, kind=kind,
                                    out_dtype=dtype)))
    out["fused"] = _median_time_us(fus, (xs,), reps)
    return out


# ---------------------------------------------------------------- planning

@dataclass
class LayerDecision:
    """One layer's serving decision (a decision-table row)."""

    path: str
    m: int
    n: int
    r: int
    kind: str
    mode: str            # precompose | fused | dense (unfactorized)
    impl: str            # w8 | einsum | gram | tile | cache_residual | einsum
    weight_dtype: str
    predicted_us: Dict[str, float] = field(default_factory=dict)
    measured_us: Dict[str, float] = field(default_factory=dict)
    crossover_batch: int = 0

    def as_row(self) -> Dict[str, Any]:
        return {
            "path": self.path, "m": self.m, "n": self.n, "r": self.r,
            "kind": self.kind, "mode": self.mode, "impl": self.impl,
            "weight_dtype": self.weight_dtype,
            "predicted_us": self.predicted_us,
            "measured_us": self.measured_us,
            "crossover_batch": self.crossover_batch,
        }


def decide(path: str, m: int, n: int, r: int, *, batch: int,
           kind: str = "fedpara", mode: str = "auto",
           weight_dtype: str = "int8", users: int = 0,
           measure: bool = False) -> LayerDecision:
    """Resolve one layer's mode. ``mode`` precompose/fused forces the
    layout; ``auto`` ranks by measured µs when ``measure`` else by the
    analytic roofline."""
    costs = mode_costs(m, n, r, batch, kind=kind, weight_dtype=weight_dtype,
                       users=users)
    predicted = {md: predict_us(**_bf(c)) for md, c in costs.items()}
    measured = {}
    if measure:
        measured = measure_modes(m, n, r, batch, kind=kind,
                                 weight_dtype=weight_dtype, users=users)
    if mode in MODES:
        chosen = mode
    else:
        ranking = measured or predicted
        chosen = min(ranking, key=ranking.get)
    return LayerDecision(
        path=path, m=m, n=n, r=r, kind=kind, mode=chosen,
        impl=costs[chosen]["impl"], weight_dtype=weight_dtype,
        predicted_us=predicted, measured_us=measured,
        crossover_batch=crossover_batch(m, n, r, kind=kind,
                                        weight_dtype=weight_dtype),
    )


def _node_spec(node) -> Optional[Dict[str, int]]:
    """(m, n, r) of a factor node, tolerating scan-stacked (L, ...)
    leaves."""
    from repro.core import parameterization as par

    if not isinstance(node, dict) or "x1" not in node or "y1" not in node:
        return None
    probe = node
    if getattr(node["x1"], "ndim", 0) == 3:
        probe = {k: v[0] for k, v in node.items()}
    return par.factor_spec(probe)


def plan_params(params: Any, kind: str, *, batch: int, mode: str = "auto",
                weight_dtype: str = "int8", users: int = 0,
                measure: bool = False) -> Dict[str, LayerDecision]:
    """Walk a params tree and produce {path: LayerDecision} for every
    matrix factor node (dense {'w'} nodes become mode 'dense' rows)."""
    plan: Dict[str, LayerDecision] = {}

    def walk(node, path):
        spec = _node_spec(node)
        if spec is not None and spec.get("kind") == "matrix":
            plan[path] = decide(path, spec["m"], spec["n"], spec["r"],
                                batch=batch, kind=kind, mode=mode,
                                weight_dtype=weight_dtype,
                                users=users if kind == "pfedpara" else 0,
                                measure=measure)
            return
        if isinstance(node, dict):
            if "w" in node and getattr(node["w"], "ndim", 0) >= 2:
                plan[path] = LayerDecision(
                    path=path, m=int(node["w"].shape[-2]),
                    n=int(node["w"].shape[-1]), r=0, kind=kind,
                    mode="dense", impl="einsum", weight_dtype="native")
                return
            for k, v in node.items():
                walk(v, f"{path}/{k}" if path else str(k))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, f"{path}/{i}" if path else str(i))

    walk(params, "")
    return plan


def decision_table(plan: Dict[str, LayerDecision]) -> List[Dict[str, Any]]:
    """JSON-ready decision-table rows, sorted by path."""
    return [plan[p].as_row() for p in sorted(plan)]
