"""Load-time serve-weight construction from a decision plan.

``build_serve_params`` walks the restored global params in lockstep
with a ``{path: LayerDecision}`` plan (``cost_model.plan_params``) and
rewrites each factor node to the layout its decision calls for:

fused
    factors kept verbatim — decode composes nothing, streaming tiles
    through VMEM (tile kernel) or running the Gram identity.

precompose
    W composed once here and cached: fp16 ``{'w'}`` or int8
    ``{'w_q', 'scale'}`` with per-output-channel scales. For pFedPara
    layers only the *shared* half W1 = X1·Y1ᵀ is composed —
    ``{'w1_q'|'w1', 'scale'}`` — because the per-user (X2, Y2) residual
    is applied inside the fused cache+residual kernel at decode time;
    no per-user W ever exists.

Embeddings/unembed stay in their native dtype (int8 would quantize the
logit head; the paper keeps these dense anyway).
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.nn.layers import materialize_auto, quantize_int8
from repro.serve.cost_model import LayerDecision

_NO_QUANT = ("embed", "unembed", "pos_embed")


def _personalized(node: Dict[str, Any], kind: str) -> bool:
    """A pFedPara factor node whose personal half lives in the arena
    (global checkpoint halves carry x1/y1 only)."""
    return kind == "pfedpara" and "x1" in node and "x2" not in node


def build_serve_params(params: Any, kind: str,
                       plan: Dict[str, LayerDecision],
                       cache_dtype: str = "int8") -> Any:
    """Rewrite ``params`` per the plan. ``cache_dtype``: 'int8' | 'fp16'
    for precomposed caches."""

    def compose_cached(node, name):
        if _personalized(node, kind):
            # shared W1 only; residual factors arrive via inject_users
            w1 = jnp.einsum("...mr,...nr->...mn",
                            node["x1"].astype(jnp.float32),
                            node["y1"].astype(jnp.float32))
            if cache_dtype == "int8":
                q = quantize_int8(w1)
                return {"w1_q": q["w_q"], "scale": q["scale"]}
            return {"w1": w1.astype(jnp.float16)}
        w = materialize_auto(node, kind, jnp.float32)
        if cache_dtype == "int8" and name not in _NO_QUANT:
            return quantize_int8(w)
        return {"w": w.astype(jnp.float16)}

    def walk(node, path="", name=""):
        dec = plan.get(path)
        if dec is not None and isinstance(node, dict):
            if dec.mode == "precompose":
                return compose_cached(node, name)
            return dict(node)           # fused / dense: leave verbatim
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}" if path else str(k), k)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, f"{path}/{i}" if path else str(i),
                                   name) for i, v in enumerate(node))
        return node

    return walk(params)


def serve_state_bytes(params: Any) -> int:
    """Device bytes of a serve-params tree (cache-size accounting for
    the many-user flat-memory claim)."""
    import jax

    return int(sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(params)
                   if hasattr(leaf, "size")))
