"""Serving: planned precompose-vs-fused decode over FL checkpoints.

See docs/serving.md. Entry points: :class:`ServeEngine` (load, plan,
decode), :func:`load_fl_checkpoint`, the :class:`UserArena` many-user
personalization store, and the :mod:`repro.serve.cost_model` planner.
"""
from repro.serve.cache import build_serve_params, serve_state_bytes
from repro.serve.cost_model import (
    LayerDecision,
    crossover_batch,
    decide,
    decision_table,
    measure_modes,
    mode_costs,
    plan_params,
    predict_us,
)
from repro.serve.engine import ServeEngine, load_fl_checkpoint
from repro.serve.user_arena import UserArena, inject_users

__all__ = [
    "ServeEngine",
    "UserArena",
    "LayerDecision",
    "build_serve_params",
    "serve_state_bytes",
    "crossover_batch",
    "decide",
    "decision_table",
    "inject_users",
    "load_fl_checkpoint",
    "measure_modes",
    "mode_costs",
    "plan_params",
    "predict_us",
]
