"""Stacked per-user personalization factors for many-user serving.

pFedPara keeps each user's (X2, Y2) factors on their device during
training; at serve time the engine hosts thousands of such users at
once. Materializing one dense W per user would cost O(users · m · n)
HBM — instead the arena reuses the :class:`repro.fl.arena.ClientArena`
indexing pattern: every personal tree lives ONCE as stacked device
arrays with a leading user-row axis, a decode step gathers the cohort's
rows with one vectorized ``jnp.take`` (user ids are *traced* — new
cohorts never recompile), and the gathered (B, m, r)/(B, n, r) slices
are injected next to the shared weights as ``ux2``/``uy2`` so
``repro.nn.layers.dense`` streams them through the fused cache+residual
kernel or the per-user Gram path. Resident memory grows only by the
factor rows — 2r(m+n) floats per user per layer, never m·n.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _is_personal_node(node: Any) -> bool:
    return isinstance(node, dict) and "x2" in node and "y2" in node \
        and "x1" not in node


class UserArena:
    """Device-resident stacked per-user (X2, Y2) factor trees.

    ``tree`` mirrors the *local* half of ``split_pfedpara`` (factor
    nodes hold only ``x2``/``y2``), with every leaf stacked to
    ``(U, ...)``. ``uids`` maps external user ids to rows; unknown
    users resolve to row 0's factors (a "default personality" — the
    first registered user, typically the global server round's
    residents).
    """

    def __init__(self, tree: Any, uids: Sequence[Any]):
        self.tree = tree
        self.uids: List[Any] = list(uids)
        self._row: Dict[Any, int] = {u: i for i, u in enumerate(self.uids)}

    # -------------------------------------------------------------- build
    @classmethod
    def create(cls, local_trees: Dict[Any, Any]) -> "UserArena":
        """Stack ``{uid: local_tree}`` (the FL server's per-client
        personal halves) into one arena. All trees must share a
        structure; uids keep their insertion order as rows."""
        if not local_trees:
            raise ValueError("UserArena.create: no users")
        uids = list(local_trees)
        stacked = jax.tree.map(
            lambda *leaves: jnp.stack([jnp.asarray(x) for x in leaves]),
            *[local_trees[u] for u in uids])
        return cls(stacked, uids)

    @property
    def n_users(self) -> int:
        return len(self.uids)

    def nbytes(self) -> int:
        """Total device bytes held by the stacked factors."""
        return int(sum(leaf.size * leaf.dtype.itemsize
                       for leaf in jax.tree.leaves(self.tree)
                       if hasattr(leaf, "size")))

    # ---------------------------------------------------------- addressing
    def rows_for(self, uids: Sequence[Any]) -> jax.Array:
        """(B,) int32 row indices for a request cohort (host-side id
        lookup; the returned array is what gets traced)."""
        return jnp.asarray(
            np.asarray([self._row.get(u, 0) for u in uids], np.int32))

    # ------------------------------------------------------------- gather
    def gather(self, rows: jax.Array) -> Any:
        """One vectorized row gather: the cohort's local trees stacked
        along a leading (B,) axis. Safe under jit with traced rows."""
        return jax.tree.map(lambda a: jnp.take(a, rows, axis=0), self.tree)


def inject_users(serve_params: Any, gathered: Any) -> Any:
    """Overlay a gathered cohort onto serve params: every personal
    ``{'x2', 'y2'}`` node in ``gathered`` contributes ``ux2``/``uy2``
    keys to the matching serve node (shared cache or global factors),
    which ``dense`` recognizes as the many-user serve layouts.

    Scan-stacked layers need one transpose: the model stacks layers
    leading — serve leaves are (L, m, r) and ``lax.scan`` slices the
    layer axis — while a gather stacks users leading, giving
    (B, L, m, r). Gathered 4-D leaves are moved to (L, B, m, r) so the
    scan still slices layers and each slice carries the cohort.
    """
    def overlay(sp, gp):
        if _is_personal_node(gp):
            if not isinstance(sp, dict):
                raise ValueError("inject_users: serve tree misses a "
                                 "personalized node present in the arena")
            def orient(leaf):
                return jnp.moveaxis(leaf, 0, 1) if leaf.ndim == 4 else leaf
            return {**sp, "ux2": orient(gp["x2"]), "uy2": orient(gp["y2"])}
        if isinstance(gp, dict):
            return {k: overlay(sp[k], v) if k in sp else sp.get(k)
                    for k, v in gp.items()} | {
                        k: v for k, v in sp.items() if k not in gp}
        if isinstance(gp, (list, tuple)):
            return type(gp)(overlay(s, g) for s, g in zip(sp, gp))
        return sp

    return overlay(serve_params, gathered)
