"""VGG16 (and a reduced VGG-small) with FedPara conv parameterization.

Matches the paper's setup: VGG16 with *group* normalization (Hsieh et
al. 2020), FedPara (Prop. 3 tensor form) on every conv layer, the last
three FC layers (512-512-#classes) kept dense, same gamma for all convs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ParamCfg
from repro.core import tensor_fedpara
from repro.nn.layers import group_norm, materialize_auto

VGG16_PLAN: Tuple = (64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                     512, 512, 512, "M", 512, 512, 512, "M")
VGG_SMALL_PLAN: Tuple = (16, "M", 32, "M", 64, "M")


@dataclass(frozen=True)
class VGGConfig:
    plan: Tuple = VGG16_PLAN
    classes: int = 10
    in_channels: int = 3
    image_size: int = 32
    fc_dims: Tuple[int, ...] = (512, 512)
    param: ParamCfg = field(default_factory=ParamCfg)
    gn_groups: int = 32


def _conv_param(key, out_ch, in_ch, pcfg: ParamCfg):
    # FedPara applies from the first conv on; tiny convs fall back dense
    if pcfg.kind == "original" or min(out_ch, in_ch) < 16:
        return tensor_fedpara.init_conv(key, out_ch, in_ch, 3, 3, kind="original")
    return tensor_fedpara.init_conv(key, out_ch, in_ch, 3, 3, kind=pcfg.kind,
                                    gamma=pcfg.gamma)


def init_vgg(key: jax.Array, cfg: VGGConfig) -> Dict:
    params: Dict = {"convs": [], "fcs": []}
    in_ch = cfg.in_channels
    keys = jax.random.split(key, len(cfg.plan) + len(cfg.fc_dims) + 1)
    ki = 0
    size = cfg.image_size
    for item in cfg.plan:
        if item == "M":
            size //= 2
            continue
        params["convs"].append({
            "kernel": _conv_param(keys[ki], item, in_ch, cfg.param),
            "gn": {"scale": jnp.ones((item,), jnp.float32),
                   "bias": jnp.zeros((item,), jnp.float32)},
        })
        in_ch = item
        ki += 1
    feat = in_ch * size * size
    dims = (feat,) + cfg.fc_dims + (cfg.classes,)
    for i in range(len(dims) - 1):
        # last FC layers stay dense (paper keeps them unfactorized)
        w = jax.random.normal(keys[ki], (dims[i], dims[i + 1]), jnp.float32)
        params["fcs"].append({
            "w": w * (2.0 / dims[i]) ** 0.5,
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        })
        ki += 1
    return params


def vgg_apply(params: Dict, cfg: VGGConfig, x: jax.Array) -> jax.Array:
    """x: (B, H, W, C) -> logits (B, classes)."""
    ci = 0
    for item in cfg.plan:
        if item == "M":
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            continue
        p = params["convs"][ci]
        w = materialize_auto(p["kernel"], cfg.param.kind)      # (O,I,3,3)
        w = jnp.transpose(w, (2, 3, 1, 0))                      # HWIO
        x = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = group_norm(x, p["gn"], cfg.gn_groups)
        x = jax.nn.relu(x)
        ci += 1
    x = x.reshape(x.shape[0], -1)
    for i, fc in enumerate(params["fcs"]):
        x = x @ fc["w"] + fc["b"]
        if i < len(params["fcs"]) - 1:
            x = jax.nn.relu(x)
    return x


def vgg_loss(params: Dict, cfg: VGGConfig, batch: Dict) -> jax.Array:
    logits = vgg_apply(params, cfg, batch["x"])
    labels = batch["y"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def vgg_accuracy(params: Dict, cfg: VGGConfig, batch: Dict) -> jax.Array:
    logits = vgg_apply(params, cfg, batch["x"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
