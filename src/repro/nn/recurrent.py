"""Paper RNN models: 2-layer character LSTM (Shakespeare) and the
two-FC-layer MLP (FEMNIST/MNIST personalization experiments).

The LSTM gate matrices (input-to-hidden and hidden-to-hidden) are
FedPara-factorized; the embedding and output head stay dense, per the
paper's convention of leaving small/last layers unfactorized.

All parameterized matmuls route through :func:`repro.nn.layers.dense`,
so ``ParamCfg(use_pallas=True)`` switches every FL client's local
training step onto the fused differentiable Pallas kernels (W never
materialized, forward or backward) with no model-code changes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ParamCfg
from repro.nn.layers import dense, init_dense


@dataclass(frozen=True)
class LSTMConfig:
    vocab: int = 80
    embed: int = 8
    hidden: int = 256
    layers: int = 2
    param: ParamCfg = field(default_factory=lambda: ParamCfg(min_dim_for_factorization=8))


def init_lstm(key: jax.Array, cfg: LSTMConfig) -> Dict:
    ks = jax.random.split(key, 2 + 2 * cfg.layers)
    params: Dict = {
        "embed": {"w": jax.random.normal(ks[0], (cfg.vocab, cfg.embed), jnp.float32) * 0.1},
        "cells": [],
        "head": {"w": jax.random.normal(ks[1], (cfg.hidden, cfg.vocab), jnp.float32)
                 * (1.0 / cfg.hidden) ** 0.5},
    }
    d_in = cfg.embed
    for l in range(cfg.layers):
        params["cells"].append({
            "wi": init_dense(ks[2 + 2 * l], d_in, 4 * cfg.hidden, cfg.param),
            "wh": init_dense(ks[3 + 2 * l], cfg.hidden, 4 * cfg.hidden, cfg.param),
            "b": jnp.zeros((4 * cfg.hidden,), jnp.float32)
                 .at[cfg.hidden: 2 * cfg.hidden].set(1.0),  # forget-gate bias
        })
        d_in = cfg.hidden
    return params


def _cell_step(p, pcfg: ParamCfg, carry, x_t):
    h, c = carry
    z = (dense(p["wi"], x_t, pcfg, jnp.float32)
         + dense(p["wh"], h, pcfg, jnp.float32) + p["b"])
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def lstm_apply(params: Dict, cfg: LSTMConfig, tokens: jax.Array) -> jax.Array:
    """tokens: (B, S) -> logits (B, S, vocab)."""
    B, S = tokens.shape
    x = params["embed"]["w"][tokens]
    for p in params["cells"]:
        h0 = (jnp.zeros((B, cfg.hidden)), jnp.zeros((B, cfg.hidden)))
        _, hs = jax.lax.scan(lambda c, xt: _cell_step(p, cfg.param, c, xt),
                             h0, jnp.moveaxis(x, 1, 0))
        x = jnp.moveaxis(hs, 0, 1)
    return x @ params["head"]["w"]


def lstm_loss(params: Dict, cfg: LSTMConfig, batch: Dict) -> jax.Array:
    tokens = batch["tokens"]
    logits = lstm_apply(params, cfg, tokens[:, :-1])
    logp = jax.nn.log_softmax(logits)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


def lstm_accuracy(params: Dict, cfg: LSTMConfig, batch: Dict) -> jax.Array:
    tokens = batch["tokens"]
    logits = lstm_apply(params, cfg, tokens[:, :-1])
    return jnp.mean((jnp.argmax(logits, -1) == tokens[:, 1:]).astype(jnp.float32))


# --------------------------------------------------------------------- MLP

@dataclass(frozen=True)
class MLPConfig:
    in_dim: int = 784
    hidden: int = 256
    classes: int = 62
    param: ParamCfg = field(default_factory=lambda: ParamCfg(gamma=0.5,
                                                             min_dim_for_factorization=8))


def init_mlp_model(key: jax.Array, cfg: MLPConfig) -> Dict:
    ks = jax.random.split(key, 2)
    return {
        "fc1": init_dense(ks[0], cfg.in_dim, cfg.hidden, cfg.param),
        "fc2": init_dense(ks[1], cfg.hidden, cfg.classes, cfg.param),
        "b1": jnp.zeros((cfg.hidden,), jnp.float32),
        "b2": jnp.zeros((cfg.classes,), jnp.float32),
    }


def mlp_apply(params: Dict, cfg: MLPConfig, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(dense(params["fc1"], x, cfg.param, jnp.float32)
                    + params["b1"])
    return dense(params["fc2"], h, cfg.param, jnp.float32) + params["b2"]


def mlp_loss(params: Dict, cfg: MLPConfig, batch: Dict) -> jax.Array:
    logits = mlp_apply(params, cfg, batch["x"])
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def mlp_accuracy(params: Dict, cfg: MLPConfig, batch: Dict) -> jax.Array:
    logits = mlp_apply(params, cfg, batch["x"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
