"""Model assembly: decoder-only LMs, MoE, hybrid (zamba2), xLSTM stacks,
and the whisper enc-dec — all driven by ArchConfig.

Layer iteration supports two modes:
  scan=True   lax.scan over stacked layer params (compact HLO, fast
              compiles, correct memory_analysis) — default.
  scan=False  python-unrolled (used by the dry-run cost-accounting
              variants, where every layer must appear in the HLO so
              cost_analysis/collective-byte counts are exact).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.nn import attention as attn
from repro.nn import moe as moe_mod
from repro.nn import ssm as ssm_mod
from repro.nn import xlstm as xlstm_mod
from repro.nn.layers import (
    act_fn,
    dense,
    init_dense,
    init_scale,
    precompose_tree,
    rms_norm,
)


@dataclass(frozen=True)
class ModelOptions:
    attn_chunk: int = 512
    ssm_chunk: int = 256
    logit_chunk: int = 1024
    scan_layers: bool = True
    remat: bool = True
    use_pallas: bool = False
    int8_kv: bool = False          # quantized decode KV cache (DecoderLM)
    dtype: Any = jnp.bfloat16


# ------------------------------------------------------------------ helpers

def _tree_index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _remat_group(n: int, threshold: int = 32) -> int:
    """Divisor of n nearest sqrt(n) (1 => flat scan). Deep stacks (126
    layers x 128MB residuals = 16GB/chip for llama3-405B) use a nested
    sqrt-schedule scan: the outer scan saves only n/G group boundaries,
    the checkpointed inner scan re-runs G layers during backward —
    O(n/G + G) residuals instead of O(n)."""
    if n < threshold:
        return 1
    import math

    best = 1
    for g in range(2, n + 1):
        if n % g == 0 and abs(g - math.isqrt(n)) < abs(best - math.isqrt(n)):
            best = g
    return best


def iterate_layers(body, carry, stacked, xs, n: int, scan: bool, remat: bool):
    """Run ``body(carry, layer_params, x_i) -> carry`` over n layers."""
    def wrapped(c, px):
        p, x = px
        return body(c, p, x), None

    if remat:
        wrapped = jax.checkpoint(wrapped)
    if scan:
        g = _remat_group(n) if remat else 1
        if g > 1:
            grouped = jax.tree.map(
                lambda a: a.reshape(n // g, g, *a.shape[1:]), (stacked, xs))

            @jax.checkpoint
            def group(c, gx):
                return jax.lax.scan(wrapped, c, gx)

            carry, _ = jax.lax.scan(group, carry, grouped)
            return carry
        carry, _ = jax.lax.scan(wrapped, carry, (stacked, xs))
        return carry
    for i in range(n):
        carry, _ = wrapped(carry, (_tree_index(stacked, i), _tree_index(xs, i)))
    return carry


def iterate_layers_cache(body, carry, stacked, cache, n: int, scan: bool):
    """Like iterate_layers but threads and returns per-layer cache."""
    def wrapped(c, pc):
        p, cch = pc
        c, new_cch = body(c, p, cch)
        return c, new_cch

    if scan:
        carry, new_cache = jax.lax.scan(wrapped, carry, (stacked, cache))
        return carry, new_cache
    new_caches = []
    for i in range(n):
        carry, nc = wrapped(carry, (_tree_index(stacked, i), _tree_index(cache, i)))
        new_caches.append(nc)
    stacked_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
    return carry, stacked_cache


def sinusoidal_pos(positions: jax.Array, d: int) -> jax.Array:
    inv = 1.0 / (10000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------- MLP/FFN

def init_mlp(key, cfg: ArchConfig, d_in: Optional[int] = None, d_ff: Optional[int] = None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":  # SwiGLU
        return {
            "w_gate": init_dense(ks[0], d, f, cfg.param),
            "w_up": init_dense(ks[1], d, f, cfg.param),
            "w_down": init_dense(ks[2], f, d, cfg.param),
        }
    return {
        "w_up": init_dense(ks[0], d, f, cfg.param),
        "w_down": init_dense(ks[1], f, d, cfg.param),
    }


def mlp(p, x, cfg: ArchConfig, dtype, use_pallas=False):
    a = act_fn(cfg.act)
    if "w_gate" in p:
        h = a(dense(p["w_gate"], x, cfg.param, dtype, use_pallas)) * dense(
            p["w_up"], x, cfg.param, dtype, use_pallas
        )
    else:
        h = a(dense(p["w_up"], x, cfg.param, dtype, use_pallas))
    h = constrain(h, "batch", None, "ffn")
    return constrain(dense(p["w_down"], h, cfg.param, dtype, use_pallas),
                     "batch", "seq", None)


# ----------------------------------------------------------- loss utilities

def chunked_ce_loss(h: jax.Array, unembed_w: jax.Array, targets: jax.Array,
                    mask: jax.Array, chunk: int) -> jax.Array:
    """Next-token CE, unembedding seq-chunk by seq-chunk (bounds the fp32
    logit buffer to (B, chunk, V))."""
    B, S, d = h.shape
    C = min(chunk, S)
    nc = (S + C - 1) // C
    Sp = nc * C
    if Sp != S:
        h = jnp.pad(h, ((0, 0), (0, Sp - S), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, Sp - S)))
        mask = jnp.pad(mask, ((0, 0), (0, Sp - S)))
    hc = jnp.moveaxis(h.reshape(B, nc, C, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, nc, C), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, nc, C), 1, 0)

    def step(acc, inp):
        hi, ti, mi = inp
        # bf16 matmul (fp32 MXU accumulation), fp32 softmax math. The
        # target logit is read with a one-hot contraction — a gather
        # across the vocab-sharded axis would force GSPMD to all-gather
        # the full fp32 logits.
        logits = jnp.einsum("bcd,dv->bcv", hi, unembed_w).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(ti, logits.shape[-1], dtype=logits.dtype)
        onehot = constrain(onehot, "batch", None, "vocab")
        tgt = jnp.sum(logits * onehot, axis=-1)
        nll = (lse - tgt) * mi
        return (acc[0] + nll.sum(), acc[1] + mi.sum()), None

    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(step),
                                 (jnp.zeros((), jnp.float32),) * 2, (hc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# ============================================================ decoder-only LM

class DecoderLM:
    """dense / moe / vlm families (llama4, mixtral, chatglm3, llama3,
    gemma3, qwen3, chameleon)."""

    def __init__(self, cfg: ArchConfig, opts: ModelOptions = ModelOptions()):
        self.cfg = cfg
        self.opts = opts

    # ---------------- init
    def init_layer(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p = {
            "ln1": init_scale(cfg.d_model),
            "attn": attn.init_attention(ks[0], cfg),
            "ln2": init_scale(cfg.d_model),
        }
        if cfg.n_experts:
            p["moe"] = moe_mod.init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg)
        return p

    def init_params(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        layer_keys = jax.random.split(ks[0], cfg.n_layers)
        layers = jax.vmap(self.init_layer)(layer_keys)
        emb = jax.random.normal(ks[1], (cfg.vocab_size, cfg.d_model), jnp.float32)
        p = {
            "embed": {"w": emb * (1.0 / cfg.d_model ** 0.5)},
            "layers": layers,
            "final_norm": init_scale(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            unemb = jax.random.normal(ks[2], (cfg.d_model, cfg.vocab_size), jnp.float32)
            p["unembed"] = {"w": unemb * (1.0 / cfg.d_model ** 0.5)}
        return p

    # ---------------- per-layer window schedule (gemma3 local:global)
    def layer_windows(self, seq_hint: int) -> jax.Array:
        cfg = self.cfg
        L = cfg.n_layers
        if cfg.local_global_period:
            is_global = (jnp.arange(L) % cfg.local_global_period) == (
                cfg.local_global_period - 1
            )
            return jnp.where(is_global, 0, cfg.local_window).astype(jnp.int32)
        return jnp.full((L,), cfg.sliding_window, jnp.int32)

    # ---------------- train forward
    def hidden_states(self, params, tokens) -> jax.Array:
        cfg, opts = self.cfg, self.opts
        h = params["embed"]["w"][tokens].astype(opts.dtype)
        h = constrain(h, "batch", "seq", None)
        windows = self.layer_windows(tokens.shape[1])

        def body(h, p, window):
            x = rms_norm(h, p["ln1"], cfg.norm_eps)
            h = h + attn.full_attention(
                p["attn"], x, cfg, window=window, chunk=opts.attn_chunk,
                dtype=opts.dtype, use_pallas=opts.use_pallas,
            )
            x = rms_norm(h, p["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                h = h + moe_mod.moe_ffn(p["moe"], x, cfg, opts.dtype)
            else:
                h = h + mlp(p["mlp"], x, cfg, opts.dtype, opts.use_pallas)
            return constrain(h, "batch", "seq", None)

        h = iterate_layers(body, h, params["layers"], windows,
                           cfg.n_layers, opts.scan_layers, opts.remat)
        return rms_norm(h, params["final_norm"], cfg.norm_eps)

    def unembed_w(self, params, dtype):
        if self.cfg.tie_embeddings:
            return params["embed"]["w"].astype(dtype).T
        return params["unembed"]["w"].astype(dtype)

    def loss(self, params, batch) -> jax.Array:
        tokens = batch["tokens"]
        h = self.hidden_states(params, tokens[:, :-1])
        targets = tokens[:, 1:]
        mask = jnp.ones_like(targets, jnp.float32)
        return chunked_ce_loss(h, self.unembed_w(params, self.opts.dtype),
                               targets, mask, self.opts.logit_chunk)

    # ---------------- serving
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        return attn.init_kv_cache(self.cfg, batch, max_seq, self.cfg.n_layers,
                                  dtype=self.opts.dtype,
                                  int8=self.opts.int8_kv)

    def prefill(self, params, tokens, cache) -> Tuple[Dict, jax.Array]:
        cfg, opts = self.cfg, self.opts
        h = params["embed"]["w"][tokens].astype(opts.dtype)
        windows = self.layer_windows(tokens.shape[1])

        def body(h, p_cache_w):
            (p, kv, window) = p_cache_w
            x = rms_norm(h, p["ln1"], cfg.norm_eps)
            y, kv = attn.prefill_attention(
                p["attn"], x, cfg, kv, window=window, chunk=opts.attn_chunk,
                dtype=opts.dtype, use_pallas=opts.use_pallas,
            )
            h = h + y
            x = rms_norm(h, p["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                h = h + moe_mod.moe_ffn(p["moe"], x, cfg, opts.dtype)
            else:
                h = h + mlp(p["mlp"], x, cfg, opts.dtype, opts.use_pallas)
            return h, kv

        def wrapped(c, pcw):
            p, kvc, w = pcw
            if "k_q" in kvc:
                kin = (attn.dequantize_kv(kvc["k_q"], kvc["k_s"], opts.dtype),
                       attn.dequantize_kv(kvc["v_q"], kvc["v_s"], opts.dtype))
                c, kv = body(c, (p, kin, w))
                kq, ks = attn.quantize_kv(kv[0])
                vq, vs = attn.quantize_kv(kv[1])
                return c, {"k_q": kq, "k_s": ks, "v_q": vq, "v_s": vs}
            c, kv = body(c, (p, (kvc["k"], kvc["v"]), w))
            return c, {"k": kv[0], "v": kv[1]}

        if opts.scan_layers:
            h, cache = jax.lax.scan(wrapped, h, (params["layers"], cache, windows))
        else:
            new = []
            for i in range(cfg.n_layers):
                h, kv = wrapped(h, (_tree_index(params["layers"], i),
                                    _tree_index(cache, i), windows[i]))
                new.append(kv)
            cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                            self.unembed_w(params, jnp.float32))
        return cache, logits

    def decode_step(self, params, cache, token, pos) -> Tuple[jax.Array, Dict]:
        cfg, opts = self.cfg, self.opts
        h = params["embed"]["w"][token].astype(opts.dtype)   # (B,1,d)
        windows = self.layer_windows(0)

        def body(h, pcw):
            p, kvc, window = pcw
            x = rms_norm(h, p["ln1"], cfg.norm_eps)
            if "k_q" in kvc:  # int8 cache: dequant for attend, quant writes
                k = attn.dequantize_kv(kvc["k_q"], kvc["k_s"], opts.dtype)
                v = attn.dequantize_kv(kvc["v_q"], kvc["v_s"], opts.dtype)
                y, (ck, cv) = attn.decode_attention(
                    p["attn"], x, cfg, (k, v), pos,
                    window=window, dtype=opts.dtype,
                    use_pallas=opts.use_pallas)
                kq, ks = attn.quantize_kv(ck)
                vq, vs = attn.quantize_kv(cv)
                new_kvc = {"k_q": kq, "k_s": ks, "v_q": vq, "v_s": vs}
            else:
                y, (ck, cv) = attn.decode_attention(
                    p["attn"], x, cfg, (kvc["k"], kvc["v"]), pos,
                    window=window, dtype=opts.dtype,
                    use_pallas=opts.use_pallas,
                )
                new_kvc = {"k": ck, "v": cv}
            h = h + y
            x = rms_norm(h, p["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                h = h + moe_mod.moe_ffn(p["moe"], x, cfg, opts.dtype)
            else:
                h = h + mlp(p["mlp"], x, cfg, opts.dtype, opts.use_pallas)
            return h, new_kvc

        h, cache = self._decode_layers(body, h, params, cache, windows)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bod,dv->bov", h.astype(jnp.float32),
                            self.unembed_w(params, jnp.float32))
        return logits[:, 0], cache

    def _decode_layers(self, body, h, params, cache, windows):
        cfg, opts = self.cfg, self.opts

        def wrapped(c, x):
            p, kvc, w = x
            c, nkv = body(c, (p, kvc, w))
            return c, nkv

        if opts.scan_layers:
            return jax.lax.scan(wrapped, h, (params["layers"], cache, windows))
        new = []
        for i in range(cfg.n_layers):
            h, kv = wrapped(h, (_tree_index(params["layers"], i),
                                _tree_index(cache, i), windows[i]))
            new.append(kv)
        return h, jax.tree.map(lambda *xs: jnp.stack(xs), *new)

    def precompose(self, params, int8: bool = False):
        return precompose_tree(params, self.cfg.param, self.opts.dtype,
                               int8=int8)


# ============================================================= zamba2 hybrid

class HybridSSM:
    """zamba2: stacks of Mamba2 blocks with ONE shared attention+MLP
    block applied every ``attn_every`` positions (zamba's weight-sharing
    trick: a single parameter set, ``n_sites`` call sites, each with its
    own KV cache)."""

    def __init__(self, cfg: ArchConfig, opts: ModelOptions = ModelOptions()):
        assert cfg.n_layers % cfg.attn_every == 0
        self.cfg = cfg
        self.opts = opts
        self.per = cfg.attn_every
        self.n_sites = cfg.n_layers // cfg.attn_every

    def init_params(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        layer_keys = jax.random.split(ks[0], cfg.n_layers)

        def init_block(k):
            kk = jax.random.split(k, 2)
            return {"ln": init_scale(cfg.d_model),
                    "mamba": ssm_mod.init_mamba(kk[0], cfg)}

        blocks = jax.vmap(init_block)(layer_keys)
        blocks = jax.tree.map(
            lambda a: a.reshape(self.n_sites, self.per, *a.shape[1:]), blocks
        )
        emb = jax.random.normal(ks[1], (cfg.vocab_size, cfg.d_model), jnp.float32)
        return {
            "embed": {"w": emb * (1.0 / cfg.d_model ** 0.5)},
            "blocks": blocks,
            "shared": {
                "ln1": init_scale(cfg.d_model),
                "attn": attn.init_attention(ks[2], cfg),
                "ln2": init_scale(cfg.d_model),
                "mlp": init_mlp(ks[3], cfg),
            },
            "final_norm": init_scale(cfg.d_model),
            "unembed": {"w": jax.random.normal(ks[4], (cfg.d_model, cfg.vocab_size),
                                               jnp.float32) * (1.0 / cfg.d_model ** 0.5)},
        }

    def _shared_block(self, params, h, cache_kv=None, pos=None, mode="train"):
        cfg, opts = self.cfg, self.opts
        sp = params["shared"]
        x = rms_norm(h, sp["ln1"], cfg.norm_eps)
        if mode == "train":
            y = attn.full_attention(sp["attn"], x, cfg, window=0,
                                    chunk=opts.attn_chunk, dtype=opts.dtype,
                                    use_pallas=opts.use_pallas)
            new_kv = None
        elif mode == "prefill":
            y, new_kv = attn.prefill_attention(sp["attn"], x, cfg, cache_kv,
                                               window=0, chunk=opts.attn_chunk,
                                               dtype=opts.dtype,
                                               use_pallas=opts.use_pallas)
        else:
            y, new_kv = attn.decode_attention(sp["attn"], x, cfg, cache_kv, pos,
                                              window=0, dtype=opts.dtype,
                                              use_pallas=opts.use_pallas)
        h = h + y
        x = rms_norm(h, sp["ln2"], cfg.norm_eps)
        h = h + mlp(sp["mlp"], x, cfg, opts.dtype, opts.use_pallas)
        return h, new_kv

    def hidden_states(self, params, tokens) -> jax.Array:
        cfg, opts = self.cfg, self.opts
        h = params["embed"]["w"][tokens].astype(opts.dtype)
        h = constrain(h, "batch", "seq", None)

        def body(h, p, _):
            x = rms_norm(h, p["ln"], cfg.norm_eps)
            return h + ssm_mod.mamba_forward(p["mamba"], x, cfg,
                                             chunk=opts.ssm_chunk, dtype=opts.dtype,
                                             use_pallas=opts.use_pallas)

        def shared(h, sp_params):
            return self._shared_block(sp_params, h, mode="train")[0]

        shared_fn = jax.checkpoint(shared) if opts.remat else shared
        for s in range(self.n_sites):
            site = _tree_index(params["blocks"], s)
            h = iterate_layers(body, h, site, jnp.zeros((self.per,)),
                               self.per, opts.scan_layers, opts.remat)
            h = shared_fn(h, params)
        return rms_norm(h, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch) -> jax.Array:
        tokens = batch["tokens"]
        h = self.hidden_states(params, tokens[:, :-1])
        targets = tokens[:, 1:]
        mask = jnp.ones_like(targets, jnp.float32)
        return chunked_ce_loss(h, params["unembed"]["w"].astype(self.opts.dtype),
                               targets, mask, self.opts.logit_chunk)

    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        mc = ssm_mod.init_mamba_cache(cfg, batch, cfg.n_layers)
        mc = jax.tree.map(
            lambda a: a.reshape(self.n_sites, self.per, *a.shape[1:]), mc
        )
        kv = attn.init_kv_cache(cfg, batch, max_seq, self.n_sites, dtype=self.opts.dtype)
        return {"mamba": mc, "kv": kv}

    def prefill(self, params, tokens, cache) -> Tuple[Dict, jax.Array]:
        cfg, opts = self.cfg, self.opts
        h = params["embed"]["w"][tokens].astype(opts.dtype)
        new_mamba, new_kv = [], []
        for s in range(self.n_sites):
            site = _tree_index(params["blocks"], s)
            site_states = []
            for l in range(self.per):
                p = _tree_index(site, l)
                x = rms_norm(h, p["ln"], cfg.norm_eps)
                y, (ssm_s, conv_s) = ssm_mod.mamba_forward(
                    p["mamba"], x, cfg, chunk=opts.ssm_chunk, dtype=opts.dtype,
                    return_state=True)
                h = h + y
                site_states.append({"ssm": ssm_s, "conv": conv_s})
            new_mamba.append(jax.tree.map(lambda *xs: jnp.stack(xs), *site_states))
            kvc = _tree_index(cache["kv"], s)
            h, kv = self._shared_block(params, h, (kvc["k"], kvc["v"]), mode="prefill")
            new_kv.append({"k": kv[0], "v": kv[1]})
        cache = {
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba),
            "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv),
        }
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                            params["unembed"]["w"].astype(jnp.float32))
        return cache, logits

    def decode_step(self, params, cache, token, pos) -> Tuple[jax.Array, Dict]:
        cfg, opts = self.cfg, self.opts
        h = params["embed"]["w"][token].astype(opts.dtype)
        new_mamba, new_kv = [], []
        for s in range(self.n_sites):
            site = _tree_index(params["blocks"], s)
            site_states = []
            for l in range(self.per):
                p = _tree_index(site, l)
                mc = _tree_index(cache["mamba"], s)
                mcl = _tree_index(mc, l)
                x = rms_norm(h, p["ln"], cfg.norm_eps)
                y, (ssm_s, conv_s) = ssm_mod.mamba_decode_step(
                    p["mamba"], x, cfg, (mcl["ssm"], mcl["conv"]), dtype=opts.dtype)
                h = h + y
                site_states.append({"ssm": ssm_s, "conv": conv_s})
            new_mamba.append(jax.tree.map(lambda *xs: jnp.stack(xs), *site_states))
            kvc = _tree_index(cache["kv"], s)
            h, kv = self._shared_block(params, h, (kvc["k"], kvc["v"]), pos=pos,
                                       mode="decode")
            new_kv.append({"k": kv[0], "v": kv[1]})
        cache = {
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba),
            "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv),
        }
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bod,dv->bov", h.astype(jnp.float32),
                            params["unembed"]["w"].astype(jnp.float32))
        return logits[:, 0], cache

    def precompose(self, params, int8: bool = False):
        return precompose_tree(params, self.cfg.param, self.opts.dtype,
                               int8=int8)


# ================================================================ xLSTM stack

class XLSTMStack:
    """Alternating sLSTM / mLSTM blocks per ``cfg.block_pattern`` repeated
    over n_layers. Blocks are python-unrolled (the interleaved block types
    have different param structures; 12 small blocks keep the HLO tiny, so
    cost_analysis is exact without scan tricks)."""

    def __init__(self, cfg: ArchConfig, opts: ModelOptions = ModelOptions()):
        self.cfg = cfg
        self.opts = opts
        pat = cfg.block_pattern or "m"
        reps = (cfg.n_layers + len(pat) - 1) // len(pat)
        self.pattern = (pat * reps)[: cfg.n_layers]

    def init_params(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, cfg.n_layers + 3)
        blocks = []
        for i, t in enumerate(self.pattern):
            sub = {"ln": init_scale(cfg.d_model)}
            if t == "s":
                sub["slstm"] = xlstm_mod.init_slstm(ks[i], cfg)
            else:
                sub["mlstm"] = xlstm_mod.init_mlstm(ks[i], cfg)
            blocks.append(sub)
        emb = jax.random.normal(ks[-2], (cfg.vocab_size, cfg.d_model), jnp.float32)
        return {
            "embed": {"w": emb * (1.0 / cfg.d_model ** 0.5)},
            "blocks": blocks,
            "final_norm": init_scale(cfg.d_model),
            "unembed": {"w": jax.random.normal(ks[-1], (cfg.d_model, cfg.vocab_size),
                                               jnp.float32) * (1.0 / cfg.d_model ** 0.5)},
        }

    def hidden_states(self, params, tokens) -> jax.Array:
        cfg, opts = self.cfg, self.opts
        h = params["embed"]["w"][tokens].astype(opts.dtype)
        h = constrain(h, "batch", "seq", None)
        def block(h, p, t):
            x = rms_norm(h, p["ln"], cfg.norm_eps)
            if t == "s":
                return h + xlstm_mod.slstm_forward(p["slstm"], x, cfg,
                                                   dtype=opts.dtype,
                                                   use_pallas=opts.use_pallas)
            return h + xlstm_mod.mlstm_forward(p["mlstm"], x, cfg,
                                               chunk=opts.ssm_chunk,
                                               dtype=opts.dtype,
                                               use_pallas=opts.use_pallas)

        for p, t in zip(params["blocks"], self.pattern):
            fn = jax.checkpoint(block, static_argnums=(2,)) if opts.remat else block
            h = fn(h, p, t)
        return rms_norm(h, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch) -> jax.Array:
        tokens = batch["tokens"]
        h = self.hidden_states(params, tokens[:, :-1])
        targets = tokens[:, 1:]
        mask = jnp.ones_like(targets, jnp.float32)
        return chunked_ce_loss(h, params["unembed"]["w"].astype(self.opts.dtype),
                               targets, mask, self.opts.logit_chunk)

    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        states = []
        for t in self.pattern:
            if t == "s":
                states.append(xlstm_mod.init_slstm_state(cfg, batch))
            else:
                states.append(xlstm_mod.init_mlstm_state(cfg, batch))
        return {"states": states}

    def prefill(self, params, tokens, cache) -> Tuple[Dict, jax.Array]:
        cfg, opts = self.cfg, self.opts
        h = params["embed"]["w"][tokens].astype(opts.dtype)
        new_states = []
        for p, t in zip(params["blocks"], self.pattern):
            x = rms_norm(h, p["ln"], cfg.norm_eps)
            if t == "s":
                y, st = xlstm_mod.slstm_forward(p["slstm"], x, cfg, dtype=opts.dtype,
                                                return_state=True)
            else:
                y, st = xlstm_mod.mlstm_forward(p["mlstm"], x, cfg,
                                                chunk=opts.ssm_chunk,
                                                dtype=opts.dtype, return_state=True)
            h = h + y
            new_states.append(st)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                            params["unembed"]["w"].astype(jnp.float32))
        return {"states": new_states}, logits

    def decode_step(self, params, cache, token, pos) -> Tuple[jax.Array, Dict]:
        cfg, opts = self.cfg, self.opts
        h = params["embed"]["w"][token].astype(opts.dtype)
        new_states = []
        for p, t, st in zip(params["blocks"], self.pattern, cache["states"]):
            x = rms_norm(h, p["ln"], cfg.norm_eps)
            if t == "s":
                y, st = xlstm_mod.slstm_decode_step(p["slstm"], x, cfg, st,
                                                    dtype=opts.dtype)
            else:
                y, st = xlstm_mod.mlstm_decode_step(p["mlstm"], x, cfg, st,
                                                    dtype=opts.dtype)
            h = h + y
            new_states.append(st)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bod,dv->bov", h.astype(jnp.float32),
                            params["unembed"]["w"].astype(jnp.float32))
        return logits[:, 0], {"states": new_states}

    def precompose(self, params, int8: bool = False):
        return precompose_tree(params, self.cfg.param, self.opts.dtype,
                               int8=int8)


# ================================================================ whisper

class EncDecLM:
    """whisper-small backbone: bidirectional encoder over (stub) frame
    embeddings + causal decoder with cross-attention. Sinusoidal absolute
    positions (adaptation: supports the assigned 32k decode shapes beyond
    whisper's 448-token learned table — noted in DESIGN.md)."""

    def __init__(self, cfg: ArchConfig, opts: ModelOptions = ModelOptions()):
        self.cfg = cfg
        self.opts = opts

    def _init_enc_layer(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        return {
            "ln1": init_scale(cfg.d_model),
            "attn": attn.init_attention(ks[0], cfg),
            "ln2": init_scale(cfg.d_model),
            "mlp": init_mlp(ks[1], cfg),
        }

    def _init_dec_layer(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        return {
            "ln1": init_scale(cfg.d_model),
            "self_attn": attn.init_attention(ks[0], cfg),
            "ln_x": init_scale(cfg.d_model),
            "cross_attn": attn.init_attention(ks[1], cfg),
            "ln2": init_scale(cfg.d_model),
            "mlp": init_mlp(ks[2], cfg),
        }

    def init_params(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        enc = jax.vmap(self._init_enc_layer)(jax.random.split(ks[0], cfg.encoder_layers))
        dec = jax.vmap(self._init_dec_layer)(jax.random.split(ks[1], cfg.n_layers))
        emb = jax.random.normal(ks[2], (cfg.vocab_size, cfg.d_model), jnp.float32)
        return {
            "embed": {"w": emb * (1.0 / cfg.d_model ** 0.5)},
            "enc_layers": enc,
            "enc_norm": init_scale(cfg.d_model),
            "dec_layers": dec,
            "final_norm": init_scale(cfg.d_model),
            "unembed": {"w": jax.random.normal(ks[3], (cfg.d_model, cfg.vocab_size),
                                               jnp.float32) * (1.0 / cfg.d_model ** 0.5)},
        }

    def encode(self, params, frames) -> jax.Array:
        cfg, opts = self.cfg, self.opts
        B, S, _ = frames.shape
        h = frames.astype(opts.dtype) + sinusoidal_pos(jnp.arange(S), cfg.d_model
                                                       ).astype(opts.dtype)[None]
        h = constrain(h, "batch", "seq", None)

        def body(h, p, _):
            x = rms_norm(h, p["ln1"], cfg.norm_eps)
            h = h + attn.full_attention(p["attn"], x, cfg, window=0,
                                        chunk=opts.attn_chunk, causal=False,
                                        use_rope=False, dtype=opts.dtype,
                                        use_pallas=opts.use_pallas)
            x = rms_norm(h, p["ln2"], cfg.norm_eps)
            return h + mlp(p["mlp"], x, cfg, opts.dtype, opts.use_pallas)

        h = iterate_layers(body, h, params["enc_layers"],
                           jnp.zeros((cfg.encoder_layers,)), cfg.encoder_layers,
                           opts.scan_layers, opts.remat)
        return rms_norm(h, params["enc_norm"], cfg.norm_eps)

    def _embed_dec(self, params, tokens, pos0=0):
        cfg, opts = self.cfg, self.opts
        S = tokens.shape[1]
        pos = jnp.arange(S) + pos0
        return (params["embed"]["w"][tokens].astype(opts.dtype)
                + sinusoidal_pos(pos, cfg.d_model).astype(opts.dtype)[None])

    def decoder_hidden(self, params, tokens, enc_out) -> jax.Array:
        cfg, opts = self.cfg, self.opts
        h = self._embed_dec(params, tokens)

        def body(h, p, _):
            x = rms_norm(h, p["ln1"], cfg.norm_eps)
            h = h + attn.full_attention(p["self_attn"], x, cfg, window=0,
                                        chunk=opts.attn_chunk, causal=True,
                                        use_rope=False, dtype=opts.dtype,
                                        use_pallas=opts.use_pallas)
            x = rms_norm(h, p["ln_x"], cfg.norm_eps)
            h = h + attn.full_attention(p["cross_attn"], x, cfg, window=0,
                                        chunk=opts.attn_chunk, causal=False,
                                        use_rope=False, xkv=enc_out,
                                        dtype=opts.dtype, use_pallas=opts.use_pallas)
            x = rms_norm(h, p["ln2"], cfg.norm_eps)
            return h + mlp(p["mlp"], x, cfg, opts.dtype, opts.use_pallas)

        h = iterate_layers(body, h, params["dec_layers"],
                           jnp.zeros((cfg.n_layers,)), cfg.n_layers,
                           opts.scan_layers, opts.remat)
        return rms_norm(h, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch) -> jax.Array:
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        h = self.decoder_hidden(params, tokens[:, :-1], enc_out)
        targets = tokens[:, 1:]
        mask = jnp.ones_like(targets, jnp.float32)
        return chunked_ce_loss(h, params["unembed"]["w"].astype(self.opts.dtype),
                               targets, mask, self.opts.logit_chunk)

    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        hd = cfg.resolved_head_dim()
        kv = attn.init_kv_cache(cfg, batch, max_seq, cfg.n_layers, dtype=self.opts.dtype)
        cross = {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd),
                           self.opts.dtype),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd),
                           self.opts.dtype),
        }
        return {"self": kv, "cross": cross}

    def prefill(self, params, batch, cache) -> Tuple[Dict, jax.Array]:
        """Encode frames, precompute cross K/V, prefill decoder prompt."""
        cfg, opts = self.cfg, self.opts
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        h = self._embed_dec(params, tokens)

        def body(h, x_in):
            p, kvc = x_in
            x = rms_norm(h, p["ln1"], cfg.norm_eps)
            y, kv = attn.prefill_attention(p["self_attn"], x, cfg,
                                           (kvc["self"]["k"], kvc["self"]["v"]),
                                           window=0, chunk=opts.attn_chunk,
                                           use_rope=False, dtype=opts.dtype,
                                           use_pallas=opts.use_pallas)
            h = h + y
            ck, cv = attn.cross_kv(p["cross_attn"], enc_out, cfg, opts.dtype)
            x = rms_norm(h, p["ln_x"], cfg.norm_eps)
            h = h + attn.full_attention(p["cross_attn"], x, cfg, window=0,
                                        chunk=opts.attn_chunk, causal=False,
                                        use_rope=False, xkv=enc_out, dtype=opts.dtype,
                                        use_pallas=opts.use_pallas)
            x = rms_norm(h, p["ln2"], cfg.norm_eps)
            h = h + mlp(p["mlp"], x, cfg, opts.dtype, opts.use_pallas)
            return h, {"self": {"k": kv[0], "v": kv[1]}, "cross": {"k": ck, "v": cv}}

        def wrapped(c, px):
            return body(c, px)

        zipped = ({"self": cache["self"], "cross": cache["cross"]})
        per_layer = jax.tree.map(lambda a: a, zipped)
        if opts.scan_layers:
            h, new_cache = jax.lax.scan(
                wrapped, h, (params["dec_layers"], per_layer))
        else:
            outs = []
            for i in range(cfg.n_layers):
                h, nc = wrapped(h, (_tree_index(params["dec_layers"], i),
                                    _tree_index(per_layer, i)))
                outs.append(nc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                            params["unembed"]["w"].astype(jnp.float32))
        return {"self": new_cache["self"], "cross": new_cache["cross"]}, logits

    def decode_step(self, params, cache, token, pos) -> Tuple[jax.Array, Dict]:
        cfg, opts = self.cfg, self.opts
        h = self._embed_dec(params, token, pos0=pos)

        def body(h, x_in):
            p, kvc = x_in
            x = rms_norm(h, p["ln1"], cfg.norm_eps)
            y, (ck, cv) = attn.decode_attention(
                p["self_attn"], x, cfg, (kvc["self"]["k"], kvc["self"]["v"]), pos,
                window=0, use_rope=False, dtype=opts.dtype,
                use_pallas=opts.use_pallas)
            h = h + y
            x = rms_norm(h, p["ln_x"], cfg.norm_eps)
            h = h + attn.cross_decode_attention(p["cross_attn"], x, cfg,
                                                (kvc["cross"]["k"], kvc["cross"]["v"]),
                                                opts.dtype, opts.use_pallas)
            x = rms_norm(h, p["ln2"], cfg.norm_eps)
            h = h + mlp(p["mlp"], x, cfg, opts.dtype, opts.use_pallas)
            return h, {"self": {"k": ck, "v": cv}, "cross": kvc["cross"]}

        per_layer = {"self": cache["self"], "cross": cache["cross"]}
        if opts.scan_layers:
            h, new_cache = jax.lax.scan(lambda c, px: body(c, px), h,
                                        (params["dec_layers"], per_layer))
        else:
            outs = []
            for i in range(cfg.n_layers):
                h, nc = body(h, (_tree_index(params["dec_layers"], i),
                                 _tree_index(per_layer, i)))
                outs.append(nc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bod,dv->bov", h.astype(jnp.float32),
                            params["unembed"]["w"].astype(jnp.float32))
        return logits[:, 0], {"self": new_cache["self"], "cross": new_cache["cross"]}

    def precompose(self, params, int8: bool = False):
        return precompose_tree(params, self.cfg.param, self.opts.dtype,
                               int8=int8)


# ================================================================= factory

def build_model(cfg: ArchConfig, opts: ModelOptions = ModelOptions()):
    if cfg.is_encdec:
        return EncDecLM(cfg, opts)
    if cfg.attn_every:
        return HybridSSM(cfg, opts)
    if cfg.block_pattern:
        return XLSTMStack(cfg, opts)
    return DecoderLM(cfg, opts)
