"""Mamba2-style SSD block (for zamba2) — chunked train form + O(1) decode.

Simplified-but-faithful SSD: per head h with state N, scalar decay
a_t = exp(-softplus(dt_t)·exp(A_log)) and input/output projections B, C:

    S_t = a_t · S_{t-1} + dt·x_t ⊗ B_t          (state: (P, N))
    y_t = C_t · S_t + D ⊙ x_t

Training uses the chunkwise-parallel algorithm (quadratic within a
chunk, linear scan across chunks) — the TPU-native adaptation of the
Mamba2 kernel: each chunk's intra-term is a masked (C×C) matmul on the
MXU, the inter-term carries the (H, P, N) state.

The in/out/gate projections are FedPara-factorized; the SSM dynamics
parameters (A_log, D, dt bias, conv) are small and stay dense.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.layers import dense, init_dense


def ssm_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_inner // P
    return d_inner, H, P


def init_mamba(key: jax.Array, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    d_inner, H, P = ssm_dims(cfg)
    N = cfg.ssm_state
    ks = jax.random.split(key, 8)
    return {
        "w_in": init_dense(ks[0], d, 2 * d_inner + 2 * N + H, cfg.param),  # x, z, B, C, dt
        "w_out": init_dense(ks[1], d_inner, d, cfg.param),
        "conv_w": jax.random.normal(ks[2], (cfg.ssm_conv, d_inner + 2 * N), jnp.float32) * 0.2,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": {"scale": jnp.ones((d_inner,), jnp.float32)},
    }


def _split_proj(proj, cfg):
    d_inner, H, P = ssm_dims(cfg)
    N = cfg.ssm_state
    xz, rest = proj[..., : 2 * d_inner], proj[..., 2 * d_inner:]
    xbc = xz[..., :d_inner]
    z = xz[..., d_inner:]
    B = rest[..., :N]
    C = rest[..., N: 2 * N]
    dt = rest[..., 2 * N:]
    return xbc, z, B, C, dt


def _causal_conv(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv along seq. x: (B,S,D), w: (K,D).

    Returns conv output and the trailing (K-1) inputs as next state."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return jax.nn.silu(out), new_state


def mamba_forward(
    p: Dict,
    x: jax.Array,                      # (B, S, d)
    cfg: ArchConfig,
    *,
    chunk: int = 256,
    dtype=jnp.bfloat16,
    use_pallas: bool = False,
    return_state: bool = False,
):
    B, S, d = x.shape
    d_inner, H, P = ssm_dims(cfg)
    N = cfg.ssm_state

    proj = dense(p["w_in"], x, cfg.param, dtype, use_pallas)
    xbc_raw, z, Bmat, Cmat, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xbc_raw, Bmat, Cmat], axis=-1).astype(jnp.float32)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"])
    final_conv_state = conv_in[:, -(cfg.ssm_conv - 1):] if cfg.ssm_conv > 1 else None
    xs = conv_out[..., :d_inner]
    Bmat = conv_out[..., d_inner: d_inner + N]
    Cmat = conv_out[..., d_inner + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,S,H)
    xh = xs.reshape(B, S, H, P)

    C = min(chunk, S)
    nc = (S + C - 1) // C
    Sp = nc * C
    if Sp != S:  # pad with dt=0 steps: a=1 (no decay), zero state input
        pad = Sp - S
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        dt = dt * (jnp.arange(Sp) < S).astype(dt.dtype)[None, :, None]
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))                           # decay in (0,1]

    def reshape_c(t):  # (B,S,...) -> (nc, B, C, ...)
        return jnp.moveaxis(t.reshape(B, nc, C, *t.shape[2:]), 1, 0)

    ac, dtc, xc = reshape_c(a), reshape_c(dt), reshape_c(xh)
    Bc, Cc = reshape_c(Bmat), reshape_c(Cmat)

    def chunk_step(state, inp):
        a_, dt_, x_, B_, C_ = inp                                    # (B,C,H),(B,C,H),(B,C,H,P),(B,C,N)
        loga = jnp.log(a_ + 1e-20)
        cum = jnp.cumsum(loga, axis=1)                               # (B,C,H)
        # intra-chunk: y_t += C_t · Σ_{s<=t} exp(cum_t - cum_s) dt_s x_s B_sᵀ
        rel = cum[:, :, None, :] - cum[:, None, :, :]                # (B,C,C,H) t,s
        mask = jnp.tril(jnp.ones((C, C), bool))
        # mask BEFORE exp: where(mask, exp(rel), 0) with inf in the dead
        # branch produces NaN gradients (inf * 0 cotangent)
        rel = jnp.where(mask[None, :, :, None], rel, -1e30)
        g = jnp.exp(rel)                                             # (B,C,C,H)
        kernel = jnp.einsum("btsh,btn,bsn,bsh->btsh", g, C_, B_, dt_)
        y_intra = jnp.einsum("btsh,bshp->bthp", kernel, x_)
        # inter-chunk: y_t += C_t · exp(cum_t) state
        y_inter = jnp.einsum("bth,btn,bhpn->bthp", jnp.exp(cum), C_, state)
        # state update: state' = exp(cum_C) state + Σ_s exp(cum_C - cum_s) dt_s x_s B_sᵀ
        tail = jnp.exp(cum[:, -1:, :] - cum)                         # (B,C,H)
        state = jnp.exp(cum[:, -1])[:, :, None, None] * state + jnp.einsum(
            "bsh,bsh,bshp,bsn->bhpn", tail, dt_, x_, B_
        )
        return state, y_intra + y_inter

    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    final_state, ys = jax.lax.scan(jax.checkpoint(chunk_step), s0,
                                   (ac, dtc, xc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H, P)[:, :S]
    y = y + p["D"][None, None, :, None] * xh[:, :S]
    y = y.reshape(B, S, d_inner).astype(dtype)
    y = y * jax.nn.silu(z.astype(dtype))
    # group RMS norm on d_inner (mamba2 style)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6) * p["norm"]["scale"]).astype(dtype)
    out = dense(p["w_out"], y, cfg.param, dtype, use_pallas)
    if return_state:
        return out, (final_state, final_conv_state)
    return out


def init_mamba_cache(cfg: ArchConfig, batch: int, n_layers: int) -> Dict:
    d_inner, H, P = ssm_dims(cfg)
    N = cfg.ssm_state
    K = cfg.ssm_conv
    return {
        "ssm": jnp.zeros((n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, K - 1, d_inner + 2 * N), jnp.float32),
    }


def mamba_decode_step(
    p: Dict,
    x: jax.Array,                     # (B, 1, d)
    cfg: ArchConfig,
    cache: Tuple[jax.Array, jax.Array],  # ssm (B,H,P,N), conv (B,K-1,D)
    dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    B = x.shape[0]
    d_inner, H, P = ssm_dims(cfg)
    N = cfg.ssm_state
    ssm_state, conv_state = cache

    proj = dense(p["w_in"], x, cfg.param, dtype)
    xbc_raw, z, Bmat, Cmat, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xbc_raw, Bmat, Cmat], axis=-1).astype(jnp.float32)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], conv_state)
    xs = conv_out[..., :d_inner]
    Bm = conv_out[:, 0, d_inner: d_inner + N]                        # (B,N)
    Cm = conv_out[:, 0, d_inner + N:]

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))
    xh = xs[:, 0].reshape(B, H, P)
    ssm_state = a[:, :, None, None] * ssm_state + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bm
    )
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, Cm) + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_inner).astype(dtype) * jax.nn.silu(z.astype(dtype))
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6) * p["norm"]["scale"]).astype(dtype)
    return dense(p["w_out"], y, cfg.param, dtype), (ssm_state, conv_state)
