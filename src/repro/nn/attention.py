"""GQA attention: chunked full-sequence form + single-token decode.

Features used by the assigned archs: grouped-query attention, rotary
embeddings (full / half "2d"), qk-norm (qwen3/gemma3/chameleon), sliding
windows (mixtral), per-layer local/global windows (gemma3, passed as a
traced scalar so layers can be scanned), cross-attention (whisper), and
ring-buffer KV caches for windowed decode at 500k.

Full-sequence attention scans over query chunks (flash-style memory
behaviour: the (C, S) score tile is the only quadratic buffer alive).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.nn import layers
from repro.nn.layers import dense, init_dense, init_scale, rms_norm

NEG_INF = -1e30


def init_attention(key: jax.Array, cfg: ArchConfig, *, cross: bool = False) -> Dict:
    hd = cfg.resolved_head_dim()
    d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_dense(ks[0], d, H * hd, cfg.param),
        "wk": init_dense(ks[1], d, Hkv * hd, cfg.param),
        "wv": init_dense(ks[2], d, Hkv * hd, cfg.param),
        "wo": init_dense(ks[3], H * hd, d, cfg.param),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_scale(hd)
        p["k_norm"] = init_scale(hd)
    return p


def _project_qkv(p, cfg: ArchConfig, xq, xkv, positions_q, positions_kv, dtype, use_pallas):
    """Project and rope q (from xq) and k,v (from xkv)."""
    hd = cfg.resolved_head_dim()
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    rotary_frac = 0.5 if cfg.rope_style == "half" else 1.0

    q = dense(p["wq"], xq, cfg.param, dtype, use_pallas)
    q = q.reshape(*xq.shape[:-1], H, hd)
    k = dense(p["wk"], xkv, cfg.param, dtype, use_pallas)
    k = k.reshape(*xkv.shape[:-1], Hkv, hd)
    v = dense(p["wv"], xkv, cfg.param, dtype, use_pallas)
    v = v.reshape(*xkv.shape[:-1], Hkv, hd)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if positions_q is not None:  # rope disabled for cross-attn / whisper abs
        q = layers.apply_rope(q, positions_q, cfg.rope_base, rotary_frac)
        k = layers.apply_rope(k, positions_kv, cfg.rope_base, rotary_frac)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,C,Hkv,G,hd), k: (B,S,Hkv,hd) -> (B,Hkv,G,C,S).

    bf16 in/out (MXU accumulates fp32 internally); callers cast to fp32
    at the softmax. Keeping the einsum in compute dtype keeps BACKWARD
    cotangents bf16 too — with preferred_element_type=f32 the fp32
    cotangents propagate into every TP all-reduce on the residual
    stream (measured 3x collective-byte inflation)."""
    return jnp.einsum("bckgh,bskh->bkgcs", q, k)


def _gqa_out(probs, v):
    """probs: (B,Hkv,G,C,S), v: (B,S,Hkv,hd) -> (B,C,Hkv,G,hd)."""
    return jnp.einsum("bkgcs,bskh->bckgh", probs.astype(v.dtype), v)


def full_attention(
    p: Dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    window,                      # traced or static; 0/None => full causal
    chunk: int = 512,
    causal: bool = True,
    use_rope: bool = True,
    xkv: Optional[jax.Array] = None,
    dtype=jnp.bfloat16,
    use_pallas: bool = False,
) -> jax.Array:
    """Full-sequence (train / prefill) attention, scanned over q chunks."""
    B, S, d = x.shape
    hd = cfg.resolved_head_dim()
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    G = H // Hkv
    xkv = x if xkv is None else xkv
    Skv = xkv.shape[1]

    pos_q = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos_kv = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    q, k, v = _project_qkv(
        p, cfg, x, xkv,
        pos_q if use_rope else None, pos_kv if use_rope else None,
        dtype, use_pallas,
    )
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", "kv_seq_attn", "kv_heads", None)
    v = constrain(v, "batch", "kv_seq_attn", "kv_heads", None)

    C = min(chunk, S)
    n_chunks = (S + C - 1) // C
    Spad = n_chunks * C
    if Spad != S:
        q = jnp.pad(q, ((0, 0), (0, Spad - S), (0, 0), (0, 0)))
    qc = q.reshape(B, n_chunks, C, Hkv, G, hd)
    scale = 1.0 / (hd ** 0.5)

    kv_pos = jnp.arange(Skv)
    if window is None:
        window = 0
    w = jnp.asarray(window, jnp.int32)

    def chunk_fn(carry, qi_idx):
        qi, idx = qi_idx
        q_pos = idx * C + jnp.arange(C)
        s = _gqa_scores(qi, k).astype(jnp.float32) * scale   # (B,Hkv,G,C,S)
        s = constrain(s, "batch", None, None, None, "kv_seq_attn")
        if causal:
            m = q_pos[:, None] >= kv_pos[None, :]
            m &= jnp.where(w > 0, kv_pos[None, :] > q_pos[:, None] - w, True)
        else:
            m = jnp.ones((C, Skv), bool)
        s = jnp.where(m[None, None, None], s, NEG_INF)
        pbs = jax.nn.softmax(s, axis=-1)
        return carry, _gqa_out(pbs, v)               # (B,C,Hkv,G,hd)

    _, outs = jax.lax.scan(
        jax.checkpoint(chunk_fn), 0,
        (jnp.moveaxis(qc, 1, 0), jnp.arange(n_chunks)),
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Spad, H * hd)[:, :S]
    out = constrain(out.reshape(B, S, H, hd), "batch", None, "heads", None)
    y = dense(p["wo"], out.reshape(B, S, H * hd), cfg.param, dtype, use_pallas)
    return constrain(y, "batch", "seq", None)


# ----------------------------------------------------------------- KV cache

def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, n_sites: int,
                  dtype=jnp.bfloat16, int8: bool = False) -> Dict[str, jax.Array]:
    """(sites, B, S_cache, Hkv, hd) ring-buffered when a sliding window
    bounds the reuse distance. ``int8=True`` stores K/V quantized with
    per-(position, head) scales — halves the decode-dominant KV
    streaming bytes (§Perf cell B)."""
    hd = cfg.resolved_head_dim()
    S_cache = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    shape = (n_sites, batch, S_cache, cfg.n_kv_heads, hd)
    if int8:
        sshape = (n_sites, batch, S_cache, cfg.n_kv_heads, 1)
        return {"k_q": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(sshape, jnp.float32),
                "v_q": jnp.zeros(shape, jnp.int8),
                "v_s": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def quantize_kv(x: jax.Array):
    """Per-(position, head) symmetric int8. x: (..., hd)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                                keepdims=True), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return q.astype(dtype) * scale.astype(dtype)


def prefill_attention(
    p: Dict,
    x: jax.Array,
    cfg: ArchConfig,
    cache_kv: Tuple[jax.Array, jax.Array],   # (B, S_cache, Hkv, hd) slices
    *,
    window,
    chunk: int = 512,
    use_rope: bool = True,
    dtype=jnp.bfloat16,
    use_pallas: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-seq attention that also fills the (single-site) KV cache.

    Assumes prompt length S <= S_cache (ring wrap handled by modulo
    scatter when windowed).
    """
    B, S, _ = x.shape
    ck, cv = cache_kv
    S_cache = ck.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    rotary = pos if use_rope else None
    q, k, v = _project_qkv(p, cfg, x, x, rotary, rotary, dtype, use_pallas)

    if S <= S_cache:
        # common case: prompt fits the cache — a plain slice write (the
        # modulo scatter materializes giant gather/scatter temporaries)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), 0, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), 0, 1)
    elif S % S_cache == 0:
        # windowed ring with aligned wrap: the last S_cache positions land
        # on slots 0..S_cache-1 in order
        ck = k[:, -S_cache:].astype(ck.dtype)
        cv = v[:, -S_cache:].astype(cv.dtype)
    else:
        slots = jnp.arange(S) % S_cache               # general ring scatter
        ck = ck.at[:, slots].set(k.astype(ck.dtype))
        cv = cv.at[:, slots].set(v.astype(cv.dtype))

    # reuse the chunked path for the actual attention over (k, v)
    y = _chunked_attend(q, k, v, cfg, window=window, chunk=chunk)
    out = dense(p["wo"], y.reshape(B, S, -1), cfg.param, dtype, use_pallas)
    return out, (ck, cv)


def _chunked_attend(q, k, v, cfg, *, window, chunk):
    k = constrain(k, "batch", "kv_seq_attn", "kv_heads", None)
    v = constrain(v, "batch", "kv_seq_attn", "kv_heads", None)
    B, S, H, hd = q.shape
    Hkv = cfg.n_kv_heads
    G = H // Hkv
    C = min(chunk, S)
    n_chunks = (S + C - 1) // C
    Spad = n_chunks * C
    if Spad != S:
        q = jnp.pad(q, ((0, 0), (0, Spad - S), (0, 0), (0, 0)))
    qc = jnp.moveaxis(q.reshape(B, n_chunks, C, Hkv, G, hd), 1, 0)
    kv_pos = jnp.arange(S)
    w = jnp.asarray(0 if window is None else window, jnp.int32)
    scale = 1.0 / (hd ** 0.5)

    def chunk_fn(carry, qi_idx):
        qi, idx = qi_idx
        q_pos = idx * C + jnp.arange(C)
        s = _gqa_scores(qi, k).astype(jnp.float32) * scale
        s = constrain(s, "batch", None, None, None, "kv_seq_attn")
        m = q_pos[:, None] >= kv_pos[None, :]
        m &= jnp.where(w > 0, kv_pos[None, :] > q_pos[:, None] - w, True)
        s = jnp.where(m[None, None, None], s, NEG_INF)
        return carry, _gqa_out(jax.nn.softmax(s, axis=-1), v)

    _, outs = jax.lax.scan(jax.checkpoint(chunk_fn), 0, (qc, jnp.arange(n_chunks)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Spad, H, hd)[:, :S]


def decode_attention(
    p: Dict,
    x: jax.Array,                      # (B, 1, d)
    cfg: ArchConfig,
    cache_kv: Tuple[jax.Array, jax.Array],
    pos: jax.Array,                    # scalar int32: index of the new token
    *,
    window,
    use_rope: bool = True,
    dtype=jnp.bfloat16,
    use_pallas: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token decode against the (possibly ring-buffered) KV cache."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim()
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    G = H // Hkv
    ck, cv = cache_kv
    S_cache = ck.shape[1]

    pos_b = jnp.broadcast_to(pos, (B, 1)) if use_rope else None
    q, k, v = _project_qkv(p, cfg, x, x, pos_b, pos_b, dtype, use_pallas)

    slot = pos % S_cache
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, axis=1)
    ck = constrain(ck, "batch", "kv_seq", None, None)
    cv = constrain(cv, "batch", "kv_seq", None, None)

    qh = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qh, ck).astype(jnp.float32)
    s = s / (hd ** 0.5)
    # slot i holds global position: before wrap, i; after, the newest
    # S_cache positions — valid iff written (slot idx <= pos) and within window
    idx = jnp.arange(S_cache)
    written = idx <= pos
    if cfg.sliding_window:
        valid = written  # ring size == window: everything written is in-window
    else:
        w = jnp.asarray(0 if window is None else window, jnp.int32)
        valid = written & jnp.where(w > 0, idx > pos - w, True)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    pbs = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", pbs.astype(cv.dtype), cv)
    out = out.reshape(B, 1, H * hd)
    return dense(p["wo"], out, cfg.param, dtype, use_pallas), (ck, cv)


def cross_decode_attention(
    p: Dict,
    x: jax.Array,                      # (B, 1, d)
    cfg: ArchConfig,
    kv: Tuple[jax.Array, jax.Array],   # precomputed encoder K/V (B, S_enc, Hkv, hd)
    dtype=jnp.bfloat16,
    use_pallas: bool = False,
) -> jax.Array:
    B = x.shape[0]
    hd = cfg.resolved_head_dim()
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    G = H // Hkv
    k, v = kv
    q = dense(p["wq"], x, cfg.param, dtype, use_pallas).reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", q, k).astype(jnp.float32)
    pbs = jax.nn.softmax(s / (hd ** 0.5), axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", pbs.astype(v.dtype), v).reshape(B, 1, H * hd)
    return dense(p["wo"], out, cfg.param, dtype, use_pallas)


def cross_kv(p: Dict, enc_out: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16):
    """Precompute cross-attention K/V once from encoder output."""
    hd = cfg.resolved_head_dim()
    Hkv = cfg.n_kv_heads
    B, S, _ = enc_out.shape
    k = dense(p["wk"], enc_out, cfg.param, dtype).reshape(B, S, Hkv, hd)
    v = dense(p["wv"], enc_out, cfg.param, dtype).reshape(B, S, Hkv, hd)
    return k, v
