"""Core layers: parameterized dense, norms, rotary embeddings, activations.

Every weight matrix in the zoo goes through :func:`dense` /
:func:`init_dense`, which dispatch on the configured parameterization
(original / lowrank / fedpara / fedpara_tanh / pfedpara). Serving uses
:func:`precompose_tree` to replace factor subtrees with dense ``{'w'}``
weights (the paper pre-composes W for inference).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import parameterization as par
from repro.configs.base import ParamCfg


# ----------------------------------------------------------------- dispatch

def materialize_auto(sub: Dict[str, jax.Array], kind_hint: str, dtype=None) -> jax.Array:
    """Compose the dense weight from whatever factor set is stored."""
    if "w_q" in sub:  # int8 serving weights: dequantize per output channel
        w = sub["w_q"].astype(dtype or jnp.bfloat16) * sub["scale"].astype(
            dtype or jnp.bfloat16)
        return w
    if "w" in sub:
        w = sub["w"]
        return w.astype(dtype) if dtype is not None else w
    if "t1" in sub:
        from repro.core import tensor_fedpara

        k = kind_hint if kind_hint in ("fedpara", "fedpara_tanh") else "fedpara"
        return tensor_fedpara.materialize_conv(sub, k, dtype)
    if "t" in sub:
        from repro.core import tensor_fedpara

        return tensor_fedpara.materialize_conv(sub, "lowrank", dtype)
    if "x" in sub:
        return par.compose_lowrank(sub, dtype)
    if "x1" in sub:
        k = kind_hint if kind_hint in ("fedpara", "fedpara_tanh", "pfedpara") else "fedpara"
        return par.materialize(sub, k, dtype)
    raise ValueError(f"unrecognized parameterized weight keys: {list(sub)}")


def quantize_int8(w: jax.Array) -> Dict[str, jax.Array]:
    """Quantize a composed weight to int8 with per-output-channel scales
    ({'w_q', 'scale'}). The scale reduces only the contraction dim (-2),
    keeping scan-stacked leading dims (L, ...) intact. Non-matrix or
    integer leaves pass through as {'w'}."""
    if w.ndim < 2 or w.dtype == jnp.int32:
        return {"w": w}
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    wq = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127
                  ).astype(jnp.int8)
    return {"w_q": wq, "scale": scale.astype(jnp.float32)}


def should_factorize(m: int, n: int, pcfg: ParamCfg) -> bool:
    if pcfg.kind == "original":
        return False
    if min(m, n) < pcfg.min_dim_for_factorization:
        return False
    # below break-even, 2R(m+n) at r_min already exceeds mn
    from repro.core import rank_policy

    r = rank_policy.matrix_rank_for_gamma(m, n, pcfg.gamma)
    return 2 * r * (m + n) < m * n


def init_dense(key: jax.Array, m: int, n: int, pcfg: ParamCfg) -> Dict[str, jax.Array]:
    if should_factorize(m, n, pcfg):
        return par.init_linear(key, m, n, kind=pcfg.kind, gamma=pcfg.gamma)
    return par.init_original(key, m, n)


def dense(
    sub: Dict[str, jax.Array],
    x: jax.Array,
    pcfg: ParamCfg,
    dtype=jnp.bfloat16,
    use_pallas: bool = False,
) -> jax.Array:
    """y = x @ W for any parameterization. ``x``: (..., m) -> (..., n).

    With ``use_pallas`` (argument or ``pcfg.use_pallas``) every FedPara
    variant — fedpara, fedpara_tanh AND pfedpara — routes through the
    fused differentiable matmul (``repro.kernels.ops.fedpara_matmul``, a
    custom-VJP pair of Pallas kernels), so neither the forward nor the
    ``jax.grad`` backward ever materializes the dense (m, n) weight.

    The serving engine (``repro.serve``) adds three more node layouts:
    ``{'w_q', 'scale'}`` (int8 pre-composed cache, routed through the
    serve Pallas kernel so the int8 array is never widened outside
    ``pallas_call``), ``{'w1_q'|'w1', 'scale', 'ux2', 'uy2'}`` (pFedPara
    shared cache + injected per-user residual factors — the fused
    cache+residual kernel, single- or many-user), and factor nodes with
    injected ``ux2/uy2`` (the fully-fused per-user Gram path). At row
    counts <= ``pcfg.gram_batch`` fused fedpara/pfedpara matmuls use the
    Hadamard-Gram decode identity instead of the tile kernel.
    """
    pallas = use_pallas or pcfg.use_pallas
    lead = x.shape[:-1]
    m = x.shape[-1]
    rows = 1
    for d in lead:
        rows *= int(d)

    if "ux2" in sub:  # serve: per-user pFedPara residual injected
        return _serve_personalized(sub, x, pcfg, dtype, pallas)
    if pallas and "w_q" in sub and sub["w_q"].ndim == 2:
        from repro.kernels import ops

        y = ops.w8_matmul(x.reshape(-1, m).astype(dtype), sub["w_q"],
                          sub["scale"], out_dtype=dtype)
        return y.reshape(*lead, y.shape[-1])
    if (pallas and "x1" in sub
            and sub["x1"].ndim == 2
            and pcfg.kind in ("fedpara", "fedpara_tanh", "pfedpara")):
        from repro.kernels import ops

        if pcfg.gram_batch >= rows > 0 and pcfg.kind != "fedpara_tanh":
            y = ops.fedpara_gram_decode(
                x.reshape(-1, m).astype(dtype),
                sub["x1"], sub["y1"], sub["x2"], sub["y2"],
                kind=pcfg.kind, out_dtype=dtype)
            return y.reshape(*lead, y.shape[-1])
        y = ops.fedpara_matmul(
            x.reshape(-1, m).astype(dtype),
            sub["x1"], sub["y1"], sub["x2"], sub["y2"],
            kind=pcfg.kind,
            out_dtype=dtype,
        )
        return y.reshape(*lead, y.shape[-1])
    # materialize_auto already delivers ``dtype`` for every factor path
    w = materialize_auto(sub, pcfg.kind, dtype)
    return jnp.einsum("...m,mn->...n", x.astype(dtype), w)


def _serve_personalized(sub, x, pcfg: ParamCfg, dtype, pallas: bool):
    """Serve-time pFedPara node with injected per-user factors.

    ``{'w1_q'|'w1', 'scale', 'ux2', 'uy2'}`` — cache + residual kernel;
    ``{'x1', 'y1', 'ux2', 'uy2'}`` — fully-fused per-user Gram decode.
    ``ux2`` 3-D means many users: x (..., m) regroups to (U, t, m).
    """
    from repro.kernels import ops

    lead = x.shape[:-1]
    m = x.shape[-1]
    ux2, uy2 = sub["ux2"], sub["uy2"]
    many = ux2.ndim == 3
    if many:
        U = ux2.shape[0]
        xk = x.reshape(U, -1, m).astype(dtype)
    else:
        xk = x.reshape(-1, m).astype(dtype)

    if "w1_q" in sub or "w1" in sub:
        w1 = sub.get("w1_q", sub.get("w1"))
        scale = sub.get("scale")
        if pallas:
            y = ops.cache_residual_matmul(xk, w1, scale, ux2, uy2,
                                          out_dtype=dtype)
        else:  # dense fallback (materializes per-user W; oracles/tests)
            from repro.kernels import ref

            y = ref.cache_residual_ref(xk, w1, scale, ux2, uy2,
                                       out_dtype=dtype)
        return y.reshape(*lead, y.shape[-1])
    # fully fused: shared (x1, y1) + per-user residual, via the Gram path
    y = ops.fedpara_gram_decode(xk, sub["x1"], sub["y1"], ux2, uy2,
                                kind="pfedpara", out_dtype=dtype)
    return y.reshape(*lead, y.shape[-1])


def precompose_tree(params: Any, pcfg: ParamCfg, dtype=jnp.bfloat16,
                    int8: bool = False) -> Any:
    """Replace every factorized weight subtree with {'w': dense} (serving).

    ``int8=True`` additionally quantizes composed 2-D weights to int8 with
    per-output-channel scales ({'w_q', 'scale'}) — halves serving HBM and
    weight-load bytes vs bf16 (§Perf decode hillclimb)."""
    def is_param_leafdict(d):
        return isinstance(d, dict) and any(k in d for k in ("w", "x", "x1", "t", "t1"))

    def walk(node, name=""):
        if is_param_leafdict(node):
            w = materialize_auto(node, pcfg.kind, dtype)
            if int8 and name not in ("embed", "unembed"):
                return quantize_int8(w)
            return {"w": w}
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        return node

    return walk(params)


# -------------------------------------------------------------------- norms

def init_scale(n: int) -> Dict[str, jax.Array]:
    return {"scale": jnp.ones((n,), jnp.float32)}


def rms_norm(x: jax.Array, sub: Dict[str, jax.Array], eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * sub["scale"]
    return y.astype(x.dtype)


def init_layer_norm(n: int) -> Dict[str, jax.Array]:
    return {"scale": jnp.ones((n,), jnp.float32), "bias": jnp.zeros((n,), jnp.float32)}


def layer_norm(x: jax.Array, sub: Dict[str, jax.Array], eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * sub["scale"] + sub["bias"]
    return y.astype(x.dtype)


def group_norm(x: jax.Array, sub: Dict[str, jax.Array], groups: int = 32, eps: float = 1e-5) -> jax.Array:
    """GroupNorm over NHWC feature maps (paper replaces VGG BN with GN)."""
    n, h, w, c = x.shape
    g = min(groups, c)
    xf = x.astype(jnp.float32).reshape(n, h, w, g, c // g)
    mu = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(n, h, w, c)
    return (y * sub["scale"] + sub["bias"]).astype(x.dtype)


# -------------------------------------------------------------------- rope

def rope_angles(positions: jax.Array, rotary_dim: int, base: float) -> jax.Array:
    """(..., rotary_dim/2) angles for given integer positions."""
    inv = 1.0 / (base ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim))
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x: jax.Array, positions: jax.Array, base: float, rotary_frac: float = 1.0) -> jax.Array:
    """Rotary embedding on (..., S, H, hd). ``positions``: (..., S).

    ``rotary_frac`` < 1 applies rotation to the leading fraction of the
    head dim (chatglm-style 2d-RoPE uses 0.5).
    """
    hd = x.shape[-1]
    rd = int(hd * rotary_frac)
    rd -= rd % 2
    ang = rope_angles(positions, rd, base)          # (..., S, rd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]                          # broadcast over heads
    cos = cos[..., None, :]
    xr = x[..., :rd].astype(jnp.float32)
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    out = jnp.concatenate([rot.astype(x.dtype), x[..., rd:]], axis=-1)
    return out


# -------------------------------------------------------------- activations

def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "tanh": jnp.tanh,
    }[name]


def count_factorized(params: Any) -> Dict[str, int]:
    """#params transferred (factors+dense) vs dense-equivalent count."""
    stats = {"total": 0}
    for leaf in jax.tree.leaves(params):
        if hasattr(leaf, "size"):
            stats["total"] += int(leaf.size)
    return stats
