"""Model zoo: assigned LM architectures + the paper's own models."""
from repro.nn import attention, layers, moe, recurrent, ssm, transformer, vision, xlstm
from repro.nn.transformer import (
    DecoderLM,
    EncDecLM,
    HybridSSM,
    ModelOptions,
    XLSTMStack,
    build_model,
)

__all__ = [
    "attention", "layers", "moe", "recurrent", "ssm", "transformer",
    "vision", "xlstm", "DecoderLM", "EncDecLM", "HybridSSM",
    "ModelOptions", "XLSTMStack", "build_model",
]
