"""Dropless-ish Mixture-of-Experts with capacity dispatch (GShard-style).

Per batch element: route -> top-k -> scatter tokens into per-expert
capacity buffers -> batched expert matmul -> combine. Keeping the batch
dim outermost makes the scatter local to each data shard, so GSPMD
shards dispatch/combine cleanly over 'data' while the expert FFN hidden
dim is tensor-parallel over 'model'.

Expert weights are FedPara-factorized *per expert* (leading E dim on
every factor; compose is a batched einsum). Router stays dense fp32
(below the 2R(m+n) < mn break-even and numerically sensitive).

FLOPs = B*S*top_k*capacity_factor*(expert FFN) — honest MoE accounting,
no dense-all-experts waste. Overflow beyond capacity is dropped
(weighted combine of nothing = 0), standard for capacity-based TPU MoE.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ParamCfg
from repro.core import parameterization as par
from repro.core import rank_policy
from repro.distributed.sharding import constrain
from repro.nn.layers import act_fn


def _init_expert_factors(key, E: int, m: int, n: int, pcfg: ParamCfg):
    """Stacked factors (E, dim, r) for one expert weight family."""
    if pcfg.kind == "original":
        ws = jax.random.normal(key, (E, m, n), jnp.float32) * (2.0 / m) ** 0.5
        return {"w": ws}
    r = rank_policy.matrix_rank_for_gamma(m, n, pcfg.gamma)
    if pcfg.kind == "lowrank":
        r2 = 2 * r
        std = par.lowrank_factor_std(m, r2)
        kx, ky = jax.random.split(key)
        return {
            "x": jax.random.normal(kx, (E, m, r2), jnp.float32) * std,
            "y": jax.random.normal(ky, (E, n, r2), jnp.float32) * std,
        }
    std = par.fedpara_factor_std(m, r)
    ks = jax.random.split(key, 4)
    return {
        "x1": jax.random.normal(ks[0], (E, m, r), jnp.float32) * std,
        "y1": jax.random.normal(ks[1], (E, n, r), jnp.float32) * std,
        "x2": jax.random.normal(ks[2], (E, m, r), jnp.float32) * std,
        "y2": jax.random.normal(ks[3], (E, n, r), jnp.float32) * std,
    }


def compose_expert(sub: Dict, kind: str, dtype) -> jax.Array:
    """(E, m, n) dense expert stack from stacked factors (composed in
    ``dtype``: post-compose casts get folded into the dot as fp32)."""
    if "w" in sub:
        return sub["w"].astype(dtype)
    if "x" in sub:
        return jnp.einsum("emr,enr->emn", sub["x"].astype(dtype),
                          sub["y"].astype(dtype))
    w1 = jnp.einsum("emr,enr->emn", sub["x1"].astype(dtype), sub["y1"].astype(dtype))
    w2 = jnp.einsum("emr,enr->emn", sub["x2"].astype(dtype), sub["y2"].astype(dtype))
    if kind == "fedpara_tanh":
        w1, w2 = jnp.tanh(w1), jnp.tanh(w2)
    if kind == "pfedpara":
        return w1 * (w2 + jnp.asarray(1.0, w2.dtype))
    return w1 * w2


def init_moe(key: jax.Array, cfg: ArchConfig) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": {"w": jax.random.normal(ks[0], (d, E), jnp.float32) * (1.0 / d) ** 0.5},
        "experts": {
            "w_gate": _init_expert_factors(ks[1], E, d, f, cfg.param),
            "w_up": _init_expert_factors(ks[2], E, d, f, cfg.param),
            "w_down": _init_expert_factors(ks[3], E, f, d, cfg.param),
        },
    }


def moe_ffn(p: Dict, x: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> jax.Array:
    """x: (B, S, d) -> (B, S, d). Capacity = ceil(S*k/E * cf)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    cap = int(max(1, round(S * k / E * cfg.moe_capacity_factor)))
    act = act_fn(cfg.act)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"]["w"])
    gates, idx = jax.lax.top_k(logits, k)                    # (B,S,k)
    gates = jax.nn.softmax(gates, axis=-1)

    # position of each (token, choice) within its expert queue, per batch el.
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)          # (B,S,k,E)
    flat = onehot.reshape(B, S * k, E)
    ranks = jnp.cumsum(flat, axis=1) - flat                   # (B,S*k,E)
    rank_of = jnp.sum(ranks * flat, axis=-1)                  # (B,S*k)
    expert_of = idx.reshape(B, S * k)
    keep = rank_of < cap
    slot = jnp.where(keep, expert_of * cap + rank_of, E * cap)  # overflow -> pad row

    # dispatch: (B, E*cap + 1, d) buffers (last row = dropped tokens)
    xk = jnp.repeat(x, k, axis=1) if k > 1 else x             # (B,S*k,d)
    buf = jnp.zeros((B, E * cap + 1, d), dtype).at[
        jnp.arange(B)[:, None], slot
    ].set(xk.astype(dtype))
    buf = buf[:, : E * cap].reshape(B, E, cap, d)
    buf = constrain(buf, "batch", None, None, None)

    wg = compose_expert(p["experts"]["w_gate"], cfg.param.kind, dtype)
    wu = compose_expert(p["experts"]["w_up"], cfg.param.kind, dtype)
    wd = compose_expert(p["experts"]["w_down"], cfg.param.kind, dtype)
    h = act(jnp.einsum("becd,edf->becf", buf, wg)) * jnp.einsum("becd,edf->becf", buf, wu)
    h = constrain(h, "batch", None, None, "ffn")
    out_buf = jnp.einsum("becf,efd->becd", h, wd)             # (B,E,cap,d)

    # combine: gather each (token, choice) slot back and weight by gate
    out_flat = out_buf.reshape(B, E * cap, d)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((B, 1, d), dtype)], axis=1)
    picked = out_flat[jnp.arange(B)[:, None], slot]           # (B,S*k,d)
    picked = picked.reshape(B, S, k, d)
    y = jnp.sum(picked * gates[..., None].astype(dtype), axis=2)
    return y.astype(x.dtype)


def aux_load_balance_loss(logits: jax.Array, idx: jax.Array, E: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    probs = jax.nn.softmax(logits, axis=-1)                   # (B,S,E)
    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(idx[..., 0], E).mean(axis=(0, 1))
    return E * jnp.sum(me * ce)
