"""xLSTM blocks: mLSTM (matrix memory, parallelizable) + sLSTM (scalar
memory, sequential) — arXiv:2405.04517, simplified.

mLSTM training uses the quadratic parallel form (attention-like with a
log-gate decay mask, stabilized exp gating); decode is the O(1)
recurrent update of the (H, P, N) matrix memory. sLSTM is inherently
sequential (the xLSTM paper says so) and runs a lax.scan over time.

q/k/v/gate/out projections are FedPara-factorized; per-head gate
parameters stay dense.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.layers import dense, init_dense

NEG_INF = -1e30


def mlstm_dims(cfg: ArchConfig) -> Tuple[int, int]:
    H = cfg.n_heads
    P = cfg.resolved_head_dim()
    return H, P


def init_mlstm(key: jax.Array, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    H, P = mlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_q": init_dense(ks[0], d, H * P, cfg.param),
        "w_k": init_dense(ks[1], d, H * P, cfg.param),
        "w_v": init_dense(ks[2], d, H * P, cfg.param),
        "w_out": init_dense(ks[3], H * P, d, cfg.param),
        # scalar input/forget gates per head from the residual stream
        "w_if": jax.random.normal(ks[4], (d, 2 * H), jnp.float32) * (1.0 / d) ** 0.5,
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "norm": {"scale": jnp.ones((H * P,), jnp.float32)},
    }


def mlstm_forward(p: Dict, x: jax.Array, cfg: ArchConfig, *, chunk: int = 256,
                  dtype=jnp.bfloat16, use_pallas: bool = False,
                  state=None, return_state: bool = False):
    """Chunkwise-parallel mLSTM: quadratic within a chunk, O(1) matrix
    memory across chunks, carried log-scale stabilizer M.

    Derivation: S_t = Σ_{u<=t} exp(cumf_t − cumf_u + i_u)·k_u⊗v_u. We
    store Ŝ = S·exp(−M); per chunk with g_s = i_s − cumf_s and
    h_t = max(M, cummax_{s<=t} g_s), both the inter weight exp(M − h_t)
    and the intra weights exp(g_s − h_t) are ≤ 1 (exp(cumf_t) cancels
    between numerator and normalizer).
    """
    B, S, d = x.shape
    H, P = mlstm_dims(cfg)
    q = dense(p["w_q"], x, cfg.param, dtype, use_pallas).reshape(B, S, H, P)
    k = dense(p["w_k"], x, cfg.param, dtype, use_pallas).reshape(B, S, H, P)
    v = dense(p["w_v"], x, cfg.param, dtype, use_pallas).reshape(B, S, H, P)
    gates = x.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_gate, f_gate = gates[..., :H], gates[..., H:]            # (B,S,H)
    logf = -jax.nn.softplus(-f_gate)                            # log sigmoid(f)

    C = min(chunk, S)
    nc = (S + C - 1) // C
    Sp = nc * C
    if Sp != S:  # pad: f=1 (logf=0, no decay), i=-inf (no contribution)
        pad = Sp - S
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)),
                         constant_values=NEG_INF)

    def rc(t):  # (B,Sp,...) -> (nc,B,C,...)
        return jnp.moveaxis(t.reshape(B, nc, C, *t.shape[2:]), 1, 0)

    qc, kc, vc = rc(q / (P ** 0.5)), rc(k), rc(v)
    ic, fc = rc(i_gate), rc(logf)
    mask = jnp.tril(jnp.ones((C, C), bool))

    if state is None:
        state = init_mlstm_state(cfg, B)

    def chunk_step(carry, inp):
        S_h, n_h, M = carry["C"], carry["n"], carry["m"]        # Ŝ,(B,H,P,P) ñ,(B,H,P) M,(B,H)
        qi, ki, vi, ii, fi = inp
        cumf = jnp.cumsum(fi, axis=1)                           # (B,C,H)
        g = ii - cumf                                           # (B,C,H)
        hmax = jnp.maximum(M[:, None], jax.lax.cummax(g, axis=1))  # (B,C,H)
        w_inter = jnp.exp(M[:, None] - hmax)                    # (B,C,H) ≤ 1
        rel = g[:, None, :, :] - hmax[:, :, None, :]             # (B,C_t,C_s,H)
        rel = jnp.where(mask[None, :, :, None], rel, -1e30)      # mask pre-exp
        D = jnp.exp(rel)
        scores = jnp.einsum("bthp,bshp->btsh", qi, ki,
                            preferred_element_type=jnp.float32)
        w = scores * D
        num = (jnp.einsum("btsh,bshp->bthp", w.astype(vi.dtype), vi,
                          preferred_element_type=jnp.float32)
               + w_inter[..., None] * jnp.einsum(
                   "bthp,bhpq->bthq", qi.astype(jnp.float32), S_h))
        # normalizer: n_t = q_t·(Σ_s gate_ts k_s) = Σ_s w_ts (+ carried state)
        n_t = (jnp.sum(w, axis=2)
               + w_inter * jnp.einsum("bthp,bhp->bth", qi, n_h))
        denom = jnp.maximum(jnp.abs(n_t), jnp.exp(-(cumf + hmax)))
        y = (num / denom[..., None]).astype(vi.dtype)
        # ---- state to end of chunk
        F = cumf[:, -1]                                         # (B,H)
        m_loc = jnp.max(g, axis=1)                              # (B,H)
        Mx = jnp.maximum(M, m_loc)
        gexp = jnp.exp(g - Mx[:, None]).astype(ki.dtype)
        T = jnp.einsum("bsh,bshp,bshq->bhpq", gexp, ki, vi,
                       preferred_element_type=jnp.float32)
        Tn = jnp.einsum("bsh,bshp->bhp", gexp, ki,
                        preferred_element_type=jnp.float32)
        S_new = jnp.exp(M - Mx)[..., None, None] * S_h + T
        n_new = jnp.exp(M - Mx)[..., None] * n_h + Tn
        return {"C": S_new, "n": n_new, "m": F + Mx}, y

    final, ys = jax.lax.scan(jax.checkpoint(chunk_step), state,
                             (qc, kc, vc, ic, fc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H * P)[:, :S]
    y = (y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-6)
         * p["norm"]["scale"]).astype(dtype)
    out = dense(p["w_out"], y, cfg.param, dtype, use_pallas)
    if return_state:
        return out, final
    return out


def init_mlstm_state(cfg: ArchConfig, batch: int):
    H, P = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, P, P), jnp.float32),   # matrix memory (k ⊗ v)
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),   # stabilizer
    }


def mlstm_decode_step(p: Dict, x: jax.Array, cfg: ArchConfig, state: Dict,
                      dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    H, P = mlstm_dims(cfg)
    q = dense(p["w_q"], x, cfg.param, dtype).reshape(B, H, P).astype(jnp.float32)
    k = dense(p["w_k"], x, cfg.param, dtype).reshape(B, H, P).astype(jnp.float32)
    v = dense(p["w_v"], x, cfg.param, dtype).reshape(B, H, P).astype(jnp.float32)
    gates = x[:, 0].astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_g, f_g = gates[..., :H], gates[..., H:]
    logf = -jax.nn.softplus(-f_g)

    m_new = jnp.maximum(logf + state["m"], i_g)
    fs = jnp.exp(logf + state["m"] - m_new)[..., None]
    is_ = jnp.exp(i_g - m_new)[..., None]
    q_ = q / (P ** 0.5)  # same convention as the chunked form (k stored raw)
    C = fs[..., None] * state["C"] + is_[..., None] * jnp.einsum("bhp,bhq->bhpq", k, v)
    n = fs * state["n"] + is_ * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q_)), jnp.exp(-m_new))
    y = jnp.einsum("bhpq,bhp->bhq", C, q_) / denom[..., None]
    yf = y.reshape(B, 1, H * P)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm"]["scale"]).astype(dtype)
    out = dense(p["w_out"], y, cfg.param, dtype)
    return out, {"C": C, "n": n, "m": m_new}


# ------------------------------------------------------------------- sLSTM

def init_slstm(key: jax.Array, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    H, P = mlstm_dims(cfg)
    ks = jax.random.split(key, 3)
    return {
        # 4 gates (i, f, z, o) from input; recurrent mixing is per-head
        "w_in": init_dense(ks[0], d, 4 * H * P, cfg.param),
        "r": jax.random.normal(ks[1], (H, P, 4 * P), jnp.float32) * (1.0 / P) ** 0.5,
        "w_out": init_dense(ks[2], H * P, d, cfg.param),
        "b": jnp.zeros((4 * H * P,), jnp.float32),
        "norm": {"scale": jnp.ones((H * P,), jnp.float32)},
    }


def slstm_forward(p: Dict, x: jax.Array, cfg: ArchConfig, *, dtype=jnp.bfloat16,
                  use_pallas: bool = False, state=None, return_state: bool = False,
                  bptt_chunk: int = 64):
    """Sequential sLSTM over time.

    BPTT memory: a flat 4096-step scan saves a carry per step. We nest
    two scans (sqrt schedule): the outer scan over S/chunk chunks saves
    only chunk-boundary carries; the checkpointed inner chunk recomputes
    its steps during backward — peak residency O(S/chunk + chunk)
    carries instead of O(S)."""
    B, S, d = x.shape
    H, P = mlstm_dims(cfg)
    zin = (dense(p["w_in"], x, cfg.param, dtype, use_pallas)
           + p["b"].astype(dtype)).reshape(B, S, H, 4 * P)

    if state is None:
        state = init_slstm_state(cfg, B)

    def step(carry, z_t):
        c, n, h, m = carry["c"], carry["n"], carry["h"], carry["m"]
        rec = jnp.einsum("bhp,hpq->bhq", h, p["r"])             # (B,H,4P)
        g = z_t.astype(jnp.float32) + rec
        i_t, f_t, z_raw, o_t = jnp.split(g, 4, axis=-1)         # (B,H,P) each
        m_new = jnp.maximum(f_t + m, i_t)
        i_e = jnp.exp(i_t - m_new)
        f_e = jnp.exp(f_t + m - m_new)
        c = f_e * c + i_e * jnp.tanh(z_raw)
        n = f_e * n + i_e
        h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
        new = {"c": c, "n": n, "h": h, "m": m_new}
        return new, h.astype(z_t.dtype)

    C = min(bptt_chunk, S)
    nc = (S + C - 1) // C
    Sp = nc * C
    zt = jnp.moveaxis(zin, 1, 0)                                # (S,B,H,4P)
    if Sp != S:  # pad: i=-inf (no input), f=+inf (keep state), o=-inf
        padrow = jnp.zeros((Sp - S, B, H, 4 * P), zt.dtype)
        padrow = padrow.at[..., :P].set(-1e30 if padrow.dtype == jnp.float32
                                        else -3e38)             # i gate
        padrow = padrow.at[..., P:2 * P].set(30.0)              # f gate
        zt = jnp.concatenate([zt, padrow], axis=0)
    zc = zt.reshape(nc, C, B, H, 4 * P)

    @jax.checkpoint
    def chunk(carry, z_chunk):
        return jax.lax.scan(step, carry, z_chunk)

    final, hs = jax.lax.scan(chunk, state, zc)
    y = jnp.moveaxis(hs.reshape(Sp, B, H, P)[:S], 0, 1).reshape(B, S, H * P)
    y = (y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-6)
         * p["norm"]["scale"]).astype(dtype)
    out = dense(p["w_out"], y, cfg.param, dtype, use_pallas)
    if return_state:
        return out, final
    return out


def init_slstm_state(cfg: ArchConfig, batch: int):
    H, P = mlstm_dims(cfg)
    z = jnp.zeros((batch, H, P), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, H, P), -30.0, jnp.float32)}


def slstm_decode_step(p: Dict, x: jax.Array, cfg: ArchConfig, state: Dict,
                      dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict]:
    out, new_state = slstm_forward(p, x, cfg, dtype=dtype, state=state, return_state=True)
    return out, new_state
