"""Production meshes.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the
'pod' axis rides DCN; FedPara's factor sync is the only collective
placed on it in fed mode.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under dryrun.py (which forces 512 host devices)"
        )
    return Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_mesh(shape, axes) -> Mesh:
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.array(devices[:n]).reshape(shape), axes)
