"""Serving driver: pre-compose FedPara weights (paper: "at the inference
phase, we pre-compose and maintain W"), prefill a batch of prompts, then
decode tokens autoregressively with the KV/state caches.

Runs for real on CPU with --preset cpu-small; the production shapes are
exercised by dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import make_token_lm_dataset
from repro.launch.train import cpu_small
from repro.nn.transformer import ModelOptions, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--preset", default="cpu-small", choices=["cpu-small", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.preset == "cpu-small":
        cfg = cpu_small(cfg)
    opts = ModelOptions(attn_chunk=64, ssm_chunk=32, logit_chunk=64)
    model = build_model(cfg, opts)
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(key)

    t0 = time.time()
    composed = jax.jit(model.precompose)(params)
    jax.block_until_ready(composed)
    print(f"pre-compose: {time.time()-t0:.2f}s "
          f"(factors -> dense; done once per deployment)")

    prompts = make_token_lm_dataset(args.batch, args.prompt_len, cfg.vocab_size,
                                    seed=args.seed)
    tokens = jnp.asarray(prompts)
    max_seq = args.prompt_len + args.gen_len
    cache = model.init_cache(args.batch, max_seq)
    batch = {"tokens": tokens}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)

    t0 = time.time()
    if cfg.is_encdec:
        cache, logits = jax.jit(model.prefill)(composed, batch, cache)
    else:
        cache, logits = jax.jit(model.prefill)(composed, tokens, cache)
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    out = []
    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.time()
    for i in range(args.gen_len):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = decode(composed, cache, tok, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1)[:, None]
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"decode {args.gen_len} tokens: {dt:.2f}s "
          f"({args.batch*args.gen_len/dt:.1f} tok/s)")
    print("sample generations (token ids):")
    gen = np.stack(out, 1)
    for row in gen[:2]:
        print("  ", row[:16].tolist())


if __name__ == "__main__":
    main()
