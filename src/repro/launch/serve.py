"""Serving driver: FL checkpoint -> planned decode engine.

Loads a trained federation from a :class:`CheckpointManager` directory
(``--ckpt``; without one it trains a tiny pFedPara federation first so
the full checkpoint->serve handoff always runs) and serves it through
:class:`repro.serve.ServeEngine`:

* ``--mode {precompose,fused,auto}`` — per-layer weight layout: the
  load-time composed cache (fp16 / int8 + per-channel scales), the
  never-materialize fused path (Gram identity / tile kernel), or the
  cost-model pick. The per-layer decision table is printed.
* ``--users N`` — pFedPara: serve a rotating cohort of N distinct
  users per step from the resident :class:`repro.serve.UserArena`.
* ``--smoke`` — CI gate: tiny checkpoint, decode 8 tokens under BOTH
  modes, assert cross-mode parity and exactly zero recompiles after
  the single warmup step.

Timing discipline (the numbers this driver reports):

* prefill and decode are timed SEPARATELY — they answer different
  questions (time-to-first-token vs steady-state tokens/sec);
* one untimed warmup step triggers compilation before any clock
  starts, so reported numbers are steady-state;
* every timed region ends with ``jax.block_until_ready`` INSIDE the
  region — async dispatch otherwise stops the clock before the device
  finishes.

Runs for real on CPU (Pallas serve kernels auto-disable off-TPU; the
XLA paths are numerically identical).
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import iid_partition, make_token_lm_dataset
from repro.nn.transformer import ModelOptions, build_model
from repro.serve import ServeEngine


def tiny_fl_checkpoint(workdir: str, *, arch: str = "qwen3-8b",
                       rounds: int = 2, clients: int = 4,
                       kind: str = "pfedpara", seed: int = 0):
    """Train a miniature federation and checkpoint it; returns
    ``(ckpt_dir, cfg, opts)`` ready for ``ServeEngine.from_checkpoint``.

    This is the demo/CI path — real deployments pass ``--ckpt`` from a
    full training run instead.
    """
    from repro.fl.client import ClientConfig
    from repro.fl.server import FLServer, ServerConfig
    from repro.fl.strategies import make_strategy

    cfg = get_arch(arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, param=dataclasses.replace(
        cfg.param, kind=kind, min_dim_for_factorization=8, gamma=0.5))
    opts = ModelOptions(attn_chunk=8, ssm_chunk=8, logit_chunk=16,
                        dtype=jnp.float32)
    model = build_model(cfg, opts)
    params = model.init_params(jax.random.PRNGKey(seed))

    toks = make_token_lm_dataset(12 * clients, 16, cfg.vocab_size, seed=seed)
    parts = iid_partition(len(toks), clients)
    personalization = "pfedpara" if kind == "pfedpara" else "none"
    srv = FLServer(lambda p, b: model.loss(p, b), params,
                   {"tokens": toks}, parts, make_strategy("fedavg"),
                   ClientConfig(lr=0.05, batch=8, epochs=1),
                   ServerConfig(clients=clients, participation=1.0,
                                rounds=rounds,
                                personalization=personalization))
    srv.run()
    srv.save_checkpoint(CheckpointManager(workdir))
    return workdir, cfg, opts


def _print_plan(eng: ServeEngine) -> None:
    rows = eng.decision_table()
    by_mode = {}
    for r in rows:
        by_mode[r["mode"]] = by_mode.get(r["mode"], 0) + 1
    print(f"plan: {len(rows)} layers "
          + " ".join(f"{k}={v}" for k, v in sorted(by_mode.items()))
          + f" | serve weights {eng.state_bytes() / 1e6:.2f} MB"
          + (f" | user arena {eng.arena_bytes() / 1e6:.2f} MB"
             f" ({eng.arena.n_users} residents)" if eng.arena else ""))
    print(f"{'path':40s} {'m':>6s} {'n':>6s} {'r':>4s} "
          f"{'mode':>10s} {'impl':>14s} {'B*':>5s}")
    for r in rows:
        print(f"{r['path'][:40]:40s} {r['m']:6d} {r['n']:6d} {r['r']:4d} "
              f"{r['mode']:>10s} {r['impl']:>14s} "
              f"{r['crossover_batch']:5d}")


def serve_timed(eng: ServeEngine, prompts, gen_len: int,
                user_ids=None) -> dict:
    """Warmed-up prefill + decode with the timing discipline from the
    module docstring; returns the report dict (times in seconds)."""
    tokens = jnp.asarray(prompts)
    B, S = tokens.shape

    # untimed warmup: compile prefill + decode on a throwaway cache
    wcache = eng.init_cache(B, S + gen_len)
    wcache, wlogits = eng.prefill(tokens, wcache, user_ids)
    wtok = jnp.argmax(wlogits, -1)[:, None]
    wlogits, wcache = eng.decode_step(wcache, wtok, S, user_ids)
    jax.block_until_ready(wlogits)
    del wcache

    cache = eng.init_cache(B, S + gen_len)
    t0 = time.perf_counter()
    cache, logits = eng.prefill(tokens, cache, user_ids)
    jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.perf_counter()
    for i in range(gen_len):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = eng.decode_step(cache, tok, S + i, user_ids)
        tok = jnp.argmax(logits, -1)[:, None]
    jax.block_until_ready(logits)
    decode_s = time.perf_counter() - t0

    return {
        "batch": B, "prompt_len": S, "gen_len": gen_len,
        "prefill_s": prefill_s,
        "prefill_tok_s": B * S / max(prefill_s, 1e-9),
        "decode_s": decode_s,
        "decode_tok_s": B * gen_len / max(decode_s, 1e-9),
        "tokens": np.stack(out, 1),
    }


def run_smoke(args) -> None:
    """CI gate: tiny checkpoint -> decode 8 tokens under both modes ->
    cross-mode parity + zero recompiles after one warmup step."""
    from repro.analysis.program_check import CompileCounter

    with tempfile.TemporaryDirectory() as d:
        ckpt, cfg, opts = tiny_fl_checkpoint(d, rounds=1, clients=2,
                                             seed=args.seed)
        uids = [0, 1]
        prompts = make_token_lm_dataset(2, 8, cfg.vocab_size, seed=1)
        tokens = jnp.asarray(prompts)
        logits_by_mode = {}
        for mode in ("precompose", "fused"):
            eng = ServeEngine.from_checkpoint(
                ckpt, cfg, mode=mode, cache_dtype="fp16", batch=2,
                use_pallas=args.use_pallas, opts=opts)
            cache = eng.init_cache(2, 8 + 8)
            cache, logits = eng.prefill(tokens, cache, user_ids=uids)
            tok = jnp.argmax(logits, -1)[:, None]
            # warmup = the first decode step; the remaining 7 (and a
            # second user cohort) must not trigger a single compile
            logits, cache = eng.decode_step(cache, tok, 8, user_ids=uids)
            tok = jnp.argmax(logits, -1)[:, None]
            with CompileCounter() as cc:
                for i in range(1, 8):
                    cohort = uids if i % 2 else uids[::-1]
                    logits, cache = eng.decode_step(cache, tok, 8 + i,
                                                    user_ids=cohort)
                    tok = jnp.argmax(logits, -1)[:, None]
                jax.block_until_ready(logits)
            assert len(cc.events) == 0, (
                f"{mode}: decode recompiled: {cc.events}")
            logits_by_mode[mode] = np.asarray(logits)
            print(f"smoke {mode}: 8 decode steps, 2 cohorts, "
                  f"0 recompiles after warmup")
        a, b = logits_by_mode["precompose"], logits_by_mode["fused"]
        rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-9)
        assert rel < 2e-2, f"mode parity: rel err {rel:.3e}"
        print(f"smoke parity: precompose-vs-fused rel err {rel:.2e} OK")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ckpt", default=None,
                    help="CheckpointManager dir from an FL run; omitted ->"
                         " a tiny pFedPara federation is trained first")
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--kind", default=None,
                    choices=["fedpara", "fedpara_tanh", "pfedpara"],
                    help="factorization the --ckpt run trained with "
                         "(self-made checkpoints pick from --users)")
    ap.add_argument("--mode", default="auto",
                    choices=["precompose", "fused", "auto"])
    ap.add_argument("--cache-dtype", default="int8",
                    choices=["int8", "fp16"])
    ap.add_argument("--users", type=int, default=0,
                    help="pFedPara cohort width (0 = global model only)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=2,
                    help="training rounds for the self-made checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-pallas", action="store_true", default=None,
                    help="force the Pallas serve kernels (default: TPU only)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: both modes, parity + 0-recompile asserts")
    args = ap.parse_args()

    if args.smoke:
        run_smoke(args)
        return

    if args.ckpt:
        # the serve config must mirror the training one — same tiny
        # reduction tiny_fl_checkpoint used (full-scale runs would load
        # their own ArchConfig here)
        kind = args.kind or ("pfedpara" if args.users else "fedpara")
        cfg = get_arch(args.arch).reduced()
        cfg = dataclasses.replace(cfg, n_layers=2, param=dataclasses.replace(
            cfg.param, kind=kind, min_dim_for_factorization=8, gamma=0.5))
        opts = ModelOptions(attn_chunk=8, ssm_chunk=8, logit_chunk=16,
                            dtype=jnp.float32)
        ckpt = args.ckpt
        tmp = None
    else:
        tmp = tempfile.TemporaryDirectory()
        kind = args.kind or ("pfedpara" if args.users else "fedpara")
        t0 = time.perf_counter()
        ckpt, cfg, opts = tiny_fl_checkpoint(
            tmp.name, arch=args.arch, rounds=args.rounds,
            clients=max(2, args.users), kind=kind, seed=args.seed)
        print(f"trained + checkpointed tiny federation "
              f"({args.rounds} rounds): {time.perf_counter() - t0:.1f}s")

    t0 = time.perf_counter()
    eng = ServeEngine.from_checkpoint(
        ckpt, cfg, mode=args.mode, cache_dtype=args.cache_dtype,
        batch=args.batch, use_pallas=args.use_pallas, opts=opts)
    print(f"engine ({args.mode}, cache={args.cache_dtype}): "
          f"{time.perf_counter() - t0:.2f}s to plan + build caches")
    _print_plan(eng)

    uids = None
    if eng.arena is not None:
        uids = [eng.arena.uids[i % eng.arena.n_users]
                for i in range(args.batch)]
        print(f"cohort: users {uids}")

    prompts = make_token_lm_dataset(args.batch, args.prompt_len,
                                    cfg.vocab_size, seed=args.seed + 1)
    rep = serve_timed(eng, prompts, args.gen_len, uids)
    print(f"prefill {rep['batch']}x{rep['prompt_len']}: "
          f"{rep['prefill_s'] * 1e3:.1f} ms "
          f"({rep['prefill_tok_s']:.0f} tok/s)")
    print(f"decode {rep['gen_len']} steps x{rep['batch']}: "
          f"{rep['decode_s'] * 1e3:.1f} ms "
          f"({rep['decode_tok_s']:.1f} tok/s)")
    print("sample generations (token ids):")
    for row in rep["tokens"][:2]:
        print("  ", row[:12].tolist())
    if tmp is not None:
        tmp.cleanup()


if __name__ == "__main__":
    main()
