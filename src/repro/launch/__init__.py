from repro.launch import elastic, mesh, specs
from repro.launch.mesh import make_mesh, make_production_mesh

__all__ = ["elastic", "mesh", "specs", "make_mesh", "make_production_mesh"]
