"""Elastic scaling: restart-time mesh adaptation.

FedAvg's aggregation is insensitive to the number of participants per
round, so pod count can change freely between restarts; within a pod,
checkpoints are host-format (see repro.checkpoint) and re-shard onto
whatever mesh exists at restore. This module provides the glue:

  plan = plan_mesh(available_chips)        # largest valid (pods, dp, tp)
  shardings = reshard_plan(params, mesh)   # NamedShardings for restore
  params, extra = ckpt.restore(None, params_shapes, shardings)
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro.configs.base import ShapeCfg
from repro.distributed.sharding import tree_shardings
from repro.launch.specs import rules_for


def plan_mesh(n_chips: int, *, tp: int = 16, min_dp: int = 1,
              pods: Optional[int] = None) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Choose the largest (pods, data, model) layout for ``n_chips``.

    Keeps TP fixed (model-parallel width is architecture-bound) and
    absorbs chip-count changes into the data/pod axes — the dimensions
    FedAvg tolerates elastically.
    """
    tp = min(tp, n_chips)
    per_pod = n_chips if pods in (None, 1) else n_chips // pods
    dp = max(min_dp, per_pod // tp)
    if pods and pods > 1:
        return (pods, dp, tp), ("pod", "data", "model")
    return (dp, tp), ("data", "model")


def make_elastic_mesh(n_chips: Optional[int] = None, **kw) -> Mesh:
    devices = jax.devices()
    n = n_chips or len(devices)
    shape, axes = plan_mesh(n, **kw)
    used = int(np.prod(shape))
    return Mesh(np.array(devices[:used]).reshape(shape), axes)


def reshard_plan(params_shapes: Any, mesh: Mesh, shape: ShapeCfg) -> Any:
    """Shardings for restoring a host checkpoint onto ``mesh``."""
    rules = rules_for(mesh, shape)
    return tree_shardings(params_shapes, mesh, rules)
