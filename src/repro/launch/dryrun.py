import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Collective-byte accounting reads the post-SPMD-partitioner HLO dump:
# the CPU backend's float normalization upcasts bf16 dots (and thus the
# GSPMD collectives fused around them) to f32, inflating byte counts 2x
# vs. the TPU lowering. The pass-level dump runs before that.
_DUMP_DIR = f"/tmp/repro_xla_dump_{os.getpid()}"
os.environ["XLA_FLAGS"] += (
    f" --xla_dump_to={_DUMP_DIR} --xla_dump_hlo_pass_re=spmd-partitioning"
)

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
cell lowers, compiles, fits, and report its roofline inputs.

For each cell:
  1. FULL model (lax.scan over layers) -> .lower().compile() on the
     production mesh; memory_analysis() proves the per-device footprint
     fits a 16 GB v5e chip; the collective schedule comes from the same
     artifact.
  2. COST variants: 1-period and 2-period python-unrolled models ->
     exact per-period FLOPs / HLO bytes / collective bytes (XLA's
     cost_analysis counts a while-loop body once, verified), linearly
     extrapolated to the full depth:  total = u1 + (P-1)(u2-u1).

Artifacts land in benchmarks/artifacts/<cell>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline via benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/artifacts
"""
import argparse
import dataclasses
import glob
import json
import shutil
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis import hlo as hlo_mod
from repro.analysis import roofline as rf
from repro.configs import ASSIGNED, SHAPES, get_arch
from repro.configs.base import ArchConfig, ShapeCfg
from repro.distributed.fedpod import make_dp_step, make_fed_round
from repro.distributed.sharding import use_rules
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.nn.transformer import ModelOptions
from repro.optim import adamw


def arch_period(cfg: ArchConfig) -> int:
    if cfg.attn_every:
        return cfg.attn_every
    if cfg.local_global_period:
        return cfg.local_global_period
    if cfg.block_pattern:
        return len(cfg.block_pattern)
    return 1


def with_periods(cfg: ArchConfig, k: int) -> ArchConfig:
    per = arch_period(cfg)
    kw = {"n_layers": per * k}
    if cfg.encoder_layers:
        kw["encoder_layers"] = k
    return cfg.with_(**kw)


def count_params(shapes: Any) -> int:
    return int(sum(s.size for s in jax.tree.leaves(shapes)))


def active_dense_params(cfg: ArchConfig, model, params_shapes) -> float:
    """Dense-equivalent active params for MODEL_FLOPS (6·N_active·D)."""
    composed = jax.eval_shape(model.precompose, params_shapes)
    flat = jax.tree_util.tree_flatten_with_path(composed)[0]
    total, expert, embed = 0, 0, 0
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        total += leaf.size
        if "experts" in key:
            expert += leaf.size
        if "embed/" in key or key.endswith("embed/w"):
            if "unembed" not in key:
                embed += leaf.size
    active = total - embed
    if cfg.n_experts and expert:
        active -= expert * (1.0 - cfg.experts_per_token / cfg.n_experts)
    return float(active)


def _shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _clear_dump():
    if os.path.isdir(_DUMP_DIR):
        shutil.rmtree(_DUMP_DIR, ignore_errors=True)


def _post_spmd_text() -> Optional[str]:
    """Newest post-SPMD-partitioner pass dump (bf16-faithful collectives)."""
    files = sorted(glob.glob(os.path.join(
        _DUMP_DIR, "*after_spmd-partitioning*.txt")))
    if not files:
        return None
    return open(files[-1]).read()


def _analyze(compiled, pod_size: int) -> Dict:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per computation
        cost = cost[0] if cost else {}
    spmd_txt = _post_spmd_text()
    colls = hlo_mod.collective_stats(spmd_txt if spmd_txt is not None
                                     else compiled.as_text(), pod_size)
    return {
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        },
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "collectives": colls,
    }


def lower_cell(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh, *,
               fed: bool, opts: ModelOptions, fed_local_steps: int = 4,
               donate: bool = True, variant: Optional[Dict] = None):
    """Build + lower + compile one cell; returns (compiled, cell)."""
    variant = variant or {}
    cell = specs_mod.build_cell(cfg, shape, mesh, opts, fed=fed,
                                fed_local_steps=fed_local_steps,
                                n_pods=mesh.shape.get("pod", 1) if fed else 2,
                                seq_parallel=variant.get("seq_parallel", True),
                                int8=variant.get("int8", False))
    model, rules = cell["model"], cell["rules"]
    pspec = _shardings(mesh, cell["param_specs"])
    bspec = _shardings(mesh, cell["batch_specs"])
    scalar = NamedSharding(mesh, P())

    # ZeRO-3 split between STORAGE (2D fsdp2/tp2) and COMPUTE (1D) factor
    # shardings: params enter the step 2D-sharded and are re-constrained
    # to the 1D compute layout (a cheap factor all-gather whose transpose
    # reduce-scatters the gradients back). Without this, GSPMD pushes the
    # 2D storage layout into the compose dots and replicates work
    # (measured 4x per-device FLOPs on llama3-405B).
    from repro.distributed.sharding import AxisRules as _AR, tree_param_specs as _tps
    rules_c = _AR(mesh, {**rules.rules,
                         "fsdp2": rules.rules.get("fsdp", "data"),
                         "tp2": rules.rules.get("tp", "model")})

    def _to_compute(params):
        if shape.kind == "decode":
            return params
        base = cell.get("base_params_shapes")
        if base is not None:  # fed: stacked leading pod dim
            cspecs = jax.tree.map(
                lambda sp: P("pod", *sp), _tps(base, rules_c),
                is_leaf=lambda x: isinstance(x, P))
        else:
            cspecs = _tps(params, rules_c)
        return jax.tree.map(
            lambda x, sp: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, sp)), params, cspecs)

    with use_rules(rules):
        if shape.kind == "train":
            opt = adamw(3e-4)
            from repro.distributed.sharding import tree_param_specs
            if fed:
                # per-pod optimizer state: every leaf (incl. the scalar
                # step) gets a leading n_pods dim sharded over 'pod'
                n_pods = mesh.shape["pod"]
                base_opt = jax.eval_shape(opt.init, cell["base_params_shapes"])
                base_ospec = tree_param_specs(base_opt, rules)
                opt_shapes = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((n_pods, *s.shape), s.dtype),
                    base_opt)
                ospec_tree = jax.tree.map(
                    lambda sp: P("pod", *sp), base_ospec,
                    is_leaf=lambda x: isinstance(x, P))
            else:
                opt_shapes = jax.eval_shape(opt.init, cell["params_shapes"])
                ospec_tree = tree_param_specs(opt_shapes, rules)
            ospec = _shardings(mesh, ospec_tree)
            accum = variant.get("accum", 1)
            if fed:
                inner = make_fed_round(
                    model.loss, opt, local_steps=fed_local_steps,
                    sync=variant.get("sync", "factors"),
                    sync_dtype=(jnp.bfloat16
                                if variant.get("sync_dtype") == "bf16" else None),
                    accum=accum)
            else:
                inner = make_dp_step(model.loss, opt, accum=accum)

            def step(params, opt_state, batch):
                return inner(_to_compute(params), opt_state, batch)

            jitted = jax.jit(
                step,
                in_shardings=(pspec, ospec, bspec),
                out_shardings=(pspec, ospec, scalar),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(cell["params_shapes"], opt_shapes,
                                   cell["batch_shapes"])
        elif shape.kind == "prefill":
            cspec = _shardings(mesh, cell["cache_specs"])
            logits_spec = NamedSharding(
                mesh, rules.spec(("batch", "vocab"),
                                 (shape.global_batch, cfg.vocab_size)))

            if cfg.is_encdec:
                def step(params, batch, cache):
                    return model.prefill(_to_compute(params), batch, cache)
            else:
                def step(params, batch, cache):
                    return model.prefill(_to_compute(params), batch["tokens"],
                                         cache)

            jitted = jax.jit(step, in_shardings=(pspec, bspec, cspec),
                             out_shardings=(cspec, logits_spec),
                             donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(cell["params_shapes"], cell["batch_shapes"],
                                   cell["cache_shapes"])
        else:  # decode
            cspec = _shardings(mesh, cell["cache_specs"])
            logits_spec = NamedSharding(
                mesh, rules.spec(("batch", "vocab"),
                                 (shape.global_batch, cfg.vocab_size)))

            def step(params, cache, token, pos):
                return model.decode_step(params, cache, token, pos)

            jitted = jax.jit(
                step,
                in_shardings=(pspec, cspec, bspec["token"], bspec["pos"]),
                out_shardings=(logits_spec, cspec),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(cell["params_shapes"], cell["cache_shapes"],
                                   cell["batch_shapes"]["token"],
                                   cell["batch_shapes"]["pos"])
        compiled = lowered.compile()
    return compiled, cell


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             fed: Optional[bool] = None, quick: bool = False,
             skip_cost: bool = False, fed_local_steps: int = 4,
             variant: Optional[Dict] = None) -> Dict:
    variant = dict(variant or {})
    cfg = get_arch(arch)
    # default gradient accumulation for the widest models: per-chip
    # batch*seq at 256 chips otherwise exceeds HBM (napkin: llama3-405B
    # gathered activation (16,4096,16384)bf16 = 2.1GB x ~6 live)
    if shape_name == "train_4k" and "accum" not in variant:
        # measured in §Perf D-series: MoE dispatch buffers scale with the
        # per-micro batch; mixtral fits HBM at accum=16
        variant["accum"] = {"llama3-405b": 8, "chameleon-34b": 4,
                            "mixtral-8x22b": 16,
                            "llama4-scout-17b-a16e": 8}.get(arch, 1)
    kw = {}
    if variant.get("capacity_factor"):
        kw["moe_capacity_factor"] = variant["capacity_factor"]
    if variant.get("param_kind") or variant.get("gamma") is not None:
        kw["param"] = cfg.param.__class__(
            kind=variant.get("param_kind", cfg.param.kind),
            gamma=(cfg.param.gamma if variant.get("gamma") is None
                   else variant["gamma"]))
    if kw:
        cfg = cfg.with_(**kw)
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    art: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "fed": bool(fed) if fed is not None else (multi and shape.kind == "train"),
    }

    # applicability gates
    if shape_name == "long_500k" and not cfg.subquadratic:
        art.update(skipped=True,
                   reason="pure full-attention arch: long_500k requires "
                          "sub-quadratic attention (DESIGN.md §6)")
        return art
    if shape.kind == "decode" and getattr(cfg, "encoder_only", False):
        art.update(skipped=True, reason="encoder-only arch: no decode step")
        return art

    if quick:
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model")) if multi \
            else make_mesh((2, 2), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi)
    pod_size = (mesh.devices.size // mesh.shape["pod"]) if "pod" in mesh.shape else 0
    use_fed = art["fed"] and multi and shape.kind == "train"
    art["fed"] = use_fed

    opts = ModelOptions(scan_layers=True,
                        attn_chunk=variant.get("attn_chunk", 512),
                        logit_chunk=variant.get("logit_chunk", 1024),
                        int8_kv=variant.get("int8_kv", False))
    if variant:
        art["variant"] = {k: v for k, v in variant.items()}
    t0 = time.time()
    _clear_dump()
    compiled, cell = lower_cell(cfg, shape, mesh, fed=use_fed, opts=opts,
                                fed_local_steps=fed_local_steps,
                                variant=variant)
    art["compile_seconds"] = round(time.time() - t0, 2)
    full = _analyze(compiled, pod_size)
    art["memory"] = full["memory"]
    art["collectives_scan_model"] = {
        k: v for k, v in full["collectives"].items()
        if k in ("total", "cross_pod", "intra_pod")}

    # ---- model-level accounting
    model = cell["model"]
    base_params_shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    art["trainable_params"] = count_params(base_params_shapes)
    art["fed_local_steps"] = fed_local_steps if use_fed else None
    n_active = active_dense_params(cfg, model, base_params_shapes)
    art["dense_equiv_active_params"] = n_active
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    art["tokens_per_step"] = tokens
    if shape.kind == "train":
        art["model_flops_global"] = rf.model_flops_train(int(n_active), tokens)
    else:
        art["model_flops_global"] = rf.model_flops_forward(int(n_active), tokens)

    # ---- cost extrapolation (exact per-period counting)
    if not skip_cost:
        per = arch_period(cfg)
        periods_total = cfg.n_layers // per
        opts_u = dataclasses.replace(opts, scan_layers=False)
        t1 = time.time()
        cost_u = []
        variant_u = {k: v for k, v in variant.items() if k != "accum"}
        for k in (1, 2):
            _clear_dump()
            ck = with_periods(cfg, k)
            comp_k, _ = lower_cell(ck, shape, mesh, fed=use_fed, opts=opts_u,
                                   fed_local_steps=1, donate=False,
                                   variant=variant_u)
            cost_u.append(_analyze(comp_k, pod_size))
        art["cost_variant_seconds"] = round(time.time() - t1, 2)
        u1, u2 = cost_u
        art["flops_per_device"] = max(
            0.0, u1["flops"] + (periods_total - 1) * (u2["flops"] - u1["flops"]))
        art["bytes_per_device"] = max(
            0.0, u1["bytes_accessed"]
            + (periods_total - 1) * (u2["bytes_accessed"] - u1["bytes_accessed"]))
        colls = hlo_mod.extrapolate(u1["collectives"], u2["collectives"],
                                    periods_total)
        art["collectives"] = colls
        art["collective_bytes_per_device"] = colls.get("total", {}).get("bytes", 0.0)
        art["cross_pod_bytes_per_device"] = colls.get("cross_pod", {}).get("bytes", 0.0)
        if use_fed:  # amortize the per-round numbers over K local steps
            K = fed_local_steps
            art["flops_per_device"] /= 1.0  # u-variants lowered with K=1
            art["per_step_cross_pod_bytes"] = art["cross_pod_bytes_per_device"] / K
        terms = rf.terms_from_artifact(art)
        art["roofline"] = {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "cross_pod_s": terms.cross_pod_s,
            "dominant": terms.dominant,
            "roofline_fraction": terms.roofline_fraction,
        }
        chips = int(mesh.devices.size)
        art["chips"] = chips
        art["useful_flops_ratio"] = (
            art["model_flops_global"] / (art["flops_per_device"] * chips)
            if art["flops_per_device"] else 0.0)
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fed", action="store_true", default=None)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells whose artifact already exists")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                name = f"{arch}_{shape}_{mesh_kind}"
                path = os.path.join(args.out, name + ".json")
                if not args.force and os.path.exists(path):
                    try:
                        prev = json.load(open(path))
                        if "error" not in prev:
                            print(f"=== {name} (cached)", flush=True)
                            continue
                    except Exception:
                        pass
                print(f"=== {name}", flush=True)
                try:
                    art = run_cell(arch, shape, mesh_kind, fed=args.fed,
                                   quick=args.quick, skip_cost=args.skip_cost,
                                   fed_local_steps=args.local_steps)
                except Exception as e:  # a failing cell is a bug — surface it
                    failures += 1
                    art = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"FAILED {name}: {art['error']}", flush=True)
                with open(os.path.join(args.out, name + ".json"), "w") as f:
                    json.dump(art, f, indent=1, default=float)
                if "roofline" in art:
                    r = art["roofline"]
                    print(f"  mem/device: {art['memory']['argument_bytes']/1e9:.2f}GB args "
                          f"+ {art['memory']['temp_bytes']/1e9:.2f}GB temp | "
                          f"compute {r['compute_s']*1e3:.2f}ms mem {r['memory_s']*1e3:.2f}ms "
                          f"coll {r['collective_s']*1e3:.2f}ms -> {r['dominant']}",
                          flush=True)
                elif art.get("skipped"):
                    print(f"  skipped: {art['reason']}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
