"""ShapeDtypeStruct input specs + sharding trees for every
(architecture x shape) cell — the shannon/kernels pattern: weak-type
correct, shardable, zero device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg
from repro.distributed.sharding import AxisRules, tree_param_specs
from repro.nn.transformer import ModelOptions, build_model


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def rules_for(mesh: Optional[Mesh], shape: ShapeCfg, *, fed: bool = False,
              seq_parallel: bool = True) -> AxisRules:
    """Logical->physical mapping per shape kind (see DESIGN.md §4)."""
    overrides: Dict[str, Any] = {}
    dp = ("pod", "data") if (mesh is not None and "pod" in mesh.axis_names and not fed) \
        else "data"
    overrides["batch"] = dp
    overrides["fsdp"] = dp
    if isinstance(dp, tuple):  # non-fed multi-pod: storage shards over pod too
        overrides["fsdp2"] = ("pod", "data", "model")
        overrides["tp2"] = ("model", "pod", "data")
    if shape.kind in ("train", "prefill") and seq_parallel:
        # Megatron-style sequence parallelism on the residual stream:
        # saved layer inputs are (B/dp, S/model, d) — without this, 36+
        # full (B,S,d) remat residuals alone exceed a v5e's 16 GB HBM.
        overrides["seq"] = "model"
        # flash-over-sharded-KV: K/V and the (C, S) score tiles stay
        # sharded along the KV-seq dim; softmax/AV reduce via psums.
        # A 32k-prefill score tile at llama3-405B width is 17GB unsharded.
        overrides["kv_seq_attn"] = "model"
    if shape.kind == "prefill":
        overrides["kv_seq"] = "model"
    if shape.kind == "decode":
        if shape.global_batch == 1:  # long-context: shard the KV sequence
            overrides["batch"] = None
            overrides["kv_seq"] = (("pod", "data", "model")
                                   if mesh is not None and "pod" in mesh.axis_names
                                   else ("data", "model"))
        else:
            overrides["kv_seq"] = "model"
    return AxisRules(mesh, overrides)


# ----------------------------------------------------------- batch specs

def batch_specs(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {"tokens": sds((B, S + 1), jnp.int32)}
        if cfg.is_encdec:
            out["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return out
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32)}
        if cfg.is_encdec:
            out["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return out
    # decode: one new token against a cache of S
    return {"token": sds((B, 1), jnp.int32), "pos": sds((), jnp.int32)}


def batch_partition_specs(cfg: ArchConfig, shape: ShapeCfg, rules: AxisRules) -> Dict:
    dp = rules.rules["batch"]
    B = shape.global_batch
    def bspec(*rest):
        ax = dp if (dp and B % rules._axis_size(dp) == 0) else None
        return P(ax, *rest)

    if shape.kind in ("train", "prefill"):
        out = {"tokens": bspec(None)}
        if cfg.is_encdec:
            out["frames"] = bspec(None, None)
        return out
    return {"token": bspec(None), "pos": P()}


# ----------------------------------------------------------- cache specs

def cache_partition_specs(cfg: ArchConfig, cache_shapes: Any, rules: AxisRules) -> Any:
    """Per-leaf specs for the decode caches of every model family."""
    batch_ax = rules.rules["batch"]
    seq_ax = rules.rules["kv_seq"]

    def visit(path_elems, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_elems)
        shp = leaf.shape
        def ok(ax, dim):
            return ax is not None and dim % rules._axis_size(ax) == 0
        name = path.rsplit("/", 1)[-1]
        if name in ("k", "v", "k_q", "v_q", "k_s", "v_s") and len(shp) == 5:
            return P(None,
                     batch_ax if ok(batch_ax, shp[1]) else None,
                     seq_ax if ok(seq_ax, shp[2]) else None,
                     None, None)
        if name == "ssm" and len(shp) == 6:           # (sites,per,B,H,P,N)
            return P(None, None,
                     batch_ax if ok(batch_ax, shp[2]) else None,
                     "model" if ok("model", shp[3]) else None, None, None)
        if name == "conv" and len(shp) == 4:          # (sites*? ,B,K-1,D)
            return P(*([None] * (len(shp) - 1)), None)
        if len(shp) >= 1 and batch_ax is not None and shp[0] % rules._axis_size(batch_ax) == 0 \
                and name in ("C", "n", "m", "c", "h"):
            return P(batch_ax, *([None] * (len(shp) - 1)))
        if name == "conv" and len(shp) == 5:          # (sites,per,B,K-1,D)
            return P(None, None,
                     batch_ax if ok(batch_ax, shp[2]) else None, None, None)
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(visit, cache_shapes)


# ------------------------------------------------------------- cell specs

def build_cell(cfg: ArchConfig, shape: ShapeCfg, mesh: Optional[Mesh],
               opts: ModelOptions, *, fed: bool = False,
               fed_local_steps: int = 4, n_pods: int = 2,
               seq_parallel: bool = True, int8: bool = False):
    """Everything the dry-run needs for one (arch x shape x mesh) cell:
    model, abstract inputs, and matching sharding trees."""
    model = build_model(cfg, opts)
    rules = rules_for(mesh, shape, fed=fed, seq_parallel=seq_parallel)
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(model.init_params, key)
    specs = tree_param_specs(params_shapes, rules)

    out: Dict[str, Any] = {"model": model, "rules": rules,
                           "params_shapes": params_shapes, "param_specs": specs}

    if shape.kind == "train":
        bs = batch_specs(cfg, shape)
        bps = batch_partition_specs(cfg, shape, rules)
        if fed:
            K = fed_local_steps
            def stack(s, extra):
                return sds((n_pods, *extra, *s.shape[1:]), s.dtype)
            per_pod = shape.global_batch // n_pods
            fed_bs = {k: sds((n_pods, K, per_pod, *v.shape[1:]), v.dtype)
                      for k, v in bs.items()}
            fed_bps = {k: P("pod", None, *v) for k, v in bps.items()}
            out["batch_shapes"] = fed_bs
            out["batch_specs"] = fed_bps
            out["base_params_shapes"] = params_shapes
            out["base_param_specs"] = specs
            out["params_shapes"] = jax.tree.map(
                lambda s: sds((n_pods, *s.shape), s.dtype), params_shapes)
            out["param_specs"] = jax.tree.map(
                lambda s: P("pod", *s), specs, is_leaf=lambda x: isinstance(x, P))
        else:
            out["batch_shapes"] = bs
            out["batch_specs"] = bps
        return out

    if shape.kind == "prefill":
        out["batch_shapes"] = batch_specs(cfg, shape)
        out["batch_specs"] = batch_partition_specs(cfg, shape, rules)
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        out["cache_shapes"] = cache_shapes
        out["cache_specs"] = cache_partition_specs(cfg, cache_shapes, rules)
        return out

    # decode: pre-composed weights (the paper pre-composes W for serving)
    composed_shapes = jax.eval_shape(
        lambda p: model.precompose(p, int8=int8), params_shapes)
    out["params_shapes"] = composed_shapes
    out["param_specs"] = tree_param_specs(composed_shapes, rules)
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    out["cache_shapes"] = cache_shapes
    out["cache_specs"] = cache_partition_specs(cfg, cache_shapes, rules)
    out["batch_shapes"] = batch_specs(cfg, shape)
    out["batch_specs"] = batch_partition_specs(cfg, shape, rules)
    return out
