"""End-to-end training driver.

Two modes:
  --mode pods   Cross-pod federated local-SGD (the paper's protocol on
                the 'pod' mesh axis) or plain DP/TP — runs on whatever
                devices exist (use dryrun.py for the 512-device lowering
                proof; this driver EXECUTES on real hardware or small
                CPU meshes).
  --mode fl     Classic client/server FL simulation (VGG/LSTM/MLP on
                synthetic datasets) — the paper's own experimental
                regime.

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode fl --model mlp --rounds 10
  PYTHONPATH=src python -m repro.launch.train --mode pods --arch qwen3-8b \
      --preset cpu-small --steps 20
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.configs.base import ParamCfg, ShapeCfg
from repro.data import ShardedBatcher, make_token_lm_dataset
from repro.distributed.fedpod import (
    make_dp_step, make_fed_round, stack_for_pods)
from repro.distributed.sharding import use_rules
from repro.launch import specs as specs_mod
from repro.nn.transformer import ModelOptions, build_model
from repro.optim import adamw, chain_clip


def cpu_small(cfg):
    """Shrink an arch config so it trains for real on CPU."""
    return cfg.with_(
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=128, n_heads=4,
        n_kv_heads=min(4, cfg.n_kv_heads), head_dim=32,
        d_ff=256 if cfg.d_ff else 0, vocab_size=512,
        **({"n_experts": 4, "experts_per_token": min(2, cfg.experts_per_token)}
           if cfg.n_experts else {}),
        **({"encoder_layers": 2, "encoder_seq": 16} if cfg.encoder_layers else {}),
    )


def train_pods(args):
    cfg = get_arch(args.arch)
    if args.preset == "cpu-small":
        cfg = cpu_small(cfg)
    seq, batch = args.seq, args.batch
    devices = jax.devices()
    n_pods = args.pods
    if len(devices) >= 2 * n_pods and n_pods > 1:
        dp = len(devices) // n_pods
        mesh = Mesh(np.array(devices[: n_pods * dp]).reshape(n_pods, dp, 1),
                    ("pod", "data", "model"))
    else:
        mesh = Mesh(np.array(devices[:1]).reshape(1, 1), ("data", "model"))
        n_pods = 1

    shape = ShapeCfg("custom", seq, batch, "train")
    opts = ModelOptions(attn_chunk=min(512, seq), ssm_chunk=min(256, seq),
                        logit_chunk=min(1024, seq), scan_layers=True,
                        use_pallas=args.use_pallas)
    model = build_model(cfg, opts)
    rules = specs_mod.rules_for(mesh, shape, fed=n_pods > 1)
    key = jax.random.PRNGKey(args.seed)

    with use_rules(rules):
        params = model.init_params(key)
    opt = chain_clip(adamw(args.lr), 1.0)
    opt_state = opt.init(params)

    data = make_token_lm_dataset(max(512, batch * 8), seq + 1, cfg.vocab_size,
                                 seed=args.seed)
    fed = n_pods > 1
    ckpt = CheckpointManager(args.ckpt_dir, keep=2, async_save=True) \
        if args.ckpt_dir else None

    if fed:
        K = args.local_steps
        params = stack_for_pods(params, n_pods)
        opt_state = stack_for_pods(opt_state, n_pods)
        step_fn = jax.jit(make_fed_round(model.loss, opt, local_steps=K,
                                         sync=args.sync))
        batcher = ShardedBatcher({"tokens": data}, batch * K)
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            (params, opt_state), extra = ckpt.restore(
                None, (params, opt_state))
            start = extra.get("step", 0)
            batcher.restore(extra.get("stream", batcher.position()))
        batcher.start()
        for step in range(start, args.steps):
            raw = batcher.get()["tokens"]
            tokens = raw.reshape(n_pods, K, batch // n_pods, seq + 1)
            t0 = time.time()
            with use_rules(rules):
                params, opt_state, loss = step_fn(params, opt_state,
                                                  {"tokens": jnp.asarray(tokens)})
            if step % args.log_every == 0:
                print(f"round {step} loss {float(loss):.4f} "
                      f"({time.time()-t0:.2f}s)", flush=True)
            if ckpt and step and step % args.ckpt_every == 0:
                ckpt.save(step, (params, opt_state),
                          extra={"step": step, "stream": batcher.position()})
        batcher.stop()
    else:
        step_fn = jax.jit(make_dp_step(model.loss, opt), donate_argnums=(0, 1))
        batcher = ShardedBatcher({"tokens": data}, batch)
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            (params, opt_state), extra = ckpt.restore(None, (params, opt_state))
            start = extra.get("step", 0)
            batcher.restore(extra.get("stream", batcher.position()))
        batcher.start()
        for step in range(start, args.steps):
            batch_np = batcher.get()
            t0 = time.time()
            with use_rules(rules):
                params, opt_state, loss = step_fn(
                    params, opt_state, {"tokens": jnp.asarray(batch_np["tokens"])})
            if step % args.log_every == 0:
                print(f"step {step} loss {float(loss):.4f} "
                      f"({time.time()-t0:.2f}s)", flush=True)
            if ckpt and step and step % args.ckpt_every == 0:
                ckpt.save(step, (params, opt_state),
                          extra={"step": step, "stream": batcher.position()})
        batcher.stop()
    if ckpt:
        ckpt.save(args.steps, (params, opt_state), extra={"step": args.steps})
        ckpt.wait()
    print("done")


def train_fl(args):
    """Paper-regime FL simulation on synthetic data."""
    from repro.data import dirichlet_partition, make_image_dataset, train_test_split
    from repro.fl import (ClientConfig, FaultPlan, FLServer, ServerConfig,
                          make_strategy)
    from repro.nn import recurrent as rec

    if args.model == "mlp":
        ds = make_image_dataset(4000, 10, size=28, channels=1, noise=0.4,
                                seed=args.seed)
        data = {"x": ds["x"].reshape(len(ds["y"]), -1), "y": ds["y"]}
        tr, te = train_test_split(data)
        cfg = rec.MLPConfig(in_dim=784, hidden=256, classes=10,
                            param=ParamCfg(kind=args.param, gamma=args.gamma,
                                           min_dim_for_factorization=8,
                                           use_pallas=args.use_pallas))
        params = rec.init_mlp_model(jax.random.PRNGKey(args.seed), cfg)
        loss_fn = functools.partial(_mlp_loss, cfg)
        def eval_fn(p):
            return float(rec.mlp_accuracy(p, cfg, {"x": te["x"][:1000],
                                                   "y": te["y"][:1000]}))
    else:
        raise SystemExit("--mode fl supports --model mlp here; use "
                         "benchmarks/ for VGG16/LSTM experiments")

    parts = dirichlet_partition(tr["y"], args.clients, 0.5, seed=args.seed)
    mesh = None
    if (args.engine in ("batched", "streaming", "async")
            and len(jax.devices()) > 1):
        mesh = Mesh(np.array(jax.devices()), ("clients",))
    gamma_tiers = tuple(float(g) for g in args.gamma_tiers.split(",")
                        if g.strip()) if args.gamma_tiers else ()
    plan = (FaultPlan(rate=args.fault_rate, seed=args.seed)
            if args.fault_rate > 0 else None)
    srv = FLServer(loss_fn, params, tr, parts, make_strategy(args.strategy),
                   ClientConfig(lr=args.lr, batch=64, epochs=args.local_epochs),
                   ServerConfig(clients=args.clients, participation=0.16,
                                rounds=args.rounds,
                                personalization=args.personalization,
                                uplink_codec=args.uplink_codec,
                                downlink_codec=args.downlink_codec,
                                engine=args.engine,
                                client_chunk=args.client_chunk,
                                gamma_tiers=gamma_tiers,
                                tier_assignment=args.tier_assignment,
                                state_store=args.state_store,
                                data_stream=args.data_stream,
                                defense=args.defense, faults=plan,
                                recover_retries=args.recover_retries,
                                buffer_k=args.buffer_k,
                                staleness=args.staleness,
                                max_staleness=args.max_staleness),
                   eval_fn=eval_fn, mesh=mesh)
    ckpt = (CheckpointManager(args.ckpt_dir, keep=2)
            if args.ckpt_dir else None)
    if args.resume:
        if ckpt is None:
            raise SystemExit("--resume requires --ckpt-dir")
        if ckpt.latest_step() is not None:
            step = srv.restore_checkpoint(ckpt)
            print(f"resumed at round {step}", flush=True)
    hist = srv.run(log_every=1, ckpt=ckpt,
                   ckpt_every=max(1, args.ckpt_every) if ckpt else 1)
    hist[-1]["comm_up_mb"] = srv.comm_log.up_bytes / 1e6
    hist[-1]["comm_down_mb"] = srv.comm_log.down_bytes / 1e6
    print(json.dumps(hist[-1], indent=1))


def _mlp_loss(cfg, p, b):
    from repro.nn import recurrent as rec

    return rec.mlp_loss(p, cfg, b)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="pods", choices=["pods", "fl"])
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--preset", default="cpu-small", choices=["cpu-small", "full"])
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--sync", default="factors", choices=["factors", "full"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    # fl mode
    ap.add_argument("--model", default="mlp")
    ap.add_argument("--strategy", default="fedavg")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--param", default="fedpara")
    ap.add_argument("--gamma", type=float, default=0.3)
    ap.add_argument("--personalization", default="none")
    ap.add_argument("--uplink-codec", default="",
                    help="uplink codec spec, e.g. 'delta|topk0.1|int8' "
                         "(stages: delta, topk<f>, lowrank<r>, int8, fp16)")
    ap.add_argument("--downlink-codec", default="",
                    help="downlink codec spec (same grammar); applied to "
                         "the payload clients actually train on")
    ap.add_argument("--engine", default="batched",
                    choices=["sequential", "batched", "streaming", "async"],
                    help="FL round engine: sequential reference loop, the "
                         "client-batched vmap/shard_map program, the "
                         "streaming chunked scan (O(chunk) round memory — "
                         "use for cohorts the stacked engine cannot hold), "
                         "or the event-driven async buffered engine "
                         "(FedBuff-style; see docs/async.md and "
                         "--buffer-k/--staleness/--max-staleness)")
    ap.add_argument("--buffer-k", type=int, default=0,
                    help="async engine: folded arrivals per version bump "
                         "(0 = the sync participation target, the parity "
                         "regime)")
    ap.add_argument("--staleness", default="constant",
                    help="async engine staleness weight s(tau): constant, "
                         "poly[:a] = (1+tau)^-a, or hinge[:b] (flat up to "
                         "b versions, hyperbolic decay past it)")
    ap.add_argument("--max-staleness", type=int, default=-1,
                    help="async engine: drop arrivals staler than this "
                         "many versions (-1 = never drop)")
    ap.add_argument("--client-chunk", type=int, default=16,
                    help="streaming engine: clients per scan step; round "
                         "memory peaks at O(client_chunk * model)")
    ap.add_argument("--state-store", default="dict",
                    choices=["dict", "arena"],
                    help="per-client state residency: host dicts, or the "
                         "device-resident index-addressed arena (one "
                         "vectorized gather/scatter per round; batched "
                         "and streaming engines only)")
    ap.add_argument("--data-stream", default="eager",
                    choices=["eager", "chunked"],
                    help="cohort batch materialization: eager full-cohort "
                         "host stack, or chunked per-scan-step host "
                         "callbacks (streaming engine only; host memory "
                         "stays O(client_chunk))")
    ap.add_argument("--gamma-tiers", default="",
                    help="heterogeneous capacity tiers: comma-separated "
                         "rank gammas, one per device tier (e.g. "
                         "'0.05,0.1,0.3'); each client trains/uploads "
                         "only the leading tier-rank factor columns and "
                         "is charged the sliced wire bytes. Empty = "
                         "uniform full-rank clients")
    ap.add_argument("--tier-assignment", default="round_robin",
                    choices=["round_robin", "random", "size"],
                    help="client->tier rule for --gamma-tiers: cid mod T, "
                         "seeded uniform draw, or by local dataset size "
                         "(more data -> larger-gamma tier)")
    ap.add_argument("--defense", default="none",
                    choices=["none", "clip", "trimmed"],
                    help="compiled upload screening + robust aggregation: "
                         "clip (all engines; median-norm clipping), "
                         "trimmed (batched engine only: coordinate-wise "
                         "trimmed mean). See docs/robustness.md")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="chaos injection: per-client per-round fault "
                         "probability (deterministic in --seed; kinds: "
                         "crash/nan/bitflip/byzantine/stale)")
    ap.add_argument("--recover-retries", type=int, default=0,
                    help="round-level recovery: re-sample a replacement "
                         "cohort up to N times when crashed+rejected "
                         "clients exceed half the participants")
    ap.add_argument("--resume", action="store_true",
                    help="fl mode: restore the latest checkpoint in "
                         "--ckpt-dir and continue to --rounds (bitwise "
                         "identical to the uninterrupted run)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route every FedPara dense() through the fused "
                         "differentiable Pallas kernels: local training "
                         "never materializes the dense W (custom VJP; "
                         "O(r(m+n)) HBM instead of O(mn) per layer/step). "
                         "Applies to both --mode fl (MLP param cfg) and "
                         "--mode pods (transformer ModelOptions)")
    args = ap.parse_args()
    if args.mode == "pods":
        train_pods(args)
    else:
        train_fl(args)


if __name__ == "__main__":
    main()
