"""Fused dequant-and-accumulate Pallas kernels for streaming aggregation.

The FL server's hot reduction is  acc += Σ_c coeff_c · dequant(q_c)
over a client-stacked uplink wire buffer, where ``coeff_c`` folds the
arrival mask, the aggregation weight and (for int8 payloads) the
per-client quantizer scale into one fp32 scalar. The dense path
dequantizes the whole (C, L) int8 stack to fp32 in HBM (writing and
re-reading 4 bytes per element) before reducing it; the fused kernel
consumes the int8 values directly — each (bc, bl) wire tile is loaded
ONCE at 1 byte/element, converted in VMEM, and contracted against the
(1, bc) coefficient row into a resident (1, bl) fp32 accumulator tile.
HBM traffic drops from ≈ 9·C·L bytes (int8 read + fp32 write + fp32
read + reduce) to C·L + 8·L bytes.

Kernel layout: inputs are flattened to (C, L); grid is (L/bl, C/bc)
with the client axis innermost/sequential. Each L-tile's accumulator
lives in VMEM scratch, seeded from the incoming ``acc`` block at the
first client step and written to the (aliased) output at the last, so
the accumulation is one pass and ``acc`` can be donated by the caller.
Masked / padded clients carry coefficient 0.0 and int8 payloads are
finite by construction, so padding rows contribute exact zeros.

Tree-level API: :func:`tree_dequant_acc` walks a codec wire tree
(``{"q", "scale"}`` int8 nodes, fp16 or fp32 dense leaves — see
``Codec.encode_for_agg``) against a payload-structured fp32 accumulator
tree. :func:`sharded_tree_dequant_acc` is the two-level path for
shard_map meshes: each device reduces its client shard with the kernel
(partial sums), then one ``psum`` over the mesh axis combines the
per-shard partials — the classic hierarchical aggregation tree.

Oracle: ``repro.kernels.ref.tree_dequant_acc_ref`` (dense jnp).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import blocks

_QKEYS = frozenset(("q", "scale"))


def _is_qnode(n: Any) -> bool:
    return isinstance(n, dict) and set(n) == _QKEYS


def _pad_axis(a: jax.Array, axis: int, mult: int) -> jax.Array:
    rem = a.shape[axis] % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(a, pad)


# ------------------------------------------------------------------ kernel

def _agg_body(coeff_ref, q_ref, acc_ref, o_ref, scratch_ref, *, n_kc: int):
    """One (bc, bl) wire tile: scratch(1, bl) += coeff(1, bc) @ deq(q)."""
    kc = pl.program_id(1)

    @pl.when(kc == 0)
    def _seed():
        scratch_ref[...] = acc_ref[...].astype(jnp.float32)

    # The dequant happens here: the tile is loaded at its wire itemsize
    # (1 B for int8) and widened to fp32 in VMEM only.
    qf = q_ref[...].astype(jnp.float32)
    scratch_ref[...] += jax.lax.dot_general(
        coeff_ref[...], qf, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kc == n_kc - 1)
    def _done():
        o_ref[...] = scratch_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_l", "interpret"))
def dequant_acc(
    acc: jax.Array,
    q: jax.Array,
    coeff: jax.Array,
    *,
    block_c: Optional[int] = None,
    block_l: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """acc (L,) fp32 += coeff (C,) fp32 @ dequant(q (C, L)) in one pass.

    ``q`` may be int8 (codec wire), fp16 or fp32 — conversion happens
    per-tile in VMEM. Per-client quantizer scales must be pre-folded
    into ``coeff`` (dequant is linear: Σ w_c s_c q_c = Σ (w_c s_c) q_c).
    """
    C, L = q.shape
    tc, tl = blocks.select_agg_blocks(C, L)
    bc, bl = block_c or tc, block_l or tl
    qp = _pad_axis(_pad_axis(q, 0, bc), 1, bl)
    accp = _pad_axis(acc.reshape(1, -1), 1, bl)
    coeffp = _pad_axis(coeff.reshape(1, -1).astype(jnp.float32), 1, bc)
    Cp, Lp = qp.shape
    grid = (Lp // bl, Cp // bc)   # client axis innermost => sequential

    out = pl.pallas_call(
        functools.partial(_agg_body, n_kc=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc), lambda i, c: (0, c)),
            pl.BlockSpec((bc, bl), lambda i, c: (c, i)),
            pl.BlockSpec((1, bl), lambda i, c: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, bl), lambda i, c: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Lp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bl), jnp.float32)],
        input_output_aliases={2: 0},
        interpret=interpret,
    )(coeffp, qp, accp)
    return out[0, :L]


# -------------------------------------------------------------- tree level

def acc_zeros_like(wire: Any) -> Any:
    """fp32 zero accumulator tree with the payload structure of ``wire``:
    one dense leaf per ``{"q", "scale"}`` node (client axis dropped)."""
    def walk(n):
        if _is_qnode(n):
            return jnp.zeros(n["q"].shape[1:], jnp.float32)
        if isinstance(n, dict):
            return {k: walk(v) for k, v in n.items()}
        if isinstance(n, (list, tuple)):
            return type(n)(walk(v) for v in n)
        return jnp.zeros(jnp.shape(n)[1:], jnp.float32)

    return walk(wire)


def tree_dequant_acc(acc_tree: Any, wire: Any, weights: jax.Array, *,
                     interpret: Optional[bool] = None,
                     use_pallas: bool = True) -> Any:
    """Fold one client-stacked wire tree into a running fp32 accumulator.

    ``wire`` leaves are ``{"q": (C, ...), "scale": (C,)}`` int8 nodes or
    dense ``(C, ...)`` arrays (fp16/fp32); ``weights`` is the (C,)
    mask·weight vector; ``acc_tree`` mirrors the payload structure with
    fp32 leaves. Returns the updated accumulator (callers should donate
    ``acc_tree`` — the kernel aliases it through to the output).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    w = weights.astype(jnp.float32)

    def one(acc, q, coeff):
        C = q.shape[0]
        if not use_pallas:
            from repro.kernels import ref
            return ref.dequant_acc_ref(acc.reshape(-1), q.reshape(C, -1),
                                       coeff).reshape(acc.shape)
        flat = dequant_acc(acc.reshape(-1), q.reshape(C, -1), coeff,
                           interpret=interpret)
        return flat.reshape(acc.shape)

    def walk(acc, n):
        if _is_qnode(n):
            scale = n["scale"].reshape(n["q"].shape[0]).astype(jnp.float32)
            return one(acc, n["q"], w * scale)
        if isinstance(n, dict):
            return {k: walk(acc[k], v) for k, v in n.items()}
        if isinstance(n, (list, tuple)):
            return type(n)(walk(a, v) for a, v in zip(acc, n))
        return one(acc, n, w)

    return walk(acc_tree, wire)


def sharded_tree_dequant_acc(wire: Any, weights: jax.Array, mesh, axis: str,
                             *, interpret: Optional[bool] = None,
                             use_pallas: bool = True) -> Any:
    """Two-level hierarchical reduction for shard_map meshes.

    The client axis of ``wire``/``weights`` is sharded over ``axis``;
    each device reduces ITS shard with the fused kernel (level one:
    per-shard partial sums, O(C/devices · L) wire bytes touched per
    device) and a single ``psum`` over the mesh axis combines the fp32
    partials (level two: O(L) per hop). Returns the replicated summed
    tree — the caller adds it to its running accumulator.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.collectives import shard_map

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis)), out_specs=P(),
        check_rep=False)
    def reduce_shard(wire_s, w_s):
        part = tree_dequant_acc(acc_zeros_like(wire_s), wire_s, w_s,
                                interpret=interpret, use_pallas=use_pallas)
        return jax.tree.map(lambda x: jax.lax.psum(x, axis), part)

    return reduce_shard(wire, weights)
