"""Fused FedPara backward Pallas-TPU kernels + the custom-VJP wiring.

Gradients of  y = x @ W,  W = f1(W1) ⊙ f2(W2),  W1 = X1 Y1ᵀ, W2 = X2 Y2ᵀ,
with (f1, f2) covering identity (fedpara), tanh (fedpara_tanh) and the
pFedPara "+1 switch" f2(w) = w + 1:

  dx  = dy @ Wᵀ
  dW  = xᵀ dy                            (never materialized)
  G1  = dW ⊙ f2(W2) ⊙ f1'(W1)           dX1 = G1 Y1,   dY1 = G1ᵀ X1
  G2  = dW ⊙ f1(W1) ⊙ f2'(W2)           dX2 = G2 Y2,   dY2 = G2ᵀ X2

Three kernel bodies, each composing every (bm, bn) tile of W / dW in
VMEM from factor slices and contracting it on the spot, so the dense
(m, n) weight and its cotangent never touch HBM on the backward either:

  _dx_body        grid (B/bb, m/bm, n/bn), n sequential: compose W tile,
                  acc(bb, bm) += dy_tile @ W_tileᵀ.
  _dfactors_body  side="x": grid (m/bm, n/bn, B/bb) — dW tile
                  accumulated over the batch axis in VMEM scratch; at
                  the last batch step the tile is composed into G1/G2
                  and contracted against Y1/Y2 slices into (bm, r)
                  accumulators; dX1/dX2 are written once per m-tile
                  after the n sweep. side="y": grid (n/bn, m/bm, B/bb),
                  the transpose dance — G1ᵀ X1 / G2ᵀ X2 into (bn, r)
                  accumulators for dY1/dY2.

The dX and dY halves are two kernel launches, each re-accumulating the
dW tiles: fusing them would need the full (n, r) dY accumulators
resident in VMEM (27 MB fp32 at the 405B-FFN config — over budget) or
o_ref revisit traffic of O((m/bm)·n·r) — worse than the duplicate
compute. The price is one extra MXU pass and one extra HBM read of
x/dy, still free of any (m, n) term.

All accumulation is fp32 VMEM scratch over sequential grid axes. Every
body also runs with a leading client axis (stacked (C, ...) factors from
the client-batched FL engine) by prepending C to the grid — one launch
per layer for the whole client batch. ``jax.vmap`` over the custom-VJP
entry point lowers the same way: Pallas' batching rule folds the mapped
axis into a leading grid dimension, so the ``ClientBatch`` vmap program
also issues a single launch per layer.

HBM roofline of a full training step (fwd+bwd) per layer: factors are
read 3× and written once (≈4·2r(m+n)·4 B); x is read on the forward and
twice on the backward, dy three times on the backward — ≈5·B(m+n)·4 B.
O(r·(m+n) + B·(m+n)) total, vs the materialize path's O(m·n) for
writing + re-reading W (and dW, and the chain-rule Hadamards) on
forward and backward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fedpara_matmul import (
    _ceil_mult,
    _pad_to,
    apply_variant,
    fedpara_matmul,
)


def _tile_factor_grads(dw, w1, w2, *, use_tanh: bool, plus_one: bool):
    """(G1, G2) tiles from a dW tile and the PRE-activation W1/W2 tiles."""
    if use_tanh:
        t1, t2 = jnp.tanh(w1), jnp.tanh(w2)
        f1, f2 = t1, (t2 + 1.0 if plus_one else t2)
        g1 = dw * f2 * (1.0 - t1 * t1)
        g2 = dw * f1 * (1.0 - t2 * t2)
        return g1, g2
    f2 = w2 + 1.0 if plus_one else w2
    return dw * f2, dw * w1


# --------------------------------------------------------------- dx kernel

def _dx_body(dy_ref, x1_ref, y1_ref, x2_ref, y2_ref, o_ref, acc_ref, *,
             use_tanh: bool, plus_one: bool, n_kn: int, lead: bool):
    kn = pl.program_id(3 if lead else 2)
    ld = (lambda ref: ref[0]) if lead else (lambda ref: ref[...])

    @pl.when(kn == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w1 = jax.lax.dot_general(
        ld(x1_ref), ld(y1_ref), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    w2 = jax.lax.dot_general(
        ld(x2_ref), ld(y2_ref), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    w1, w2 = apply_variant(w1, w2, use_tanh=use_tanh, plus_one=plus_one)
    w_tile = w1 * w2  # (bm, bn)

    # dx tile += dy_tile @ W_tileᵀ  (contract the shared n dim).
    acc_ref[...] += jax.lax.dot_general(
        ld(dy_ref), w_tile.astype(dy_ref.dtype), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kn == n_kn - 1)
    def _done():
        out = acc_ref[...].astype(o_ref.dtype)
        if lead:
            o_ref[0] = out
        else:
            o_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=("use_tanh", "plus_one", "block_b", "block_m", "block_n",
                     "interpret", "out_dtype"),
)
def fedpara_dx(
    dy: jax.Array,
    x1: jax.Array,
    y1: jax.Array,
    x2: jax.Array,
    y2: jax.Array,
    *,
    use_tanh: bool = False,
    plus_one: bool = False,
    block_b: int = 128,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """dx = dy @ Wᵀ without materializing W; dy: (B, n) -> dx: (B, m).

    A leading client axis (dy: (C, B, n), Xi: (C, m, r)) selects the
    batched grid.
    """
    lead = dy.ndim == 3
    m = x1.shape[-2]
    n = y1.shape[-2]
    r = x1.shape[-1]
    b = dy.shape[-2]
    out_dtype = out_dtype or dy.dtype
    bb, bm, bn = min(block_b, _ceil_mult(b, 8)), block_m, block_n
    ax = 1 if lead else 0
    dyp = _pad_to(_pad_to(dy, ax, bb), ax + 1, bn)
    x1p, x2p = _pad_to(x1, ax, bm), _pad_to(x2, ax, bm)
    y1p, y2p = _pad_to(y1, ax, bn), _pad_to(y2, ax, bn)
    bp, np_ = dyp.shape[-2], dyp.shape[-1]
    mp = x1p.shape[-2]
    core = (bp // bb, mp // bm, np_ // bn)

    if lead:
        C = dy.shape[0]
        grid = (C,) + core
        in_specs = [
            pl.BlockSpec((1, bb, bn), lambda c, i, j, k: (c, i, k)),
            pl.BlockSpec((1, bm, r), lambda c, i, j, k: (c, j, 0)),
            pl.BlockSpec((1, bn, r), lambda c, i, j, k: (c, k, 0)),
            pl.BlockSpec((1, bm, r), lambda c, i, j, k: (c, j, 0)),
            pl.BlockSpec((1, bn, r), lambda c, i, j, k: (c, k, 0)),
        ]
        out_specs = pl.BlockSpec((1, bb, bm), lambda c, i, j, k: (c, i, j))
        out_shape = jax.ShapeDtypeStruct((C, bp, mp), out_dtype)
    else:
        grid = core
        in_specs = [
            pl.BlockSpec((bb, bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, r), lambda i, j, k: (j, 0)),
            pl.BlockSpec((bn, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((bm, r), lambda i, j, k: (j, 0)),
            pl.BlockSpec((bn, r), lambda i, j, k: (k, 0)),
        ]
        out_specs = pl.BlockSpec((bb, bm), lambda i, j, k: (i, j))
        out_shape = jax.ShapeDtypeStruct((bp, mp), out_dtype)

    out = pl.pallas_call(
        functools.partial(_dx_body, use_tanh=use_tanh, plus_one=plus_one,
                          n_kn=core[2], lead=lead),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bb, bm), jnp.float32)],
        interpret=interpret,
    )(dyp, x1p, y1p, x2p, y2p)
    return out[..., :b, :m]


# ----------------------------------------------- dX1/dX2, dY1/dY2 kernel

def _dfactors_body(x_ref, dy_ref, x1_ref, y1_ref, x2_ref, y2_ref,
                   d1_ref, d2_ref, dw_ref, a1_ref, a2_ref, *,
                   side: str, use_tanh: bool, plus_one: bool,
                   n_inner: int, n_kb: int, lead: bool):
    """side="x": outputs (dX1, dX2), the inner sweep axis is n tiles.
    side="y": outputs (dY1, dY2), the inner sweep axis is m tiles."""
    off = 1 if lead else 0
    inner = pl.program_id(off + 1)
    kb = pl.program_id(off + 2)
    ld = (lambda ref: ref[0]) if lead else (lambda ref: ref[...])

    @pl.when(kb == 0)
    def _init_dw():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    @pl.when((kb == 0) & (inner == 0))
    def _init_acc():
        a1_ref[...] = jnp.zeros_like(a1_ref)
        a2_ref[...] = jnp.zeros_like(a2_ref)

    # dW tile += x_tileᵀ @ dy_tile  (contract the shared batch dim).
    dw_ref[...] += jax.lax.dot_general(
        ld(x_ref), ld(dy_ref), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kb == n_kb - 1)
    def _contract():
        w1 = jax.lax.dot_general(
            ld(x1_ref), ld(y1_ref), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        w2 = jax.lax.dot_general(
            ld(x2_ref), ld(y2_ref), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        g1, g2 = _tile_factor_grads(dw_ref[...], w1, w2,
                                    use_tanh=use_tanh, plus_one=plus_one)
        if side == "x":
            # dX tiles += G @ Y slices  (bm, bn) x (bn, r) -> (bm, r)
            dims, f1_ref, f2_ref = (((1,), (0,)), ((), ())), y1_ref, y2_ref
        else:
            # dY tiles += Gᵀ @ X slices (bm, bn)ᵀ x (bm, r) -> (bn, r)
            dims, f1_ref, f2_ref = (((0,), (0,)), ((), ())), x1_ref, x2_ref
        a1_ref[...] += jax.lax.dot_general(
            g1, ld(f1_ref).astype(jnp.float32), dims,
            preferred_element_type=jnp.float32)
        a2_ref[...] += jax.lax.dot_general(
            g2, ld(f2_ref).astype(jnp.float32), dims,
            preferred_element_type=jnp.float32)

    @pl.when((kb == n_kb - 1) & (inner == n_inner - 1))
    def _done():
        if lead:
            d1_ref[0] = a1_ref[...].astype(d1_ref.dtype)
            d2_ref[0] = a2_ref[...].astype(d2_ref.dtype)
        else:
            d1_ref[...] = a1_ref[...].astype(d1_ref.dtype)
            d2_ref[...] = a2_ref[...].astype(d2_ref.dtype)


def _dfactors(x, dy, x1, y1, x2, y2, *, side: str, use_tanh, plus_one,
              block_b, block_m, block_n, interpret):
    """Shared wrapper for the dX (side='x') / dY (side='y') kernels."""
    lead = x.ndim == 3
    b, m = x.shape[-2], x.shape[-1]
    n = dy.shape[-1]
    r = x1.shape[-1]
    bb, bm, bn = min(block_b, _ceil_mult(b, 8)), block_m, block_n
    ax = 1 if lead else 0
    xp = _pad_to(_pad_to(x, ax, bb), ax + 1, bm)
    dyp = _pad_to(_pad_to(dy, ax, bb), ax + 1, bn)
    x1p, x2p = _pad_to(x1, ax, bm), _pad_to(x2, ax, bm)
    y1p, y2p = _pad_to(y1, ax, bn), _pad_to(y2, ax, bn)
    bp, mp = xp.shape[-2], xp.shape[-1]
    np_ = dyp.shape[-1]
    n_ki, n_kj, n_kb = mp // bm, np_ // bn, bp // bb

    if side == "x":
        core = (n_ki, n_kj, n_kb)         # (i, j, kb): j, kb sequential
        # grid ids within core: a=i (m tile), b=j (n tile), k=batch tile
        i_of, j_of = (lambda a, b: a), (lambda a, b: b)
        out_rows, out_blk = mp, bm
    else:
        core = (n_kj, n_ki, n_kb)         # (j, i, kb): i, kb sequential
        i_of, j_of = (lambda a, b: b), (lambda a, b: a)
        out_rows, out_blk = np_, bn
    body = functools.partial(_dfactors_body, side=side, use_tanh=use_tanh,
                             plus_one=plus_one, n_inner=core[1], n_kb=n_kb,
                             lead=lead)

    if lead:
        C = x.shape[0]
        grid = (C,) + core
        in_specs = [
            pl.BlockSpec((1, bb, bm), lambda c, a, b, k: (c, k, i_of(a, b))),
            pl.BlockSpec((1, bb, bn), lambda c, a, b, k: (c, k, j_of(a, b))),
            pl.BlockSpec((1, bm, r), lambda c, a, b, k: (c, i_of(a, b), 0)),
            pl.BlockSpec((1, bn, r), lambda c, a, b, k: (c, j_of(a, b), 0)),
            pl.BlockSpec((1, bm, r), lambda c, a, b, k: (c, i_of(a, b), 0)),
            pl.BlockSpec((1, bn, r), lambda c, a, b, k: (c, j_of(a, b), 0)),
        ]
        out_specs = [
            pl.BlockSpec((1, out_blk, r), lambda c, a, b, k: (c, a, 0)),
            pl.BlockSpec((1, out_blk, r), lambda c, a, b, k: (c, a, 0)),
        ]
        out_shape = [jax.ShapeDtypeStruct((C, out_rows, r), jnp.float32)] * 2
    else:
        grid = core
        in_specs = [
            pl.BlockSpec((bb, bm), lambda a, b, k: (k, i_of(a, b))),
            pl.BlockSpec((bb, bn), lambda a, b, k: (k, j_of(a, b))),
            pl.BlockSpec((bm, r), lambda a, b, k: (i_of(a, b), 0)),
            pl.BlockSpec((bn, r), lambda a, b, k: (j_of(a, b), 0)),
            pl.BlockSpec((bm, r), lambda a, b, k: (i_of(a, b), 0)),
            pl.BlockSpec((bn, r), lambda a, b, k: (j_of(a, b), 0)),
        ]
        out_specs = [
            pl.BlockSpec((out_blk, r), lambda a, b, k: (a, 0)),
            pl.BlockSpec((out_blk, r), lambda a, b, k: (a, 0)),
        ]
        out_shape = [jax.ShapeDtypeStruct((out_rows, r), jnp.float32)] * 2

    d1, d2 = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),     # dW tile accumulator
            pltpu.VMEM((out_blk, r), jnp.float32),
            pltpu.VMEM((out_blk, r), jnp.float32),
        ],
        interpret=interpret,
    )(xp, dyp, x1p, y1p, x2p, y2p)
    rows = m if side == "x" else n
    return d1[..., :rows, :], d2[..., :rows, :]


@functools.partial(
    jax.jit,
    static_argnames=("use_tanh", "plus_one", "block_b", "block_m", "block_n",
                     "interpret"),
)
def fedpara_dx_factors(x, dy, x1, y1, x2, y2, *, use_tanh=False,
                       plus_one=False, block_b=128, block_m=256,
                       block_n=256, interpret=False):
    """(dX1, dX2) = (G1 Y1, G2 Y2) with dW/W tiles composed in VMEM."""
    return _dfactors(x, dy, x1, y1, x2, y2, side="x", use_tanh=use_tanh,
                     plus_one=plus_one, block_b=block_b, block_m=block_m,
                     block_n=block_n, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("use_tanh", "plus_one", "block_b", "block_m", "block_n",
                     "interpret"),
)
def fedpara_dy_factors(x, dy, x1, y1, x2, y2, *, use_tanh=False,
                       plus_one=False, block_b=128, block_m=256,
                       block_n=256, interpret=False):
    """(dY1, dY2) = (G1ᵀ X1, G2ᵀ X2) with dW/W tiles composed in VMEM."""
    return _dfactors(x, dy, x1, y1, x2, y2, side="y", use_tanh=use_tanh,
                     plus_one=plus_one, block_b=block_b, block_m=block_m,
                     block_n=block_n, interpret=interpret)


# ------------------------------------------------------------- custom VJP

@functools.lru_cache(maxsize=None)
def differentiable_matmul(use_tanh: bool, plus_one: bool, block_b: int,
                          block_m: int, block_n: int, interpret: bool,
                          out_dtype=None):
    """A ``jax.custom_vjp`` around the fused matmul: forward saves only
    the factors and activations (never W), backward runs the fused grad
    kernels. Cached per static config so repeated traces reuse one
    primitive. Works on (B, m) inputs and on client-stacked (C, B, m)
    inputs (batched grids), and composes with ``jax.vmap`` (Pallas'
    batching rule folds the mapped axis into the grid — one launch)."""
    kw = dict(use_tanh=use_tanh, plus_one=plus_one, block_b=block_b,
              block_m=block_m, block_n=block_n, interpret=interpret)

    @jax.custom_vjp
    def matmul(x, x1, y1, x2, y2):
        return fedpara_matmul(x, x1, y1, x2, y2, out_dtype=out_dtype, **kw)

    def fwd(x, x1, y1, x2, y2):
        return matmul(x, x1, y1, x2, y2), (x, x1, y1, x2, y2)

    def bwd(res, dy):
        x, x1, y1, x2, y2 = res
        dx = fedpara_dx(dy, x1, y1, x2, y2, out_dtype=x.dtype, **kw)
        dx1, dx2 = fedpara_dx_factors(x, dy, x1, y1, x2, y2, **kw)
        dy1, dy2 = fedpara_dy_factors(x, dy, x1, y1, x2, y2, **kw)
        return (dx, dx1.astype(x1.dtype), dy1.astype(y1.dtype),
                dx2.astype(x2.dtype), dy2.astype(y2.dtype))

    matmul.defvjp(fwd, bwd)
    return matmul
