"""Pure-jnp oracles for the Pallas kernels (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fedpara_compose_ref(
    x1: jax.Array,
    y1: jax.Array,
    x2: jax.Array,
    y2: jax.Array,
    *,
    use_tanh: bool = False,
    out_dtype=None,
) -> jax.Array:
    """W = (X1 Y1ᵀ) ⊙ (X2 Y2ᵀ), computed densely in fp32."""
    w1 = x1.astype(jnp.float32) @ y1.astype(jnp.float32).T
    w2 = x2.astype(jnp.float32) @ y2.astype(jnp.float32).T
    if use_tanh:
        w1, w2 = jnp.tanh(w1), jnp.tanh(w2)
    w = w1 * w2
    return w.astype(out_dtype or x1.dtype)


def fedpara_matmul_ref(
    x: jax.Array,
    x1: jax.Array,
    y1: jax.Array,
    x2: jax.Array,
    y2: jax.Array,
    *,
    use_tanh: bool = False,
    out_dtype=None,
) -> jax.Array:
    """y = x @ W with W = (X1Y1ᵀ)⊙(X2Y2ᵀ); x: (B, m) -> y: (B, n)."""
    w = fedpara_compose_ref(x1, y1, x2, y2, use_tanh=use_tanh, out_dtype=jnp.float32)
    y = x.astype(jnp.float32) @ w
    return y.astype(out_dtype or x.dtype)


def pfedpara_compose_ref(
    x1: jax.Array, y1: jax.Array, x2: jax.Array, y2: jax.Array, *, out_dtype=None
) -> jax.Array:
    """W = W1 ⊙ (W2 + 1) — pFedPara personalization compose."""
    w1 = x1.astype(jnp.float32) @ y1.astype(jnp.float32).T
    w2 = x2.astype(jnp.float32) @ y2.astype(jnp.float32).T
    w = w1 * (w2 + 1.0)
    return w.astype(out_dtype or x1.dtype)
