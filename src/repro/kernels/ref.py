"""Pure-jnp oracles for the Pallas kernels (ground truth for tests).

``kind`` selects the paper variant — "fedpara" (identity), "fedpara_tanh"
(tanh ⊙ tanh, supp. B) or "pfedpara" (the "+1 switch", §2.3). The legacy
``use_tanh`` flag maps onto ``kind`` for older call sites.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _resolve_kind(kind, use_tanh):
    if kind is None:
        return "fedpara_tanh" if use_tanh else "fedpara"
    return kind


def fedpara_compose_ref(
    x1: jax.Array,
    y1: jax.Array,
    x2: jax.Array,
    y2: jax.Array,
    *,
    use_tanh: bool = False,
    kind: str = None,
    out_dtype=None,
) -> jax.Array:
    """W = f1(X1 Y1ᵀ) ⊙ f2(X2 Y2ᵀ), computed densely in fp32."""
    kind = _resolve_kind(kind, use_tanh)
    w1 = x1.astype(jnp.float32) @ y1.astype(jnp.float32).T
    w2 = x2.astype(jnp.float32) @ y2.astype(jnp.float32).T
    if kind == "fedpara_tanh":
        w1, w2 = jnp.tanh(w1), jnp.tanh(w2)
    if kind == "pfedpara":
        w2 = w2 + 1.0
    w = w1 * w2
    return w.astype(out_dtype or x1.dtype)


def fedpara_matmul_ref(
    x: jax.Array,
    x1: jax.Array,
    y1: jax.Array,
    x2: jax.Array,
    y2: jax.Array,
    *,
    use_tanh: bool = False,
    kind: str = None,
    out_dtype=None,
) -> jax.Array:
    """y = x @ W with W = f1(X1Y1ᵀ)⊙f2(X2Y2ᵀ); x: (B, m) -> y: (B, n)."""
    kind = _resolve_kind(kind, use_tanh)
    w = fedpara_compose_ref(x1, y1, x2, y2, kind=kind, out_dtype=jnp.float32)
    y = x.astype(jnp.float32) @ w
    return y.astype(out_dtype or x.dtype)


def pfedpara_compose_ref(
    x1: jax.Array, y1: jax.Array, x2: jax.Array, y2: jax.Array, *, out_dtype=None
) -> jax.Array:
    """W = W1 ⊙ (W2 + 1) — pFedPara personalization compose."""
    return fedpara_compose_ref(x1, y1, x2, y2, kind="pfedpara",
                               out_dtype=out_dtype)


def dequant_acc_ref(acc: jax.Array, q: jax.Array, coeff: jax.Array) -> jax.Array:
    """Dense oracle for the fused dequant-accumulate kernel:
    acc (L,) + coeff (C,) @ q (C, L) — the decode-then-reduce path the
    kernel must match bit-for-bit up to fp32 accumulation order."""
    return acc + jnp.tensordot(coeff.astype(jnp.float32),
                               q.astype(jnp.float32), axes=1)


def tree_dequant_acc_ref(acc_tree, wire, weights: jax.Array):
    """Tree-level oracle: dequantize every client's wire payload densely
    (``{"q", "scale"}`` nodes to fp32) and weighted-sum over the client
    axis into the accumulator."""
    def is_q(n):
        return isinstance(n, dict) and set(n) == {"q", "scale"}

    w = weights.astype(jnp.float32)

    def walk(acc, n):
        if is_q(n):
            C = n["q"].shape[0]
            deq = (n["q"].astype(jnp.float32).reshape(C, -1)
                   * n["scale"].reshape(C, 1).astype(jnp.float32))
            return acc + jnp.tensordot(w, deq, axes=1).reshape(acc.shape)
        if isinstance(n, dict):
            return {k: walk(acc[k], v) for k, v in n.items()}
        if isinstance(n, (list, tuple)):
            return type(n)(walk(a, v) for a, v in zip(acc, n))
        C = n.shape[0]
        return acc + jnp.tensordot(
            w, n.astype(jnp.float32).reshape(C, -1), axes=1).reshape(acc.shape)

    return walk(acc_tree, wire)


def w8_matmul_ref(x: jax.Array, w: jax.Array, scale: jax.Array = None, *,
                  out_dtype=None) -> jax.Array:
    """Dense oracle for the serve weight-cache matmul: y = (x @ W) · s,
    dequantizing the whole cache to fp32 up front (the widening the
    Pallas kernel must avoid outside VMEM)."""
    wf = w.astype(jnp.float32)
    if scale is not None:
        wf = wf * scale.reshape(1, -1).astype(jnp.float32)
    y = x.astype(jnp.float32) @ wf
    return y.astype(out_dtype or x.dtype)


def cache_residual_ref(x: jax.Array, w: jax.Array, scale: jax.Array,
                       x2: jax.Array, y2: jax.Array, *,
                       out_dtype=None) -> jax.Array:
    """Dense oracle for the pFedPara cache+residual kernel: materialize
    W_u = dequant(W) ⊙ (X2ᵤY2ᵤᵀ + 1) per user and contract. Handles the
    single-user (x: (B, m)) and many-user (x: (U, t, m), per-user
    factors) layouts."""
    wf = w.astype(jnp.float32)
    if scale is not None:
        wf = wf * scale.reshape(1, -1).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    x2f, y2f = x2.astype(jnp.float32), y2.astype(jnp.float32)
    if x.ndim == 3:
        wu = wf[None] * (jnp.einsum("umr,unr->umn", x2f, y2f) + 1.0)
        y = jnp.einsum("utm,umn->utn", xf, wu)
    else:
        y = xf @ (wf * (x2f @ y2f.T + 1.0))
    return y.astype(out_dtype or x.dtype)


def fedpara_matmul_vjp_ref(
    x: jax.Array,
    x1: jax.Array,
    y1: jax.Array,
    x2: jax.Array,
    y2: jax.Array,
    dy: jax.Array,
    *,
    kind: str = "fedpara",
):
    """Closed-form dense VJP oracle: (dx, dX1, dY1, dX2, dY2) in fp32.

    Materializes W, dW = xᵀdy and the chain-rule tiles densely — the
    ground truth the fused backward kernels must reproduce without ever
    building these (m, n) intermediates.
    """
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    w1 = x1.astype(jnp.float32) @ y1.astype(jnp.float32).T
    w2 = x2.astype(jnp.float32) @ y2.astype(jnp.float32).T
    if kind == "fedpara_tanh":
        t1, t2 = jnp.tanh(w1), jnp.tanh(w2)
        f1, f2 = t1, t2
        d1, d2 = 1.0 - t1 * t1, 1.0 - t2 * t2
    elif kind == "pfedpara":
        f1, f2 = w1, w2 + 1.0
        d1 = d2 = None
    else:
        f1, f2 = w1, w2
        d1 = d2 = None
    w = f1 * f2
    dw = xf.T @ dyf
    g1 = dw * f2 if d1 is None else dw * f2 * d1
    g2 = dw * f1 if d2 is None else dw * f1 * d2
    dx = (dyf @ w.T).astype(x.dtype)
    return (dx, g1 @ y1.astype(jnp.float32), g1.T @ x1.astype(jnp.float32),
            g2 @ y2.astype(jnp.float32), g2.T @ x2.astype(jnp.float32))
