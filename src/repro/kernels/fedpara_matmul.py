"""Fused FedPara matmul Pallas-TPU kernel (forward).

Computes  y = x @ W  with  W = f1(X1 Y1ᵀ) ⊙ f2(X2 Y2ᵀ)  WITHOUT
materializing the dense (m, n) weight in HBM: each (bm, bn) tile of W is
composed in VMEM from factor slices and immediately contracted against
the matching x tile on the MXU. The elementwise pair (f1, f2) covers all
paper variants: identity (fedpara), tanh (fedpara_tanh, supp. B) and the
pFedPara "+1 switch" f2(w) = w + 1 (§2.3).

Memory-roofline rationale (TPU v5e, 819 GB/s HBM): the unfused path
writes + reads W once per step — 2·m·n·2 bytes of HBM traffic per layer.
For a (16384, 53248) LLaMA-405B FFN weight that is 3.5 GB; fused, HBM
traffic is only the factors (≈2·2R(m+n)·2 bytes ≈ 71 MB at R=128) plus
x/y activations. Compose FLOPs run on the MXU at bm×bn×r granularity.

Grid = (B/bb, n/bn, m/bm); the last (m) axis is the sequential reduction
axis on TPU, accumulated in an fp32 VMEM scratch. With a leading client
axis — x: (C, B, m), Xi: (C, m, r), Yi: (C, n, r), the stacked layout of
the client-batched FL engine — the same body runs on a
(C, B/bb, n/bn, m/bm) grid: one launch composes every client's tiles.

The matching backward kernels (``repro.kernels.fedpara_grad``) keep the
whole training step dense-W-free; ``repro.kernels.ops.fedpara_matmul``
wires them together as a ``jax.custom_vjp``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def apply_variant(w1, w2, *, use_tanh: bool, plus_one: bool):
    """(f1(W1), f2(W2)) tiles for the active FedPara variant."""
    if use_tanh:
        w1, w2 = jnp.tanh(w1), jnp.tanh(w2)
    if plus_one:
        w2 = w2 + 1.0
    return w1, w2


def _kernel(x_ref, x1_ref, y1_ref, x2_ref, y2_ref, o_ref, acc_ref, *,
            use_tanh: bool, plus_one: bool, n_km: int):
    km = pl.program_id(2)

    @pl.when(km == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Compose the (bm, bn) weight tile in VMEM (fp32 on the MXU).
    w1 = jax.lax.dot_general(
        x1_ref[...], y1_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w2 = jax.lax.dot_general(
        x2_ref[...], y2_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w1, w2 = apply_variant(w1, w2, use_tanh=use_tanh, plus_one=plus_one)
    w_tile = w1 * w2  # (bm, bn)

    # Contract the x tile against the composed tile; accumulate fp32.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_tile.astype(x_ref.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(km == n_km - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_batched(x_ref, x1_ref, y1_ref, x2_ref, y2_ref, o_ref, acc_ref, *,
                    use_tanh: bool, plus_one: bool, n_km: int):
    # refs carry a leading (1,) client dim: one client per grid step.
    km = pl.program_id(3)

    @pl.when(km == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w1 = jax.lax.dot_general(
        x1_ref[0], y1_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w2 = jax.lax.dot_general(
        x2_ref[0], y2_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w1, w2 = apply_variant(w1, w2, use_tanh=use_tanh, plus_one=plus_one)
    w_tile = w1 * w2

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_tile.astype(x_ref.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(km == n_km - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    rem = a.shape[axis] % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(a, pad)


@functools.partial(
    jax.jit,
    static_argnames=("use_tanh", "plus_one", "block_b", "block_m", "block_n",
                     "interpret", "out_dtype"),
)
def fedpara_matmul(
    x: jax.Array,
    x1: jax.Array,
    y1: jax.Array,
    x2: jax.Array,
    y2: jax.Array,
    *,
    use_tanh: bool = False,
    plus_one: bool = False,
    block_b: int = 128,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """y = x @ (f1(X1Y1ᵀ)⊙f2(X2Y2ᵀ));  x: (B, m), Xi: (m, r), Yi: (n, r).

    With a leading client axis (x: (C, B, m), Xi: (C, m, r)) the batched
    grid variant runs — one launch for all C clients.
    """
    if x.ndim == 3:
        return _fedpara_matmul_batched(
            x, x1, y1, x2, y2, use_tanh=use_tanh, plus_one=plus_one,
            block_b=block_b, block_m=block_m, block_n=block_n,
            interpret=interpret, out_dtype=out_dtype)
    b, m = x.shape
    n = y1.shape[0]
    r = x1.shape[1]
    out_dtype = out_dtype or x.dtype

    bb, bm, bn = min(block_b, _ceil_mult(b, 8)), block_m, block_n
    xp = _pad_to(_pad_to(x, 0, bb), 1, bm)
    x1p, x2p = _pad_to(x1, 0, bm), _pad_to(x2, 0, bm)
    y1p, y2p = _pad_to(y1, 0, bn), _pad_to(y2, 0, bn)
    bp, mp = xp.shape
    np_ = y1p.shape[0]
    grid = (bp // bb, np_ // bn, mp // bm)

    out = pl.pallas_call(
        functools.partial(_kernel, use_tanh=use_tanh, plus_one=plus_one,
                          n_km=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bm), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((bn, r), lambda i, j, k: (j, 0)),
            pl.BlockSpec((bm, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((bn, r), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(xp, x1p, y1p, x2p, y2p)
    return out[:b, :n]


def _fedpara_matmul_batched(x, x1, y1, x2, y2, *, use_tanh, plus_one,
                            block_b, block_m, block_n, interpret, out_dtype):
    C, b, m = x.shape
    n = y1.shape[1]
    r = x1.shape[2]
    out_dtype = out_dtype or x.dtype

    bb, bm, bn = min(block_b, _ceil_mult(b, 8)), block_m, block_n
    xp = _pad_to(_pad_to(x, 1, bb), 2, bm)
    x1p, x2p = _pad_to(x1, 1, bm), _pad_to(x2, 1, bm)
    y1p, y2p = _pad_to(y1, 1, bn), _pad_to(y2, 1, bn)
    bp, mp = xp.shape[1], xp.shape[2]
    np_ = y1p.shape[1]
    grid = (C, bp // bb, np_ // bn, mp // bm)

    out = pl.pallas_call(
        functools.partial(_kernel_batched, use_tanh=use_tanh,
                          plus_one=plus_one, n_km=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bb, bm), lambda c, i, j, k: (c, i, k)),
            pl.BlockSpec((1, bm, r), lambda c, i, j, k: (c, k, 0)),
            pl.BlockSpec((1, bn, r), lambda c, i, j, k: (c, j, 0)),
            pl.BlockSpec((1, bm, r), lambda c, i, j, k: (c, k, 0)),
            pl.BlockSpec((1, bn, r), lambda c, i, j, k: (c, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bb, bn), lambda c, i, j, k: (c, i, j)),
        out_shape=jax.ShapeDtypeStruct((C, bp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(xp, x1p, y1p, x2p, y2p)
    return out[:, :b, :n]


def _ceil_mult(v: int, mult: int) -> int:
    return max(mult, ((v + mult - 1) // mult) * mult)
