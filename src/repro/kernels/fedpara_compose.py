"""Tiled FedPara compose Pallas-TPU kernel.

W = (X1 Y1ᵀ) ⊙ (X2 Y2ᵀ)  (optionally with tanh, or the pFedPara
"+1 switch"), produced tile-by-tile. Used on the serving path where the
paper pre-composes W once ("at the inference phase, we pre-compose and
maintain W") and by the training path when XLA's native fusion is
bypassed. Output tiles are MXU-aligned (multiples of 128) and each tile's
working set (two factor slices + the fp32 tile) stays in VMEM.

Batched (client-leading-dim) path: when the factors carry a leading
client axis — Xi: (C, m, r), Yi: (C, n, r), as produced by the
client-batched FL engine (`repro.fl.batch_engine`) — the same kernel
runs on a (C, m/bm, n/bn) grid, one client per leading grid step, so a
vmapped loss can compose every client's W in one kernel launch instead
of C sequential calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x1_ref, y1_ref, x2_ref, y2_ref, o_ref, *, use_tanh: bool, plus_one: bool):
    w1 = jax.lax.dot_general(
        x1_ref[...], y1_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w2 = jax.lax.dot_general(
        x2_ref[...], y2_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if use_tanh:
        w1, w2 = jnp.tanh(w1), jnp.tanh(w2)
    if plus_one:
        w2 = w2 + 1.0
    o_ref[...] = (w1 * w2).astype(o_ref.dtype)


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    rem = a.shape[axis] % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(a, pad)


@functools.partial(
    jax.jit,
    static_argnames=("use_tanh", "plus_one", "block_m", "block_n", "interpret", "out_dtype"),
)
def fedpara_compose(
    x1: jax.Array,
    y1: jax.Array,
    x2: jax.Array,
    y2: jax.Array,
    *,
    use_tanh: bool = False,
    plus_one: bool = False,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Compose W ∈ (m, n) from Xi: (m, r), Yi: (n, r) — or, with a
    leading client axis, W ∈ (C, m, n) from Xi: (C, m, r), Yi: (C, n, r)
    on a (C, m/bm, n/bn) grid."""
    if x1.ndim == 3:
        return _fedpara_compose_batched(
            x1, y1, x2, y2, use_tanh=use_tanh, plus_one=plus_one,
            block_m=block_m, block_n=block_n, interpret=interpret,
            out_dtype=out_dtype)
    m, r = x1.shape
    n = y1.shape[0]
    out_dtype = out_dtype or x1.dtype
    bm, bn = block_m, block_n
    x1p, x2p = _pad_to(x1, 0, bm), _pad_to(x2, 0, bm)
    y1p, y2p = _pad_to(y1, 0, bn), _pad_to(y2, 0, bn)
    mp, np_ = x1p.shape[0], y1p.shape[0]
    grid = (mp // bm, np_ // bn)

    out = pl.pallas_call(
        functools.partial(_kernel, use_tanh=use_tanh, plus_one=plus_one),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=interpret,
    )(x1p, y1p, x2p, y2p)
    return out[:m, :n]


def _kernel_batched(x1_ref, y1_ref, x2_ref, y2_ref, o_ref, *,
                    use_tanh: bool, plus_one: bool):
    # refs are (1, bm, r)/(1, bn, r)/(1, bm, bn): one client per grid step
    w1 = jax.lax.dot_general(
        x1_ref[0], y1_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w2 = jax.lax.dot_general(
        x2_ref[0], y2_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if use_tanh:
        w1, w2 = jnp.tanh(w1), jnp.tanh(w2)
    if plus_one:
        w2 = w2 + 1.0
    o_ref[0] = (w1 * w2).astype(o_ref.dtype)


def _fedpara_compose_batched(x1, y1, x2, y2, *, use_tanh, plus_one,
                             block_m, block_n, interpret, out_dtype):
    C, m, r = x1.shape
    n = y1.shape[1]
    out_dtype = out_dtype or x1.dtype
    bm, bn = block_m, block_n
    x1p, x2p = _pad_to(x1, 1, bm), _pad_to(x2, 1, bm)
    y1p, y2p = _pad_to(y1, 1, bn), _pad_to(y2, 1, bn)
    mp, np_ = x1p.shape[1], y1p.shape[1]
    grid = (C, mp // bm, np_ // bn)

    out = pl.pallas_call(
        functools.partial(_kernel_batched, use_tanh=use_tanh, plus_one=plus_one),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, r), lambda c, i, j: (c, i, 0)),
            pl.BlockSpec((1, bn, r), lambda c, i, j: (c, j, 0)),
            pl.BlockSpec((1, bm, r), lambda c, i, j: (c, i, 0)),
            pl.BlockSpec((1, bn, r), lambda c, i, j: (c, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda c, i, j: (c, i, j)),
        out_shape=jax.ShapeDtypeStruct((C, mp, np_), out_dtype),
        interpret=interpret,
    )(x1p, y1p, x2p, y2p)
    return out[:, :m, :n]
