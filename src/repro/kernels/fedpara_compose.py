"""Tiled FedPara compose Pallas-TPU kernel.

W = (X1 Y1ᵀ) ⊙ (X2 Y2ᵀ)  (optionally with tanh, or the pFedPara
"+1 switch"), produced tile-by-tile. Used on the serving path where the
paper pre-composes W once ("at the inference phase, we pre-compose and
maintain W") and by the training path when XLA's native fusion is
bypassed. Output tiles are MXU-aligned (multiples of 128) and each tile's
working set (two factor slices + the fp32 tile) stays in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x1_ref, y1_ref, x2_ref, y2_ref, o_ref, *, use_tanh: bool, plus_one: bool):
    w1 = jax.lax.dot_general(
        x1_ref[...], y1_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w2 = jax.lax.dot_general(
        x2_ref[...], y2_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if use_tanh:
        w1, w2 = jnp.tanh(w1), jnp.tanh(w2)
    if plus_one:
        w2 = w2 + 1.0
    o_ref[...] = (w1 * w2).astype(o_ref.dtype)


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    rem = a.shape[axis] % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(a, pad)


@functools.partial(
    jax.jit,
    static_argnames=("use_tanh", "plus_one", "block_m", "block_n", "interpret", "out_dtype"),
)
def fedpara_compose(
    x1: jax.Array,
    y1: jax.Array,
    x2: jax.Array,
    y2: jax.Array,
    *,
    use_tanh: bool = False,
    plus_one: bool = False,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Compose W ∈ (m, n) from Xi: (m, r), Yi: (n, r)."""
    m, r = x1.shape
    n = y1.shape[0]
    out_dtype = out_dtype or x1.dtype
    bm, bn = block_m, block_n
    x1p, x2p = _pad_to(x1, 0, bm), _pad_to(x2, 0, bm)
    y1p, y2p = _pad_to(y1, 0, bn), _pad_to(y2, 0, bn)
    mp, np_ = x1p.shape[0], y1p.shape[0]
    grid = (mp // bm, np_ // bn)

    out = pl.pallas_call(
        functools.partial(_kernel, use_tanh=use_tanh, plus_one=plus_one),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=interpret,
    )(x1p, y1p, x2p, y2p)
    return out[:m, :n]
