"""Serving-path Pallas kernels: int8 weight cache × activation matmul
and the pFedPara "cache + residual" matmul (single- and multi-user).

Three kernel bodies back ``repro.serve``'s two weight layouts:

``_w8_kernel``
    y = (x @ W_q) · s  for a pre-composed weight cache stored int8 (or
    fp16) with per-output-channel scales s (1, n). The cache tile enters
    VMEM at wire width (1 B/elt for int8) and is widened there — the
    int8 array is NEVER widened in HBM, which the serve program contract
    (``repro.analysis.program_check.check_serve_widening``) enforces.
    Because s depends only on the output channel, it commutes with the
    row contraction: the scale multiply happens once on the fp32
    accumulator at the final grid step, not per weight tile.

``_resid_kernel``
    pFedPara decode for ONE personalized user against the shared cache:
    W_u = W1 ⊙ (X2ᵤY2ᵤᵀ + 1) where W1 = X1Y1ᵀ is the globally-shared
    half, cached as W_q·s. Each (bm, bn) residual tile X2ᵤY2ᵤᵀ is
    composed in VMEM from factor slices, the "+1 switch" applied, and
    Hadamard-multiplied into the dequantized cache tile — W_u never
    exists in HBM. The scale still commutes:
    (W_q·s) ⊙ (R+1) = (W_q ⊙ (R+1))·s.

``_resid_kernel_users``
    The many-user variant: x (U, t, m) carries one row-block per user,
    per-user factors are (U, m, r)/(U, n, r) slices gathered from the
    serve user arena, and the W1 cache is SHARED — its BlockSpec index
    map ignores the user grid axis, so serving B distinct users is one
    launch that streams B factor sets plus one cache through VMEM with
    zero per-user W materialization.

Grids put the m (contraction) axis innermost-sequential with an fp32
VMEM scratch accumulator, like ``repro.kernels.fedpara_matmul``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fedpara_matmul import _ceil_mult, _pad_to


def _w8_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, n_km: int):
    km = pl.program_id(2)

    @pl.when(km == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Widen the cache tile in VMEM only (int8 -> activation dtype).
    w_tile = w_ref[...].astype(x_ref.dtype)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_tile, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(km == n_km - 1)
    def _done():
        # per-output-channel scale commutes with the row sum: apply once.
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def _resid_kernel(x_ref, w_ref, s_ref, x2_ref, y2_ref, o_ref, acc_ref, *,
                  n_km: int):
    km = pl.program_id(2)

    @pl.when(km == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (bm, bn) residual tile from factor slices; "+1 switch" in VMEM.
    r_tile = jax.lax.dot_general(
        x2_ref[...], y2_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w_tile = w_ref[...].astype(jnp.float32) * (r_tile + 1.0)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_tile.astype(x_ref.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(km == n_km - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def _resid_kernel_users(x_ref, w_ref, s_ref, x2_ref, y2_ref, o_ref, acc_ref,
                        *, n_km: int):
    # x/x2/y2/o carry a leading (1,) user dim; w/s are user-shared.
    km = pl.program_id(3)

    @pl.when(km == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    r_tile = jax.lax.dot_general(
        x2_ref[0], y2_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w_tile = w_ref[...].astype(jnp.float32) * (r_tile + 1.0)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_tile.astype(x_ref.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(km == n_km - 1)
    def _done():
        o_ref[0] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def _scale_row(scale, w, n: int):
    """Normalize per-channel scales to a padded (1, n) fp32 row (ones
    when the cache is not quantized)."""
    if scale is None:
        return jnp.ones((1, n), jnp.float32)
    return _pad_to(scale.reshape(1, -1).astype(jnp.float32), 1, n)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_m", "block_n", "interpret",
                     "out_dtype"),
)
def w8_matmul(x, w, scale=None, *, block_b: int = 64, block_m: int = 256,
              block_n: int = 256, interpret: bool = False, out_dtype=None):
    """y = (x @ W) · s;  x: (B, m), W: (m, n) int8/fp16, s: (1, n)."""
    b, m = x.shape
    n = w.shape[1]
    out_dtype = out_dtype or x.dtype
    bb, bm, bn = min(block_b, _ceil_mult(b, 8)), block_m, block_n
    xp = _pad_to(_pad_to(x, 0, bb), 1, bm)
    wp = _pad_to(_pad_to(w, 0, bm), 1, bn)
    bp, mp = xp.shape
    np_ = wp.shape[1]
    sp = _scale_row(scale, wp, np_)
    grid = (bp // bb, np_ // bn, mp // bm)

    out = pl.pallas_call(
        functools.partial(_w8_kernel, n_km=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bm), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, sp)
    return out[:b, :n]


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_m", "block_n", "interpret",
                     "out_dtype"),
)
def cache_residual_matmul(x, w, scale, x2, y2, *, block_b: int = 64,
                          block_m: int = 256, block_n: int = 256,
                          interpret: bool = False, out_dtype=None):
    """y = (x @ (W ⊙ (X2Y2ᵀ + 1))) · s — pFedPara cache + residual.

    Single user: x (B, m), X2 (m, r), Y2 (n, r). Many users: x (U, t, m)
    with per-user factors X2 (U, m, r), Y2 (U, n, r) and a SHARED cache
    W (m, n) — one launch serves all U users.
    """
    if x.ndim == 3:
        return _cache_residual_users(
            x, w, scale, x2, y2, block_b=block_b, block_m=block_m,
            block_n=block_n, interpret=interpret, out_dtype=out_dtype)
    b, m = x.shape
    n = w.shape[1]
    r = x2.shape[1]
    out_dtype = out_dtype or x.dtype
    bb, bm, bn = min(block_b, _ceil_mult(b, 8)), block_m, block_n
    xp = _pad_to(_pad_to(x, 0, bb), 1, bm)
    wp = _pad_to(_pad_to(w, 0, bm), 1, bn)
    x2p = _pad_to(x2, 0, bm)
    y2p = _pad_to(y2, 0, bn)
    bp, mp = xp.shape
    np_ = wp.shape[1]
    sp = _scale_row(scale, wp, np_)
    grid = (bp // bb, np_ // bn, mp // bm)

    out = pl.pallas_call(
        functools.partial(_resid_kernel, n_km=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bm), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((bm, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((bn, r), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, sp, x2p, y2p)
    return out[:b, :n]


def _cache_residual_users(x, w, scale, x2, y2, *, block_b, block_m, block_n,
                          interpret, out_dtype):
    U, t, m = x.shape
    n = w.shape[1]
    r = x2.shape[2]
    out_dtype = out_dtype or x.dtype
    bb, bm, bn = min(block_b, _ceil_mult(t, 8)), block_m, block_n
    xp = _pad_to(_pad_to(x, 1, bb), 2, bm)
    wp = _pad_to(_pad_to(w, 0, bm), 1, bn)
    x2p = _pad_to(x2, 1, bm)
    y2p = _pad_to(y2, 1, bn)
    tp, mp = xp.shape[1], xp.shape[2]
    np_ = wp.shape[1]
    sp = _scale_row(scale, wp, np_)
    grid = (U, tp // bb, np_ // bn, mp // bm)

    out = pl.pallas_call(
        functools.partial(_resid_kernel_users, n_km=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bb, bm), lambda u, i, j, k: (u, i, k)),
            # the shared cache ignores the user axis: one W1 for all U
            pl.BlockSpec((bm, bn), lambda u, i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda u, i, j, k: (0, j)),
            pl.BlockSpec((1, bm, r), lambda u, i, j, k: (u, k, 0)),
            pl.BlockSpec((1, bn, r), lambda u, i, j, k: (u, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bb, bn), lambda u, i, j, k: (u, i, j)),
        out_shape=jax.ShapeDtypeStruct((U, tp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, sp, x2p, y2p)
    return out[:, :t, :n]


# ------------------------------------------------------- Gram decode path
#
# At decode batch sizes the fused tile kernel recomposes every (bm, bn)
# W tile for a handful of activation rows — O(m·n·r) compose FLOPs per
# token. The Hadamard-Gram identity removes the (m, n) object entirely:
#
#   y_n = Σ_m x_m (X1Y1ᵀ)_mn (X2Y2ᵀ)_mn
#       = Σ_{i,j} Y1_ni Y2_nj · G_ij,   G = X1ᵀ diag(x) X2   (r1 × r2)
#
# so  y = rowsum((Y1 G) ⊙ Y2)  at O(r²(m+n)) FLOPs per token and factor
# bytes only. No Pallas kernel is needed: there is no dense (m, n)
# intermediate anywhere for XLA to materialize. Invalid for the tanh
# variant (tanh(X1Y1ᵀ) is not low-rank); pFedPara's "+1 switch" adds the
# rank-r term x@X1@Y1ᵀ.

def fedpara_gram_decode(x, x1, y1, x2, y2, *, kind: str = "fedpara",
                        out_dtype=None):
    """y = x @ (X1Y1ᵀ ⊙ f2(X2Y2ᵀ)) via the Gram identity (decode path).

    x: (B, m) with shared factors, or (U, t, m) with per-user residual
    factors x2/y2: (U, m, r)/(U, n, r) (x1/y1 always shared).
    """
    if kind not in ("fedpara", "pfedpara"):
        raise ValueError(f"gram decode is invalid for kind {kind!r}")
    out_dtype = out_dtype or x.dtype
    xf = x.astype(jnp.float32)
    x1f, y1f = x1.astype(jnp.float32), y1.astype(jnp.float32)
    x2f, y2f = x2.astype(jnp.float32), y2.astype(jnp.float32)
    if x.ndim == 3:
        g = jnp.einsum("utm,mi,umj->utij", xf, x1f, x2f)
        y = jnp.einsum("ni,utij,unj->utn", y1f, g, y2f)
        if kind == "pfedpara":
            y = y + jnp.einsum("utm,mi,ni->utn", xf, x1f, y1f)
        return y.astype(out_dtype)
    g = jnp.einsum("bm,mi,mj->bij", xf, x1f, x2f)
    y = jnp.einsum("ni,bij,nj->bn", y1f, g, y2f)
    if kind == "pfedpara":
        y = y + (xf @ x1f) @ y1f.T
    return y.astype(out_dtype)
