"""Public jit'd wrappers for the FedPara Pallas kernels.

``interpret`` defaults to True when no TPU is present so the same code
path runs (slowly but correctly) on CPU; on TPU backends the compiled
Mosaic kernels are used.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.fedpara_compose import fedpara_compose as _compose
from repro.kernels.fedpara_matmul import fedpara_matmul as _matmul


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fedpara_matmul(x, x1, y1, x2, y2, *, use_tanh=False, interpret=None, **kw):
    """y = x @ ((X1Y1ᵀ)⊙(X2Y2ᵀ)) — fused, W never materialized in HBM."""
    interpret = _default_interpret() if interpret is None else interpret
    return _matmul(x, x1, y1, x2, y2, use_tanh=use_tanh, interpret=interpret, **kw)


def fedpara_compose(x1, y1, x2, y2, *, use_tanh=False, interpret=None, **kw):
    """W = (X1Y1ᵀ)⊙(X2Y2ᵀ) — tiled compose (serving pre-composition)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _compose(x1, y1, x2, y2, use_tanh=use_tanh, interpret=interpret, **kw)


def pfedpara_compose(x1, y1, x2, y2, *, interpret=None, **kw):
    """W = (X1Y1ᵀ) ⊙ (X2Y2ᵀ + 1) — pFedPara compose."""
    interpret = _default_interpret() if interpret is None else interpret
    return _compose(x1, y1, x2, y2, plus_one=True, interpret=interpret, **kw)


# Re-export oracles for convenience.
fedpara_matmul_ref = ref.fedpara_matmul_ref
fedpara_compose_ref = ref.fedpara_compose_ref
pfedpara_compose_ref = ref.pfedpara_compose_ref
