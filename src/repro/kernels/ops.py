"""Public jit'd wrappers for the FedPara Pallas kernels.

``interpret`` defaults to True when no TPU is present so the same code
path runs (slowly but correctly) on CPU; on TPU backends the compiled
Mosaic kernels are used.

``fedpara_matmul`` is DIFFERENTIABLE: it is a ``jax.custom_vjp`` whose
forward and backward are both fused Pallas kernels
(``repro.kernels.fedpara_grad``), so ``jax.value_and_grad`` of a loss
through it never materializes the dense (m, n) weight or its cotangent
— in HBM the training step moves only factors and activations,
O(r·(m+n) + B·(m+n)) bytes instead of O(m·n). All three paper variants
(fedpara, fedpara_tanh, pfedpara) are supported, block sizes come from
one table shared by forward and backward (``repro.kernels.blocks``),
and client-stacked (C, ...) inputs — or ``jax.vmap`` over a client axis,
as in the batched FL engine — lower to a single launch per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import agg, blocks, fedpara_grad, ref, serve_matmul
from repro.kernels.fedpara_compose import fedpara_compose as _compose


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_kind(kind=None, use_tanh: bool = False) -> str:
    """Normalize a fused-matmul variant name: ``kind`` wins when given
    (validated against fedpara | fedpara_tanh | pfedpara), else the
    legacy ``use_tanh`` flag selects fedpara vs fedpara_tanh."""
    if kind is None:
        return "fedpara_tanh" if use_tanh else "fedpara"
    if kind not in ("fedpara", "fedpara_tanh", "pfedpara"):
        raise ValueError(f"unsupported fused-matmul kind: {kind!r}")
    return kind


def _resolve_cfg(x1, y1, kind, use_tanh, interpret, block_b, block_m, block_n):
    kind = resolve_kind(kind, use_tanh)
    interpret = _default_interpret() if interpret is None else interpret
    m, n, r = x1.shape[-2], y1.shape[-2], x1.shape[-1]
    tb, tm, tn = blocks.select_blocks(m, n, r)
    return (kind, interpret, block_b or tb, block_m or tm, block_n or tn)


def fedpara_matmul(x, x1, y1, x2, y2, *, kind=None, use_tanh=False,
                   interpret=None, block_b=None, block_m=None, block_n=None,
                   out_dtype=None):
    """y = x @ (f1(X1Y1ᵀ)⊙f2(X2Y2ᵀ)) — fused AND differentiable; W never
    materialized in HBM on forward or backward.

    Args:
        x: activations ``(..., B, m)``.
        x1, x2: row factors ``(..., m, r)``.
        y1, y2: column factors ``(..., n, r)``.
        kind: ``fedpara`` (f = identity) | ``fedpara_tanh`` | ``pfedpara``
            (f2 adds the "+1 switch"); see :func:`resolve_kind`.
        interpret: force Pallas interpret mode (default: auto — compiled
            on TPU, interpret elsewhere).
        block_b/block_m/block_n: tile overrides (default: the shared
            ``repro.kernels.blocks`` table keyed on (m, n, r)).
        out_dtype: output dtype (default: x's dtype).

    Returns:
        ``(..., B, n)``. Leading batch dims (e.g. a client axis) fold
        into the kernel grid — one launch per layer even under vmap.
    """
    kind, interpret, bb, bm, bn = _resolve_cfg(
        x1, y1, kind, use_tanh, interpret, block_b, block_m, block_n)
    f = fedpara_grad.differentiable_matmul(
        kind == "fedpara_tanh", kind == "pfedpara", bb, bm, bn, interpret,
        jnp.dtype(out_dtype).name if out_dtype is not None else None)
    return f(x, x1, y1, x2, y2)


def fedpara_matmul_vjp(x, x1, y1, x2, y2, dy, *, kind=None, use_tanh=False,
                       interpret=None, block_b=None, block_m=None,
                       block_n=None):
    """Directly evaluate the fused backward: (dx, dX1, dY1, dX2, dY2).

    Exposed for tests/benchmarks; training paths get this implicitly via
    ``jax.grad`` through :func:`fedpara_matmul`.
    """
    kind, interpret, bb, bm, bn = _resolve_cfg(
        x1, y1, kind, use_tanh, interpret, block_b, block_m, block_n)
    kw = dict(use_tanh=kind == "fedpara_tanh", plus_one=kind == "pfedpara",
              block_b=bb, block_m=bm, block_n=bn, interpret=interpret)
    dx = fedpara_grad.fedpara_dx(dy, x1, y1, x2, y2, out_dtype=x.dtype, **kw)
    dx1, dx2 = fedpara_grad.fedpara_dx_factors(x, dy, x1, y1, x2, y2, **kw)
    dy1, dy2 = fedpara_grad.fedpara_dy_factors(x, dy, x1, y1, x2, y2, **kw)
    return dx, dx1, dy1, dx2, dy2


def fedpara_compose(x1, y1, x2, y2, *, use_tanh=False, interpret=None, **kw):
    """W = (X1Y1ᵀ)⊙(X2Y2ᵀ) — tiled compose (serving pre-composition)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _compose(x1, y1, x2, y2, use_tanh=use_tanh, interpret=interpret, **kw)


def pfedpara_compose(x1, y1, x2, y2, *, interpret=None, **kw):
    """W = (X1Y1ᵀ) ⊙ (X2Y2ᵀ + 1) — pFedPara compose."""
    interpret = _default_interpret() if interpret is None else interpret
    return _compose(x1, y1, x2, y2, plus_one=True, interpret=interpret, **kw)


def _serve_blocks(m, n, r, block_b, block_m, block_n):
    tb, tm, tn = blocks.select_serve_blocks(m, n, r)
    return block_b or tb, block_m or tm, block_n or tn


def w8_matmul(x, w, scale=None, *, interpret=None, block_b=None,
              block_m=None, block_n=None, out_dtype=None):
    """y = (x @ W)·s against a pre-composed serving weight cache.

    Args:
        x: activations ``(B, m)``.
        w: cached weight ``(m, n)`` — int8 (with ``scale``) or fp16/bf16.
        scale: per-output-channel scales ``(1, n)`` fp32 (None for an
            unquantized cache).
        interpret: force Pallas interpret mode (default: auto).
        block_b/block_m/block_n: tile overrides (default: the serve tile
            table ``repro.kernels.blocks.select_serve_blocks``).
        out_dtype: output dtype (default: x's dtype).

    Returns:
        ``(B, n)``. An int8 cache is widened only inside the kernel's
        VMEM tiles — never in HBM (the serve program contract).
    """
    interpret = _default_interpret() if interpret is None else interpret
    bb, bm, bn = _serve_blocks(w.shape[0], w.shape[1], 0,
                               block_b, block_m, block_n)
    return serve_matmul.w8_matmul(
        x, w, scale, block_b=bb, block_m=bm, block_n=bn,
        interpret=interpret, out_dtype=out_dtype)


def cache_residual_matmul(x, w, scale, x2, y2, *, interpret=None,
                          block_b=None, block_m=None, block_n=None,
                          out_dtype=None):
    """pFedPara serve matmul: y = (x @ (W ⊙ (X2Y2ᵀ + 1)))·s, where W is
    the shared composed-W1 cache (int8 or fp16) and (X2, Y2) are a
    user's personal factors — the per-user weight never exists.

    Args:
        x: activations — ``(B, m)`` for one user, or ``(U, t, m)`` for U
            distinct users (t tokens each, one launch total).
        w: shared cache ``(m, n)``; ``scale``: ``(1, n)`` fp32 or None.
        x2, y2: personal factors ``(m, r)``/``(n, r)``, with a leading
            user axis in the many-user layout.
        interpret / block_* / out_dtype: as :func:`w8_matmul`.

    Returns:
        ``(B, n)`` or ``(U, t, n)``.
    """
    interpret = _default_interpret() if interpret is None else interpret
    bb, bm, bn = _serve_blocks(w.shape[0], w.shape[1], x2.shape[-1],
                               block_b, block_m, block_n)
    return serve_matmul.cache_residual_matmul(
        x, w, scale, x2, y2, block_b=bb, block_m=bm, block_n=bn,
        interpret=interpret, out_dtype=out_dtype)


def fedpara_gram_decode(x, x1, y1, x2, y2, *, kind=None, out_dtype=None):
    """Decode-batch fused matmul via the Hadamard-Gram identity:
    y = rowsum((Y1·(X1ᵀ diag(x) X2)) ⊙ Y2) — O(r²(m+n)) FLOPs per token,
    factor bytes only, and NO dense (m, n) intermediate anywhere (so no
    Pallas kernel is needed; XLA has nothing to materialize).

    Args:
        x: activations ``(B, m)``, or ``(U, t, m)`` with per-user
            residual factors.
        x1, y1: shared factors ``(m, r1)``/``(n, r1)``.
        x2, y2: residual factors — shared ``(m, r2)``/``(n, r2)`` or
            per-user ``(U, m, r2)``/``(U, n, r2)``.
        kind: ``fedpara`` | ``pfedpara`` (the tanh variant is not
            low-rank and is rejected).
        out_dtype: output dtype (default: x's dtype).

    Returns:
        ``(B, n)`` or ``(U, t, n)``.
    """
    return serve_matmul.fedpara_gram_decode(
        x, x1, y1, x2, y2, kind=resolve_kind(kind), out_dtype=out_dtype)


def dequant_acc(acc, q, coeff, *, interpret=None, **kw):
    """acc += coeff @ dequant(q): fused streaming-aggregation reduction
    (interpret resolved like the matmul kernels)."""
    interpret = _default_interpret() if interpret is None else interpret
    return agg.dequant_acc(acc, q, coeff, interpret=interpret, **kw)


tree_dequant_acc = agg.tree_dequant_acc
sharded_tree_dequant_acc = agg.sharded_tree_dequant_acc

# Re-export oracles for convenience.
fedpara_matmul_ref = ref.fedpara_matmul_ref
fedpara_compose_ref = ref.fedpara_compose_ref
pfedpara_compose_ref = ref.pfedpara_compose_ref
fedpara_matmul_vjp_ref = ref.fedpara_matmul_vjp_ref
dequant_acc_ref = ref.dequant_acc_ref
tree_dequant_acc_ref = ref.tree_dequant_acc_ref
w8_matmul_ref = ref.w8_matmul_ref
cache_residual_ref = ref.cache_residual_ref
select_blocks = blocks.select_blocks
select_agg_blocks = blocks.select_agg_blocks
select_serve_blocks = blocks.select_serve_blocks
