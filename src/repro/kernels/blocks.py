"""Block-size selection shared by the fused forward and backward kernels.

One (block_b, block_m, block_n) choice per (m, n, r) regime, so the
custom-VJP forward and its backward kernels tile identically (the
backward's VMEM high-water mark is the (bm, bn) dW scratch plus four
factor slices — the same working set the forward composes). ``r`` rides
along in each tile's minor dimension (bm·r / bn·r factor slices), so
the regimes are keyed on the layer extent max(m, n) alone; the tiles
stay within VMEM budget up to r ≈ 512.
"""
from __future__ import annotations

from typing import Tuple

# max(m, n) lower bound -> (block_b, block_m, block_n); first match
# wins, rows ordered largest-extent first.
_TABLE = (
    # huge layers (405B-config FFN): wide n tiles amortize factor reloads
    (8192, (128, 256, 512)),
    # large MXU-aligned layers
    (1024, (128, 256, 256)),
    # mid-size layers; smaller tiles keep padding waste bounded
    (256, (64, 256, 256)),
    # small layers (MLP/LSTM miniatures): one or two tiles per axis
    (0, (32, 128, 128)),
)


def select_blocks(m: int, n: int, r: int) -> Tuple[int, int, int]:
    """(block_b, block_m, block_n) for a (m, n) layer of inner rank r."""
    del r  # tiles carry r in the minor dim; extent decides the regime
    mn = max(m, n)
    for min_mn, blocks in _TABLE:
        if mn >= min_mn:
            return blocks
    return _TABLE[-1][1]
