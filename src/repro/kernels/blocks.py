"""Block-size selection shared by the fused forward and backward kernels.

One (block_b, block_m, block_n) choice per (m, n, r) regime, so the
custom-VJP forward and its backward kernels tile identically (the
backward's VMEM high-water mark is the (bm, bn) dW scratch plus four
factor slices — the same working set the forward composes). ``r`` rides
along in each tile's minor dimension (bm·r / bn·r factor slices), so
the regimes are keyed on the layer extent max(m, n) alone; the tiles
stay within VMEM budget up to r ≈ 512.
"""
from __future__ import annotations

from typing import Tuple

# max(m, n) lower bound -> (block_b, block_m, block_n); first match
# wins, rows ordered largest-extent first.
_TABLE = (
    # huge layers (405B-config FFN): wide n tiles amortize factor reloads
    (8192, (128, 256, 512)),
    # large MXU-aligned layers
    (1024, (128, 256, 256)),
    # mid-size layers; smaller tiles keep padding waste bounded
    (256, (64, 256, 256)),
    # small layers (MLP/LSTM miniatures): one or two tiles per axis
    (0, (32, 128, 128)),
)


def select_blocks(m: int, n: int, r: int) -> Tuple[int, int, int]:
    """(block_b, block_m, block_n) for a (m, n) layer of inner rank r."""
    del r  # tiles carry r in the minor dim; extent decides the regime
    mn = max(m, n)
    for min_mn, blocks in _TABLE:
        if mn >= min_mn:
            return blocks
    return _TABLE[-1][1]


# ---------------------------------------------------------- serving tiles
#
# The serve kernels (kernels/serve_matmul.py) carry a dense (bm, bn)
# weight-cache tile — int8 at 1 B/elt, widened to fp32 in VMEM — so
# their VMEM high-water mark is the widened cache tile plus the fp32
# accumulator, not factor slices. block_b stays small: decode batches
# are tiny and a narrow activation tile leaves headroom for wide n
# tiles that amortize cache-tile fetches. bm is kept a multiple of 32
# (the int8 sublane minimum) and bn of 128 (lane minimum).

# max(m, n) lower bound -> (block_b, block_m, block_n); first match wins.
_SERVE_TABLE = (
    # huge layers: wide tiles, ~1 MB widened cache tile in VMEM
    (8192, (64, 512, 512)),
    # large MXU-aligned layers
    (1024, (64, 256, 512)),
    # mid-size layers
    (256, (32, 256, 256)),
    # small layers (smoke-size models): one or two tiles per axis
    (0, (8, 128, 128)),
)


def select_serve_blocks(m: int, n: int, r: int) -> Tuple[int, int, int]:
    """(block_b, block_m, block_n) for the serve cache/residual kernels."""
    del r  # residual factor slices ride in the minor dim
    mn = max(m, n)
    for min_mn, blocks in _SERVE_TABLE:
        if mn >= min_mn:
            return blocks
    return _SERVE_TABLE[-1][1]


# --------------------------------------------------- dequant-aggregate tiles
#
# The fused dequant-accumulate kernel (kernels/agg.py) reduces a
# (C, L) client-stacked wire buffer into a (L,) fp32 accumulator. The
# client axis is the sublane dim of the wire tile, so it follows the
# int8 tiling minimum (32 sublanes); the flat-value axis is the lane
# dim and widens with L so large leaves amortize per-step overheads
# while one wire tile + the (1, bl) fp32 accumulator stay far inside
# VMEM (a (32, 8192) int8 tile is 256 KB).

# flat length lower bound -> (block_c, block_l); first match wins.
_AGG_TABLE = (
    (1 << 20, (32, 16384)),
    (1 << 16, (32, 8192)),
    (1 << 12, (32, 2048)),
    (0, (32, 512)),
)


def select_agg_blocks(c: int, length: int) -> Tuple[int, int]:
    """(block_c, block_l) for reducing a (c, length) wire stack."""
    del c  # the client axis is padded to the int8 sublane minimum
    for min_l, blocks in _AGG_TABLE:
        if length >= min_l:
            return blocks
    return _AGG_TABLE[-1][1]
