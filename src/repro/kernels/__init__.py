"""Pallas TPU kernels for the FedPara hot spots.

fedpara_matmul: fused compose+matmul — the dense W never hits HBM.
fedpara_compose / pfedpara_compose: tiled serving-time pre-composition.
ref.py holds the pure-jnp oracles; tests sweep shapes/dtypes against
them in interpret mode (CPU).
"""
from repro.kernels import ops, ref
from repro.kernels.ops import fedpara_compose, fedpara_matmul, pfedpara_compose

__all__ = ["ops", "ref", "fedpara_compose", "fedpara_matmul",
           "pfedpara_compose"]
