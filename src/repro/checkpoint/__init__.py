from repro.checkpoint.manager import CheckpointManager, unflatten_paths

__all__ = ["CheckpointManager", "unflatten_paths"]
