"""Fault-tolerant checkpointing.

Format: a directory per step — ``step_<N>/arrays.npz`` (flattened
pytree leaves, host-gathered) + ``manifest.msgpack`` (treedef paths,
shapes, dtypes, step, stream position, extra metadata). Writes go to a
temp dir and are atomically renamed, so a crash mid-save never corrupts
the latest checkpoint. Saves can run on a background thread (async);
``keep`` bounds disk use.

Restore is mesh-agnostic: arrays are loaded on host and re-sharded by
``jax.device_put`` against whatever shardings the *new* mesh prescribes
— the elasticity path (restart on a different pod count re-shards
transparently).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def unflatten_paths(by_path: Dict[str, Any], prefix: str = "",
                    listify: bool = True) -> Any:
    """Rebuild a nested tree from the "/"-joined paths of
    :meth:`CheckpointManager.restore_items`.

    The inverse of the manager's path flattening for dict/list pytrees:
    every path component becomes a dict key; with ``listify`` (default),
    dicts whose keys are exactly "0".."k-1" are converted back to lists
    (list-structured model subtrees, e.g. xLSTM block stacks, roundtrip
    losslessly). ``prefix`` selects a subtree ("global_params",
    "local_trees/3", ...) and strips it from the returned keys.

    Serving restores through this: the FL server's checkpoint structure
    is data-dependent (per-client entries), so a serve process cannot
    supply a target_tree up front — it rebuilds the tree from paths and
    picks out ``global_params`` / ``local_trees/<cid>``.
    """
    if prefix and not prefix.endswith("/"):
        prefix = prefix + "/"
    root: Dict[str, Any] = {}
    for path, leaf in by_path.items():
        if prefix:
            if not path.startswith(prefix):
                continue
            path = path[len(prefix):]
        parts = path.split("/")
        node = root
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = leaf

    def walk(node):
        if not isinstance(node, dict):
            return node
        out = {k: walk(v) for k, v in node.items()}
        if listify and out and all(k.isdigit() for k in out):
            idx = sorted(out, key=int)
            if idx == [str(i) for i in range(len(idx))]:
                return [out[k] for k in idx]
        return out

    return walk(root)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self.wait()   # re-raises a previous async save's failure
            self._pending = threading.Thread(
                target=self._write_guarded, args=(step, host_tree, extra),
                daemon=True)
            self._pending.start()
        else:
            self._write(step, host_tree, extra)
        return os.path.join(self.dir, f"step_{step:010d}")

    def _write_guarded(self, step: int, host_tree: Any,
                       extra: Optional[Dict]):
        # daemon-thread body: a raised exception would otherwise die with
        # the thread and the caller would keep training on the silent
        # assumption that the checkpoint exists — capture it and let the
        # next wait()/save() raise it on the caller's thread
        try:
            self._write(step, host_tree, extra)
        except BaseException as e:   # noqa: BLE001  (re-raised in wait)
            self._error = e

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree: Any, extra: Optional[Dict]):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        items = _flatten_with_paths(host_tree)
        # store raw bytes (npz can't serialize bf16/fp8 ml_dtypes)
        arrays = {f"a{i}": np.frombuffer(np.ascontiguousarray(leaf).tobytes(),
                                         np.uint8)
                  for i, (_, leaf) in enumerate(items)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "paths": [k for k, _ in items],
            "dtypes": [str(leaf.dtype) for _, leaf in items],
            "shapes": [list(leaf.shape) for _, leaf in items],
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.msgpack")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_items(self, step: Optional[int]) -> Tuple[Dict, Dict, int]:
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)

        data = np.load(os.path.join(d, "arrays.npz"))
        by_path = {}
        for i, p in enumerate(manifest["paths"]):
            raw = data[f"a{i}"]
            dt = np.dtype(manifest["dtypes"][i])
            by_path[p] = raw.view(dt).reshape(manifest["shapes"][i])
        return by_path, manifest["extra"], int(manifest["step"])

    def restore_items(self, step: Optional[int] = None
                      ) -> Tuple[Dict, Dict, int]:
        """Structure-free restore: ``(by_path, extra, step)`` where
        ``by_path`` maps "/"-joined tree paths to host arrays. For
        callers whose checkpointed structure is data-dependent (e.g. an
        FL server's per-client state dicts) and therefore cannot supply
        a target_tree before reading the checkpoint."""
        return self._load_items(step)

    def restore(self, step: Optional[int], target_tree: Any,
                shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``target_tree``; optionally
        device_put against per-leaf shardings (elastic re-shard)."""
        by_path, extra, _ = self._load_items(step)
        tgt_items = _flatten_with_paths(target_tree)
        leaves = []
        for key, tgt in tgt_items:
            if key not in by_path:
                raise KeyError(f"checkpoint missing leaf '{key}'")
            arr = by_path[key]
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {tgt.shape}")
            if arr.dtype != tgt.dtype:
                arr = arr.astype(tgt.dtype)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(target_tree)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, extra
