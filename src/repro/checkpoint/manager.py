"""Fault-tolerant checkpointing.

Format: a directory per step — ``step_<N>/arrays.npz`` (flattened
pytree leaves, host-gathered) + ``manifest.msgpack`` (treedef paths,
shapes, dtypes, step, stream position, extra metadata). Writes go to a
temp dir and are atomically renamed, so a crash mid-save never corrupts
the latest checkpoint. Saves can run on a background thread (async);
``keep`` bounds disk use.

Restore is mesh-agnostic: arrays are loaded on host and re-sharded by
``jax.device_put`` against whatever shardings the *new* mesh prescribes
— the elasticity path (restart on a different pod count re-shards
transparently).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(step, host_tree, extra), daemon=True
            )
            self._pending.start()
        else:
            self._write(step, host_tree, extra)
        return os.path.join(self.dir, f"step_{step:010d}")

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree: Any, extra: Optional[Dict]):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        items = _flatten_with_paths(host_tree)
        # store raw bytes (npz can't serialize bf16/fp8 ml_dtypes)
        arrays = {f"a{i}": np.frombuffer(np.ascontiguousarray(leaf).tobytes(),
                                         np.uint8)
                  for i, (_, leaf) in enumerate(items)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "paths": [k for k, _ in items],
            "dtypes": [str(leaf.dtype) for _, leaf in items],
            "shapes": [list(leaf.shape) for _, leaf in items],
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.msgpack")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int], target_tree: Any,
                shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``target_tree``; optionally
        device_put against per-leaf shardings (elastic re-shard)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)

        data = np.load(os.path.join(d, "arrays.npz"))
        by_path = {}
        for i, p in enumerate(manifest["paths"]):
            raw = data[f"a{i}"]
            dt = np.dtype(manifest["dtypes"][i])
            by_path[p] = raw.view(dt).reshape(manifest["shapes"][i])

        tgt_items = _flatten_with_paths(target_tree)
        leaves = []
        for key, tgt in tgt_items:
            if key not in by_path:
                raise KeyError(f"checkpoint missing leaf '{key}'")
            arr = by_path[key]
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {tgt.shape}")
            if arr.dtype != tgt.dtype:
                arr = arr.astype(tgt.dtype)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(target_tree)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest["extra"]
