"""Post-SPMD HLO analysis: collective-byte accounting per op type and
per mesh domain (intra-pod ICI vs cross-pod DCN).

cost_analysis() has no collective term, so we parse the partitioned
module (the post-SPMD-partitioner pass dump, which still carries bf16
types — the CPU backend's float normalization would upcast dot-adjacent
collectives to f32) and account every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute:

  bytes       sum of *operand* sizes (task-spec accounting). Operands
              are resolved through the instruction-definition table
              (pass dumps print operands by name, not by shape).
  ring_bytes  realistic per-device ring traffic:
              all-reduce 2·b·(g-1)/g, all-gather b·(g-1),
              reduce-scatter/all-to-all b·(g-1)/g, permute b.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RX = re.compile(r"(\w+?)\[([\d,]*)\]")
_DEF_RX = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\w+\[[\d,]*\]\S*)")
_COLL_RX = re.compile(
    r"=\s*(?:\([^)]*\)|\w+\[[\d,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(([^)]*)\)"
)
_GROUPS_RX = re.compile(
    r"replica_groups=(\{\{[^}]*(?:\},\{[^}]*)*\}\}|"
    r"\[[\d,]+\]<=\[[\d,]+\](?:T\([\d,]+\))?)"
)


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RX.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_replica_groups(s: str) -> Optional[List[List[int]]]:
    if s.startswith("{{"):
        groups = []
        for grp in re.findall(r"\{([\d, ]*)\}", s[1:-1]):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids:
                groups.append(ids)
        return groups
    m = re.match(r"\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", s)
    if not m:
        return None
    out_shape = [int(x) for x in m.group(1).split(",")]
    in_shape = [int(x) for x in m.group(2).split(",")]
    total = int(np.prod(in_shape))
    arr = np.arange(total).reshape(in_shape)
    if m.group(3):
        perm = [int(x) for x in m.group(3).split(",")]
        arr = arr.transpose(perm)
    arr = arr.reshape(out_shape)
    return [list(map(int, row)) for row in arr.reshape(out_shape[0], -1)]


def classify_domain(groups: Optional[List[List[int]]], pod_size: int) -> str:
    """'cross_pod' if any group spans devices in different pods."""
    if not groups or not pod_size:
        return "intra_pod"
    for g in groups:
        pods = {d // pod_size for d in g}
        if len(pods) > 1:
            return "cross_pod"
    return "intra_pod"


def _ring_factor(op: str, gsize: int) -> float:
    if gsize <= 1:
        return 0.0
    frac = (gsize - 1) / gsize
    if op == "all-reduce":
        return 2.0 * frac
    if op == "all-gather":
        return float(gsize - 1)
    if op in ("reduce-scatter", "all-to-all"):
        return frac
    return 1.0  # collective-permute


def collective_stats(hlo_text: str, pod_size: int = 0) -> Dict[str, Dict]:
    """Sum collective *operand* bytes by (op type, domain); see module doc."""
    defs: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RX.match(line)
        if m:
            defs[m.group(1)] = m.group(2)

    stats: Dict[str, Dict] = defaultdict(lambda: {"bytes": 0, "ring_bytes": 0.0,
                                                  "count": 0})
    for line in hlo_text.splitlines():
        m = _COLL_RX.search(line)
        if not m:
            continue
        op, is_start, operands_str = m.group(1), m.group(2), m.group(3)
        if "-done(" in line:
            continue
        nbytes = 0
        for opnd in operands_str.split(","):
            opnd = opnd.strip()
            if not opnd:
                continue
            if "[" in opnd:                       # typed operand inline
                nbytes += shape_bytes(opnd)
            else:                                 # resolve by name
                name = opnd.lstrip("%")
                if name in defs:
                    nbytes += shape_bytes(defs[name])
        gm = _GROUPS_RX.search(line)
        groups = parse_replica_groups(gm.group(1)) if gm else None
        gsize = len(groups[0]) if groups and groups[0] else 1
        # source-target_pairs form (collective-permute without groups)
        if groups is None and op == "collective-permute":
            gsize = 2
        domain = classify_domain(groups, pod_size)
        key = f"{op}:{domain}"
        stats[key]["bytes"] += nbytes
        stats[key]["ring_bytes"] += nbytes * _ring_factor(op, gsize)
        stats[key]["count"] += 1

    agg = {"total": {"bytes": 0, "ring_bytes": 0.0, "count": 0},
           "cross_pod": {"bytes": 0, "ring_bytes": 0.0, "count": 0},
           "intra_pod": {"bytes": 0, "ring_bytes": 0.0, "count": 0}}
    for key, v in list(stats.items()):
        dom = key.split(":")[1]
        for f in ("bytes", "ring_bytes", "count"):
            agg["total"][f] += v[f]
            agg[dom][f] += v[f]
    stats.update(agg)
    return dict(stats)


def extrapolate(u1: Dict, u2: Dict, periods: int) -> Dict:
    """total = u1 + (periods-1) * (u2 - u1), per stat key/field."""
    keys = set(u1) | set(u2)
    out: Dict[str, Dict] = {}
    zero = {"bytes": 0, "ring_bytes": 0.0, "count": 0}
    for k in keys:
        a = u1.get(k, zero)
        b = u2.get(k, zero)
        out[k] = {
            f: max(0.0, a[f] + (periods - 1) * (b[f] - a[f]))
            for f in ("bytes", "ring_bytes", "count")
        }
    return out
