"""fedlint layer 1: the AST rule engine (rules FED001-FED006).

The engine builds a *project-wide* picture before any rule fires:

  1. every ``.py`` file is parsed once into a :class:`ModuleInfo`
     (functions with qualnames, per-module import aliases, class
     method tables);
  2. **traced roots** are collected — functions that jax will trace:
     ``@jax.jit`` / ``functools.partial(jax.jit, ...)`` decorations,
     functions passed to ``jax.jit`` / ``vmap`` / ``grad`` /
     ``lax.scan`` / ``lax.cond`` / ``shard_map`` /
     ``pl.pallas_call`` / ``jax.checkpoint`` call sites, Pallas kernel
     bodies (``*_ref`` parameter convention), and functions nested
     inside any of those;
  3. traced-ness propagates over the *cross-module* call graph
     (``from repro.fl.batch_engine import chunk_round_program`` inside
     a jitted body makes ``chunk_round_program`` traced too), stopping
     at host-callback boundaries: a callee handed to
     ``jax.pure_callback`` / ``io_callback`` runs host-side and is
     exempt from the traced-body rules.

Rules (see docs/analysis.md for the catalog with examples):

  FED001  host RNG (``np.random`` / stdlib ``random``) reachable from
          a traced body — silently constant-folds at trace time.
  FED002  implicit host sync (``.item()``, ``float()`` / ``int()`` /
          ``bool()`` on non-shape values, ``np.asarray`` /
          ``np.array``) inside a traced body.
  FED003  ``static_argnames`` / ``static_argnums`` entries must name
          real parameters of the wrapped function.
  FED004  donated arguments must not be read again after the jitted
          call site in the enclosing scope.
  FED005  ``jax.pure_callback`` callees must have stable identity
          (module-level function, bound method) — lambdas, nested
          defs and inline ``functools.partial`` retrace per call.
  FED006  iteration over unordered ``set`` expressions when building
          collections — param-tree key order must be deterministic.

The resolution is heuristic (names, not types) but repo-shaped: it is
tuned to how this codebase spells its tracing constructs, and the
committed baseline absorbs the rare intentional hit.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

RULES = {
    "FED001": "host RNG reachable from a traced function body",
    "FED002": "implicit host sync on a traced value inside a jitted body",
    "FED003": "static_argnames/static_argnums entry names no real parameter",
    "FED004": "donated argument referenced after the jitted call site",
    "FED005": "pure_callback callee must be module-level / stable identity",
    "FED006": "dict/tree built by iterating an unordered set",
    "FED007": "dead relative link in markdown docs",
}

# call heads that trace their first function-valued argument
_JIT_HEADS = ("jax.jit", "jit", "pjit", "jax.pmap", "pmap")
_TRACE_ARG0_HEADS = (
    "jax.vmap", "vmap", "jax.grad", "jax.value_and_grad", "jax.checkpoint",
    "jax.remat", "jax.custom_vjp", "jax.custom_jvp", "jax.linearize",
    "jax.jacfwd", "jax.jacrev", "jax.hessian",
)
_LAX_HEADS = (
    "jax.lax.scan", "lax.scan", "jax.lax.while_loop", "lax.while_loop",
    "jax.lax.fori_loop", "lax.fori_loop", "jax.lax.cond", "lax.cond",
    "jax.lax.switch", "lax.switch", "jax.lax.map", "lax.map",
    "jax.lax.associative_scan", "lax.associative_scan",
)
_SHARD_HEADS = ("shard_map", "jax.experimental.shard_map.shard_map")
_PALLAS_HEADS = ("pl.pallas_call", "pallas_call",
                 "jax.experimental.pallas.pallas_call")
_CALLBACK_HEADS = ("jax.pure_callback", "pure_callback",
                   "jax.experimental.io_callback", "io_callback",
                   "jax.debug.callback")
_PARTIAL_HEADS = ("functools.partial", "partial")
_STATIC_KW_HEADS = _JIT_HEADS + ("jax.checkpoint", "jax.remat", "checkpoint")

_TRACING_HEADS = (_JIT_HEADS + _TRACE_ARG0_HEADS + _LAX_HEADS + _SHARD_HEADS
                  + _PALLAS_HEADS)


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str      # repo-relative posix path
    line: int
    col: int
    symbol: str    # enclosing function qualname ('<module>' at top level)
    message: str
    snippet: str   # stripped source line the finding anchors to

    @property
    def key(self) -> str:
        """Line-number-independent identity used by the baseline file."""
        return "::".join((self.rule, self.path, self.symbol,
                          " ".join(self.snippet.split())))

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.symbol}] {self.message}\n    {self.snippet}")


@dataclass
class FuncInfo:
    module: str
    qualname: str
    node: ast.AST                      # FunctionDef / AsyncFunctionDef / Lambda
    pos_params: List[str]
    kwonly_params: List[str]
    has_varargs: bool
    parent_class: Optional[str]
    parent_func: Optional[str]         # enclosing function qualname (nested)
    traced: bool = False
    host_cb: bool = False
    trace_reason: str = ""


@dataclass
class ModuleInfo:
    name: str                          # dotted module name
    path: Path
    rel: str                           # repo-relative posix path
    tree: ast.Module
    lines: List[str]
    # local name -> ("module", dotted) | ("symbol", dotted_module, symbol)
    imports: Dict[str, Tuple] = field(default_factory=dict)
    functions: Dict[str, FuncInfo] = field(default_factory=dict)
    toplevel: Dict[str, str] = field(default_factory=dict)     # name -> qualname
    methods: Dict[str, Dict[str, str]] = field(default_factory=dict)
    parents: Dict[int, ast.AST] = field(default_factory=dict)  # id(node) -> parent
    func_of_node: Dict[int, str] = field(default_factory=dict)

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""


def dotted(node: ast.AST) -> Optional[str]:
    """'jax.lax.scan' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _unwrap_partial(call_arg: ast.AST) -> ast.AST:
    """functools.partial(f, ...) -> f (one level is enough here)."""
    if (isinstance(call_arg, ast.Call)
            and dotted(call_arg.func) in _PARTIAL_HEADS and call_arg.args):
        return call_arg.args[0]
    return call_arg


def _literal(node: Optional[ast.AST]):
    if node is None:
        return None
    try:
        return ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return None


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


class Project:
    """Parsed view of every source file; runs the rules."""

    def __init__(self, files: Iterable[Path], repo_root: Path,
                 src_root: Optional[Path] = None):
        self.repo_root = Path(repo_root)
        self.src_root = Path(src_root) if src_root else self.repo_root / "src"
        self.modules: Dict[str, ModuleInfo] = {}
        self.errors: List[Finding] = []
        for f in sorted(set(Path(p) for p in files)):
            self._load(f)
        self._collect_roots()
        self._propagate()

    # -------------------------------------------------------------- loading
    def _module_name(self, path: Path) -> str:
        try:
            rel = path.resolve().relative_to(self.src_root.resolve())
            parts = list(rel.with_suffix("").parts)
            if parts and parts[-1] == "__init__":
                parts = parts[:-1]
            return ".".join(parts)
        except ValueError:
            return path.stem

    def _load(self, path: Path):
        try:
            src = path.read_text()
            tree = ast.parse(src)
        except (OSError, SyntaxError) as e:
            rel = self._rel(path)
            self.errors.append(Finding(
                "PARSE", rel, getattr(e, "lineno", 0) or 0, 0, "<module>",
                f"cannot parse: {e}", ""))
            return
        mod = ModuleInfo(self._module_name(path), path, self._rel(path),
                         tree, src.splitlines())
        self._index(mod)
        self.modules[mod.name] = mod

    def _rel(self, path: Path) -> str:
        try:
            return path.resolve().relative_to(
                self.repo_root.resolve()).as_posix()
        except ValueError:
            return path.as_posix()

    def _index(self, mod: ModuleInfo):
        for node in ast.walk(mod.tree):
            for child in ast.iter_child_nodes(node):
                mod.parents[id(child)] = node
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mod.imports[a.asname or a.name.split(".")[0]] = (
                        "module", a.name)
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if a.name == "*":
                        continue
                    mod.imports[a.asname or a.name] = (
                        "symbol", node.module, a.name)
        self._index_funcs(mod, mod.tree, cls=None, func=None)

    def _index_funcs(self, mod: ModuleInfo, node: ast.AST,
                     cls: Optional[str], func: Optional[str]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if func:
                    q = f"{func}.<locals>.{child.name}"
                elif cls:
                    q = f"{cls}.{child.name}"
                else:
                    q = child.name
                a = child.args
                pos = [p.arg for p in a.posonlyargs + a.args]
                fi = FuncInfo(mod.name, q, child, pos,
                              [p.arg for p in a.kwonlyargs],
                              a.vararg is not None, cls, func)
                mod.functions[q] = fi
                if func is None and cls is None:
                    mod.toplevel[child.name] = q
                if func is None and cls is not None:
                    mod.methods.setdefault(cls, {})[child.name] = q
                for sub in ast.walk(child):
                    mod.func_of_node.setdefault(id(sub), q)
                self._index_funcs(mod, child, cls=cls, func=q)
            elif isinstance(child, ast.ClassDef):
                if cls is None and func is None:
                    self._index_funcs(mod, child, cls=child.name, func=None)
                else:
                    self._index_funcs(mod, child, cls=cls, func=func)
            elif isinstance(child, ast.Lambda):
                pass   # lambdas handled at their use sites
            else:
                self._index_funcs(mod, child, cls=cls, func=func)

    # --------------------------------------------------------- resolution
    def _head(self, mod: ModuleInfo, expr: ast.AST) -> Optional[str]:
        """Dotted head of a call target, normalizing import aliases of
        plain modules (``import jax.numpy as jnp`` keeps its alias —
        rules match on the common spellings instead)."""
        return dotted(expr)

    def resolve(self, mod: ModuleInfo, ctx_func: Optional[str],
                expr: ast.AST) -> Optional[Tuple[str, str]]:
        """Resolve a function-valued expression to (module, qualname)."""
        expr = _unwrap_partial(expr)
        if isinstance(expr, ast.Name):
            name = expr.id
            # nested defs of the enclosing function chain, innermost out
            q = ctx_func
            while q:
                cand = f"{q}.<locals>.{name}"
                if cand in mod.functions:
                    return (mod.name, cand)
                q = mod.functions[q].parent_func if q in mod.functions else None
            if name in mod.toplevel:
                return (mod.name, mod.toplevel[name])
            imp = mod.imports.get(name)
            if imp and imp[0] == "symbol":
                target = self.modules.get(imp[1])
                if target and imp[2] in target.toplevel:
                    return (target.name, target.toplevel[imp[2]])
            return None
        if isinstance(expr, ast.Attribute):
            base = expr.value
            if isinstance(base, ast.Name):
                if base.id == "self" and ctx_func and ctx_func in mod.functions:
                    cls = mod.functions[ctx_func].parent_class
                    if cls and expr.attr in mod.methods.get(cls, {}):
                        return (mod.name, mod.methods[cls][expr.attr])
                imp = mod.imports.get(base.id)
                if imp and imp[0] == "module":
                    target = self.modules.get(imp[1])
                    if target and expr.attr in target.toplevel:
                        return (target.name, target.toplevel[expr.attr])
                if imp and imp[0] == "symbol":
                    # `from repro.core import parameterization as param_lib`
                    target = self.modules.get(f"{imp[1]}.{imp[2]}")
                    if target and expr.attr in target.toplevel:
                        return (target.name, target.toplevel[expr.attr])
        return None

    def func(self, ref: Tuple[str, str]) -> Optional[FuncInfo]:
        mod = self.modules.get(ref[0])
        return mod.functions.get(ref[1]) if mod else None

    # ----------------------------------------------------- traced roots
    def _mark(self, ref: Optional[Tuple[str, str]], reason: str,
              host_cb: bool = False):
        fi = self.func(ref) if ref else None
        if fi is None:
            return
        if host_cb:
            fi.host_cb = True
        elif not fi.traced:
            fi.traced = True
            fi.trace_reason = reason

    def _collect_roots(self):
        for mod in self.modules.values():
            # decorator roots
            for fi in mod.functions.values():
                node = fi.node
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                for dec in node.decorator_list:
                    head = dotted(dec) or (dotted(dec.func)
                                           if isinstance(dec, ast.Call)
                                           else None)
                    if head in _JIT_HEADS + _TRACE_ARG0_HEADS + _SHARD_HEADS:
                        self._mark((mod.name, fi.qualname), f"@{head}")
                    elif (head in _PARTIAL_HEADS and isinstance(dec, ast.Call)
                          and dec.args):
                        inner = dotted(dec.args[0])
                        if inner in (_JIT_HEADS + _TRACE_ARG0_HEADS
                                     + _SHARD_HEADS):
                            self._mark((mod.name, fi.qualname),
                                       f"@partial({inner})")
                # pallas kernel-body convention: *_ref parameters
                refs = [p for p in fi.pos_params if p.endswith("_ref")]
                if len(refs) >= 2:
                    self._mark((mod.name, fi.qualname), "pallas kernel body")
            # call-site roots
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                head = dotted(node.func)
                if head is None:
                    continue
                ctx = mod.func_of_node.get(id(node))
                if head in _CALLBACK_HEADS:
                    if node.args:
                        self._mark(self.resolve(mod, ctx, node.args[0]),
                                   "host callback", host_cb=True)
                    continue
                if head in _JIT_HEADS + _TRACE_ARG0_HEADS + _SHARD_HEADS \
                        + _PALLAS_HEADS:
                    if node.args:
                        self._mark(self.resolve(mod, ctx, node.args[0]),
                                   f"passed to {head}")
                elif head in _LAX_HEADS:
                    for a in node.args:
                        self._mark(self.resolve(mod, ctx, a),
                                   f"passed to {head}")

    def _propagate(self):
        # call + containment edges, then BFS from the traced roots
        edges: Dict[Tuple[str, str], Set[Tuple[str, str]]] = {}
        for mod in self.modules.values():
            for fi in mod.functions.values():
                src = (mod.name, fi.qualname)
                out = edges.setdefault(src, set())
                for node in ast.walk(fi.node):
                    if mod.func_of_node.get(id(node)) != fi.qualname:
                        continue   # body of a nested def — its own node
                    if isinstance(node, ast.Call):
                        ref = self.resolve(mod, fi.qualname, node.func)
                        if ref:
                            out.add(ref)
                # containment: nested defs trace with their parent
                for q, sub in mod.functions.items():
                    if sub.parent_func == fi.qualname:
                        out.add((mod.name, q))
        work = [(m.name, f.qualname) for m in self.modules.values()
                for f in m.functions.values() if f.traced]
        seen = set(work)
        while work:
            src = work.pop()
            for dst in edges.get(src, ()):
                fi = self.func(dst)
                if fi is None or fi.host_cb or dst in seen:
                    continue
                seen.add(dst)
                if not fi.traced:
                    fi.traced = True
                    fi.trace_reason = f"called from {src[1]}"
                work.append(dst)

    # ------------------------------------------------------------- rules
    def run(self, select: Optional[Set[str]] = None) -> List[Finding]:
        findings: List[Finding] = list(self.errors)
        for mod in self.modules.values():
            for fi in mod.functions.values():
                if fi.traced and not fi.host_cb:
                    findings += self._fed001(mod, fi)
                    findings += self._fed002(mod, fi)
            findings += self._fed003(mod)
            findings += self._fed004(mod)
            findings += self._fed005(mod)
            findings += self._fed006(mod)
        if select:
            findings = [f for f in findings if f.rule in select]
        findings.sort(key=lambda f: (f.path, f.line, f.rule))
        return findings

    def _own_nodes(self, mod: ModuleInfo, fi: FuncInfo):
        """Nodes belonging to this function body, not to nested defs."""
        for node in ast.walk(fi.node):
            if mod.func_of_node.get(id(node)) == fi.qualname:
                yield node

    def _mk(self, mod: ModuleInfo, node: ast.AST, rule: str, symbol: str,
            msg: str) -> Finding:
        return Finding(rule, mod.rel, node.lineno, node.col_offset, symbol,
                       msg, mod.line(node.lineno))

    # FED001 — host RNG inside traced bodies
    def _fed001(self, mod: ModuleInfo, fi: FuncInfo) -> List[Finding]:
        np_aliases = {n for n, imp in mod.imports.items()
                      if imp == ("module", "numpy")}
        np_aliases.add("numpy")
        rand_aliases = {n for n, imp in mod.imports.items()
                        if imp == ("module", "random")}
        out = []
        for node in self._own_nodes(mod, fi):
            if not isinstance(node, (ast.Call, ast.Attribute)):
                continue
            head = dotted(node.func if isinstance(node, ast.Call) else node)
            if not head:
                continue
            parts = head.split(".")
            if (len(parts) >= 2 and parts[0] in np_aliases
                    and parts[1] == "random" and isinstance(node, ast.Call)):
                out.append(self._mk(
                    mod, node, "FED001", fi.qualname,
                    f"host RNG `{head}` inside traced body "
                    f"({fi.trace_reason}); use jax.random"))
            elif (parts[0] in rand_aliases and len(parts) == 2
                  and isinstance(node, ast.Call)):
                out.append(self._mk(
                    mod, node, "FED001", fi.qualname,
                    f"stdlib RNG `{head}` inside traced body "
                    f"({fi.trace_reason}); use jax.random"))
        return out

    # FED002 — implicit host sync inside traced bodies
    @staticmethod
    def _shape_like(node: ast.AST) -> bool:
        """True when the expression only touches static shape metadata."""
        names = False
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute) and sub.attr in (
                    "shape", "ndim", "size", "dtype", "itemsize", "nbytes"):
                return True
            if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)
                    and sub.func.id == "len"):
                return True
            if isinstance(sub, ast.Name):
                names = True
        return not names   # pure-constant arithmetic is static

    @staticmethod
    def _static_scalar_expr(node: ast.AST, fi: FuncInfo) -> bool:
        """True when every Name leaf is a parameter annotated with a
        Python scalar type (int/float/bool) — such values are static by
        the function's own contract, so float()/int() on them is not a
        sync. Calls other than min/max/abs/round/len disqualify."""
        static = set()
        fnode = fi.node
        if isinstance(fnode, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = fnode.args
            for p in (*a.posonlyargs, *a.args, *a.kwonlyargs):
                ann = p.annotation
                if isinstance(ann, ast.Name) and ann.id in (
                        "int", "float", "bool", "str"):
                    static.add(p.arg)
        if not static:
            return False
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
                if sub.id not in static and sub.id not in (
                        "min", "max", "abs", "round", "len"):
                    return False
            elif isinstance(sub, ast.Attribute):
                return False
        return True

    def _fed002(self, mod: ModuleInfo, fi: FuncInfo) -> List[Finding]:
        np_aliases = {n for n, imp in mod.imports.items()
                      if imp == ("module", "numpy")}
        np_aliases.add("numpy")
        out = []
        for node in self._own_nodes(mod, fi):
            if not isinstance(node, ast.Call):
                continue
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                out.append(self._mk(
                    mod, node, "FED002", fi.qualname,
                    ".item() forces a device sync inside a traced body"))
                continue
            head = dotted(node.func)
            if head and "." in head:
                base, attr = head.rsplit(".", 1)
                if base in np_aliases and attr in ("asarray", "array"):
                    out.append(self._mk(
                        mod, node, "FED002", fi.qualname,
                        f"`{head}` materializes a traced value host-side "
                        f"inside a traced body ({fi.trace_reason})"))
                    continue
            if (isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int", "bool")
                    and len(node.args) == 1
                    and not self._shape_like(node.args[0])
                    and not self._static_scalar_expr(node.args[0], fi)):
                out.append(self._mk(
                    mod, node, "FED002", fi.qualname,
                    f"`{node.func.id}(...)` on a traced value forces a "
                    "host sync (TracerConversionError under jit)"))
        return out

    # FED003 — static_argnames/nums must name real parameters
    def _static_kw_sites(self, mod: ModuleInfo):
        """(call, target FuncInfo) pairs carrying static_* keywords."""
        for node in ast.walk(mod.tree):
            ctx = mod.func_of_node.get(id(node))
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fi = None
                for q, f in mod.functions.items():
                    if f.node is node:
                        fi = f
                        break
                for dec in node.decorator_list:
                    if not isinstance(dec, ast.Call):
                        continue
                    head = dotted(dec.func)
                    if head in _STATIC_KW_HEADS:
                        yield dec, fi
                    elif head in _PARTIAL_HEADS and dec.args and \
                            dotted(dec.args[0]) in _STATIC_KW_HEADS:
                        yield dec, fi
            elif isinstance(node, ast.Call):
                head = dotted(node.func)
                if head in _STATIC_KW_HEADS and node.args:
                    ref = self.resolve(mod, ctx, node.args[0])
                    if ref:
                        yield node, self.func(ref)

    def _fed003(self, mod: ModuleInfo) -> List[Finding]:
        out = []
        for call, fi in self._static_kw_sites(mod):
            if fi is None:
                continue
            names = _literal(_kw(call, "static_argnames"))
            if isinstance(names, str):
                names = (names,)
            if names:
                valid = set(fi.pos_params) | set(fi.kwonly_params)
                for n in names:
                    if n not in valid:
                        out.append(self._mk(
                            mod, call, "FED003", fi.qualname,
                            f"static_argnames entry {n!r} is not a "
                            f"parameter of {fi.qualname} "
                            f"(has: {', '.join(fi.pos_params)})"))
            nums = _literal(_kw(call, "static_argnums"))
            if isinstance(nums, int):
                nums = (nums,)
            if nums and not fi.has_varargs:
                for i in nums:
                    if not (0 <= int(i) < len(fi.pos_params)):
                        out.append(self._mk(
                            mod, call, "FED003", fi.qualname,
                            f"static_argnums index {i} out of range for "
                            f"{fi.qualname} ({len(fi.pos_params)} "
                            "positional parameters)"))
        return out

    # FED004 — donated buffers must not be read after the call site
    def _donating_bindings(self, mod: ModuleInfo):
        """{binding -> donated positions}: module defs with donate
        decorators plus `X = jax.jit(f, donate_argnums=...)` /
        `self.X = jax.jit(...)` assignments."""
        bindings: Dict[Tuple[str, str], Tuple[int, ...]] = {}

        def donate_of(call: ast.Call) -> Tuple[int, ...]:
            v = _literal(_kw(call, "donate_argnums"))
            if isinstance(v, int):
                v = (v,)
            return tuple(int(i) for i in v) if v else ()

        for fi in mod.functions.values():
            node = fi.node
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fi.parent_func or fi.parent_class:
                continue
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                head = dotted(dec.func)
                target = None
                if head in _JIT_HEADS:
                    target = dec
                elif head in _PARTIAL_HEADS and dec.args and \
                        dotted(dec.args[0]) in _JIT_HEADS:
                    target = dec
                if target is not None:
                    d = donate_of(target)
                    if d:
                        bindings[("name", node.name)] = d
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            val = node.value
            if not (isinstance(val, ast.Call)
                    and dotted(val.func) in _JIT_HEADS):
                continue
            d = _literal(_kw(val, "donate_argnums"))
            if isinstance(d, int):
                d = (d,)
            if not d:
                continue
            t = node.targets[0]
            if isinstance(t, ast.Name):
                bindings[("name", t.id)] = tuple(int(i) for i in d)
            elif (isinstance(t, ast.Attribute)
                  and isinstance(t.value, ast.Name) and t.value.id == "self"):
                bindings[("attr", t.attr)] = tuple(int(i) for i in d)
        return bindings

    def _fed004(self, mod: ModuleInfo) -> List[Finding]:
        bindings = self._donating_bindings(mod)
        if not bindings:
            return []
        out = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            kind = None
            if isinstance(node.func, ast.Name):
                kind = ("name", node.func.id)
            elif (isinstance(node.func, ast.Attribute)
                  and isinstance(node.func.value, ast.Name)
                  and node.func.value.id == "self"):
                kind = ("attr", node.func.attr)
            donated = bindings.get(kind)
            if not donated:
                continue
            ctx = mod.func_of_node.get(id(node))
            if ctx is None or ctx not in mod.functions:
                continue
            fn = mod.functions[ctx].node
            for pos in donated:
                if pos >= len(node.args):
                    continue
                arg = node.args[pos]
                use = self._used_after(mod, fn, node, arg)
                if use is not None:
                    label = (dotted(arg) or
                             getattr(arg, "id", "<expr>"))
                    out.append(self._mk(
                        mod, use, "FED004", ctx,
                        f"donated argument `{label}` (position {pos} of "
                        f"`{kind[1]}`) is read again after the jitted "
                        f"call at line {node.lineno} — its buffer is "
                        "invalid after donation"))
        return out

    @staticmethod
    def _used_after(mod: ModuleInfo, fn: ast.AST, call: ast.Call,
                    arg: ast.AST) -> Optional[ast.AST]:
        """First read of ``arg`` (simple Name or self.X) after ``call``
        inside ``fn`` with no intervening rebind; None if clean."""
        if isinstance(arg, ast.Name):
            def is_load(n):
                return (isinstance(n, ast.Name) and n.id == arg.id
                        and isinstance(n.ctx, ast.Load))

            def is_store(n):
                return (isinstance(n, ast.Name) and n.id == arg.id
                        and isinstance(n.ctx, (ast.Store, ast.Del)))
        elif (isinstance(arg, ast.Attribute)
              and isinstance(arg.value, ast.Name)
              and arg.value.id == "self"):
            def is_load(n):
                return (isinstance(n, ast.Attribute) and n.attr == arg.attr
                        and isinstance(n.value, ast.Name)
                        and n.value.id == "self"
                        and isinstance(n.ctx, ast.Load))

            def is_store(n):
                return (isinstance(n, ast.Attribute) and n.attr == arg.attr
                        and isinstance(n.value, ast.Name)
                        and n.value.id == "self"
                        and isinstance(n.ctx, (ast.Store, ast.Del)))
        else:
            return None   # fresh inline expression: nothing to re-read

        call_end = getattr(call, "end_lineno", call.lineno)
        in_call = {id(n) for n in ast.walk(call)}
        # region of interest: statements after the call; if the call sits
        # in a loop, the whole loop body re-executes, so include it too
        loop_start = None
        cur = mod.parents.get(id(call))
        while cur is not None and cur is not fn:
            if isinstance(cur, (ast.For, ast.While)):
                loop_start = cur.lineno
            cur = mod.parents.get(id(cur))
        loads, stores = [], []
        for n in ast.walk(fn):
            if id(n) in in_call:
                continue
            line = getattr(n, "lineno", None)
            if line is None:
                continue
            after = line > call_end or (loop_start is not None
                                        and line >= loop_start
                                        and line < call.lineno)
            # A store on the call's own line is the assignment target of
            # `x, y = donating_fn(x, y)` — it executes after the call and
            # kills the taint, so collect it even though it isn't "after".
            if is_store(n) and (after or line >= call.lineno):
                stores.append(n)
            elif is_load(n) and after:
                loads.append(n)
        for ld in sorted(loads, key=lambda n: n.lineno):
            if ld.lineno > call_end:
                rebound = any(call.lineno <= s.lineno <= ld.lineno
                              for s in stores)
            else:
                # Loop-prefix read: executes on the *next* iteration, after
                # the call. Killed by any rebind at/after the call or in
                # the prefix before the read.
                rebound = any(s.lineno >= call.lineno
                              or (loop_start is not None
                                  and loop_start <= s.lineno <= ld.lineno)
                              for s in stores)
            if not rebound:
                return ld
        return None

    # FED005 — pure_callback callee identity
    def _fed005(self, mod: ModuleInfo) -> List[Finding]:
        out = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if dotted(node.func) not in _CALLBACK_HEADS or not node.args:
                continue
            ctx = mod.func_of_node.get(id(node), "<module>")
            cb = node.args[0]
            if isinstance(cb, ast.Lambda):
                out.append(self._mk(
                    mod, node, "FED005", ctx,
                    "pure_callback callee is a lambda — fresh identity "
                    "per call retraces the enclosing program"))
                continue
            if (isinstance(cb, ast.Call)
                    and dotted(cb.func) in _PARTIAL_HEADS):
                out.append(self._mk(
                    mod, node, "FED005", ctx,
                    "pure_callback callee is an inline functools.partial "
                    "— fresh identity per call retraces the program"))
                continue
            ref = self.resolve(mod, ctx if ctx != "<module>" else None, cb)
            fi = self.func(ref) if ref else None
            if fi is not None and fi.parent_func is not None:
                out.append(self._mk(
                    mod, node, "FED005", ctx,
                    f"pure_callback callee `{fi.qualname}` is a nested "
                    "def — a new function object per enclosing call "
                    "retraces the program; hoist it to module level"))
        return out

    # FED006 — iteration over unordered sets
    @staticmethod
    def _is_set_expr(node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("set", "frozenset"):
            return True
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            def keysish(n):
                return ((isinstance(n, ast.Call)
                         and isinstance(n.func, ast.Attribute)
                         and n.func.attr == "keys")
                        or Project._is_set_expr(n))
            return keysish(node.left) and keysish(node.right)
        return False

    def _fed006(self, mod: ModuleInfo) -> List[Finding]:
        out = []
        for node in ast.walk(mod.tree):
            ctx = mod.func_of_node.get(id(node), "<module>")
            iters = []
            if isinstance(node, ast.For):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.DictComp, ast.SetComp,
                                   ast.GeneratorExp)):
                iters += [g.iter for g in node.generators]
            for it in iters:
                if self._is_set_expr(it):
                    out.append(self._mk(
                        mod, it, "FED006", ctx,
                        "iterating an unordered set while building a "
                        "collection — wrap in sorted(...) so param-tree "
                        "key order is deterministic"))
        return out
