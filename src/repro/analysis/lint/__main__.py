"""CLI for fedlint: ``python -m repro.analysis.lint [PATHS...] [flags]``."""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.lint import (RULES, run_lint, write_baseline)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="fedlint: FedPara-repo static analysis (FED001-FED007).")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs to lint (default: <repo>/src)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any unsuppressed finding remains")
    ap.add_argument("--docs", action="store_true",
                    help="also run FED007 doc-link checks on docs/ + README")
    ap.add_argument("--docs-only", action="store_true",
                    help="run only the FED007 doc-link checks")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline file (default: <repo>/fedlint_baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline and exit")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule IDs to run (e.g. FED001,FED004)")
    ap.add_argument("--repo-root", type=Path, default=None,
                    help="override repo root (used by tests on fixtures)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print only the summary line")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, desc in sorted(RULES.items()):
            print(f"{rid}  {desc}")
        return 0

    select = ({s.strip() for s in args.select.split(",") if s.strip()}
              if args.select else None)
    unknown = (select or set()) - set(RULES)
    if unknown:
        print(f"fedlint: unknown rule(s): {', '.join(sorted(unknown))}",
              file=sys.stderr)
        return 2

    if args.write_baseline:
        # Collect everything (ignoring the existing baseline) and accept it.
        result = run_lint(paths=args.paths or None,
                          baseline_path=Path("/nonexistent"),
                          select=select, include_docs=args.docs,
                          docs_only=args.docs_only, repo_root=args.repo_root)
        from repro.analysis.lint import REPO_ROOT
        root = args.repo_root or REPO_ROOT
        target = args.baseline or (Path(root) / "fedlint_baseline.json")
        write_baseline(target, result.findings)
        print(f"fedlint: wrote {len(result.findings)} suppression(s) "
              f"to {target}")
        return 0

    result = run_lint(paths=args.paths or None, baseline_path=args.baseline,
                      select=select, include_docs=args.docs,
                      docs_only=args.docs_only, repo_root=args.repo_root)

    if not args.quiet:
        for f in result.findings:
            print(f.render())
        for key in result.stale_baseline:
            print(f"stale-baseline: {key} (no longer matches; "
                  f"remove from fedlint_baseline.json)")
    n, s = len(result.findings), len(result.suppressed)
    print(f"fedlint: {n} finding(s), {s} suppressed, "
          f"{len(result.stale_baseline)} stale baseline entr"
          f"{'y' if len(result.stale_baseline) == 1 else 'ies'}")
    if args.check and not result.ok:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
