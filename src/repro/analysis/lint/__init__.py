"""fedlint — repo-specific static analysis for the FedPara codebase.

Layer 1 of the two-layer contract checker (see ``docs/analysis.md``):
an AST rule engine (``repro.analysis.lint.rules``) guarding the
tracing/donation/callback/tree-order invariants every FL engine depends
on, plus a markdown doc-link rule. Layer 2 — the compiled-program and
kernel contract checkers — lives in ``repro.analysis.program_check``
and ``repro.analysis.kernel_check``.

Usage::

    python -m repro.analysis.lint            # report findings
    python -m repro.analysis.lint --check    # exit 1 on unsuppressed ones
    python -m repro.analysis.lint --docs     # include FED007 doc links
    python -m repro.analysis.lint --write-baseline   # accept current set

Suppression, two mechanisms:

  * inline: ``# fedlint: disable=FED002`` on the finding's line;
  * the committed baseline (``fedlint_baseline.json`` at the repo
    root): line-number-independent keys with a one-line justification
    each. ``--check`` fails on any finding not covered by either, and
    reports (without failing) baseline entries that no longer match —
    delete them when the code they excused is gone.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.lint.rules import RULES, Finding, Project

REPO_ROOT = Path(__file__).resolve().parents[4]
DEFAULT_BASELINE = REPO_ROOT / "fedlint_baseline.json"

_DISABLE_RX = re.compile(r"#\s*fedlint:\s*disable=([A-Z0-9,\s]+)")
_MD_LINK_RX = re.compile(r"\[([^\]]*)\]\(([^)\s]+)\)")


@dataclass
class LintResult:
    findings: List[Finding] = field(default_factory=list)   # unsuppressed
    suppressed: List[Finding] = field(default_factory=list)
    stale_baseline: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings


def discover(paths: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files += [f for f in sorted(p.rglob("*.py"))
                      if "__pycache__" not in f.parts]
        elif p.suffix == ".py":
            files.append(p)
    return files


def load_baseline(path: Path) -> Dict[str, str]:
    """{finding key -> justification} from the committed baseline."""
    if not Path(path).exists():
        return {}
    data = json.loads(Path(path).read_text())
    out = {}
    for entry in data.get("suppressions", []):
        key = "::".join((entry["rule"], entry["path"], entry["symbol"],
                         " ".join(entry["snippet"].split())))
        out[key] = entry.get("justification", "")
    return out


def write_baseline(path: Path, findings: Sequence[Finding],
                   justifications: Optional[Dict[str, str]] = None):
    justifications = justifications or {}
    entries = [{
        "rule": f.rule,
        "path": f.path,
        "symbol": f.symbol,
        "snippet": " ".join(f.snippet.split()),
        "justification": justifications.get(f.key, "TODO: justify"),
    } for f in findings]
    Path(path).write_text(json.dumps(
        {"version": 1, "suppressions": entries}, indent=2) + "\n")


def _inline_disabled(finding: Finding, repo_root: Path) -> bool:
    try:
        line = (repo_root / finding.path).read_text().splitlines()[
            finding.line - 1]
    except (OSError, IndexError):
        return False
    m = _DISABLE_RX.search(line)
    if not m:
        return False
    rules = {r.strip() for r in m.group(1).split(",")}
    return finding.rule in rules


def check_doc_links(md_files: Sequence[Path], repo_root: Path
                    ) -> List[Finding]:
    """FED007: every relative markdown link must resolve to a file."""
    out: List[Finding] = []
    for md in md_files:
        md = Path(md)
        try:
            lines = md.read_text().splitlines()
        except OSError:
            continue
        try:
            rel = md.resolve().relative_to(repo_root.resolve()).as_posix()
        except ValueError:
            rel = md.as_posix()
        for i, line in enumerate(lines, 1):
            for m in _MD_LINK_RX.finditer(line):
                target = m.group(2)
                if target.startswith(("http://", "https://", "mailto:",
                                      "#", "data:")):
                    continue
                tpath = target.split("#")[0]
                if not tpath:
                    continue
                if not (md.parent / tpath).exists():
                    out.append(Finding(
                        "FED007", rel, i, m.start(), "<doc>",
                        f"dead relative link `{target}` "
                        f"(resolved against {md.parent.name}/)",
                        line.strip()))
    return out


def run_lint(paths: Optional[Sequence[Path]] = None,
             baseline_path: Optional[Path] = None,
             select: Optional[Set[str]] = None,
             include_docs: bool = False,
             docs_only: bool = False,
             repo_root: Optional[Path] = None) -> LintResult:
    """Run the rule engine; split findings into live / suppressed."""
    repo_root = Path(repo_root) if repo_root else REPO_ROOT
    baseline = load_baseline(
        baseline_path if baseline_path is not None
        else repo_root / "fedlint_baseline.json")

    findings: List[Finding] = []
    if not docs_only:
        src_paths = [Path(p) for p in paths] if paths else [repo_root / "src"]
        project = Project(discover(src_paths), repo_root,
                          src_root=repo_root / "src")
        findings += project.run(select)
    if include_docs or docs_only:
        md = sorted((repo_root / "docs").glob("*.md"))
        readme = repo_root / "README.md"
        if readme.exists():
            md.append(readme)
        doc_findings = check_doc_links(md, repo_root)
        if select:
            doc_findings = [f for f in doc_findings if f.rule in select]
        findings += doc_findings

    result = LintResult()
    matched_keys = set()
    for f in findings:
        if f.key in baseline:
            matched_keys.add(f.key)
            result.suppressed.append(f)
        elif _inline_disabled(f, repo_root):
            result.suppressed.append(f)
        else:
            result.findings.append(f)
    result.stale_baseline = sorted(set(baseline) - matched_keys)
    return result


__all__ = ["RULES", "Finding", "LintResult", "Project", "check_doc_links",
           "discover", "load_baseline", "run_lint", "write_baseline",
           "REPO_ROOT", "DEFAULT_BASELINE"]
