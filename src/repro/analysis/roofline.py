"""Roofline terms for TPU v5e from dry-run artifacts.

  compute_term    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory_term     = HLO_bytes_per_device / HBM_BW
  collective_term = collective_bytes_per_device / LINK_BW

cost_analysis() on the compiled (SPMD-partitioned) executable reports
*per-device* flops/bytes (verified empirically), so no chip division is
needed; MODEL_FLOPS (6·N·D, or 6·N_active·D for MoE) is global and is
divided by chip count for the usefulness ratio.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link
DCN_BW = 6.25e9           # bytes/s per chip cross-pod (assumed 50 Gb/s DCN)
HBM_PER_CHIP = 16e9       # v5e HBM capacity


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    cross_pod_s: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s, "cross_pod": self.cross_pod_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s,
                   self.cross_pod_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step the dominant (useful-work) term occupies
        if terms overlapped perfectly: compute / bound."""
        return self.compute_s / max(self.bound_s, 1e-30)


def terms_from_artifact(art: Dict) -> RooflineTerms:
    flops = art["flops_per_device"]
    bytes_hbm = art["bytes_per_device"]
    coll = art["collective_bytes_per_device"]
    cross = art.get("cross_pod_bytes_per_device", 0.0)
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_hbm / HBM_BW,
        collective_s=coll / LINK_BW,
        cross_pod_s=cross / DCN_BW,
    )


def model_flops_train(n_params_active: int, tokens: int) -> float:
    """6·N·D (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * tokens


def model_flops_forward(n_params_active: int, tokens: int) -> float:
    return 2.0 * n_params_active * tokens


def mfu(model_flops_global: float, step_seconds: float, chips: int) -> float:
    return model_flops_global / (step_seconds * chips * PEAK_FLOPS)
