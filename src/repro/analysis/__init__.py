"""Analysis tools: HLO collective accounting, rooflines, and the
fedlint static/compiled-program contract checkers.

Submodules load lazily: ``lint`` is pure-stdlib AST analysis and must
stay importable in milliseconds (the CI lint job and editor hooks run
it constantly), while ``hlo``/``roofline``/``program_check``/
``kernel_check`` pull in jax and, transitively, the FL engines.
"""
import importlib

__all__ = ["hlo", "lint", "roofline", "program_check", "kernel_check"]


def __getattr__(name):
    if name in __all__:
        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
