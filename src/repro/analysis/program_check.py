"""Compiled-program contract checks (fedlint Layer 2).

The AST rules (``repro.analysis.lint``) catch invariant violations the
source shows directly; this module checks the ones only the COMPILED
round programs show. Each engine's program is lowered on miniature
shapes and the jaxpr / post-compile HLO text is asserted on:

  * **donation** — ``donate_argnums`` actually produced input-output
    aliasing in the compiled HLO (an ``input_output_alias={...}``
    annotation). Donation silently degrades to copying when shapes or
    layouts stop matching; this catches it.
  * **wire dtype** — int8 / fp16 codec outputs cross the aggregation
    boundary at wire dtype: no ``convert_element_type`` widening to
    fp32 outside the fused Pallas dequant-accumulate kernel body (the
    in-VMEM per-tile convert is the design; a full-stack host-side
    widen is the regression).
  * **callbacks** — exactly the registered host callbacks appear in the
    program (``StreamingRound._fetch_chunk`` in chunked-data mode,
    none otherwise), and every callee is module/class-level (stable
    identity — the jaxpr-level mirror of lint rule FED005).
  * **retrace** — a second round at the same cohort shape compiles ZERO
    new XLA programs, for all four engines and both state stores, and
    for the async engine ACROSS VERSION BUMPS (arrival position / fold
    weight / ref coefficients are traced or host-side data, never
    program constants) — :class:`CompileCounter` hooks jax's dispatch
    logger.

Run locally::

    python -m repro.analysis.program_check           # full matrix
    python -m repro.analysis.program_check --fast    # skip retrace

All checks run on tiny synthetic shapes (seconds on CPU); CI runs them
as part of the blocking lint job.
"""
from __future__ import annotations

import logging
import re
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------- counter

_COMPILE_RX = re.compile(r"Finished XLA compilation of ([^\s]+) in")


class CompileCounter:
    """Counts XLA compilations by hooking ``jax._src.dispatch``'s DEBUG
    log ("Finished XLA compilation of <name> in <t> sec") — emitted for
    every fresh compile regardless of jax_log_compiles, so cache hits
    are exactly the calls that DON'T log. Handler and level are scoped
    to the one logger (not the 'jax' root, whose DEBUG cascade is
    enormous) and restored on exit."""

    def __init__(self):
        self.events: List[str] = []
        self._logger = logging.getLogger("jax._src.dispatch")
        self._handler = None
        self._prev_level = None

    def __enter__(self):
        counter = self

        class _H(logging.Handler):
            def emit(self, record):
                m = _COMPILE_RX.search(record.getMessage())
                if m:
                    counter.events.append(m.group(1))

        self._handler = _H(level=logging.DEBUG)
        self._prev_level = self._logger.level
        self._logger.addHandler(self._handler)
        self._logger.setLevel(logging.DEBUG)
        return self

    def __exit__(self, *exc):
        self._logger.removeHandler(self._handler)
        self._logger.setLevel(self._prev_level)
        return False

    @property
    def count(self) -> int:
        return len(self.events)


# ----------------------------------------------------------- jaxpr walks

def _eqn_subjaxprs(eqn):
    for v in eqn.params.values():
        for x in (v if isinstance(v, (tuple, list)) else (v,)):
            if isinstance(x, jax.core.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jax.core.Jaxpr):
                yield x


def iter_eqns(jaxpr, *, skip: Sequence[str] = ()):
    """All equations of ``jaxpr`` and its sub-jaxprs, except the bodies
    of primitives named in ``skip`` (e.g. ``pallas_call``: converts in
    VMEM are the kernel's job, not a contract violation)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name in skip:
            continue
        for sub in _eqn_subjaxprs(eqn):
            yield from iter_eqns(sub, skip=skip)


def widening_converts(jaxpr, src_dtypes=("int8", "float16"),
                      dst_dtype="float32") -> List[str]:
    """``convert_element_type`` eqns widening a wire dtype to fp32
    anywhere OUTSIDE a pallas_call body. Returns human-readable
    descriptions (empty = contract holds)."""
    out = []
    for eqn in iter_eqns(jaxpr, skip=("pallas_call",)):
        if eqn.primitive.name != "convert_element_type":
            continue
        src = eqn.invars[0].aval
        if (str(src.dtype) in src_dtypes
                and str(eqn.params.get("new_dtype")) == dst_dtype):
            out.append(f"convert {src.dtype}{list(src.shape)} -> "
                       f"{dst_dtype}")
    return out


def callback_callees(jaxpr) -> List[str]:
    """Qualified names of every host-callback callee in the program."""
    names = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name not in ("pure_callback", "io_callback",
                                      "debug_callback"):
            continue
        cb = eqn.params.get("callback")
        fn = getattr(cb, "callback_func", cb)
        fn = getattr(fn, "__func__", fn)   # unwrap bound methods
        names.append(getattr(fn, "__qualname__", repr(fn)))
    return sorted(names)


_ALIAS_RX = re.compile(r"input_output_alias=\{([^}]*)\}")


def hlo_aliases(compiled_text: str) -> List[str]:
    """Input-output alias entries in post-compile HLO text (one per
    donated buffer XLA actually aliased)."""
    out = []
    for m in _ALIAS_RX.finditer(compiled_text):
        body = m.group(1).strip()
        if body:
            out += [p.strip() for p in body.split("),") if p.strip()]
    return out


# -------------------------------------------------------- mini FL builds

N_CLIENTS = 8
_PER_CLIENT = 32          # samples per client; 32/batch16 = 2 full steps


def _mini_task(seed: int = 0):
    from repro.data import make_image_dataset

    n = N_CLIENTS * _PER_CLIENT
    ds = make_image_dataset(n, 4, size=8, channels=1, noise=0.3, seed=seed)
    data = {"x": ds["x"].reshape(n, -1), "y": ds["y"]}
    # equal-size partitions => the streaming engine's round-wide step
    # axis S is identical every round (shape-stable programs)
    perm = np.random.RandomState(seed).permutation(n)
    parts = [perm[i * _PER_CLIENT:(i + 1) * _PER_CLIENT]
             for i in range(N_CLIENTS)]
    return data, parts


def make_mini_server(engine: str, state_store: str = "dict", *,
                     data_stream: str = "eager", uplink_codec: str = "",
                     client_chunk: int = 4, participation: float = 1.0,
                     strategy: str = "fedavg", seed: int = 0,
                     defense: str = "none", fault_rate: float = 0.0,
                     **server_kw):
    """A tiny but real FLServer (8 clients, 64-16-4 fedpara MLP) whose
    round programs have every contract of the full-size ones.
    ``fault_rate > 0`` attaches a :class:`repro.fl.faults.FaultPlan`;
    extra ``server_kw`` forward to :class:`ServerConfig`."""
    from repro.configs.base import ParamCfg
    from repro.fl import ClientConfig, FLServer, ServerConfig, make_strategy
    from repro.fl.faults import FaultPlan
    from repro.nn import recurrent as rec

    data, parts = _mini_task(seed)
    cfg = rec.MLPConfig(in_dim=64, hidden=16, classes=4,
                        param=ParamCfg(kind="fedpara", gamma=0.3,
                                       min_dim_for_factorization=8))
    params = rec.init_mlp_model(jax.random.PRNGKey(seed), cfg)

    def loss_fn(p, b):
        return rec.mlp_loss(p, cfg, b)

    plan = FaultPlan(rate=fault_rate, seed=seed) if fault_rate > 0 else None
    return FLServer(
        loss_fn, params, data, parts, make_strategy(strategy),
        ClientConfig(lr=0.1, batch=16, epochs=1),
        ServerConfig(clients=N_CLIENTS, participation=participation,
                     rounds=3, engine=engine, client_chunk=client_chunk,
                     state_store=state_store, data_stream=data_stream,
                     uplink_codec=uplink_codec, seed=seed,
                     defense=defense, faults=plan, **server_kw))


def _spec(x):
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return jax.ShapeDtypeStruct(x.shape, x.dtype)
    return x


def capture_program(target, attr: str = "_program"):
    """Spy-wrap a jitted program attribute: records the argument
    ShapeDtypeStructs of the next call BEFORE invoking it (the program
    may donate its inputs — shapes must be read first), then restores
    the original. Returns (original_jitted_fn, box); after one round
    ``box['avals']`` holds the call signature for AOT ``.lower()`` /
    ``.trace()``."""
    orig = getattr(target, attr)
    box: Dict[str, Any] = {}

    def spy(*args):
        box["avals"] = jax.tree.map(_spec, args)
        setattr(target, attr, orig)
        return orig(*args)

    setattr(target, attr, spy)
    return orig, box


# ---------------------------------------------------------------- checks

@dataclass
class CheckResult:
    name: str
    ok: bool
    detail: str = ""

    def render(self) -> str:
        return f"[{'PASS' if self.ok else 'FAIL'}] {self.name}: {self.detail}"


def _lower_engine_program(engine: str, state_store: str, *,
                          uplink_codec: str = "", data_stream: str = "eager",
                          strategy: str = "fedavg"):
    """Build a mini server, run one round through a spy, AOT-lower the
    engine's round program on the captured avals. Returns
    (server, jaxpr, compiled_hlo_text)."""
    srv = make_mini_server(engine, state_store, uplink_codec=uplink_codec,
                           data_stream=data_stream, strategy=strategy)
    target = srv._stream if engine == "streaming" else srv._engine
    prog, box = capture_program(target)
    srv.run_round()
    avals = box["avals"]
    jaxpr = prog.trace(*avals).jaxpr
    hlo = prog.lower(*avals).compile().as_text()
    return srv, jaxpr, hlo


def check_donation() -> List[CheckResult]:
    """Streaming round program (donate_argnums=(0, 1)) and the arena's
    scatter/bump programs (donate_argnums=(0,)) must show input-output
    aliasing in their compiled HLO."""
    out = []
    # scaffold gives the donated chunk-state tree real leaves (c_i / c);
    # with stateless fedavg there is nothing to donate and the check
    # would vacuously pass or fail
    _, _, hlo = _lower_engine_program("streaming", "dict",
                                      strategy="scaffold")
    aliases = hlo_aliases(hlo)
    out.append(CheckResult(
        "donation:streaming._round_program", bool(aliases),
        f"{len(aliases)} aliased buffer(s)" if aliases
        else "donate_argnums=(0, 1) produced no input_output_alias"))

    srv = make_mini_server("batched", "arena", strategy="scaffold")
    srv.run_round()   # materializes the arena and its jitted programs
    from repro.fl import arena as arena_mod
    state = srv.arena.state
    rows = jnp.arange(4, dtype=jnp.int32)
    for name in ("_scatter_rows", "_bump_rows"):
        fn = getattr(arena_mod, name)
        if name == "_scatter_rows":
            upd = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct((4,) + x.shape[1:], x.dtype),
                state)
            mask = jax.ShapeDtypeStruct((4,), jnp.float32)
            lowered = fn.lower(jax.tree.map(_spec, state), rows, upd, mask)
        else:
            part = _spec(srv.arena.participation)
            lowered = fn.lower(part, rows, jax.ShapeDtypeStruct(
                (4,), jnp.float32))
        aliases = hlo_aliases(lowered.compile().as_text())
        out.append(CheckResult(
            f"donation:arena.{name}", bool(aliases),
            f"{len(aliases)} aliased buffer(s)" if aliases
            else "donate_argnums=(0,) produced no input_output_alias"))
    return out


def check_wire_dtype() -> List[CheckResult]:
    """Streaming aggregation must consume int8 / fp16 wire payloads at
    wire dtype: any fp32 widen outside the Pallas kernel body means the
    dense fp32 upload stack (which this engine exists to avoid) is
    back."""
    out = []
    for codec in ("int8", "fp16"):
        _, jaxpr, _ = _lower_engine_program("streaming", "dict",
                                            uplink_codec=codec)
        bad = widening_converts(jaxpr)
        out.append(CheckResult(
            f"wire-dtype:streaming:{codec}", not bad,
            "all converts inside the fused kernel" if not bad
            else "; ".join(bad[:4])))
    return out


def check_callbacks() -> List[CheckResult]:
    """Exactly the registered host callbacks appear: chunked-data
    streaming has the one ``_fetch_chunk`` pure_callback, eager-data
    programs have none."""
    out = []
    _, jaxpr, _ = _lower_engine_program("streaming", "dict",
                                        data_stream="chunked")
    names = callback_callees(jaxpr)
    expected = ["StreamingRound._fetch_chunk"]
    out.append(CheckResult(
        "callbacks:streaming:chunked", names == expected,
        f"found {names}" + ("" if names == expected
                            else f", expected {expected}")))
    for engine in ("streaming", "batched"):
        _, jaxpr, _ = _lower_engine_program(engine, "dict")
        names = callback_callees(jaxpr)
        out.append(CheckResult(
            f"callbacks:{engine}:eager", not names,
            "no host callbacks" if not names else f"unexpected: {names}"))
    return out


RETRACE_MATRIX: Tuple[Tuple[str, str], ...] = (
    ("sequential", "dict"),
    ("batched", "dict"),
    ("batched", "arena"),
    ("streaming", "dict"),
    ("streaming", "arena"),
    ("async", "dict"),
    ("async", "arena"),
)


def count_retrace(engine: str, state_store: str, *, warmup: int = 1,
                  measured: int = 2,
                  server_factory: Optional[Callable] = None) -> List[str]:
    """Compile events during rounds ``warmup+1 .. warmup+measured`` at a
    fixed cohort shape (should be empty: round 1 compiled everything)."""
    factory = server_factory or (
        lambda: make_mini_server(engine, state_store))
    srv = factory()
    for _ in range(warmup):
        srv.run_round()
    with CompileCounter() as cc:
        for _ in range(measured):
            srv.run_round()
    return cc.events


def check_retrace() -> List[CheckResult]:
    out = []
    for engine, store in RETRACE_MATRIX:
        events = count_retrace(engine, store)
        out.append(CheckResult(
            f"retrace:{engine}:{store}", not events,
            "0 recompiles in rounds 2-3" if not events
            else f"{len(events)} recompile(s): {sorted(set(events))}"))
    return out


def check_async_retrace() -> List[CheckResult]:
    """The version-bump contract (docs/async.md): a genuinely
    asynchronous regime — small buffer, lognormal stragglers, delta
    codec, so version bumps interleave with stale arrivals and
    mid-version re-dispatches — must compile zero new XLA programs
    after the warm-up versions. Arrival position, fold weight and the
    host-float ref coefficients are traced/eager data; only cohort
    SHAPES key the compiled programs."""
    events = count_retrace(
        "async", "dict", warmup=2, measured=2,
        server_factory=lambda: make_mini_server(
            "async", "dict", uplink_codec="delta|topk0.5|int8",
            buffer_k=4, straggler_sigma=1.0, staleness="poly:0.5"))
    return [CheckResult(
        "retrace:async:version-bumps", not events,
        "0 recompiles across version bumps 3-4" if not events
        else f"{len(events)} recompile(s): {sorted(set(events))}")]


def check_defense_retrace() -> List[CheckResult]:
    """Chaos knobs are DATA, not program constants: with faults drawn
    every round and the defense gate active, rounds 2-3 must still
    compile zero new XLA programs (the per-round fault arrays and the
    varying drawn-fault sets ride in as traced arguments)."""
    out = []
    for engine, defense in (("batched", "clip"), ("batched", "trimmed"),
                            ("streaming", "clip")):
        events = count_retrace(
            engine, "dict",
            server_factory=lambda e=engine, d=defense: make_mini_server(
                e, "dict", defense=d, fault_rate=0.4, uplink_codec="int8"))
        out.append(CheckResult(
            f"retrace:{engine}:defense={defense}+faults", not events,
            "0 recompiles in rounds 2-3" if not events
            else f"{len(events)} recompile(s): {sorted(set(events))}"))
    return out


# ---------------------------------------------------------------- serving

def _mini_serve_engine(mode: str, *, use_pallas: bool, users: int = 4,
                       batch: int = 4, seed: int = 0):
    """A tiny pFedPara ServeEngine (2-layer decoder, 4 resident users
    with random personal halves) whose decode program carries every
    contract of the full-size one."""
    import dataclasses

    from repro.configs import get_arch
    from repro.fl import comm
    from repro.nn.transformer import ModelOptions, build_model
    from repro.serve import ServeEngine

    cfg = get_arch("qwen3-8b").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, param=dataclasses.replace(
        cfg.param, kind="pfedpara", min_dim_for_factorization=8, gamma=0.5))
    opts = ModelOptions(attn_chunk=8, ssm_chunk=8, logit_chunk=16,
                        dtype=jnp.float32)
    model = build_model(cfg, opts)
    params = model.init_params(jax.random.PRNGKey(seed))
    local_trees = {
        u: comm.split_pfedpara(
            model.init_params(jax.random.PRNGKey(seed + 1 + u)))[1]
        for u in range(users)}
    eng = ServeEngine(cfg, params, local_trees, mode=mode, batch=batch,
                      use_pallas=use_pallas, opts=opts)
    return eng, cfg


def check_serve_retrace() -> List[CheckResult]:
    """Decode must compile exactly once per engine config: 16 steps over
    2 DIFFERENT user cohorts reuse the first step's program (position
    and user-row indices are traced arguments, the KV cache is donated
    in place)."""
    out = []
    for mode in ("precompose", "fused"):
        eng, cfg = _mini_serve_engine(mode, use_pallas=False)
        cache = eng.init_cache(4, 24)
        tok = jnp.zeros((4, 1), jnp.int32)
        cohorts = ([0, 1, 2, 3], [3, 2, 1, 0])
        logits, cache = eng.decode_step(cache, tok, 0, user_ids=cohorts[0])
        with CompileCounter() as cc:
            for i in range(1, 16):
                logits, cache = eng.decode_step(
                    cache, tok, i, user_ids=cohorts[i % 2])
        out.append(CheckResult(
            f"serve-retrace:{mode}", not cc.events,
            "0 recompiles over 15 steps x 2 cohorts" if not cc.events
            else f"{len(cc.events)} recompile(s): {sorted(set(cc.events))}"))
    return out


def check_serve_wire_dtype() -> List[CheckResult]:
    """The int8 precomposed cache must reach the matmul at int8: any
    fp32 widen of an int8 array outside a pallas_call body means the
    cache is being dequantized in HBM — the full dense-fp32 weight
    stream the cache exists to avoid."""
    eng, cfg = _mini_serve_engine("precompose", use_pallas=True)
    cache = eng.init_cache(4, 24)
    rows = eng.arena.rows_for([0, 1, 2, 3])
    args = (eng.serve_params, eng.arena.tree, cache,
            jnp.zeros((4, 1), jnp.int32), jnp.int32(0), rows)
    jaxpr = eng._jit_decode.trace(*jax.tree.map(_spec, args)).jaxpr
    bad = widening_converts(jaxpr, src_dtypes=("int8",))
    return [CheckResult(
        "serve-wire-dtype:int8-cache", not bad,
        "int8 cache widened only inside pallas_call" if not bad
        else "; ".join(bad[:4]))]


def check_serve() -> List[CheckResult]:
    return check_serve_retrace() + check_serve_wire_dtype()


# ------------------------------------------------------------------- CLI

def run_all(fast: bool = False) -> List[CheckResult]:
    results = (check_donation() + check_wire_dtype() + check_callbacks()
               + check_serve())
    if not fast:
        results += (check_retrace() + check_defense_retrace()
                    + check_async_retrace())
    return results


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.program_check",
        description="fedlint Layer 2: compiled-program contract checks.")
    ap.add_argument("--fast", action="store_true",
                    help="skip the (slower) retrace matrix")
    args = ap.parse_args(argv)
    results = run_all(fast=args.fast)
    for r in results:
        print(r.render())
    bad = [r for r in results if not r.ok]
    print(f"program_check: {len(results) - len(bad)}/{len(results)} passed")
    return 1 if bad else 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
