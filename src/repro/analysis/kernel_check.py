"""Static kernel-contract checker (fedlint Layer 2, Pallas side).

Validates the shared block tables (``repro.kernels.blocks``) against
every layer shape every shipped config actually produces — WITHOUT
allocating a single parameter: each model is enumerated with
``jax.eval_shape`` (llama3-405B's 126×(16384, 53248) FFN costs nothing
abstract) and every FedPara factor node ``{"x1","y1","x2","y2"}`` is
resolved to its ``(m, n, r)`` kernel problem.

Per layer, per kernel body (forward matmul, dx, dX/dY-factor backward)
the checker asserts:

  * **alignment** — the selected ``(block_b, block_m, block_n)`` tile
    respects TPU tiling minima (sublane multiple of 8, lane multiple
    of 128);
  * **grid coverage** — the pad-to-multiple grid covers the full
    operand (and reports the padding-waste fraction);
  * **VMEM footprint** — the kernel body's working set (streamed
    input/output blocks at 2× for double-buffering, plus scratch)
    fits the v5e per-core budget (16 MiB).

A shape whose tiles are valid but whose VMEM estimate exceeds budget is
an **uncovered** entry: it is reported (xfail-style, with the estimate)
rather than silently accepted — the block table needs a new regime row
before that config can run fused on real hardware. Alignment/coverage
failures are hard errors.

The fused dequant-accumulate aggregation tiles
(``blocks.select_agg_blocks``) are checked the same way against every
payload leaf's flat wire length.

Run locally::

    python -m repro.analysis.kernel_check             # report
    python -m repro.analysis.kernel_check --strict    # fail on uncovered
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

VMEM_BUDGET = 16 * 1024 * 1024     # v5e per-core VMEM, bytes
SUBLANE, LANE = 8, 128             # fp32 tiling minima
DOUBLE_BUFFER = 2                  # streamed blocks are double-buffered
ITEMSIZE = 4                       # worst case: fp32 operands


def _ceil_mult(x: int, m: int) -> int:
    return -(-x // m) * m


# ------------------------------------------------------- VMEM estimates

def kernel_vmem(kind: str, bb: int, bm: int, bn: int, r: int) -> int:
    """Working-set bytes of one grid step of the named kernel body.

    Streamed blocks (in_specs + out_specs) count 2× (double-buffered:
    the next block loads while the current computes); VMEM scratch
    counts once. Mirrors the BlockSpecs in kernels/fedpara_matmul.py /
    fedpara_grad.py — change those, change this.
    """
    if kind == "fwd":          # x(bb,bm) + 4 factor slices -> y(bb,bn)
        stream = bb * bm + 2 * (bm * r + bn * r) + bb * bn
        scratch = bb * bn
    elif kind == "dx":         # dy(bb,bn) + 4 factor slices -> dx(bb,bm)
        stream = bb * bn + 2 * (bm * r + bn * r) + bb * bm
        scratch = bb * bm
    elif kind in ("dfx", "dfy"):   # x, dy, 4 slices -> two (ob, r) grads
        ob = bm if kind == "dfx" else bn
        stream = bb * bm + bb * bn + 2 * (bm * r + bn * r) + 2 * ob * r
        scratch = bm * bn + 2 * ob * r
    else:
        raise ValueError(f"unknown kernel body {kind!r}")
    return (DOUBLE_BUFFER * stream + scratch) * ITEMSIZE


def agg_vmem(bc: int, bl: int, wire_itemsize: int = 1) -> int:
    """Dequant-accumulate body: one (bc, bl) wire tile at wire itemsize,
    the (1, bc) coeff row, (1, bl) acc in/out, (1, bl) fp32 scratch."""
    stream = bc * bl * wire_itemsize + (bc + 2 * bl) * 4
    return DOUBLE_BUFFER * stream + bl * 4


def serve_kernel_vmem(kind: str, bb: int, bm: int, bn: int, r: int) -> int:
    """Working-set bytes of one grid step of a serve kernel body
    (kernels/serve_matmul.py; returns bytes directly — the int8 cache
    tile and its fp32 in-VMEM widened copy have different itemsizes).

    ``w8``: x(bb,bm) + int8 w(bm,bn) + scale(1,bn) + out(bb,bn)
    streamed; fp32 acc(bb,bn) + the widened w tile as scratch/temp.
    ``resid`` (cache_residual, single- or many-user — identical per-step
    footprint): additionally streams the (bm,r)/(bn,r) user factor
    slices and forms the (bm,bn) fp32 residual tile in VMEM.
    """
    stream = 4 * bb * bm + bm * bn + 4 * bn + 4 * bb * bn
    scratch = 4 * bb * bn + 4 * bm * bn
    if kind == "resid":
        stream += 4 * (bm * r + bn * r)
        scratch += 4 * bm * bn
    elif kind != "w8":
        raise ValueError(f"unknown serve kernel body {kind!r}")
    return DOUBLE_BUFFER * stream + scratch


# ----------------------------------------------------------- shape enum

@dataclass
class LayerCheck:
    """One (config, layer, kernel body) verdict."""

    config: str
    path: str
    m: int
    n: int
    r: int
    body: str
    blocks: Tuple[int, int, int]
    vmem: int
    valid: bool = True            # alignment + grid coverage
    notes: List[str] = field(default_factory=list)

    @property
    def fits(self) -> bool:
        return self.vmem <= VMEM_BUDGET

    def render(self) -> str:
        mb = self.vmem / (1 << 20)
        tag = "ok" if (self.valid and self.fits) else (
            "INVALID" if not self.valid else "OVER-VMEM")
        note = f" [{'; '.join(self.notes)}]" if self.notes else ""
        return (f"{self.config}:{self.path} ({self.m}x{self.n} r={self.r}) "
                f"{self.body} blocks={self.blocks} vmem={mb:.1f}MiB "
                f"{tag}{note}")


def factor_shapes(params_shapes: Any) -> List[Tuple[str, int, int, int]]:
    """(path, m, n, r) for every matrix FedPara factor node in an
    eval_shape'd param tree. Scan-stacked leading layer dims are
    dropped (the kernels tile the trailing (m|n, r) axes)."""
    out = []

    def walk(node, path):
        if isinstance(node, dict):
            if "x1" in node and "y1" in node:
                x1, y1 = node["x1"], node["y1"]
                out.append((path or "<root>", int(x1.shape[-2]),
                            int(y1.shape[-2]), int(x1.shape[-1])))
                return
            for k in sorted(node):
                walk(node[k], f"{path}/{k}" if path else k)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, f"{path}[{i}]")

    walk(params_shapes, "")
    return out


def payload_lengths(params_shapes: Any) -> List[Tuple[str, int]]:
    """(path, flat length) of every leaf — the aggregation kernel's
    (C, L) problem sizes."""
    import numpy as np
    import jax

    out = []
    for kp, leaf in jax.tree_util.tree_flatten_with_path(params_shapes)[0]:
        path = jax.tree_util.keystr(kp)
        out.append((path, int(np.prod(leaf.shape)) if leaf.shape else 1))
    return out


def enumerate_config(name: str):
    """eval_shape a registered config's model init — zero allocation."""
    import jax

    from repro.configs import get_arch
    from repro.nn.transformer import build_model

    model = build_model(get_arch(name))
    return jax.eval_shape(model.init_params, jax.random.PRNGKey(0))


# ---------------------------------------------------------------- checks

MATMUL_BODIES = ("fwd", "dx", "dfx", "dfy")
SERVE_BODIES = ("w8", "resid")
INT8_SUBLANE = 32                  # int8 second-minor tiling minimum
# Paper FL regime batch per local step; the kernels clamp block_b to the
# actual batch so this only caps the estimate from above.
ASSUMED_BATCH = 128


def check_layer(config: str, path: str, m: int, n: int, r: int
                ) -> List[LayerCheck]:
    from repro.kernels import blocks

    bb, bm, bn = blocks.select_blocks(m, n, r)
    bb = min(bb, _ceil_mult(ASSUMED_BATCH, SUBLANE))
    out = []
    for body in MATMUL_BODIES:
        lc = LayerCheck(config, path, m, n, r, body, (bb, bm, bn),
                        kernel_vmem(body, bb, bm, bn, r))
        if bb % SUBLANE or bm % SUBLANE or bn % LANE:
            lc.valid = False
            lc.notes.append(
                f"tile misaligned: need bb%{SUBLANE}==0, bm%{SUBLANE}==0, "
                f"bn%{LANE}==0")
        mp, np_ = _ceil_mult(m, bm), _ceil_mult(n, bn)
        if mp // bm < 1 or np_ // bn < 1:
            lc.valid = False
            lc.notes.append("grid does not cover the operand")
        waste = (mp * np_) / (m * n) - 1.0
        if waste > 1.0:
            lc.notes.append(f"padding waste {waste:.0%} (>100%)")
        if not lc.fits:
            lc.notes.append(
                f"exceeds v5e VMEM budget by "
                f"{(lc.vmem - VMEM_BUDGET) / (1 << 20):.1f}MiB")
        out.append(lc)
    return out


def check_serve_layer(config: str, path: str, m: int, n: int, r: int
                      ) -> List[LayerCheck]:
    """Serve-kernel tiles (int8 cache matmul + pFedPara cache+residual)
    for one factor layer — every factorized layer is a candidate for the
    precomposed serving cache."""
    from repro.kernels import blocks

    bb, bm, bn = blocks.select_serve_blocks(m, n, r)
    out = []
    for body in SERVE_BODIES:
        lc = LayerCheck(config, path, m, n, r, body, (bb, bm, bn),
                        serve_kernel_vmem(body, bb, bm, bn, r))
        if bb % SUBLANE or bm % INT8_SUBLANE or bn % LANE:
            lc.valid = False
            lc.notes.append(
                f"tile misaligned: need bb%{SUBLANE}==0, "
                f"bm%{INT8_SUBLANE}==0 (int8 sublane), bn%{LANE}==0")
        if _ceil_mult(m, bm) // bm < 1 or _ceil_mult(n, bn) // bn < 1:
            lc.valid = False
            lc.notes.append("grid does not cover the operand")
        if not lc.fits:
            lc.notes.append(
                f"exceeds v5e VMEM budget by "
                f"{(lc.vmem - VMEM_BUDGET) / (1 << 20):.1f}MiB")
        out.append(lc)
    return out


def check_agg_leaf(config: str, path: str, length: int,
                   clients: int = 64) -> LayerCheck:
    from repro.kernels import blocks

    bc, bl = blocks.select_agg_blocks(clients, length)
    lc = LayerCheck(config, path, clients, length, 0, "agg", (bc, bl, 0),
                    agg_vmem(bc, bl))
    if bc % 32:    # int8 sublane minimum
        lc.valid = False
        lc.notes.append("block_c must be a multiple of the int8 sublane (32)")
    if bl % LANE:
        lc.valid = False
        lc.notes.append(f"block_l must be a multiple of the lane dim ({LANE})")
    if not lc.fits:
        lc.notes.append("aggregation tile exceeds VMEM budget")
    return lc


def check_config(name: str, *, agg_leaves: bool = True) -> List[LayerCheck]:
    shapes = enumerate_config(name)
    out = []
    for path, m, n, r in factor_shapes(shapes):
        out += check_layer(name, path, m, n, r)
        out += check_serve_layer(name, path, m, n, r)
    if agg_leaves:
        seen = set()
        for path, length in payload_lengths(shapes):
            if length in seen:   # agg tiling depends only on the length
                continue
            seen.add(length)
            out.append(check_agg_leaf(name, path, length))
    return out


def check_all(configs: Optional[List[str]] = None) -> List[LayerCheck]:
    import repro.configs as cfgs

    results = []
    for name in (configs or cfgs.ASSIGNED):
        results += check_config(name)
    return results


def uncovered(results: List[LayerCheck]) -> List[LayerCheck]:
    """Valid but over-VMEM entries: the xfail report — each needs a new
    block-table regime before its config runs fused on hardware."""
    return [r for r in results if r.valid and not r.fits]


def invalid(results: List[LayerCheck]) -> List[LayerCheck]:
    return [r for r in results if not r.valid]


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.kernel_check",
        description="Static Pallas block-table checks over all configs.")
    ap.add_argument("configs", nargs="*", help="config names (default: all)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on over-VMEM (uncovered) shapes")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every entry, not just problems")
    args = ap.parse_args(argv)

    results = check_all(args.configs or None)
    bad, over = invalid(results), uncovered(results)
    if args.verbose:
        for r in results:
            print(r.render())
    else:
        for r in bad + over:
            print(r.render())
    print(f"kernel_check: {len(results)} entries over "
          f"{len(set(r.config for r in results))} config(s); "
          f"{len(bad)} invalid, {len(over)} uncovered (over-VMEM)")
    if over:
        print("uncovered shapes (xfail — block table needs a new regime):")
        for r in over:
            print(f"  {r.config}:{r.path} {r.m}x{r.n} r={r.r} "
                  f"{r.body} {r.vmem / (1 << 20):.1f}MiB")
    if bad:
        return 1
    if args.strict and over:
        return 1
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
