"""Cross-pod federated training: the paper's FL protocol on the 'pod'
mesh axis.

Each pod is an FL client: pod-local parameters carry a leading
``n_pods`` dimension sharded over 'pod' (so every pod holds exactly its
own replica, TP/FSDP-sharded over the intra-pod axes). A round =
``K`` local optimizer steps (lax.scan) followed by FedAvg — a mean over
the pod axis, which GSPMD lowers to the *only* cross-pod (DCN)
collective in the program. With FedPara parameterization the synced
tree is the factor set: 3–10× fewer bytes over the slow inter-pod links
than syncing dense weights, amortized over K steps — the paper's
communication claim, verbatim, at datacenter scale.

``sync='factors'`` additionally keeps configured dense leaves (e.g.
embeddings) pod-local — the pFedPara-style split applied at pod
granularity (beyond-paper; see DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import Optimizer, apply_updates

FACTOR_KEYS = ("x", "y", "x1", "y1", "x2", "y2", "t", "t1", "t2")


def is_factor_path(path: str) -> bool:
    last = path.rsplit("/", 1)[-1]
    return last in FACTOR_KEYS


def sync_mask(params: Any, mode: str) -> Any:
    """True leaves get cross-pod FedAvg'd. 'full' = everything;
    'factors' = everything except large dense embed/unembed tables
    (which stay pod-local, pFedPara-style)."""
    def visit(path_elems, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_elems)
        if mode == "full":
            return True
        return not (("embed" in path or "unembed" in path) and leaf.ndim >= 2)

    return jax.tree_util.tree_map_with_path(visit, params)


def stack_for_pods(tree: Any, n_pods: int) -> Any:
    """Replicate a host-side pytree with a leading pod dimension."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_pods, *a.shape)), tree
    )


def pod_specs(specs: Any) -> Any:
    """Prepend the 'pod' axis to a PartitionSpec tree."""
    return jax.tree.map(
        lambda s: P("pod", *s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def make_fed_round(
    loss_fn: Callable[[Any, Dict], jax.Array],
    optimizer: Optimizer,
    *,
    local_steps: int,
    sync: str = "factors",
    sync_dtype=None,
    sync_every_round: bool = True,
    accum: int = 1,
) -> Callable:
    """Build ``round_step(stacked_params, stacked_opt, stacked_batches)``.

    stacked_batches leaves: (n_pods, K, ...) — K local steps per pod.
    Returns (synced_params, opt_state, mean_loss).
    """

    def local_run(params, opt_state, batches):
        vg = make_value_and_grad(loss_fn, accum)

        def one(carry, batch):
            p, o = carry
            loss, grads = vg(p, batch)
            updates, o = optimizer.update(grads, o, p)
            return (apply_updates(p, updates), o), loss

        (params, opt_state), losses = jax.lax.scan(one, (params, opt_state), batches)
        return params, opt_state, losses.mean()

    vlocal = jax.vmap(local_run, spmd_axis_name="pod")

    def round_step(stacked_params, stacked_opt, stacked_batches):
        params, opt_state, losses = vlocal(stacked_params, stacked_opt,
                                           stacked_batches)
        if sync_every_round:
            mask = sync_mask(params, sync)

            def fedavg_leaf(do_sync, a):
                if not do_sync:
                    return a
                x = a.astype(sync_dtype) if sync_dtype is not None else a
                m = jnp.mean(x, axis=0, keepdims=True).astype(a.dtype)
                return jnp.broadcast_to(m, a.shape)

            params = jax.tree.map(fedavg_leaf, mask, params)
        return params, opt_state, losses.mean()

    return round_step


def make_value_and_grad(loss_fn: Callable, accum: int = 1) -> Callable:
    """value_and_grad with gradient accumulation over ``accum``
    micro-batches (scan): activation memory scales 1/accum at identical
    per-step FLOPs — the standard lever when per-chip batchxseq exceeds
    HBM (llama3-405B train on only 256 chips)."""
    if accum <= 1:
        return jax.value_and_grad(loss_fn)

    def vg(params, batch):
        micro = jax.tree.map(
            lambda a: a.reshape(accum, a.shape[0] // accum, *a.shape[1:]),
            batch)

        def one(carry, mb):
            acc_l, acc_g = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            return (acc_l + loss,
                    jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                 acc_g, grads)), None

        # zeros_like (not zeros(shape)): inherits the argument's sharding —
        # a bare zeros() accumulator lowers as replicated and costs
        # params-bytes per device per microbatch step
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                             params)
        (loss, grads), _ = jax.lax.scan(one, (jnp.zeros((), jnp.float32),
                                              zeros), micro)
        inv = 1.0 / accum
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    return vg


def make_dp_step(
    loss_fn: Callable[[Any, Dict], jax.Array],
    optimizer: Optimizer,
    accum: int = 1,
) -> Callable:
    """Plain synchronous step (single- or multi-pod pure DP baseline:
    batch sharded over ('pod','data'); GSPMD all-reduces gradients over
    both axes every step)."""
    vg = make_value_and_grad(loss_fn, accum)

    def step(params, opt_state, batch):
        loss, grads = vg(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    return step
