"""Logical-axis sharding: rules mapping logical names -> mesh axes.

Models annotate activations with *logical* axes ("batch", "heads",
"kv_seq", ...). An :class:`AxisRules` object (active via context) maps
those to physical mesh axes and applies
``jax.lax.with_sharding_constraint`` — or is a no-op when no mesh is
active (CPU smoke tests). A logical axis is only mapped when the
dimension is divisible by the mesh-axis size (e.g. 8 KV heads on a
16-way 'model' axis are left for GSPMD to place).
"""
from __future__ import annotations

import re
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES: Dict[str, Any] = {
    "batch": "data",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "vocab": "model",
    "embed": None,
    "embed_vocab": None,
    "seq": None,
    "kv_seq": None,
    "kv_seq_attn": None,   # train/prefill score tiles + K/V along kv-seq
    "long_kv_seq": ("data", "model"),
    "experts": None,
    "fsdp": "data",
    "tp": "model",
    # 2D storage sharding for factors & optimizer state (ZeRO-3-style):
    # a 405B model's Adam moments at 1D (16-way) sharding are 10GB/chip;
    # 2D (256-way) brings them to 0.6GB. Falls back to the 1D axis (then
    # replication) when the dim isn't divisible.
    "fsdp2": ("data", "model"),
    "tp2": ("model", "data"),
}

_FALLBACK = {"fsdp2": "fsdp", "tp2": "tp"}


class AxisRules:
    def __init__(self, mesh: Optional[Mesh], rules: Optional[Dict[str, Any]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def _axis_size(self, phys) -> int:
        if phys is None or self.mesh is None:
            return 1
        if isinstance(phys, tuple):
            s = 1
            for a in phys:
                s *= self.mesh.shape[a]
            return s
        return self.mesh.shape[phys]

    def spec(self, logical: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        parts = []
        used = set()
        for name, dim in zip(logical, shape):
            phys = self.rules.get(name) if name else None
            while True:
                if phys is None:
                    parts.append(None)
                    break
                axes = phys if isinstance(phys, tuple) else (phys,)
                size = self._axis_size(phys)
                # a mesh axis may appear at most once per spec (e.g. zamba2
                # has 32 kv heads AND a seq dim both divisible by 'model')
                if size > 1 and dim % size == 0 and not (used & set(axes)):
                    parts.append(phys)
                    used |= set(axes)
                    break
                name = _FALLBACK.get(name)
                phys = self.rules.get(name) if name else None
        return P(*parts)

    def constrain(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        if self.mesh is None or x is None:
            return x
        assert len(logical) == x.ndim, (logical, x.shape)
        spec = self.spec(logical, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


def set_rules(rules: Optional[AxisRules]) -> None:
    _state.rules = rules


def get_rules() -> AxisRules:
    r = getattr(_state, "rules", None)
    return r if r is not None else AxisRules(None)


class use_rules:
    def __init__(self, rules: Optional[AxisRules]):
        self.rules = rules

    def __enter__(self):
        self.prev = getattr(_state, "rules", None)
        set_rules(self.rules)
        return self.rules

    def __exit__(self, *a):
        set_rules(self.prev)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical axes under the active rules (no-op
    without a mesh)."""
    return get_rules().constrain(x, *logical)


# ---------------------------------------------------------------------------
# Parameter partition rules: path-pattern -> logical axes per dim.
#
# Weight naming conventions (see repro.nn):
#   embed/w (V, d)          unembed/w (d, V)
#   <attn>/{wq,wk,wv}/*     column-parallel (out dim TP)
#   <attn>/wo/*             row-parallel  (in dim TP)
#   <ffn>/{w_gate,w_up}/*   column-parallel
#   <ffn>/w_down/*          row-parallel
#   moe experts carry a leading E dim.
#   factors: x*/(m,r) on the in dim, y*/(n,r) on the out dim.
# ---------------------------------------------------------------------------

_COL = ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_qkv", "wi", "w_z",
        "w_q", "w_k", "w_v")
_ROW = ("wo", "w_down", "w_out", "wo_attn")

# (regex on 'a/b/c' joined path, logical axes tuple or callable(shape)->tuple)
def _param_rules():
    col = "|".join(_COL)
    row = "|".join(_ROW)
    return [
        # embeddings: shard d (not vocab!) — a gather over a vocab-sharded
        # table lowers to select + fp32 all-reduce of the full (B,S,d)
        # activations (measured: dominant collective). d-sharded lookup
        # is local; the (B,S,d) all-gather that follows is bf16.
        (re.compile(r"(^|/)embed/w$"), ("embed_vocab", "tp")),
        (re.compile(r"(^|/)unembed/w$"), ("embed", "vocab")),
        # MoE expert factors (leading expert dim)
        (re.compile(rf"(^|/)experts/({col})/(x1|x2|x)$"), ("experts", "fsdp2", None)),
        (re.compile(rf"(^|/)experts/({col})/(y1|y2|y)$"), ("experts", "tp2", None)),
        (re.compile(rf"(^|/)experts/({row})/(x1|x2|x)$"), ("experts", "tp2", None)),
        (re.compile(rf"(^|/)experts/({row})/(y1|y2|y)$"), ("experts", "fsdp2", None)),
        (re.compile(rf"(^|/)experts/({col})/w$"), ("experts", "fsdp", "tp")),
        (re.compile(rf"(^|/)experts/({row})/w$"), ("experts", "tp", "fsdp")),
        # column-parallel dense factors (2D ZeRO-3 storage; composing
        # gathers the small factors, never the dense W)
        (re.compile(rf"(^|/)({col})/(x1|x2|x)$"), ("fsdp2", None)),
        (re.compile(rf"(^|/)({col})/(y1|y2|y)$"), ("tp2", None)),
        (re.compile(rf"(^|/)({row})/(x1|x2|x)$"), ("tp2", None)),
        (re.compile(rf"(^|/)({row})/(y1|y2|y)$"), ("fsdp2", None)),
        # original (dense) weights (and int8 serving weights)
        (re.compile(rf"(^|/)({col})/(w|w_q)$"), ("fsdp", "tp")),
        (re.compile(rf"(^|/)({row})/(w|w_q)$"), ("tp", "fsdp")),
        (re.compile(rf"(^|/)experts/({col})/w_q$"), ("experts", "fsdp", "tp")),
        (re.compile(rf"(^|/)experts/({row})/w_q$"), ("experts", "tp", "fsdp")),
    ]


_RULES_CACHE = None


def param_spec(path: str, shape: Tuple[int, ...], rules: AxisRules, *, stacked_dims: int = 0) -> P:
    """PartitionSpec for a parameter at `path` with `shape`.

    ``stacked_dims``: number of leading scan-stacking dims (layers,
    periods) to leave unsharded.
    """
    global _RULES_CACHE
    if _RULES_CACHE is None:
        _RULES_CACHE = _param_rules()
    core_shape = shape[stacked_dims:]
    logical = None
    for rx, axes in _RULES_CACHE:
        if rx.search(path):
            logical = axes
            break
    if logical is None or len(logical) != len(core_shape):
        return P(*([None] * len(shape)))
    spec = rules.spec(logical, core_shape)
    return P(*([None] * stacked_dims), *spec)


def tree_param_specs(params: Any, rules: AxisRules, *, stacked_dims_fn=None) -> Any:
    """Build a PartitionSpec pytree matching ``params``.

    ``stacked_dims_fn(path) -> int`` reports leading stacked dims (layer
    scan stacking); defaults to counting path components named
    'layers'/'periods'/'inner' heuristically via shape-vs-rule arity.
    """
    def visit(path_elems, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems)
        shape = getattr(leaf, "shape", ())
        stacked = stacked_dims_fn(path) if stacked_dims_fn else _infer_stacked(path)
        return param_spec(path, shape, rules, stacked_dims=min(stacked, max(0, len(shape) - 1)))

    return jax.tree_util.tree_map_with_path(visit, params)


_STACK_TOKENS = ("layers", "periods", "inner", "blocks", "m_blocks")


def _infer_stacked(path: str) -> int:
    return sum(1 for tok in path.split("/") if tok in _STACK_TOKENS)


def tree_shardings(params: Any, mesh: Mesh, rules: AxisRules, **kw) -> Any:
    specs = tree_param_specs(params, rules, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
