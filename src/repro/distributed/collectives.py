"""Explicit collectives: bucketed cross-pod all-reduce (shard_map) and
PowerSGD-style low-rank gradient compression with error feedback.

The bucketed all-reduce groups leaves into ~``bucket_bytes`` flat
buffers so the runtime can overlap sync of early buckets with the
compute that produces later ones (the classic DDP overlap trick);
bucket boundaries are stable across steps, so XLA can pipeline them.

PowerSGD (Vogels et al. 2019 — cited by the paper as the distributed
counterpart of its low-rank idea) compresses a dense gradient G ≈ P Qᵀ
with one power-iteration per step and error feedback. We use it for the
*dense* leaves (embeddings) that FedPara leaves unfactorized, so the
cross-pod payload of the 'full' sync mode drops too.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6: top-level export, `check_vma` kwarg
    from jax import shard_map as _shard_map
    _VMA_KW = "check_vma"
except ImportError:  # jax 0.4.x/0.5.x: experimental module, `check_rep` kwarg
    from jax.experimental.shard_map import shard_map as _shard_map
    _VMA_KW = "check_rep"


def shard_map(f=None, **kw):
    """Version-compatible ``shard_map`` accepting either ``check_vma`` or
    ``check_rep`` and mapping to whatever this jax spells it."""
    flag = kw.pop("check_vma", kw.pop("check_rep", None))
    if flag is not None:
        kw[_VMA_KW] = flag
    if f is None:
        return functools.partial(_shard_map, **kw)
    return _shard_map(f, **kw)


# ----------------------------------------------------------- bucketed psum

def plan_buckets(tree: Any, bucket_bytes: int = 4 << 20) -> List[List[int]]:
    """Group leaf indices into buckets of ~bucket_bytes."""
    leaves = jax.tree.leaves(tree)
    buckets, cur, cur_b = [], [], 0
    for i, leaf in enumerate(leaves):
        b = leaf.size * leaf.dtype.itemsize
        if cur and cur_b + b > bucket_bytes:
            buckets.append(cur)
            cur, cur_b = [], 0
        cur.append(i)
        cur_b += b
    if cur:
        buckets.append(cur)
    return buckets


def bucketed_pmean(tree: Any, mesh: Mesh, axis: str = "pod",
                   bucket_bytes: int = 4 << 20) -> Any:
    """Mean-reduce every leaf across ``axis`` using flat per-bucket psums."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    buckets = plan_buckets(tree, bucket_bytes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=P(), out_specs=P(),
        check_vma=False,
    )
    def psum_flat(flat):
        return jax.lax.pmean(flat, axis)

    out = list(leaves)
    for bucket in buckets:
        flat = jnp.concatenate([leaves[i].reshape(-1).astype(jnp.float32)
                                for i in bucket])
        red = psum_flat(flat)
        off = 0
        for i in bucket:
            n = leaves[i].size
            out[i] = red[off: off + n].reshape(leaves[i].shape).astype(leaves[i].dtype)
            off += n
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------- PowerSGD

def powersgd_init(shape: Tuple[int, int], rank: int, key: jax.Array) -> Dict:
    m, n = shape
    return {
        "q": jax.random.normal(key, (n, rank), jnp.float32),
        "error": jnp.zeros(shape, jnp.float32),
    }


def powersgd_compress(grad: jax.Array, state: Dict) -> Tuple[jax.Array, jax.Array, Dict]:
    """One power iteration: G' = G + error; P = G'Q; Q' = orth(G'ᵀP).
    Returns (P, Q', state with new error feedback)."""
    g = grad.astype(jnp.float32) + state["error"]
    p = g @ state["q"]                       # (m, r)
    p, _ = jnp.linalg.qr(p)
    q = g.T @ p                              # (n, r)
    approx = p @ q.T
    return p, q, {"q": q, "error": g - approx}


def powersgd_decompress(p: jax.Array, q: jax.Array) -> jax.Array:
    return p @ q.T


def compressed_bytes(p: jax.Array, q: jax.Array) -> int:
    return (p.size + q.size) * 4


# -------------------------------------------------- quantized pod all-reduce

def quantized_pmean(tree: Any, mesh: Mesh, axis: str = "pod") -> Any:
    """bf16-quantized cross-pod mean (2x DCN traffic cut; FedPAQ-style
    uplink quantization applied to the pod sync)."""
    def one(x):
        @functools.partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False)
        def red(v):
            return jax.lax.pmean(v, axis)

        return red(x.astype(jnp.bfloat16)).astype(x.dtype)

    return jax.tree.map(one, tree)
