from repro.distributed import collectives, fedpod, sharding
from repro.distributed.sharding import (
    AxisRules,
    constrain,
    param_spec,
    tree_param_specs,
    tree_shardings,
    use_rules,
)

__all__ = [
    "collectives", "fedpod", "sharding", "AxisRules", "constrain",
    "param_spec", "tree_param_specs", "tree_shardings", "use_rules",
]
