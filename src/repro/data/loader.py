"""Batch loaders: local epochs for FL clients + sharded global batches
for the pod trainer (deterministic, resumable — the checkpoint stores
the stream position so restarts continue mid-epoch)."""
from __future__ import annotations

import threading
import queue as queue_mod
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


def _epoch_rng(seed: int) -> np.random.RandomState:
    """Shuffle RNG for one client's local epochs. Seeds below 2^32 keep
    the historical ``RandomState(seed)`` stream bit-exactly; the wider
    64-bit seeds the fleet path derives via ``SeedSequence.spawn``
    (``repro.fl.trace.spawn_seeds``) are folded through a SeedSequence
    into a full 128-bit ``RandomState`` key."""
    s = int(seed)
    if 0 <= s < 2 ** 32:
        return np.random.RandomState(s)
    return np.random.RandomState(np.random.SeedSequence(s).generate_state(4))


def client_epochs(data: Dict[str, np.ndarray], idx: np.ndarray, batch: int,
                  epochs: int, seed: int) -> Iterator[Dict[str, np.ndarray]]:
    """Minibatch iterator over one client's local data for E epochs."""
    rng = _epoch_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(len(idx))
        for i in range(0, len(order) - batch + 1, batch):
            sel = idx[order[i: i + batch]]
            yield {k: v[sel] for k, v in data.items()}
        if 0 < len(order) < batch:  # tiny client: one short batch per epoch
            sel = idx[order]
            yield {k: v[sel] for k, v in data.items()}


def client_step_count(n_samples: int, batch: int, epochs: int) -> int:
    """Number of local steps ``client_epochs`` yields for a client with
    ``n_samples`` points — computed from sizes alone, so chunked engines
    can fix a round-wide step axis without materializing any stream."""
    if n_samples <= 0:
        return 0
    per_epoch = n_samples // batch if n_samples >= batch else 1
    return per_epoch * epochs


def _client_steps(data: Dict[str, np.ndarray], idx: np.ndarray, batch: int,
                  epochs: int, seed: int) -> List[Dict[str, np.ndarray]]:
    """One client's materialized local-epoch minibatch list (empty for
    clients with no samples)."""
    return (list(client_epochs(data, idx, batch, epochs, seed))
            if len(idx) else [])


def _pad_batch(b: Dict[str, np.ndarray], batch: int,
               keys: Sequence[str]) -> Dict[str, np.ndarray]:
    """Wrap a tiny client's short batch up to the full batch size."""
    n = len(b[keys[0]])
    if n == batch:
        return b
    sel = np.resize(np.arange(n), batch)  # wrap tiny-client batches
    return {k: v[sel] for k, v in b.items()}


def _fill_row(out: Dict[str, np.ndarray], step_mask: np.ndarray, row: int,
              steps: List[Dict[str, np.ndarray]], S: int, batch: int,
              keys: Sequence[str]) -> None:
    """Write one client's steps into row ``row`` of the stacked output,
    right-padding by repeating its own batches. Shared by the eager
    stack (``stack_client_epochs``) and the lazy per-chunk source
    (:class:`ChunkBatchSource`) so the two are bit-identical."""
    if not steps:  # empty client: all-padding (zeros), mask stays 0
        return
    steps = [_pad_batch(b, batch, keys) for b in steps]
    step_mask[row, : len(steps)] = 1.0
    for s in range(S):
        b = steps[s] if s < len(steps) else steps[s % len(steps)]
        for k in keys:
            out[k][row, s] = b[k]


def stack_client_epochs(
    data: Dict[str, np.ndarray],
    partitions: Sequence[np.ndarray],
    cids: Sequence[int],
    batch: int,
    epochs: int,
    seeds: Sequence[int],
    pad_steps: Optional[int] = None,
    pad_clients: int = 0,
) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Materialize every sampled client's ``client_epochs`` stream into one
    stacked batch tensor for the client-batched engine.

    Returns ``(batches, step_mask)`` where ``batches[k]`` has shape
    ``(C, S, B, ...)`` — C sampled clients, S = max local steps across the
    batch, B = batch size — and ``step_mask`` is a float32 ``(C, S)``
    array with 1.0 on real steps. Clients with fewer than S steps are
    right-padded by repeating their own batches (the pad steps are
    masked out, so the pad content only needs to be numerically tame).
    Short batches from tiny clients (fewer than ``batch`` samples) are
    filled by wrapping their indices; this is the one place the batched
    engine can diverge from the sequential reference, and only for
    clients whose whole dataset is smaller than one minibatch.
    ``pad_steps`` fixes the step axis S explicitly (must cover every
    client's real step count) so chunked callers keep one shape
    signature across chunks and rounds. ``pad_clients`` appends that
    many all-zero, fully-masked client rows, pre-sized in the output
    allocation — the streaming engine's chunk padding — so callers
    never concatenate a second full-cohort copy."""
    per_client = [_client_steps(data, partitions[cid], batch, epochs, seed)
                  for cid, seed in zip(cids, seeds)]
    C = len(per_client)
    S = max(1, max(len(s) for s in per_client))
    if pad_steps is not None:
        if pad_steps < S:
            raise ValueError(
                f"pad_steps={pad_steps} below max real step count {S}")
        S = max(1, pad_steps)
    keys = list(data.keys())

    step_mask = np.zeros((C + pad_clients, S), np.float32)
    out = {k: np.zeros((C + pad_clients, S, batch) + data[k].shape[1:],
                       data[k].dtype) for k in keys}
    for c, steps in enumerate(per_client):
        _fill_row(out, step_mask, c, steps, S, batch, keys)
    return out, step_mask


class ChunkBatchSource:
    """Lazy per-chunk stand-in for :func:`stack_client_epochs`.

    The streaming engine scans over fixed-size client chunks, but the
    eager path still materializes the WHOLE cohort's ``(C, S, B, ...)``
    batch stack on the host up front — the last O(cohort · data) host
    allocation in a streamed round. This source materializes one
    chunk at a time instead: the engine's scan step calls
    :meth:`fetch` through ``jax.pure_callback``, so host batch memory
    peaks at O(chunk · S · B), whatever the cohort size.

    Rows are filled by the same ``_fill_row`` helper as the eager
    stack, so chunk ``i`` of this source is bit-identical to rows
    ``[i*chunk, (i+1)*chunk)`` of ``stack_client_epochs`` with matching
    ``pad_steps`` / ``pad_clients`` — the eager/lazy parity tests hold
    the two together. Pad slots are encoded as client id ``-1`` (zero
    batches, zero mask).
    """

    def __init__(self, data: Dict[str, np.ndarray],
                 partitions: Sequence[np.ndarray], cids: Sequence[int],
                 batch: int, epochs: int, seeds: Sequence[int],
                 chunk: int, n_chunks: int, pad_steps: int):
        self.data = data
        self.partitions = partitions
        self.keys = list(data.keys())
        self.batch = int(batch)
        self.epochs = int(epochs)
        self.chunk = int(chunk)
        self.n_chunks = int(n_chunks)
        self.S = max(1, int(pad_steps))
        pad = self.chunk * self.n_chunks - len(cids)
        if pad < 0:
            raise ValueError("chunk * n_chunks smaller than the cohort")
        self.cids = [int(c) for c in cids] + [-1] * pad
        self.seeds = [int(s) for s in seeds] + [0] * pad

    def step_mask(self) -> np.ndarray:
        """The full cohort's ``(chunk * n_chunks, S)`` float32 step mask,
        from ``client_step_count`` alone — no batch data materialized."""
        m = np.zeros((len(self.cids), self.S), np.float32)
        for row, cid in enumerate(self.cids):
            if cid < 0:
                continue
            n = client_step_count(len(self.partitions[cid]), self.batch,
                                  self.epochs)
            m[row, : n] = 1.0
        return m

    def chunk_struct(self):
        """``jax.ShapeDtypeStruct`` tree of one fetched chunk — the
        ``pure_callback`` result signature."""
        import jax

        return {k: jax.ShapeDtypeStruct(
            (self.chunk, self.S, self.batch) + self.data[k].shape[1:],
            self.data[k].dtype) for k in self.keys}

    def fetch(self, chunk_idx: int) -> Dict[str, np.ndarray]:
        """Materialize chunk ``chunk_idx``'s ``(chunk, S, B, ...)``
        batches (called from the scan step's host callback)."""
        lo = int(chunk_idx) * self.chunk
        out = {k: np.zeros(
            (self.chunk, self.S, self.batch) + self.data[k].shape[1:],
            self.data[k].dtype) for k in self.keys}
        mask = np.zeros((self.chunk, self.S), np.float32)
        for j in range(self.chunk):
            cid = self.cids[lo + j]
            if cid < 0:
                continue
            steps = _client_steps(self.data, self.partitions[cid],
                                  self.batch, self.epochs,
                                  self.seeds[lo + j])
            _fill_row(out, mask, j, steps, self.S, self.batch, self.keys)
        return out


@dataclass
class StreamState:
    epoch: int = 0
    step_in_epoch: int = 0


class ShardedBatcher:
    """Deterministic global-batch stream with resumable position and a
    background prefetch thread (overlaps host batch assembly with device
    compute — the CPU-side analogue of the input pipeline overlap used
    on real pods)."""

    def __init__(self, data: Dict[str, np.ndarray], global_batch: int,
                 seed: int = 0, prefetch: int = 2):
        self.data = data
        self.n = len(next(iter(data.values())))
        self.global_batch = global_batch
        self.seed = seed
        self.state = StreamState()
        self.prefetch = prefetch
        self._q: Optional[queue_mod.Queue] = None
        self._thread: Optional[threading.Thread] = None

    def _order(self, epoch: int) -> np.ndarray:
        return np.random.RandomState(self.seed + epoch).permutation(self.n)

    def next_batch(self) -> Dict[str, np.ndarray]:
        st = self.state
        order = self._order(st.epoch)
        per_epoch = self.n // self.global_batch
        if st.step_in_epoch >= per_epoch:
            st.epoch += 1
            st.step_in_epoch = 0
            order = self._order(st.epoch)
        lo = st.step_in_epoch * self.global_batch
        sel = order[lo: lo + self.global_batch]
        st.step_in_epoch += 1
        return {k: v[sel] for k, v in self.data.items()}

    # ---- background prefetch
    def start(self):
        self._q = queue_mod.Queue(maxsize=self.prefetch)
        self._stop = False

        def worker():
            while not self._stop:
                try:
                    self._q.put(self.next_batch(), timeout=0.5)
                except queue_mod.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def get(self) -> Dict[str, np.ndarray]:
        if self._q is None:
            return self.next_batch()
        return self._q.get()

    def stop(self):
        self._stop = True
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    # ---- checkpointable position
    def position(self) -> Dict[str, int]:
        return {"epoch": self.state.epoch, "step_in_epoch": self.state.step_in_epoch}

    def restore(self, pos: Dict[str, int]):
        self.state = StreamState(**pos)
