"""Batch loaders: local epochs for FL clients + sharded global batches
for the pod trainer (deterministic, resumable — the checkpoint stores
the stream position so restarts continue mid-epoch)."""
from __future__ import annotations

import threading
import queue as queue_mod
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


def client_epochs(data: Dict[str, np.ndarray], idx: np.ndarray, batch: int,
                  epochs: int, seed: int) -> Iterator[Dict[str, np.ndarray]]:
    """Minibatch iterator over one client's local data for E epochs."""
    rng = np.random.RandomState(seed)
    for _ in range(epochs):
        order = rng.permutation(len(idx))
        for i in range(0, len(order) - batch + 1, batch):
            sel = idx[order[i: i + batch]]
            yield {k: v[sel] for k, v in data.items()}
        if 0 < len(order) < batch:  # tiny client: one short batch per epoch
            sel = idx[order]
            yield {k: v[sel] for k, v in data.items()}


def client_step_count(n_samples: int, batch: int, epochs: int) -> int:
    """Number of local steps ``client_epochs`` yields for a client with
    ``n_samples`` points — computed from sizes alone, so chunked engines
    can fix a round-wide step axis without materializing any stream."""
    if n_samples <= 0:
        return 0
    per_epoch = n_samples // batch if n_samples >= batch else 1
    return per_epoch * epochs


def stack_client_epochs(
    data: Dict[str, np.ndarray],
    partitions: Sequence[np.ndarray],
    cids: Sequence[int],
    batch: int,
    epochs: int,
    seeds: Sequence[int],
    pad_steps: Optional[int] = None,
) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Materialize every sampled client's ``client_epochs`` stream into one
    stacked batch tensor for the client-batched engine.

    Returns ``(batches, step_mask)`` where ``batches[k]`` has shape
    ``(C, S, B, ...)`` — C sampled clients, S = max local steps across the
    batch, B = batch size — and ``step_mask`` is a float32 ``(C, S)``
    array with 1.0 on real steps. Clients with fewer than S steps are
    right-padded by repeating their own batches (the pad steps are
    masked out, so the pad content only needs to be numerically tame).
    Short batches from tiny clients (fewer than ``batch`` samples) are
    filled by wrapping their indices; this is the one place the batched
    engine can diverge from the sequential reference, and only for
    clients whose whole dataset is smaller than one minibatch.
    ``pad_steps`` fixes the step axis S explicitly (must cover every
    client's real step count) so chunked callers keep one shape
    signature across chunks and rounds."""
    per_client: List[List[Dict[str, np.ndarray]]] = []
    for cid, seed in zip(cids, seeds):
        idx = partitions[cid]
        per_client.append(
            list(client_epochs(data, idx, batch, epochs, seed))
            if len(idx) else [])  # empty client: zero real steps
    C = len(per_client)
    S = max(1, max(len(s) for s in per_client))
    if pad_steps is not None:
        if pad_steps < S:
            raise ValueError(
                f"pad_steps={pad_steps} below max real step count {S}")
        S = max(1, pad_steps)
    keys = list(data.keys())

    def pad_batch(b: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        n = len(b[keys[0]])
        if n == batch:
            return b
        sel = np.resize(np.arange(n), batch)  # wrap tiny-client batches
        return {k: v[sel] for k, v in b.items()}

    step_mask = np.zeros((C, S), np.float32)
    out = {k: np.zeros((C, S, batch) + data[k].shape[1:], data[k].dtype)
           for k in keys}
    for c, steps in enumerate(per_client):
        if not steps:  # empty client: all-padding (zeros), mask stays 0
            continue
        steps = [pad_batch(b) for b in steps]
        step_mask[c, : len(steps)] = 1.0
        for s in range(S):
            b = steps[s] if s < len(steps) else steps[s % len(steps)]
            for k in keys:
                out[k][c, s] = b[k]
    return out, step_mask


@dataclass
class StreamState:
    epoch: int = 0
    step_in_epoch: int = 0


class ShardedBatcher:
    """Deterministic global-batch stream with resumable position and a
    background prefetch thread (overlaps host batch assembly with device
    compute — the CPU-side analogue of the input pipeline overlap used
    on real pods)."""

    def __init__(self, data: Dict[str, np.ndarray], global_batch: int,
                 seed: int = 0, prefetch: int = 2):
        self.data = data
        self.n = len(next(iter(data.values())))
        self.global_batch = global_batch
        self.seed = seed
        self.state = StreamState()
        self.prefetch = prefetch
        self._q: Optional[queue_mod.Queue] = None
        self._thread: Optional[threading.Thread] = None

    def _order(self, epoch: int) -> np.ndarray:
        return np.random.RandomState(self.seed + epoch).permutation(self.n)

    def next_batch(self) -> Dict[str, np.ndarray]:
        st = self.state
        order = self._order(st.epoch)
        per_epoch = self.n // self.global_batch
        if st.step_in_epoch >= per_epoch:
            st.epoch += 1
            st.step_in_epoch = 0
            order = self._order(st.epoch)
        lo = st.step_in_epoch * self.global_batch
        sel = order[lo: lo + self.global_batch]
        st.step_in_epoch += 1
        return {k: v[sel] for k, v in self.data.items()}

    # ---- background prefetch
    def start(self):
        self._q = queue_mod.Queue(maxsize=self.prefetch)
        self._stop = False

        def worker():
            while not self._stop:
                try:
                    self._q.put(self.next_batch(), timeout=0.5)
                except queue_mod.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def get(self) -> Dict[str, np.ndarray]:
        if self._q is None:
            return self.next_batch()
        return self._q.get()

    def stop(self):
        self._stop = True
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    # ---- checkpointable position
    def position(self) -> Dict[str, int]:
        return {"epoch": self.state.epoch, "step_in_epoch": self.state.step_in_epoch}

    def restore(self, pos: Dict[str, int]):
        self.state = StreamState(**pos)
