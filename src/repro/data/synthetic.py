"""Deterministic synthetic datasets standing in for CIFAR-10/100,
CINIC-10, FEMNIST/MNIST and Shakespeare (offline container — no
downloads). Each is *learnable* (class-conditional structure) so FL
training dynamics — and the relative ordering of parameterizations the
paper measures — are meaningful.

Images: class-conditional frequency templates + per-sample Gaussian
noise (classes differ by low-frequency patterns, like coarse CIFAR
structure). Text: an order-2 Markov chain over a char vocabulary with
class-dependent transition sharpening (Shakespeare-like next-char
predictability ~ top-1 achievable accuracy 40-60%).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def make_image_dataset(
    n: int,
    classes: int,
    size: int = 32,
    channels: int = 3,
    noise: float = 0.6,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    rng = np.random.RandomState(seed)
    # class templates: superpositions of random low-frequency waves
    yy, xx = np.meshgrid(np.arange(size), np.arange(size), indexing="ij")
    templates = np.zeros((classes, size, size, channels), np.float32)
    for c in range(classes):
        for _ in range(4):
            fx, fy = rng.uniform(0.5, 3.0, 2)
            ph = rng.uniform(0, 2 * np.pi, channels)
            amp = rng.uniform(0.5, 1.0)
            wave = np.sin(2 * np.pi * (fx * xx + fy * yy) / size)[..., None] + np.cos(ph)
            templates[c] += amp * wave.astype(np.float32)
    templates /= np.abs(templates).max(axis=(1, 2, 3), keepdims=True)
    y = rng.randint(0, classes, n).astype(np.int32)
    x = templates[y] + noise * rng.randn(n, size, size, channels).astype(np.float32)
    return {"x": x.astype(np.float32), "y": y}


def make_char_corpus(
    n_seq: int,
    seq_len: int,
    vocab: int = 80,
    seed: int = 0,
    sharpness: float = 8.0,
) -> np.ndarray:
    """(n_seq, seq_len) int32 sequences from a sparse order-1 Markov chain."""
    rng = np.random.RandomState(seed)
    # sparse, peaked transition matrix
    trans = rng.dirichlet(np.full(vocab, 0.05), size=vocab).astype(np.float64)
    trans = trans ** (sharpness / 4)
    trans /= trans.sum(1, keepdims=True)
    cum = np.cumsum(trans, axis=1)
    seqs = np.zeros((n_seq, seq_len), np.int32)
    state = rng.randint(0, vocab, n_seq)
    u = rng.rand(n_seq, seq_len)
    for t in range(seq_len):
        seqs[:, t] = state
        state = (cum[state] < u[:, t: t + 1]).sum(1)
        state = np.minimum(state, vocab - 1)
    return seqs


def make_token_lm_dataset(n_seq: int, seq_len: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Token streams for LLM smoke training: Zipfian unigram + local
    repeat structure (so CE can fall well below ln(V))."""
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    base = rng.choice(vocab, size=(n_seq, seq_len), p=probs).astype(np.int32)
    # inject copy structure: with p=0.3 token t == token t-4
    mask = rng.rand(n_seq, seq_len) < 0.3
    for t in range(4, seq_len):
        base[:, t] = np.where(mask[:, t], base[:, t - 4], base[:, t])
    return base


def train_test_split(data: Dict[str, np.ndarray], test_frac: float = 0.1,
                     seed: int = 0) -> Tuple[Dict, Dict]:
    n = len(data["y"]) if "y" in data else len(next(iter(data.values())))
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    cut = int(n * (1 - test_frac))
    tr = {k: v[idx[:cut]] for k, v in data.items()}
    te = {k: v[idx[cut:]] for k, v in data.items()}
    return tr, te
