from repro.data import loader, partition, synthetic
from repro.data.loader import (
    ChunkBatchSource,
    ShardedBatcher,
    client_epochs,
    stack_client_epochs,
)
from repro.data.partition import (
    VirtualPartitions,
    dirichlet_partition,
    iid_partition,
    two_class_partition,
)
from repro.data.synthetic import (
    make_char_corpus,
    make_image_dataset,
    make_token_lm_dataset,
    train_test_split,
)

__all__ = [
    "loader", "partition", "synthetic", "ChunkBatchSource", "ShardedBatcher",
    "client_epochs", "stack_client_epochs",
    "VirtualPartitions", "dirichlet_partition", "iid_partition",
    "two_class_partition",
    "make_char_corpus", "make_image_dataset", "make_token_lm_dataset",
    "train_test_split",
]
