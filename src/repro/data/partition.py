"""Federated data partitioning: IID and Dirichlet non-IID (He et al.
2020, alpha=0.5 as in the paper), plus the McMahan highly-skewed
"at most two classes per client" split used for MNIST personalization."""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def iid_partition(n: int, clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    return [np.sort(part) for part in np.array_split(idx, clients)]


def dirichlet_partition(labels: np.ndarray, clients: int, alpha: float = 0.5,
                        seed: int = 0, min_size: int = 2) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    classes = int(labels.max()) + 1
    n = len(labels)
    while True:
        parts: List[List[int]] = [[] for _ in range(clients)]
        for c in range(classes):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(clients, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for cid, chunk in enumerate(np.split(idx_c, cuts)):
                parts[cid].extend(chunk.tolist())
        if min(len(p) for p in parts) >= min_size:
            break
    return [np.sort(np.array(p, np.int64)) for p in parts]


def two_class_partition(labels: np.ndarray, clients: int, seed: int = 0) -> List[np.ndarray]:
    """McMahan et al. (2017): sort by label, deal out 2 shards per client."""
    rng = np.random.RandomState(seed)
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, 2 * clients)
    shard_ids = rng.permutation(2 * clients)
    return [
        np.sort(np.concatenate([shards[shard_ids[2 * i]], shards[shard_ids[2 * i + 1]]]))
        for i in range(clients)
    ]


def partition_stats(labels: np.ndarray, parts: List[np.ndarray]) -> Dict:
    classes = int(labels.max()) + 1
    hist = np.stack([np.bincount(labels[p], minlength=classes) for p in parts])
    return {
        "sizes": [len(p) for p in parts],
        "class_hist": hist,
        "max_classes_per_client": int((hist > 0).sum(1).max()),
    }
