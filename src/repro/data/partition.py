"""Federated data partitioning: IID and Dirichlet non-IID (He et al.
2020, alpha=0.5 as in the paper), plus the McMahan highly-skewed
"at most two classes per client" split used for MNIST personalization."""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def iid_partition(n: int, clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    return [np.sort(part) for part in np.array_split(idx, clients)]


def dirichlet_partition(labels: np.ndarray, clients: int, alpha: float = 0.5,
                        seed: int = 0, min_size: int = 2) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    classes = int(labels.max()) + 1
    n = len(labels)
    while True:
        parts: List[List[int]] = [[] for _ in range(clients)]
        for c in range(classes):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(clients, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for cid, chunk in enumerate(np.split(idx_c, cuts)):
                parts[cid].extend(chunk.tolist())
        if min(len(p) for p in parts) >= min_size:
            break
    return [np.sort(np.array(p, np.int64)) for p in parts]


def two_class_partition(labels: np.ndarray, clients: int, seed: int = 0) -> List[np.ndarray]:
    """McMahan et al. (2017): sort by label, deal out 2 shards per client."""
    rng = np.random.RandomState(seed)
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, 2 * clients)
    shard_ids = rng.permutation(2 * clients)
    return [
        np.sort(np.concatenate([shards[shard_ids[2 * i]], shards[shard_ids[2 * i + 1]]]))
        for i in range(clients)
    ]


class VirtualPartitions:
    """Fleet-scale partitions without a per-client index table.

    Real partition lists store one index array per client — O(fleet)
    host memory before a single round runs, which caps dict-based
    simulations at maybe 10^5 clients. A :class:`VirtualPartitions`
    instead views every client as ``samples_per_client`` indices into a
    shared sample pool, computed on demand from a counter-based hash of
    the client id: ``self[cid]`` costs O(samples_per_client) and NOTHING
    is stored per client, so a 1M-client fleet costs the same host
    memory as a 10-client one.

    Deterministic: the same ``(seed, cid)`` always yields the same
    index view, so engines that re-fetch a client's partition across
    rounds (every engine) see a stable local dataset. Supports
    ``len()`` and integer indexing — the two operations the FL server
    and loaders use on partition lists.
    """

    def __init__(self, pool_size: int, clients: int,
                 samples_per_client: int, seed: int = 0):
        if samples_per_client > pool_size:
            raise ValueError("samples_per_client exceeds the sample pool")
        self.pool_size = int(pool_size)
        self.clients = int(clients)
        self.samples_per_client = int(samples_per_client)
        self.seed = int(seed)

    def __len__(self) -> int:
        return self.clients

    def __getitem__(self, cid: int) -> np.ndarray:
        if isinstance(cid, (list, np.ndarray, slice)):
            raise TypeError("VirtualPartitions supports scalar indexing only")
        cid = int(cid)
        if cid < 0:
            cid += self.clients
        if not 0 <= cid < self.clients:
            raise IndexError(f"client {cid} out of range [0, {self.clients})")
        rng = np.random.Generator(np.random.PCG64(
            np.random.SeedSequence((self.seed, 0xFA571D, cid))))
        return np.sort(rng.choice(self.pool_size, self.samples_per_client,
                                  replace=False))

    def sizes(self, cids) -> np.ndarray:
        """Per-client sample counts for a cohort — constant by
        construction, but kept as a method so callers never special-case
        virtual vs list partitions."""
        return np.full(len(cids), self.samples_per_client, np.int64)


def partition_stats(labels: np.ndarray, parts: List[np.ndarray]) -> Dict:
    classes = int(labels.max()) + 1
    hist = np.stack([np.bincount(labels[p], minlength=classes) for p in parts])
    return {
        "sizes": [len(p) for p in parts],
        "class_hist": hist,
        "max_classes_per_client": int((hist > 0).sum(1).max()),
    }
