"""Weight parameterizations: FedPara, conventional low-rank, original, pFedPara.

Functional API (no flax): each parameterization is a pair of pure
functions ``init(key, ...) -> params`` and ``materialize(params) -> W``.
Layer code calls :func:`materialize` (or the fused Pallas kernel) to get
the dense weight and then runs the ordinary einsum.

Param trees contain ONLY arrays (jit-safe); the parameterization *kind*
lives in static layer specs (see `repro.nn.layers.LinearSpec`), not in
the tree. Key-name conventions:

  original : {"w"}
  lowrank  : {"x", "y"}                      W = X Yᵀ
  fedpara  : {"x1", "y1", "x2", "y2"}        W = (X1Y1ᵀ) ⊙ (X2Y2ᵀ)
  pfedpara : {"x1", "y1", "x2", "y2"}        W = (X1Y1ᵀ) ⊙ (X2Y2ᵀ + 1)

All factors are stored fp32 (master copy); :func:`materialize` casts the
composed weight to ``dtype`` (bf16 by default on the compute path).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import rank_policy

ParamTree = Dict[str, Any]

KINDS = ("original", "lowrank", "fedpara", "fedpara_tanh", "pfedpara")


# --------------------------------------------------------------------------
# Initialization scaling.
#
# The paper uses He init. For the composed matrix W = (X1 Y1ᵀ)⊙(X2 Y2ᵀ) we
# pick the factor std so the *composed* weight matches He variance:
#   var(W1_ij) = r · σ_x² σ_y²,  var(W_ij) = var(W1)·var(W2) = (rσ⁴)²
#   ⇒ σ = target_var^(1/8) / r^(1/4) with target_var = gain/fan_in.
# --------------------------------------------------------------------------

def fedpara_factor_std(fan_in: int, r: int, target_gain: float = 2.0) -> float:
    return float((target_gain / fan_in) ** 0.125 / (r ** 0.25))


def lowrank_factor_std(fan_in: int, r: int, target_gain: float = 2.0) -> float:
    # var(W_ij) = r σ⁴ = target ⇒ σ = (target/(fan_in·r))^(1/4) · gain^(1/4)
    return float((target_gain / (fan_in * r)) ** 0.25)


# ------------------------------------------------------------------ original

def init_original(key: jax.Array, m: int, n: int, dtype=jnp.float32) -> ParamTree:
    w = jax.random.normal(key, (m, n), dtype) * jnp.asarray((2.0 / m) ** 0.5, dtype)
    return {"w": w}


# ------------------------------------------------------------------ low-rank

def init_lowrank(key: jax.Array, m: int, n: int, r: int, dtype=jnp.float32) -> ParamTree:
    kx, ky = jax.random.split(key)
    std = lowrank_factor_std(m, r)
    x = jax.random.normal(kx, (m, r), dtype) * std
    y = jax.random.normal(ky, (n, r), dtype) * std
    return {"x": x, "y": y}


def _cast(a, dtype):
    return a.astype(dtype) if dtype is not None else a


def compose_lowrank(params: ParamTree, dtype=None) -> jax.Array:
    # Cast factors BEFORE the compose dot: a post-compose cast would be
    # folded into the dot by XLA, upcasting it (and any GSPMD psum of
    # its products) to fp32. '...' handles scan-stacked leading dims.
    return jnp.einsum("...mr,...nr->...mn",
                      _cast(params["x"], dtype), _cast(params["y"], dtype))


# ------------------------------------------------------------------- fedpara

def init_fedpara(key: jax.Array, m: int, n: int, r: int, dtype=jnp.float32) -> ParamTree:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = fedpara_factor_std(m, r)
    return {
        "x1": jax.random.normal(k1, (m, r), dtype) * std,
        "y1": jax.random.normal(k2, (n, r), dtype) * std,
        "x2": jax.random.normal(k3, (m, r), dtype) * std,
        "y2": jax.random.normal(k4, (n, r), dtype) * std,
    }


def compose_fedpara(params: ParamTree, dtype=None, use_tanh: bool = False) -> jax.Array:
    """W = (X1 Y1ᵀ) ⊙ (X2 Y2ᵀ)   (optionally tanh(W1)⊙tanh(W2), supp. B)."""
    w1 = jnp.einsum("...mr,...nr->...mn",
                    _cast(params["x1"], dtype), _cast(params["y1"], dtype))
    w2 = jnp.einsum("...mr,...nr->...mn",
                    _cast(params["x2"], dtype), _cast(params["y2"], dtype))
    if use_tanh:
        w1, w2 = jnp.tanh(w1), jnp.tanh(w2)
    return w1 * w2


# ------------------------------------------------------------------ pfedpara

def init_pfedpara(key: jax.Array, m: int, n: int, r: int, dtype=jnp.float32) -> ParamTree:
    """pFedPara: W = W1 ⊙ (W2 + 1); W1 global (transferred), W2 local.

    W2 factors start near zero so W ≈ W1 at initialization (the "+1"
    acts as a switch, paper §2.3); W1 carries low-rank He scaling.
    The personal-half std is 0.5·std1: W2 entries are still tiny
    (σ_W2 ≈ r·std2² ≪ 1, so W ≈ W1 holds) but the W2 factor GRADIENTS —
    which scale with the factor magnitudes (dX2 = (dW ⊙ W1) Y2) — are
    5× larger than at the old 0.1·std1, so the personal half actually
    adapts within few-round regimes instead of staying frozen at init.
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std1 = lowrank_factor_std(m, r)
    std2 = 0.5 * std1
    return {
        "x1": jax.random.normal(k1, (m, r), dtype) * std1,  # global
        "y1": jax.random.normal(k2, (n, r), dtype) * std1,  # global
        "x2": jax.random.normal(k3, (m, r), dtype) * std2,  # local
        "y2": jax.random.normal(k4, (n, r), dtype) * std2,  # local
    }


def compose_pfedpara(params: ParamTree, dtype=None) -> jax.Array:
    """W = W1 ⊙ (W2 + 1) = W_per + W_glo  (paper §2.3)."""
    w1 = jnp.einsum("...mr,...nr->...mn",
                    _cast(params["x1"], dtype), _cast(params["y1"], dtype))
    w2 = jnp.einsum("...mr,...nr->...mn",
                    _cast(params["x2"], dtype), _cast(params["y2"], dtype))
    one = jnp.asarray(1.0, w2.dtype)
    return w1 * (w2 + one)


PFEDPARA_GLOBAL_KEYS = ("x1", "y1")   # transferred to the server
PFEDPARA_LOCAL_KEYS = ("x2", "y2")    # kept on-device


# ------------------------------------------------------- generic entry points

def resolve_rank(m: int, n: int, kind: str, gamma: float, rank: Optional[int]) -> int:
    if rank is not None:
        return rank
    return rank_policy.matrix_rank_for_gamma(m, n, gamma)


def init_linear(
    key: jax.Array,
    m: int,
    n: int,
    *,
    kind: str = "fedpara",
    gamma: float = 0.1,
    rank: Optional[int] = None,
    dtype=jnp.float32,
) -> ParamTree:
    """Initialize one parameterized (m -> n) weight.

    ``rank=None`` resolves the inner rank from ``gamma`` via the paper's
    policy. The low-rank baseline receives ``2r`` (parameter parity with
    FedPara at inner rank ``r``, cf. Fig. 1).
    """
    if kind == "original":
        return init_original(key, m, n, dtype)
    r = resolve_rank(m, n, kind, gamma, rank)
    if kind == "lowrank":
        return init_lowrank(key, m, n, 2 * r, dtype)
    if kind in ("fedpara", "fedpara_tanh"):
        return init_fedpara(key, m, n, r, dtype)
    if kind == "pfedpara":
        return init_pfedpara(key, m, n, r, dtype)
    raise ValueError(f"unknown parameterization kind: {kind}")


def materialize(params: ParamTree, kind: str, dtype=None) -> jax.Array:
    """Compose the dense weight for the given parameterization kind."""
    if kind == "original":
        w = params["w"]
        return w.astype(dtype) if dtype is not None else w
    if kind == "lowrank":
        return compose_lowrank(params, dtype)
    if kind == "fedpara":
        return compose_fedpara(params, dtype, use_tanh=False)
    if kind == "fedpara_tanh":
        return compose_fedpara(params, dtype, use_tanh=True)
    if kind == "pfedpara":
        return compose_pfedpara(params, dtype)
    raise ValueError(f"unknown parameterization kind: {kind}")


def num_params(tree: Any) -> int:
    """Total scalar count over a pytree."""
    return int(sum(x.size for x in jax.tree.leaves(tree) if hasattr(x, "size")))


def tree_bytes(tree: Any) -> int:
    return int(
        sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree) if hasattr(x, "size"))
    )
