"""Weight parameterizations: FedPara, conventional low-rank, original, pFedPara.

Functional API (no flax): each parameterization is a pair of pure
functions ``init(key, ...) -> params`` and ``materialize(params) -> W``.
Layer code calls :func:`materialize` (or the fused Pallas kernel) to get
the dense weight and then runs the ordinary einsum.

Param trees contain ONLY arrays (jit-safe); the parameterization *kind*
lives in static layer specs (see `repro.nn.layers.LinearSpec`), not in
the tree. Key-name conventions:

  original : {"w"}
  lowrank  : {"x", "y"}                      W = X Yᵀ
  fedpara  : {"x1", "y1", "x2", "y2"}        W = (X1Y1ᵀ) ⊙ (X2Y2ᵀ)
  pfedpara : {"x1", "y1", "x2", "y2"}        W = (X1Y1ᵀ) ⊙ (X2Y2ᵀ + 1)

All factors are stored fp32 (master copy); :func:`materialize` casts the
composed weight to ``dtype`` (bf16 by default on the compute path).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import rank_policy

ParamTree = Dict[str, Any]

KINDS = ("original", "lowrank", "fedpara", "fedpara_tanh", "pfedpara")


# --------------------------------------------------------------------------
# Initialization scaling.
#
# The paper uses He init. For the composed matrix W = (X1 Y1ᵀ)⊙(X2 Y2ᵀ) we
# pick the factor std so the *composed* weight matches He variance:
#   var(W1_ij) = r · σ_x² σ_y²,  var(W_ij) = var(W1)·var(W2) = (rσ⁴)²
#   ⇒ σ = target_var^(1/8) / r^(1/4) with target_var = gain/fan_in.
# --------------------------------------------------------------------------

def fedpara_factor_std(fan_in: int, r: int, target_gain: float = 2.0) -> float:
    return float((target_gain / fan_in) ** 0.125 / (r ** 0.25))


def lowrank_factor_std(fan_in: int, r: int, target_gain: float = 2.0) -> float:
    # var(W_ij) = r σ⁴ = target ⇒ σ = (target/(fan_in·r))^(1/4) · gain^(1/4)
    return float((target_gain / (fan_in * r)) ** 0.25)


# ------------------------------------------------------------------ original

def init_original(key: jax.Array, m: int, n: int, dtype=jnp.float32) -> ParamTree:
    """He-initialized dense ``{"w": (m, n)}`` baseline (no factorization)."""
    w = jax.random.normal(key, (m, n), dtype) * jnp.asarray((2.0 / m) ** 0.5, dtype)
    return {"w": w}


# ------------------------------------------------------------------ low-rank

def init_lowrank(key: jax.Array, m: int, n: int, r: int, dtype=jnp.float32) -> ParamTree:
    """Low-rank baseline ``{"x": (m, r), "y": (n, r)}`` with W = X Yᵀ,
    factor std chosen so the composed W matches He variance."""
    kx, ky = jax.random.split(key)
    std = lowrank_factor_std(m, r)
    x = jax.random.normal(kx, (m, r), dtype) * std
    y = jax.random.normal(ky, (n, r), dtype) * std
    return {"x": x, "y": y}


def _cast(a, dtype):
    return a.astype(dtype) if dtype is not None else a


def compose_lowrank(params: ParamTree, dtype=None) -> jax.Array:
    """W = X Yᵀ for ``{"x": (..., m, r), "y": (..., n, r)}`` -> (..., m, n)."""
    # Cast factors BEFORE the compose dot: a post-compose cast would be
    # folded into the dot by XLA, upcasting it (and any GSPMD psum of
    # its products) to fp32. '...' handles scan-stacked leading dims.
    return jnp.einsum("...mr,...nr->...mn",
                      _cast(params["x"], dtype), _cast(params["y"], dtype))


# ------------------------------------------------------------------- fedpara

def init_fedpara(key: jax.Array, m: int, n: int, r: int, dtype=jnp.float32) -> ParamTree:
    """FedPara factors ``{"x1"/"x2": (m, r), "y1"/"y2": (n, r)}`` with
    std set so the composed W = (X1Y1ᵀ)⊙(X2Y2ᵀ) matches He variance."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = fedpara_factor_std(m, r)
    return {
        "x1": jax.random.normal(k1, (m, r), dtype) * std,
        "y1": jax.random.normal(k2, (n, r), dtype) * std,
        "x2": jax.random.normal(k3, (m, r), dtype) * std,
        "y2": jax.random.normal(k4, (n, r), dtype) * std,
    }


def compose_fedpara(params: ParamTree, dtype=None, use_tanh: bool = False) -> jax.Array:
    """W = (X1 Y1ᵀ) ⊙ (X2 Y2ᵀ)   (optionally tanh(W1)⊙tanh(W2), supp. B)."""
    w1 = jnp.einsum("...mr,...nr->...mn",
                    _cast(params["x1"], dtype), _cast(params["y1"], dtype))
    w2 = jnp.einsum("...mr,...nr->...mn",
                    _cast(params["x2"], dtype), _cast(params["y2"], dtype))
    if use_tanh:
        w1, w2 = jnp.tanh(w1), jnp.tanh(w2)
    return w1 * w2


# ------------------------------------------------------------------ pfedpara

def init_pfedpara(key: jax.Array, m: int, n: int, r: int, dtype=jnp.float32) -> ParamTree:
    """pFedPara: W = W1 ⊙ (W2 + 1); W1 global (transferred), W2 local.

    W2 factors start near zero so W ≈ W1 at initialization (the "+1"
    acts as a switch, paper §2.3); W1 carries low-rank He scaling.
    The personal-half std is 0.5·std1: W2 entries are still tiny
    (σ_W2 ≈ r·std2² ≪ 1, so W ≈ W1 holds) but the W2 factor GRADIENTS —
    which scale with the factor magnitudes (dX2 = (dW ⊙ W1) Y2) — are
    5× larger than at the old 0.1·std1, so the personal half actually
    adapts within few-round regimes instead of staying frozen at init.
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std1 = lowrank_factor_std(m, r)
    std2 = 0.5 * std1
    return {
        "x1": jax.random.normal(k1, (m, r), dtype) * std1,  # global
        "y1": jax.random.normal(k2, (n, r), dtype) * std1,  # global
        "x2": jax.random.normal(k3, (m, r), dtype) * std2,  # local
        "y2": jax.random.normal(k4, (n, r), dtype) * std2,  # local
    }


def compose_pfedpara(params: ParamTree, dtype=None) -> jax.Array:
    """W = W1 ⊙ (W2 + 1) = W_per + W_glo  (paper §2.3)."""
    w1 = jnp.einsum("...mr,...nr->...mn",
                    _cast(params["x1"], dtype), _cast(params["y1"], dtype))
    w2 = jnp.einsum("...mr,...nr->...mn",
                    _cast(params["x2"], dtype), _cast(params["y2"], dtype))
    one = jnp.asarray(1.0, w2.dtype)
    return w1 * (w2 + one)


PFEDPARA_GLOBAL_KEYS = ("x1", "y1")   # transferred to the server
PFEDPARA_LOCAL_KEYS = ("x2", "y2")    # kept on-device


# ------------------------------------------------------- generic entry points

def resolve_rank(m: int, n: int, kind: str, gamma: float, rank: Optional[int]) -> int:
    if rank is not None:
        return rank
    return rank_policy.matrix_rank_for_gamma(m, n, gamma)


def init_linear(
    key: jax.Array,
    m: int,
    n: int,
    *,
    kind: str = "fedpara",
    gamma: float = 0.1,
    rank: Optional[int] = None,
    dtype=jnp.float32,
) -> ParamTree:
    """Initialize one parameterized (m -> n) weight.

    ``rank=None`` resolves the inner rank from ``gamma`` via the paper's
    policy. The low-rank baseline receives ``2r`` (parameter parity with
    FedPara at inner rank ``r``, cf. Fig. 1).
    """
    if kind == "original":
        return init_original(key, m, n, dtype)
    r = resolve_rank(m, n, kind, gamma, rank)
    if kind == "lowrank":
        return init_lowrank(key, m, n, 2 * r, dtype)
    if kind in ("fedpara", "fedpara_tanh"):
        return init_fedpara(key, m, n, r, dtype)
    if kind == "pfedpara":
        return init_pfedpara(key, m, n, r, dtype)
    raise ValueError(f"unknown parameterization kind: {kind}")


def materialize(params: ParamTree, kind: str, dtype=None) -> jax.Array:
    """Compose the dense weight for the given parameterization kind."""
    if kind == "original":
        w = params["w"]
        return w.astype(dtype) if dtype is not None else w
    if kind == "lowrank":
        return compose_lowrank(params, dtype)
    if kind == "fedpara":
        return compose_fedpara(params, dtype, use_tanh=False)
    if kind == "fedpara_tanh":
        return compose_fedpara(params, dtype, use_tanh=True)
    if kind == "pfedpara":
        return compose_pfedpara(params, dtype)
    raise ValueError(f"unknown parameterization kind: {kind}")


# ------------------------------------------- heterogeneous-rank tier helpers
#
# A "factor node" is any dict whose keys are exactly a FedPara/low-rank
# factor set: {x1, y1[, x2, y2]} (matrix FedPara and its pFedPara split
# halves), {x, y} (low-rank baseline), or the conv variants that add the
# 4-D Tucker cores {t1, t2} / {t}. Heterogeneous-capacity clients keep
# only the leading tier-rank columns of every factor leaf (and the
# leading (r_t, r_t) block of conv cores); these helpers detect nodes,
# build broadcastable column masks, and physically slice / zero-embed
# trees. All shape decisions are static, so the mask path is jit/vmap
# safe; detection runs on UNSTACKED trees (no leading client axis).

# matrix nodes are the conv sets minus the Tucker cores, so two subset
# checks cover all four node flavors (incl. pFedPara split halves)
_CONV_FACTOR_KEYS = frozenset(("t1", "x1", "y1", "t2", "x2", "y2"))
_CONV_LOWRANK_KEYS = frozenset(("t", "x", "y"))
_FACTOR_PAIRS = (("x1", "y1"), ("x2", "y2"), ("x", "y"))


def factor_spec(node: Any) -> Optional[Dict[str, Any]]:
    """Recognize a factor node and return its layer dimensions.

    Args:
        node: candidate pytree node (unstacked — leaves carry no client
            axis).

    Returns:
        ``{"kind": "matrix"|"conv", "m", "n", "r"[, "k1", "k2"]}`` when
        ``node`` is a FedPara / low-rank factor dict, else ``None``.
        ``m``/``n`` are the layer's outer dims, ``r`` the materialized
        inner rank (factor column count).
    """
    if not isinstance(node, dict) or not node:
        return None
    keys = set(node)
    if not (keys <= _CONV_FACTOR_KEYS or keys <= _CONV_LOWRANK_KEYS):
        return None
    for xk, yk in _FACTOR_PAIRS:
        if xk in node and yk in node:
            x, y = node[xk], node[yk]
            break
    else:
        return None
    if getattr(x, "ndim", 0) != 2 or getattr(y, "ndim", 0) != 2:
        return None
    if x.shape[-1] != y.shape[-1]:
        return None
    m, n, r = int(x.shape[0]), int(y.shape[0]), int(x.shape[-1])
    core = next((node[k] for k in ("t", "t1", "t2") if k in node), None)
    if core is None:
        return {"kind": "matrix", "m": m, "n": n, "r": r}
    if getattr(core, "ndim", 0) != 4 or int(core.shape[0]) != r \
            or int(core.shape[1]) != r:
        return None
    return {"kind": "conv", "m": m, "n": n, "r": r,
            "k1": int(core.shape[2]), "k2": int(core.shape[3])}


def tier_node_rank(spec: Dict[str, Any], gamma: float) -> int:
    """Effective tier rank for one factor node (see ``rank_policy``)."""
    if spec["kind"] == "conv":
        return rank_policy.conv_tier_rank(
            spec["m"], spec["n"], spec["k1"], spec["k2"], spec["r"], gamma)
    return rank_policy.matrix_tier_rank(spec["m"], spec["n"], spec["r"], gamma)


def _is_core_key(k: str) -> bool:
    return k in ("t", "t1", "t2")


def rank_mask_tree(tree: Any, gamma: float, dtype=jnp.float32) -> Any:
    """Broadcastable 0/1 column masks selecting a tier's factor slice.

    Args:
        tree: payload/param pytree (unstacked).
        gamma: the tier's rank-interpolation knob.
        dtype: mask dtype.

    Returns:
        A same-structure tree whose factor leaves carry ``(1, r)``
        column masks (``(r, r, 1, 1)`` block masks for conv cores) with
        ones on the leading tier-rank columns, and whose non-factor
        leaves carry all-ones masks of broadcast shape ``(1,) * ndim``.
        Masks multiply cleanly against unstacked, client-stacked
        ``(C, ...)`` and tier-stacked leaves alike.
    """
    def node_masks(node, spec):
        r_full = spec["r"]
        col = (jnp.arange(r_full) < tier_node_rank(spec, gamma)).astype(dtype)
        block = (col[:, None] * col[None, :])[..., None, None]
        return {k: (block if _is_core_key(k) else col[None, :])
                for k in node}

    def walk(node):
        spec = factor_spec(node)
        if spec is not None:
            return node_masks(node, spec)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return jnp.ones((1,) * getattr(node, "ndim", 0), dtype)

    return walk(tree)


def tier_rank_masks(tree: Any, gammas, dtype=jnp.float32) -> Any:
    """Stack :func:`rank_mask_tree` over a tier schedule.

    Returns a same-structure tree whose leaves gain a leading tier axis
    ``(T, ...)``; gather per-client masks with
    ``jax.tree.map(lambda m: jnp.take(m, tier_idx, axis=0), masks)``.
    """
    per_tier = [rank_mask_tree(tree, g, dtype) for g in gammas]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_tier)


def apply_rank_mask(tree: Any, masks: Any) -> Any:
    """Multiply a (broadcastable) mask tree into ``tree``, preserving
    each leaf's dtype. Inverse-free: masked columns become exact zeros."""
    return jax.tree.map(lambda x, m: (x * m).astype(x.dtype), tree, masks)


def slice_factor_tree(tree: Any, gamma: float) -> Any:
    """Physically slice every factor node to its tier rank.

    The ragged twin of :func:`rank_mask_tree`: factor leaves come back
    as ``x[..., :r_t]`` column prefixes (conv cores as
    ``t[:r_t, :r_t]``), non-factor leaves unchanged. This is what a
    tier's wire payload actually looks like — codecs price tier uplinks
    from these shapes (``Codec.wire_bytes`` is shape-only, so the byte
    algebra stays exact). Host-side only: slicing changes shapes, so it
    cannot run under jit with traced ranks.
    """
    def walk(node):
        spec = factor_spec(node)
        if spec is not None:
            r_t = tier_node_rank(spec, gamma)
            return {k: (v[:r_t, :r_t] if _is_core_key(k) else v[..., :r_t])
                    for k, v in node.items()}
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(tree)


def embed_factor_tree(sliced: Any, like: Any) -> Any:
    """Zero-embed a rank-sliced tree back into full-rank shapes.

    Args:
        sliced: output of :func:`slice_factor_tree`.
        like: a full-rank tree with the target shapes.

    Returns:
        ``like``-shaped tree with the slice in the leading columns and
        exact zeros beyond — the server-side inverse of slicing, so
        ``embed(slice(p)) == mask * p`` leaf-wise.
    """
    def walk(s, l):
        if isinstance(l, dict):
            return {k: walk(s[k], v) for k, v in l.items()}
        if isinstance(l, (list, tuple)):
            return type(l)(walk(a, b) for a, b in zip(s, l))
        if not hasattr(l, "shape"):
            return s
        pad = [(0, int(fd) - int(sd)) for sd, fd in zip(s.shape, l.shape)]
        return jnp.pad(s, pad) if any(p for _, p in pad) else s

    return walk(sliced, like)


def num_params(tree: Any) -> int:
    """Total scalar count over a pytree."""
    return int(sum(x.size for x in jax.tree.leaves(tree) if hasattr(x, "size")))


def tree_bytes(tree: Any) -> int:
    """Total in-memory bytes over a pytree (dtype-aware: size × itemsize)."""
    return int(
        sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree) if hasattr(x, "size"))
    )
