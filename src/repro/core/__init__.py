"""FedPara core: low-rank Hadamard product parameterizations (ICLR'22)."""
from repro.core import rank_policy, regularization, tensor_fedpara
from repro.core.parameterization import (
    PFEDPARA_GLOBAL_KEYS,
    PFEDPARA_LOCAL_KEYS,
    compose_fedpara,
    compose_lowrank,
    compose_pfedpara,
    init_fedpara,
    init_linear,
    init_lowrank,
    init_original,
    init_pfedpara,
    materialize,
    num_params,
    tree_bytes,
)
from repro.core.tensor_fedpara import compose_conv_fedpara, init_conv, materialize_conv

__all__ = [
    "rank_policy",
    "regularization",
    "tensor_fedpara",
    "PFEDPARA_GLOBAL_KEYS",
    "PFEDPARA_LOCAL_KEYS",
    "compose_fedpara",
    "compose_lowrank",
    "compose_pfedpara",
    "init_fedpara",
    "init_linear",
    "init_lowrank",
    "init_original",
    "init_pfedpara",
    "materialize",
    "num_params",
    "tree_bytes",
    "compose_conv_fedpara",
    "init_conv",
    "materialize_conv",
]
