"""Rank hyper-parameter policy for FedPara (Prop. 2 / Corollary 1).

The paper controls the per-layer inner rank with a single scalar
``gamma`` in [0, 1]:

    r = round((1 - gamma) * r_min + gamma * r_max)

* ``r_min = ceil(sqrt(min(m, n)))`` — the smallest inner rank for which
  ``r^2 >= min(m, n)``, i.e. the constructed matrix can reach full rank
  (Corollary 1).
* ``r_max`` — the largest inner rank whose parameter count does not
  exceed the original layer (parameter parity).

Heterogeneous-capacity federation extends the single knob to a **tier
schedule** (:class:`TierSchedule`): a short list of gammas, one per
device-capacity tier, plus a client→tier assignment rule. A tier's
per-layer rank is the paper's policy rank for its gamma, floored at the
layer's ``r_min`` (Corollary 1 — every tier keeps full-rank capability)
and capped at the global model's materialized rank (a tier can only
*slice* the global factors, never widen them): :func:`matrix_tier_rank`
/ :func:`conv_tier_rank`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np


def matrix_rmin(m: int, n: int) -> int:
    """Minimum inner rank achieving full-rank capability (Corollary 1)."""
    return max(1, math.isqrt(min(m, n) - 1) + 1) if min(m, n) > 1 else 1


def matrix_rmax(m: int, n: int) -> int:
    """Largest r with 2r(m+n) <= mn (parameter parity with the dense layer)."""
    return max(1, (m * n) // (2 * (m + n)))


def matrix_rank_for_gamma(m: int, n: int, gamma: float) -> int:
    """Paper's interpolation  r = (1-γ)·r_min + γ·r_max  (§3.1)."""
    rmin, rmax = matrix_rmin(m, n), matrix_rmax(m, n)
    if rmax < rmin:  # degenerate tiny layer: parity already below full-rank point
        return rmin
    return int(round((1.0 - gamma) * rmin + gamma * rmax))


def matrix_param_count(m: int, n: int, r: int) -> int:
    """FedPara FC parameter count 2R(m+n) for r1 = r2 = R (Prop. 2)."""
    return 2 * r * (m + n)


def lowrank_rank_for_params(m: int, n: int, budget: int) -> int:
    """Rank of a conventional X Yᵀ factorization with <= ``budget`` params."""
    return max(1, budget // (m + n))


# ---------------------------------------------------------------- conv (Prop 3)

def conv_rmin(out_ch: int, in_ch: int) -> int:
    return matrix_rmin(out_ch, in_ch)


def conv_rmax(out_ch: int, in_ch: int, k1: int, k2: int) -> int:
    """Largest R with 2R(O+I+R·K1K2) <= O·I·K1·K2 (Prop. 3 param count)."""
    # Solve 2k R^2 + 2(O+I) R - OIk <= 0  with k = K1*K2.
    k = k1 * k2
    a, b, c = 2 * k, 2 * (out_ch + in_ch), -(out_ch * in_ch * k)
    disc = b * b - 4 * a * c
    r = int((-b + math.sqrt(disc)) / (2 * a))
    return max(1, r)


def conv_rank_for_gamma(out_ch: int, in_ch: int, k1: int, k2: int, gamma: float) -> int:
    rmin, rmax = conv_rmin(out_ch, in_ch), conv_rmax(out_ch, in_ch, k1, k2)
    if rmax < rmin:
        return rmin
    return int(round((1.0 - gamma) * rmin + gamma * rmax))


def conv_param_count(out_ch: int, in_ch: int, k1: int, k2: int, r: int) -> int:
    """FedPara conv (Prop. 3) parameter count 2R(O + I + R·K1·K2)."""
    return 2 * r * (out_ch + in_ch + r * k1 * k2)


def conv_reshape_param_count(out_ch: int, in_ch: int, k1: int, k2: int, r: int) -> int:
    """FedPara conv via reshape (Prop. 1 on O×(I·K1·K2)): 2R(O + I·K1·K2)."""
    return 2 * r * (out_ch + in_ch * k1 * k2)


# ------------------------------------------------- heterogeneous rank tiers

TIER_ASSIGNMENTS = ("round_robin", "random", "size")


def tier_rank(r_full: int, r_min: int, policy_rank: int) -> int:
    """Clamp a tier's policy rank into ``[min(r_min, r_full), r_full]``.

    Args:
        r_full: materialized rank of the global factors (the most a
            client can receive — tiers slice, they never widen).
        r_min: the layer's Corollary-1 full-rank floor.
        policy_rank: the rank the tier's gamma resolves to under the
            paper's interpolation.

    Returns:
        The tier's effective rank: floored at ``r_min`` so every tier
        keeps full-rank capability (when the global factors themselves
        have it), capped at ``r_full``.
    """
    floor = min(r_min, r_full)
    return int(min(r_full, max(floor, policy_rank)))


def matrix_tier_rank(m: int, n: int, r_full: int, gamma: float) -> int:
    """Effective rank of a gamma tier on an (m, n) matrix layer whose
    global factors have inner rank ``r_full`` (see :func:`tier_rank`)."""
    return tier_rank(r_full, matrix_rmin(m, n),
                     matrix_rank_for_gamma(m, n, gamma))


def conv_tier_rank(out_ch: int, in_ch: int, k1: int, k2: int,
                   r_full: int, gamma: float) -> int:
    """Effective rank of a gamma tier on an (O, I, K1, K2) conv layer
    whose global Prop.-3 factors have inner rank ``r_full``."""
    return tier_rank(r_full, conv_rmin(out_ch, in_ch),
                     conv_rank_for_gamma(out_ch, in_ch, k1, k2, gamma))


@dataclass(frozen=True)
class TierSchedule:
    """A capacity-tier schedule for heterogeneous-rank federation.

    Attributes:
        gammas: one rank-interpolation gamma per tier (each in [0, 1]).
            Tier ``t``'s clients train and upload only the leading
            ``r_t`` columns of every FedPara factor, where ``r_t`` is
            the gamma's policy rank per layer (see
            :func:`matrix_tier_rank`).
        assignment: client→tier rule — ``round_robin`` (cid mod T),
            ``random`` (seeded uniform draw), or ``size`` (clients
            ranked by local dataset size; larger datasets get
            larger-gamma tiers).
    """

    gammas: Tuple[float, ...]
    assignment: str = "round_robin"

    def __post_init__(self):
        if not self.gammas:
            raise ValueError("TierSchedule needs at least one gamma tier")
        for g in self.gammas:
            if not 0.0 <= float(g) <= 1.0:
                raise ValueError(f"tier gamma must be in [0, 1]: {g!r}")
        if self.assignment not in TIER_ASSIGNMENTS:
            raise ValueError(
                f"unknown tier assignment {self.assignment!r} "
                f"(expected one of {TIER_ASSIGNMENTS})")

    @property
    def n_tiers(self) -> int:
        return len(self.gammas)

    def assign(self, n_clients: int, sizes: Optional[Sequence[int]] = None,
               seed: int = 0) -> np.ndarray:
        """Deterministic client→tier index assignment.

        Args:
            n_clients: fleet size.
            sizes: per-client local dataset sizes — required for the
                ``size`` rule, ignored otherwise.
            seed: RNG seed for the ``random`` rule.

        Returns:
            ``(n_clients,)`` int array of tier indices into ``gammas``.
        """
        T = self.n_tiers
        if self.assignment == "round_robin":
            return np.arange(n_clients, dtype=np.int64) % T
        if self.assignment == "random":
            return np.random.RandomState(seed).randint(T, size=n_clients)
        if sizes is None:
            raise ValueError("tier assignment 'size' needs per-client sizes")
        if len(sizes) != n_clients:
            raise ValueError("sizes length must equal n_clients")
        # clients sorted by dataset size; equal blocks map onto tiers
        # ordered by ascending gamma (more data -> more capacity)
        order = np.argsort(np.asarray(sizes), kind="stable")
        gamma_order = np.argsort(np.asarray(self.gammas), kind="stable")
        out = np.zeros(n_clients, dtype=np.int64)
        for pos, cid in enumerate(order):
            out[cid] = gamma_order[min(pos * T // n_clients, T - 1)]
        return out


@dataclass(frozen=True)
class RankSpec:
    """Resolved rank decision for one layer."""

    r: int
    r_min: int
    r_max: int
    params: int
    dense_params: int

    @property
    def compression(self) -> float:
        return self.params / max(1, self.dense_params)


def resolve_matrix(m: int, n: int, gamma: float) -> RankSpec:
    r = matrix_rank_for_gamma(m, n, gamma)
    return RankSpec(
        r=r,
        r_min=matrix_rmin(m, n),
        r_max=matrix_rmax(m, n),
        params=matrix_param_count(m, n, r),
        dense_params=m * n,
    )


def resolve_conv(out_ch: int, in_ch: int, k1: int, k2: int, gamma: float) -> RankSpec:
    r = conv_rank_for_gamma(out_ch, in_ch, k1, k2, gamma)
    return RankSpec(
        r=r,
        r_min=conv_rmin(out_ch, in_ch),
        r_max=conv_rmax(out_ch, in_ch, k1, k2),
        params=conv_param_count(out_ch, in_ch, k1, k2, r),
        dense_params=out_ch * in_ch * k1 * k2,
    )
