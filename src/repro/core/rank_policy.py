"""Rank hyper-parameter policy for FedPara (Prop. 2 / Corollary 1).

The paper controls the per-layer inner rank with a single scalar
``gamma`` in [0, 1]:

    r = round((1 - gamma) * r_min + gamma * r_max)

* ``r_min = ceil(sqrt(min(m, n)))`` — the smallest inner rank for which
  ``r^2 >= min(m, n)``, i.e. the constructed matrix can reach full rank
  (Corollary 1).
* ``r_max`` — the largest inner rank whose parameter count does not
  exceed the original layer (parameter parity).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def matrix_rmin(m: int, n: int) -> int:
    """Minimum inner rank achieving full-rank capability (Corollary 1)."""
    return max(1, math.isqrt(min(m, n) - 1) + 1) if min(m, n) > 1 else 1


def matrix_rmax(m: int, n: int) -> int:
    """Largest r with 2r(m+n) <= mn (parameter parity with the dense layer)."""
    return max(1, (m * n) // (2 * (m + n)))


def matrix_rank_for_gamma(m: int, n: int, gamma: float) -> int:
    """Paper's interpolation  r = (1-γ)·r_min + γ·r_max  (§3.1)."""
    rmin, rmax = matrix_rmin(m, n), matrix_rmax(m, n)
    if rmax < rmin:  # degenerate tiny layer: parity already below full-rank point
        return rmin
    return int(round((1.0 - gamma) * rmin + gamma * rmax))


def matrix_param_count(m: int, n: int, r: int) -> int:
    """FedPara FC parameter count 2R(m+n) for r1 = r2 = R (Prop. 2)."""
    return 2 * r * (m + n)


def lowrank_rank_for_params(m: int, n: int, budget: int) -> int:
    """Rank of a conventional X Yᵀ factorization with <= ``budget`` params."""
    return max(1, budget // (m + n))


# ---------------------------------------------------------------- conv (Prop 3)

def conv_rmin(out_ch: int, in_ch: int) -> int:
    return matrix_rmin(out_ch, in_ch)


def conv_rmax(out_ch: int, in_ch: int, k1: int, k2: int) -> int:
    """Largest R with 2R(O+I+R·K1K2) <= O·I·K1·K2 (Prop. 3 param count)."""
    # Solve 2k R^2 + 2(O+I) R - OIk <= 0  with k = K1*K2.
    k = k1 * k2
    a, b, c = 2 * k, 2 * (out_ch + in_ch), -(out_ch * in_ch * k)
    disc = b * b - 4 * a * c
    r = int((-b + math.sqrt(disc)) / (2 * a))
    return max(1, r)


def conv_rank_for_gamma(out_ch: int, in_ch: int, k1: int, k2: int, gamma: float) -> int:
    rmin, rmax = conv_rmin(out_ch, in_ch), conv_rmax(out_ch, in_ch, k1, k2)
    if rmax < rmin:
        return rmin
    return int(round((1.0 - gamma) * rmin + gamma * rmax))


def conv_param_count(out_ch: int, in_ch: int, k1: int, k2: int, r: int) -> int:
    """FedPara conv (Prop. 3) parameter count 2R(O + I + R·K1·K2)."""
    return 2 * r * (out_ch + in_ch + r * k1 * k2)


def conv_reshape_param_count(out_ch: int, in_ch: int, k1: int, k2: int, r: int) -> int:
    """FedPara conv via reshape (Prop. 1 on O×(I·K1·K2)): 2R(O + I·K1·K2)."""
    return 2 * r * (out_ch + in_ch * k1 * k2)


@dataclass(frozen=True)
class RankSpec:
    """Resolved rank decision for one layer."""

    r: int
    r_min: int
    r_max: int
    params: int
    dense_params: int

    @property
    def compression(self) -> float:
        return self.params / max(1, self.dense_params)


def resolve_matrix(m: int, n: int, gamma: float) -> RankSpec:
    r = matrix_rank_for_gamma(m, n, gamma)
    return RankSpec(
        r=r,
        r_min=matrix_rmin(m, n),
        r_max=matrix_rmax(m, n),
        params=matrix_param_count(m, n, r),
        dense_params=m * n,
    )


def resolve_conv(out_ch: int, in_ch: int, k1: int, k2: int, gamma: float) -> RankSpec:
    r = conv_rank_for_gamma(out_ch, in_ch, k1, k2, gamma)
    return RankSpec(
        r=r,
        r_min=conv_rmin(out_ch, in_ch),
        r_max=conv_rmax(out_ch, in_ch, k1, k2),
        params=conv_param_count(out_ch, in_ch, k1, k2, r),
        dense_params=out_ch * in_ch * k1 * k2,
    )
