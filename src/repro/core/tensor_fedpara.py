"""Tensor (Proposition 3) FedPara parameterization for convolution kernels.

W = (T1 ×₁ X1 ×₂ Y1) ⊙ (T2 ×₁ X2 ×₂ Y2)  ∈ R^{O×I×K1×K2}

with Tᵢ ∈ R^{R×R×K1×K2}, Xᵢ ∈ R^{O×R}, Yᵢ ∈ R^{I×R}. Parameter count
2R(O + I + R·K1·K2); unfolding ranks rank(W⁽¹⁾) = rank(W⁽²⁾) ≤ R².
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import rank_policy
from repro.core.parameterization import ParamTree


def init_conv_fedpara(
    key: jax.Array,
    out_ch: int,
    in_ch: int,
    k1: int,
    k2: int,
    *,
    gamma: float = 0.1,
    rank: Optional[int] = None,
    dtype=jnp.float32,
) -> ParamTree:
    r = rank if rank is not None else rank_policy.conv_rank_for_gamma(out_ch, in_ch, k1, k2, gamma)
    keys = jax.random.split(key, 6)
    fan_in = in_ch * k1 * k2
    # Composed-variance matching (see parameterization.py): each branch
    # W1[o,i,h,w] = Σ_ab X[o,a] Y[i,b] T[a,b,h,w]  has r² three-way product
    # terms ⇒ var(W1) = r²σ⁶, var(W) = (r²σ⁶)² ⇒ σ = tgt^(1/12)/r^(1/3).
    std = float((2.0 / fan_in) ** (1.0 / 12.0) / (r ** (1.0 / 3.0)))
    shape_t = (r, r, k1, k2)
    return {
        "t1": jax.random.normal(keys[0], shape_t, dtype) * std,
        "x1": jax.random.normal(keys[1], (out_ch, r), dtype) * std,
        "y1": jax.random.normal(keys[2], (in_ch, r), dtype) * std,
        "t2": jax.random.normal(keys[3], shape_t, dtype) * std,
        "x2": jax.random.normal(keys[4], (out_ch, r), dtype) * std,
        "y2": jax.random.normal(keys[5], (in_ch, r), dtype) * std,
    }


def compose_conv_fedpara(params: ParamTree, dtype=None, use_tanh: bool = False) -> jax.Array:
    """Compose the OIHW kernel via two mode products + Hadamard (Prop. 3)."""
    w1 = jnp.einsum("oa,ib,abhw->oihw", params["x1"], params["y1"], params["t1"])
    w2 = jnp.einsum("oa,ib,abhw->oihw", params["x2"], params["y2"], params["t2"])
    if use_tanh:
        w1, w2 = jnp.tanh(w1), jnp.tanh(w2)
    w = w1 * w2
    return w.astype(dtype) if dtype is not None else w


def init_conv_lowrank(
    key: jax.Array,
    out_ch: int,
    in_ch: int,
    k1: int,
    k2: int,
    *,
    rank: int,
    dtype=jnp.float32,
) -> ParamTree:
    """Tucker-2 style low-rank conv baseline (TKD, Phan et al. 2020):

    W = K ×₁ X ×₂ Y with K ∈ R^{r×r×K1×K2}; params r²K1K2 + r(O+I).
    """
    keys = jax.random.split(key, 3)
    fan_in = in_ch * k1 * k2
    std = float((2.0 / fan_in) ** (1.0 / 3.0) / (rank ** (1.0 / 3.0)))
    return {
        "t": jax.random.normal(keys[0], (rank, rank, k1, k2), dtype) * std,
        "x": jax.random.normal(keys[1], (out_ch, rank), dtype) * std,
        "y": jax.random.normal(keys[2], (in_ch, rank), dtype) * std,
    }


def compose_conv_lowrank(params: ParamTree, dtype=None) -> jax.Array:
    w = jnp.einsum("oa,ib,abhw->oihw", params["x"], params["y"], params["t"])
    return w.astype(dtype) if dtype is not None else w


def init_conv_original(
    key: jax.Array, out_ch: int, in_ch: int, k1: int, k2: int, dtype=jnp.float32
) -> ParamTree:
    fan_in = in_ch * k1 * k2
    w = jax.random.normal(key, (out_ch, in_ch, k1, k2), dtype)
    return {"w": w * jnp.asarray((2.0 / fan_in) ** 0.5, dtype)}


def materialize_conv(params: ParamTree, kind: str, dtype=None) -> jax.Array:
    """Compose the dense OIHW conv kernel for the given parameterization
    kind (original | lowrank | fedpara | fedpara_tanh)."""
    if kind == "original":
        w = params["w"]
        return w.astype(dtype) if dtype is not None else w
    if kind == "lowrank":
        return compose_conv_lowrank(params, dtype)
    if kind == "fedpara":
        return compose_conv_fedpara(params, dtype, use_tanh=False)
    if kind == "fedpara_tanh":
        return compose_conv_fedpara(params, dtype, use_tanh=True)
    raise ValueError(f"unknown conv parameterization kind: {kind}")


def init_conv(
    key: jax.Array,
    out_ch: int,
    in_ch: int,
    k1: int,
    k2: int,
    *,
    kind: str = "fedpara",
    gamma: float = 0.1,
    rank: Optional[int] = None,
    dtype=jnp.float32,
) -> ParamTree:
    """Initialize one parameterized (out_ch, in_ch, k1, k2) conv kernel;
    ``rank=None`` resolves the inner rank from ``gamma`` via the Prop.-3
    policy (the low-rank baseline gets ``2r`` for parameter parity)."""
    if kind == "original":
        return init_conv_original(key, out_ch, in_ch, k1, k2, dtype)
    if kind == "lowrank":
        r = rank if rank is not None else 2 * rank_policy.conv_rank_for_gamma(
            out_ch, in_ch, k1, k2, gamma
        )
        return init_conv_lowrank(key, out_ch, in_ch, k1, k2, rank=r, dtype=dtype)
    if kind in ("fedpara", "fedpara_tanh"):
        return init_conv_fedpara(
            key, out_ch, in_ch, k1, k2, gamma=gamma, rank=rank, dtype=dtype
        )
    raise ValueError(f"unknown conv parameterization kind: {kind}")
