"""Jacobian correction regularization (paper supplementary B, Eq. 6-9).

Induces the one-step factor update to track the ideal dense-weight SGD
step:   R = L + λ/2 · ‖W' − (W − η J_W)‖_F
where W' is the weight composed from the factor values after one SGD step
computed with the chain-rule Jacobians of Eq. 6.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def jacobian_correction_penalty(
    params: Dict[str, jax.Array],
    j_w: jax.Array,
    eta: float,
) -> jax.Array:
    """Penalty for one FedPara weight given J_W = dL/dW.

    Implements Eq. 6 (chain-rule Jacobians), Eq. 7 (one-step SGD on the
    factors) and the Frobenius mismatch of Eq. 9.
    """
    x1, y1, x2, y2 = params["x1"], params["y1"], params["x2"], params["y2"]
    w1 = x1 @ y1.T
    w2 = x2 @ y2.T
    w = w1 * w2
    # Eq. 6
    j_w1 = j_w * w2
    j_w2 = j_w * w1
    j_x1 = j_w1 @ y1          # (m,n)@(n,r) -> (m,r)
    j_y1 = j_w1.T @ x1        # (n,m)@(m,r) -> (n,r)
    j_x2 = j_w2 @ y2
    j_y2 = j_w2.T @ x2
    # Eq. 7
    x1p, y1p = x1 - eta * j_x1, y1 - eta * j_y1
    x2p, y2p = x2 - eta * j_x2, y2 - eta * j_y2
    w_prime = (x1p @ y1p.T) * (x2p @ y2p.T)
    target = w - eta * j_w
    return jnp.linalg.norm(w_prime - target)


def fedpara_loss_with_jacobian_correction(
    loss_of_weight,
    params: Dict[str, jax.Array],
    lam: float,
    eta: float,
) -> jax.Array:
    """Total objective  R = L(W(factors)) + λ/2·penalty  (Eq. 9).

    ``loss_of_weight``: callable W -> scalar loss. The penalty needs
    J_W = dL/dW, obtained by differentiating through the composed W.
    """
    def compose(p):
        return (p["x1"] @ p["y1"].T) * (p["x2"] @ p["y2"].T)

    w = compose(params)
    loss, j_w = jax.value_and_grad(loss_of_weight)(w)
    penalty = jacobian_correction_penalty(params, jax.lax.stop_gradient(j_w), eta)
    return loss + 0.5 * lam * penalty
