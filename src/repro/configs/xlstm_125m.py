"""xlstm-125m [arXiv:2405.04517; unverified].

12 blocks d_model=768, 4 heads, vocab=50304, d_ff=0 (xLSTM blocks carry
their own up/down projections). Pattern 'smmm' (sLSTM at positions
0,4,8 — the paper's 7:1-style sparse sLSTM placement scaled to 12L).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    block_pattern="smmm",
    subquadratic=True,       # recurrent: O(1) state in sequence length
))
