"""Config system: architecture, parameterization, mesh and run configs.

Every assigned architecture is an :class:`ArchConfig` in its own module
(``repro.configs.<id>``) registered under its public id. Shape suites
(train_4k / prefill_32k / decode_32k / long_500k) are global and pair
with every LM arch.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ParamCfg:
    """Parameterization (the paper's technique) settings."""

    kind: str = "fedpara"          # original | lowrank | fedpara | fedpara_tanh | pfedpara
    gamma: float = 0.1             # paper's rank interpolation knob
    factorize_embeddings: bool = False  # paper keeps embeddings/last-FC dense
    min_dim_for_factorization: int = 128  # below this, 2R(m+n) >= mn anyway
    use_pallas: bool = False       # fused differentiable fedpara_matmul in
                                   # every dense() of this parameterization:
                                   # training never materializes W (custom
                                   # VJP, repro.kernels.fedpara_grad)
    gram_batch: int = 0            # serve decode: row counts <= this use the
                                   # Hadamard-Gram identity instead of the
                                   # tile kernel (repro.serve cost model
                                   # sets it; 0 = never, so training paths
                                   # are untouched)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # attention pattern
    sliding_window: int = 0        # 0 = full attention
    local_global_period: int = 0   # gemma3: every Nth layer is global
    local_window: int = 0          # window used by the local layers
    qk_norm: bool = False
    rope_style: str = "full"       # full | half (chatglm 2d-RoPE)
    rope_base: float = 10000.0

    # hybrid / ssm
    ssm_state: int = 0             # mamba2 d_state
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    attn_every: int = 0            # zamba2: shared attn+mlp block period
    block_pattern: str = ""        # xlstm: e.g. "smmm" repeated

    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0           # stub frontend frame count

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"

    # compute policy
    param: ParamCfg = field(default_factory=ParamCfg)
    dtype: str = "bfloat16"

    # capability flags for the shape suite
    subquadratic: bool = False     # may run long_500k
    is_encdec: bool = False

    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """A smoke-test-sized config of the same family/feature set."""
        kw = dict(
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(1, self.n_heads))),
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
        )
        if self.n_experts:
            kw["n_experts"] = 4
            kw["experts_per_token"] = min(2, self.experts_per_token)
            kw["moe_capacity_factor"] = 4.0  # no drops -> exact decode tests
        if self.sliding_window:
            kw["sliding_window"] = 16
        if self.local_global_period:
            kw["local_global_period"] = 2
            kw["local_window"] = 16
        if self.attn_every:
            kw["attn_every"] = 2
            kw["n_layers"] = 4
        if self.block_pattern:
            kw["block_pattern"] = self.block_pattern[:4] or "sm"
            kw["n_layers"] = 4
        if self.encoder_layers:
            kw["encoder_layers"] = 2
            kw["encoder_seq"] = 16
        if self.ssm_state:
            kw["ssm_state"] = 16
            kw["ssm_head_dim"] = 16
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshCfg:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes


@dataclass(frozen=True)
class FedCfg:
    """Cross-pod federated (local-SGD) settings — the paper's FL protocol
    mapped onto the 'pod' mesh axis."""

    enabled: bool = False
    local_steps: int = 4           # K local optimizer steps per round
    sync: str = "factors"          # factors | full  (full = dense baseline)
    strategy: str = "fedavg"       # fedavg | fedprox | fedadam ...
    compression: str = "none"      # none | fp16 | int8 | powersgd
    engine: str = "batched"        # client-sim engine: sequential |
                                   # batched | streaming (fl mode)
    client_chunk: int = 16         # streaming engine: clients per
                                   # lax.scan step (the round's memory
                                   # high-water mark is O(chunk·model))
    gamma_tiers: Tuple[float, ...] = ()   # heterogeneous capacity tiers:
                                   # one rank-gamma per device tier;
                                   # () = uniform full-rank clients
    tier_assignment: str = "round_robin"  # client->tier rule:
                                   # round_robin | random | size
    state_store: str = "dict"      # per-client state residency: dict
                                   # (host, O(participants) Python
                                   # objects) | arena (device-resident
                                   # stacked rows, one gather/scatter
                                   # per round; see docs/fleet.md)
    data_stream: str = "eager"     # cohort batch materialization:
                                   # eager (full (C,S,B,...) host
                                   # stack) | chunked (streaming only:
                                   # per-scan-chunk host callback)
    defense: str = "none"          # upload screening/aggregation rule:
                                   # none | clip | trimmed (batched
                                   # only; see docs/robustness.md)
    fault_rate: float = 0.0        # chaos injection: per-client fault
                                   # probability per round (0 = off;
                                   # see repro.fl.faults.FaultPlan)


@dataclass(frozen=True)
class RunCfg:
    arch: ArchConfig
    shape: ShapeCfg
    mesh: MeshCfg = field(default_factory=MeshCfg)
    fed: FedCfg = field(default_factory=FedCfg)
    seed: int = 0
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    attn_chunk: int = 512          # query-chunk size for flash-style attention
    logit_chunk: int = 1024        # seq-chunk for the unembed+CE
    scan_layers: bool = True       # False => unrolled (dry-run cost accounting)
    remat: bool = True
    use_pallas: bool = False       # fused fedpara_matmul kernels (TPU path)
    sequence_parallel: bool = False


# ---------------------------------------------------------------- registry

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (ensure modules imported)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> Dict[str, ArchConfig]:
    import repro.configs  # noqa: F401

    return dict(_REGISTRY)


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
