"""llama3-405b [arXiv:2407.21783; unverified].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
The framework's flagship FSDP case.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_base=500000.0,
    subquadratic=False,
))
