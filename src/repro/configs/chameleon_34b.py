"""chameleon-34b [arXiv:2405.09818; unverified].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536; early-fusion
VQ image tokens live in the same vocab (modality frontend is a stub —
input_specs() provides token ids / patch embeddings).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    qk_norm=True,            # chameleon uses qk-norm for stability
    subquadratic=False,
))
