"""zamba2-2.7b [arXiv:2411.15242; hf].

54 Mamba2 blocks d_model=2560 ssm_state=64, with a SHARED
attention(32H, kv=32)+MLP(d_ff=10240) block applied every 6th position
(the zamba shared-block trick: one parameter set, multiple call sites).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    attn_every=6,
    subquadratic=True,       # SSM state is O(1) in sequence length
))
