"""Config registry: importing this package registers all architectures."""
from repro.configs import base
from repro.configs.base import (
    SHAPES,
    ArchConfig,
    FedCfg,
    MeshCfg,
    ParamCfg,
    RunCfg,
    ShapeCfg,
    get_arch,
    list_archs,
    register,
)

# Assigned architectures (importing registers them).
from repro.configs import (  # noqa: E402,F401
    chameleon_34b,
    chatglm3_6b,
    gemma3_12b,
    llama3_405b,
    llama4_scout_17b_a16e,
    mixtral_8x22b,
    qwen3_8b,
    whisper_small,
    xlstm_125m,
    zamba2_2p7b,
)

ASSIGNED = [
    "llama4-scout-17b-a16e",
    "mixtral-8x22b",
    "chatglm3-6b",
    "llama3-405b",
    "gemma3-12b",
    "qwen3-8b",
    "chameleon-34b",
    "zamba2-2.7b",
    "whisper-small",
    "xlstm-125m",
]

__all__ = [
    "base",
    "SHAPES",
    "ArchConfig",
    "FedCfg",
    "MeshCfg",
    "ParamCfg",
    "RunCfg",
    "ShapeCfg",
    "get_arch",
    "list_archs",
    "register",
    "ASSIGNED",
]
