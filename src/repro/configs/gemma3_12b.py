"""gemma3-12b [hf:google/gemma-3-1b-pt; unverified].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144; 5 local
(sliding-window 1024) layers per 1 global layer; 128k context family.
head_dim=256 (gemma3 uses decoupled head dim).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    local_global_period=6,   # layer % 6 == 5 is global
    local_window=1024,
    qk_norm=True,
    rope_base=1000000.0,
    subquadratic=True,       # 5/6 of layers have bounded windows
))
