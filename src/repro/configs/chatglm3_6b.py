"""chatglm3-6b [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024, 2d-RoPE
(rotary applied to half of each head dim), GQA kv=2.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    rope_style="half",
    subquadratic=False,
))
