"""whisper-small [arXiv:2212.04356; unverified].

Enc-dec backbone: 12L encoder + 12L decoder, d_model=768 12H (kv=12)
d_ff=3072 vocab=51865. The conv audio frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, 1500, 768). Decoder shapes
follow the assigned LM suite (decode_32k uses a 32k self-KV cache plus
the 1500-frame cross-attention cache); long_500k skipped (full-attention
decoder).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    encoder_layers=12,
    encoder_seq=1500,
    is_encdec=True,
    act="gelu",
    subquadratic=False,
))
