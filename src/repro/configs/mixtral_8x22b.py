"""mixtral-8x22b [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts
top-2, sliding-window attention.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    n_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_base=1000000.0,
    subquadratic=True,   # SWA: bounded KV window
))
