"""Learning-rate schedules (step -> lr callables)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def exponential_decay(lr: float, decay: float, every: int = 1):
    """Paper's per-round decay: eta_t = eta * tau^t (tau ~ 0.992)."""
    def fn(step):
        return jnp.asarray(lr, jnp.float32) * decay ** (step.astype(jnp.float32) / every)
    return fn


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.asarray(lr, jnp.float32) * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        t = jnp.clip((s - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.asarray(lr, jnp.float32) * jnp.where(s < warmup, warm, cos)
    return fn
