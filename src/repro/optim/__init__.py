"""Optimizers from scratch (no optax in this environment).

(init_fn, update_fn) pairs over pytrees, optax-style:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    apply_updates,
    chain_clip,
    global_norm,
    sgd,
)
from repro.optim.schedules import constant, cosine_decay, exponential_decay, warmup_cosine

__all__ = [
    "Optimizer", "adam", "adamw", "apply_updates", "chain_clip",
    "global_norm", "sgd", "constant", "cosine_decay", "exponential_decay",
    "warmup_cosine",
]
