"""SGD / Adam / AdamW over pytrees, with global-norm clipping.

Schedules are callables step -> lr; pass a float for a constant rate.
States are pytrees with the same structure as params (jit/pjit-safe;
sharding rules applied to params apply transparently to moments).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr(schedule: Schedule, step: jax.Array) -> jax.Array:
    if callable(schedule):
        return schedule(step)
    return jnp.asarray(schedule, jnp.float32)


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Any:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, tree)


def sgd(lr: Schedule, momentum: float = 0.0, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum:
            return {"mu": jax.tree.map(jnp.zeros_like, params),
                    "step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        rate = _lr(lr, step)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            upd_src = (jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
                       if nesterov else mu)
            updates = jax.tree.map(lambda m: -rate * m, upd_src)
            return updates, {"mu": mu, "step": step}
        updates = jax.tree.map(lambda g: -rate * g, grads)
        return updates, {"step": step}

    return Optimizer(init, update)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        rate = _lr(lr, step)
        grads32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads32)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -(rate * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps))
            if weight_decay:
                u = u - rate * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    return adam(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)


def chain_clip(opt: Optimizer, max_norm: Optional[float]) -> Optimizer:
    if not max_norm:
        return opt

    def update(grads, state, params):
        return opt.update(clip_by_global_norm(grads, max_norm), state, params)

    return Optimizer(opt.init, update)


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
