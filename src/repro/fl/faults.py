"""Deterministic fault injection + compiled upload defenses.

Production fleets fail constantly: clients crash mid-round, return
NaN/Inf-poisoned factors, flip bits on the wire, mount byzantine
scale/sign attacks, or replay stale models. This module makes those
faults a *first-class, deterministic* part of the simulation and gives
the server compiled-path defenses against them.

Fault model (:class:`FaultPlan`)
  Every fault decision is a pure function of ``(seed, round, cohort
  position)`` drawn from the same ``np.random.SeedSequence`` discipline
  as :mod:`repro.fl.trace` (re-keyed per round under a private
  domain-separation tag, never a stateful stream), so the SAME faults
  hit the SAME clients in the sequential, batched and streaming engines
  — chaos runs stay replayable and the engine-parity contract survives
  fault injection. Kinds:

    crash       crash-before-upload: the client trains, then vanishes —
                zero aggregation weight, no state writeback, download
                bytes charged but no upload bytes.
    nan         NaN/Inf-poisoned factor upload (poison value drawn per
                client), applied to the payload BEFORE the codec.
    bitflip     bit-flips applied to the ENCODED int8 wire payload
                (``{"q", "scale"}`` nodes): random (index, bit) pairs
                XORed into each int8 ``q`` leaf. Codecs with no int8
                stage have no int8 wire, so the flip is a no-op there.
    byzantine   scale/sign attack: the upload's deviation from the
                round's broadcast is multiplied by a drawn factor in
                ``byzantine_scales`` (e.g. -1 = sign flip, 10 = blow-up).
    stale       the upload is replaced by the client's PREVIOUS
                broadcast version (the server's last decoded downlink)
                — a replayed round-old model.

Defenses (``ServerConfig.defense``, computed INSIDE the round program)
  gate        per-client validity gate: finite-check over the upload's
              factor leaves plus a per-layer upload-norm z-score
              against the statistics block (cohort for the
              sequential/batched engines, scan chunk for streaming —
              the cohort is never resident there). Rejected clients
              fold into the arrival/tier weighting as zero WEIGHT (and
              a sanitized zero payload so ``0 * NaN`` can never reach
              the accumulator), exactly like a straggler.
    clip      norm-clipped weighted mean: each client's deviation from
              the broadcast is scaled by ``min(1, tau / ||dev||)`` with
              ``tau = defense_clip x median candidate norm``. The scale
              is per-client and the aggregate stays LINEAR in the
              uploads, so it composes with the streaming engine's
              encoded-form fold (the clip scale multiplies the fold
              weight; the non-delta broadcast remainder is carried as a
              scalar slack term, see ``stream_engine``).
    trimmed   coordinate-wise trimmed mean (batched engine only — the
              trim needs every upload resident along the client axis;
              the streaming engine is statically rejected, see
              docs/robustness.md).

Everything here is jit-safe and vmap-compatible; the host-side draws
return plain numpy arrays the round programs consume as data, so
toggling fault rates per round never recompiles.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import comm

# domain-separation tag for the fault RNG streams (mixed into every
# SeedSequence entropy tuple, so fault draws never collide with the
# trace's (seed, round) streams or any RandomState(seed) consumer)
_FAULT_TAG = 0xFA0175EE
# recovery re-sampling gets its own tag: a retry's replacement cohort
# must not replay the fault stream
_RECOVER_TAG = 0x5EC0FE12

FAULT_KINDS: Tuple[str, ...] = ("crash", "nan", "bitflip", "byzantine",
                                "stale")


def recovery_rng(seed: int, round_idx: int, attempt: int
                 ) -> np.random.Generator:
    """The recovery policy's private per-(round, attempt) generator —
    re-keyed like ``FleetTrace.round_rng`` so replacement cohorts are
    replayable without any stateful stream."""
    return np.random.Generator(np.random.PCG64(np.random.SeedSequence(
        (int(seed), _RECOVER_TAG, int(round_idx), int(attempt)))))


@dataclass(frozen=True)
class FaultPlan:
    """Deterministic per-round fault schedule (see module docstring).

    Attributes:
        rate: per-sampled-client fault probability per round.
        kinds: the fault kinds to draw from (uniformly), a subset of
            :data:`FAULT_KINDS`.
        byzantine_scales: the deviation multipliers a byzantine client
            draws from.
        flip_bits: (index, bit) pairs XORed into each int8 wire leaf of
            a bit-flipped client.
        seed: fault-stream seed; every round re-keys from it.
    """

    rate: float = 0.0
    kinds: Tuple[str, ...] = FAULT_KINDS
    byzantine_scales: Tuple[float, ...] = (-1.0, -10.0, 10.0)
    flip_bits: int = 4
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1]: {self.rate}")
        bad = [k for k in self.kinds if k not in FAULT_KINDS]
        if bad:
            raise ValueError(
                f"unknown fault kind(s) {bad}; expected a subset of "
                f"{FAULT_KINDS}")
        if not self.kinds:
            raise ValueError("FaultPlan.kinds must name at least one kind")

    def round_rng(self, round_idx: int, attempt: int = 0
                  ) -> np.random.Generator:
        """The round's private fault generator, re-keyed per
        ``(seed, round[, attempt])`` — draws are independent of engine,
        chunking and of how many draws earlier rounds made."""
        entropy = (int(self.seed), _FAULT_TAG, int(round_idx))
        if attempt:
            entropy = entropy + (int(attempt),)
        return np.random.Generator(np.random.PCG64(
            np.random.SeedSequence(entropy)))

    def draw(self, round_idx: int, n: int, attempt: int = 0) -> Dict:
        """Host-side fault draw for one round's ``n`` sampled clients.

        ``attempt`` salts the stream: sync rounds use it for recovery
        retries, the async engine for the dispatch index within a
        version (each re-admission broadcast is a fresh cohort with its
        own fault draw). Crash-before-upload folds into the effective
        arrival mask on every path via
        :func:`repro.fl.arrivals.fold_crashes` — the sync engines zero
        the crashed clients' aggregation weights, the async engine
        never enqueues their arrival events, and both charge downlink
        only.

        Returns a dict of plain per-client numpy arrays (the round
        program consumes them as data — no recompile when the rate or
        the drawn set changes):

          kind       (n,) int8: index into :data:`FAULT_KINDS`, -1 clean
          crash      (n,) bool
          nan        (n,) float32 mask  } traced into the program
          poison     (n,) float32 (NaN or +/-Inf per poisoned client)
          byz        (n,) float32 deviation multiplier (1 = clean)
          stale      (n,) float32 mask
          flip       (n,) float32 mask
          flip_keys  (n, 2) uint32 per-client PRNG keys for the wire
                     bit positions
        """
        rng = self.round_rng(round_idx, attempt)
        hit = rng.random(n) < self.rate
        kind_draw = rng.integers(0, len(self.kinds), size=n)
        kind = np.full(n, -1, np.int8)
        for i, name in enumerate(self.kinds):
            kind[hit & (kind_draw == i)] = FAULT_KINDS.index(name)
        poison_pool = np.array([np.nan, np.inf, -np.inf], np.float32)
        poison = poison_pool[rng.integers(0, len(poison_pool), size=n)]
        byz_pool = np.asarray(self.byzantine_scales, np.float32)
        byz_draw = byz_pool[rng.integers(0, len(byz_pool), size=n)]
        flip_keys = rng.integers(0, 2 ** 32, size=(n, 2), dtype=np.uint32)
        is_kind = {k: kind == FAULT_KINDS.index(k) for k in FAULT_KINDS}
        return {
            "kind": kind,
            "crash": is_kind["crash"],
            "nan": is_kind["nan"].astype(np.float32),
            "poison": poison,
            "byz": np.where(is_kind["byzantine"], byz_draw,
                            np.float32(1.0)).astype(np.float32),
            "stale": is_kind["stale"].astype(np.float32),
            "flip": is_kind["bitflip"].astype(np.float32),
            "flip_keys": flip_keys,
        }

    def kind_counts(self, fault: Dict, mask) -> Dict[str, int]:
        """``{kind: count}`` over the round's ARRIVED clients (faults
        drawn for non-arrived clients never fired)."""
        m = np.asarray(mask).astype(bool)
        kind = np.asarray(fault["kind"])
        return {k: int(((kind == i) & m).sum())
                for i, k in enumerate(FAULT_KINDS)
                if int(((kind == i) & m).sum())}


def device_fault_args(fault: Optional[Dict]) -> Optional[Dict]:
    """The traced subset of a :meth:`FaultPlan.draw` dict (crash and
    kind stay host-side: crashes fold into the effective arrival mask
    before the program runs)."""
    if fault is None:
        return None
    return {
        "nan": jnp.asarray(fault["nan"], jnp.float32),
        "poison": jnp.asarray(fault["poison"], jnp.float32),
        "byz": jnp.asarray(fault["byz"], jnp.float32),
        "stale": jnp.asarray(fault["stale"], jnp.float32),
        "flip": jnp.asarray(fault["flip"], jnp.float32),
        "flip_keys": jnp.asarray(fault["flip_keys"], jnp.uint32),
    }


# ------------------------------------------------------------- injection
#
# All injection helpers are pure per-client functions: the batched
# engine vmaps them over the cohort axis, the streaming engine over each
# scan chunk, and the sequential reference calls them one client at a
# time — identical per-client inputs give bitwise-identical faulted
# uploads in all three.

def _bcast(flag, leaf):
    return jnp.reshape(flag, (1,) * leaf.ndim)


def poison_upload_one(upload: Any, ref: Any, stale_ref: Any, nan_on,
                      poison_val, byz_scale, stale_on) -> Any:
    """Pre-codec faults on ONE client's payload tree: stale replay,
    byzantine deviation scaling, NaN/Inf poisoning (in that order —
    a drawn client has exactly one kind, so order never matters)."""
    def one(u, r, s):
        u = jnp.where(_bcast(stale_on > 0, u), s.astype(u.dtype), u)
        # gate the byzantine rewrite so clean clients (scale 1) keep
        # their upload BIT-exactly (r + (u - r) would reassociate)
        u = jnp.where(_bcast(byz_scale != 1.0, u),
                      r + byz_scale * (u - r), u)
        return jnp.where(_bcast(nan_on > 0, u),
                         jnp.full_like(u, poison_val), u)

    return jax.tree.map(one, upload, ref, stale_ref)


def flip_wire_bits(wire: Any, flip_on, flip_key, n_bits: int) -> Any:
    """XOR ``n_bits`` drawn (index, bit) pairs into every int8 leaf of
    ONE client's encoded wire tree (``{"q", "scale"}`` q nodes). Leaves
    that are not int8 — fp16/fp32 carriers, scales — pass through: the
    fault models a corrupted int8 wire, and codecs without an int8
    stage simply have nothing to flip."""
    leaves, treedef = jax.tree_util.tree_flatten(wire)

    def one(i, leaf):
        if leaf.dtype != jnp.int8:
            return leaf
        key = jax.random.fold_in(flip_key, i)
        k_idx, k_bit = jax.random.split(key)
        flat = leaf.reshape(-1)
        idx = jax.random.randint(k_idx, (n_bits,), 0, flat.size)
        bit = jax.random.randint(k_bit, (n_bits,), 0, 8)
        xor = jnp.zeros_like(flat).at[idx].set(
            jnp.left_shift(jnp.ones((n_bits,), jnp.int8),
                           bit.astype(jnp.int8)))
        flipped = jnp.bitwise_xor(flat, xor).reshape(leaf.shape)
        return jnp.where(_bcast(flip_on > 0, leaf), flipped, leaf)

    return jax.tree_util.tree_unflatten(
        treedef, [one(i, lf) for i, lf in enumerate(leaves)])


# --------------------------------------------------------------- defenses

def linear_decode(codec, wire: Any) -> Any:
    """Decode an ``encode_for_agg`` wire tree through every stage except
    delta (the linear dequant the streaming accumulator applies): the
    defense gate's view of what a client actually uploaded."""
    if codec.is_identity:
        return wire
    from repro.fl.codecs import Codec

    stripped = Codec(spec=codec.spec, stages=tuple(
        s for s in codec.stages if s.kind != "delta"))
    return stripped.decode(wire)


def deviation_tree(decoded: Any, down_payload: Any, has_delta: bool) -> Any:
    """Per-client deviation from the round's broadcast, given the
    linear-decoded upload (stacked along a leading client axis). With a
    delta codec the linear form IS the deviation; otherwise subtract the
    broadcast."""
    if has_delta:
        return decoded
    return jax.tree.map(lambda u, r: u - r[None].astype(u.dtype),
                        decoded, down_payload)


def upload_stats(dev: Any) -> Tuple[jax.Array, jax.Array]:
    """Per-client gate statistics from the stacked deviation tree:
    ``(norms, finite)`` where ``norms`` is (C, L) per-layer L2 norms
    and ``finite`` is (C,) all-leaves-finite flags. Non-finite entries
    contribute a non-finite norm, which the gate masks out of the
    cohort statistics."""
    leaves = jax.tree.leaves(dev)
    per_leaf = [jnp.sqrt(jnp.sum(
        jnp.square(lf.astype(jnp.float32)),
        axis=tuple(range(1, lf.ndim)))) for lf in leaves]
    norms = jnp.stack(per_leaf, axis=1)
    finite = jnp.all(jnp.isfinite(norms), axis=1)
    return norms, finite


def validity_gate(norms: jax.Array, finite: jax.Array, cand: jax.Array,
                  z_thresh: float) -> jax.Array:
    """(C,) float validity: finite AND every per-layer norm within
    ``z_thresh`` sigmas of the candidate block's mean. Statistics are
    computed only over finite candidates, so one NaN client cannot
    poison the gate itself."""
    ok = cand * finite.astype(jnp.float32)
    n = jnp.maximum(ok.sum(), 1.0)
    safe = jnp.where(ok[:, None] > 0, norms, 0.0)
    mu = safe.sum(0) / n
    var = (jnp.where(ok[:, None] > 0, jnp.square(norms - mu[None]),
                     0.0).sum(0) / n)
    sd = jnp.sqrt(jnp.maximum(var, 0.0))
    z = jnp.abs(norms - mu[None]) / jnp.maximum(sd, 1e-6)
    # degenerate blocks (<= 3 candidates) have meaningless sigmas:
    # the z stage passes everyone and the finite check stands alone
    z_ok = jnp.where(n > 3.0, jnp.all(z <= z_thresh, axis=1), True)
    return finite.astype(jnp.float32) * z_ok.astype(jnp.float32)


def clip_scales(norms: jax.Array, valid: jax.Array, cand: jax.Array,
                clip_mult: float) -> jax.Array:
    """(C,) per-client clip scale ``min(1, tau / ||dev||)`` with ``tau
    = clip_mult x median valid-candidate total norm``. Per-client and
    scalar, so the clipped aggregate stays linear in the uploads (the
    streaming engine multiplies it into the fold weight)."""
    tot = jnp.sqrt(jnp.square(norms).sum(1))
    ok = cand * valid
    n = ok.sum()
    ranked = jnp.sort(jnp.where(ok > 0, tot, jnp.inf))
    med = ranked[jnp.clip((n.astype(jnp.int32) - 1) // 2, 0,
                          tot.shape[0] - 1)]
    tau = clip_mult * jnp.where(jnp.isfinite(med), med, 0.0)
    s = jnp.minimum(1.0, tau / jnp.maximum(tot, 1e-12))
    return jnp.where((ok > 0) & (n > 0), s, 1.0)


def sanitize_stacked(upload: Any, valid: jax.Array) -> Any:
    """Zero every rejected client's upload leaves (stacked trees). The
    rejected client already carries zero WEIGHT; zeroing the VALUES as
    well keeps ``0 * NaN`` out of the fp32 accumulators."""
    def one(u):
        keep = (valid > 0).reshape((-1,) + (1,) * (u.ndim - 1))
        return jnp.where(keep, u, jnp.zeros_like(u))

    return jax.tree.map(one, upload)


def apply_clip_stacked(upload: Any, down_payload: Any, scales: jax.Array
                       ) -> Any:
    """Dense-path clip: ``down + s_c * (u_c - down)`` per client over
    stacked decoded uploads (the batched/sequential engines' form of
    the same linear clip the streaming engine applies to its fold
    weights)."""
    def one(u, r):
        s = scales.reshape((-1,) + (1,) * (u.ndim - 1))
        rb = r[None].astype(u.dtype)
        return rb + s * (u - rb)

    return jax.tree.map(one, upload, down_payload)
