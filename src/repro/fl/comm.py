"""Communication accounting + payload selection + quantization.

The paper's cost metric: total bits = 2 × #participants × model_size ×
#rounds (up + down link). Payload selection implements FedPara
(factors transferred), pFedPara (only the global half x1/y1), FedPer
(all but the last layer), and FedPAQ-style quantized uplink.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.parameterization import tree_bytes  # dtype-aware; re-exported

PFEDPARA_LOCAL = ("x2", "y2")


# ------------------------------------------------------- payload selection

def split_pfedpara(params: Any) -> Tuple[Any, Any]:
    """(global_tree, local_tree): x2/y2 subtree leaves stay local, the
    rest (x1/y1, dense weights, biases, norms) is transferred.

    List/tuple nodes keep ``None`` placeholders at pruned positions so
    the two halves stay positionally aligned and ``merge_pfedpara`` can
    zip them back without dropping leaves."""
    def walk_local(node, keep_local: bool):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                sub = walk_local(v, keep_local or k in PFEDPARA_LOCAL)
                if sub is not None:
                    out[k] = sub
            return out or None
        if isinstance(node, (list, tuple)):
            subs = type(node)(walk_local(v, keep_local) for v in node)
            return subs if any(s is not None for s in subs) else None
        return node if keep_local else None

    def walk_global(node):
        if isinstance(node, dict):
            out = {k: walk_global(v) for k, v in node.items()
                   if k not in PFEDPARA_LOCAL}
            return {k: v for k, v in out.items() if v is not None} or None
        if isinstance(node, (list, tuple)):
            return type(node)(walk_global(v) for v in node)
        return node

    return walk_global(params), walk_local(params, False)


def merge_pfedpara(global_tree: Any, local_tree: Any) -> Any:
    """Inverse of split_pfedpara."""
    if isinstance(global_tree, dict) or isinstance(local_tree, dict):
        out = {}
        keys = set()
        if isinstance(global_tree, dict):
            keys |= set(global_tree)
        if isinstance(local_tree, dict):
            keys |= set(local_tree)
        for k in keys:
            g = global_tree.get(k) if isinstance(global_tree, dict) else None
            l = local_tree.get(k) if isinstance(local_tree, dict) else None
            if g is None:
                out[k] = l
            elif l is None:
                out[k] = g
            else:
                out[k] = merge_pfedpara(g, l)
        return out
    if isinstance(global_tree, (list, tuple)) and isinstance(local_tree, (list, tuple)):
        if len(global_tree) != len(local_tree):
            raise ValueError(
                "merge_pfedpara: misaligned sequence nodes "
                f"({len(global_tree)} vs {len(local_tree)} entries); "
                "split_pfedpara keeps None placeholders so halves must "
                "have equal length")
        return type(global_tree)(
            merge_pfedpara(g, l) for g, l in zip(global_tree, local_tree)
        )
    return global_tree if global_tree is not None else local_tree


# ------------------------------------------------------------ quantization

def quantize_fp16(tree: Any) -> Any:
    return jax.tree.map(lambda x: x.astype(jnp.float16), tree)


def dequantize_fp16(tree: Any) -> Any:
    return jax.tree.map(lambda x: x.astype(jnp.float32), tree)


def quantize_int8(tree: Any, key: jax.Array) -> Any:
    """Per-tensor symmetric int8 with stochastic rounding. The rounding
    noise is drawn in each leaf's own dtype so fp16/bf16 payloads are
    not silently upcast to fp32 by the uniform draw."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = []
    for x, k in zip(leaves, keys):
        noise_dtype = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
        scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
        y = x / scale
        noise = jax.random.uniform(k, x.shape, dtype=noise_dtype) - jnp.asarray(
            0.5, noise_dtype)
        q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
        out.append({"q": q, "scale": scale})
    return jax.tree_util.tree_unflatten(treedef, out)


def dequantize_int8(tree: Any) -> Any:
    def is_q(n):
        return isinstance(n, dict) and set(n) == {"q", "scale"}

    def walk(n):
        if is_q(n):
            return n["q"].astype(jnp.float32) * n["scale"]
        if isinstance(n, dict):
            return {k: walk(v) for k, v in n.items()}
        if isinstance(n, (list, tuple)):
            return type(n)(walk(v) for v in n)
        return n

    return walk(tree)


def _is_qnode(n: Any) -> bool:
    return isinstance(n, dict) and set(n) == {"q", "scale"}


def quantized_bytes(tree: Any, scheme: str) -> int:
    """Wire bytes of ``tree`` under ``scheme``. Already-quantized
    ``{"q", "scale"}`` subtrees are counted exactly (q at its stored
    itemsize + 4 bytes per scale) regardless of ``scheme``; plain
    trees are priced by the scheme as before."""
    qb, plain = 0, []

    def walk(n):
        nonlocal qb
        if _is_qnode(n):
            q, s = n["q"], n["scale"]
            qb += int(q.size) * q.dtype.itemsize + 4 * max(int(getattr(s, "size", 1)), 1)
            return
        if isinstance(n, dict):
            for v in n.values():
                walk(v)
            return
        if isinstance(n, (list, tuple)):
            for v in n:
                walk(v)
            return
        if hasattr(n, "size"):
            plain.append(n)

    walk(tree)
    n = sum(int(x.size) for x in plain)
    if scheme == "int8":
        return qb + n * 1 + 4 * len(plain)
    if scheme == "fp16":
        return qb + n * 2
    return qb + n * 4


def quantize_dequantize(tree: Any, scheme: str, key: Optional[jax.Array] = None) -> Any:
    """Simulate one up/down-link quantization round trip (jit-safe)."""
    if scheme == "int8":
        if key is None:
            key = jax.random.PRNGKey(0)
        return dequantize_int8(quantize_int8(tree, key))
    if scheme == "fp16":
        return dequantize_fp16(quantize_fp16(tree))
    return tree


# ------------------------------------------------------------ accounting

class CommLog:
    """Accumulates up/down-link wire bytes over an FL run (paper Fig. 3).

    Bytes are exact integers measured by the active codec's
    ``wire_bytes`` (see ``repro.fl.codecs``) — already summed over the
    round's participants — not scheme-priced dense trees."""

    def __init__(self):
        self.up_bytes = 0
        self.down_bytes = 0
        self.rounds = 0

    def log_round(self, down_bytes: int, up_bytes: int):
        """Accumulate one round's exact wire bytes (already summed over
        the round's arrived participants, per link)."""
        self.down_bytes += int(down_bytes)
        self.up_bytes += int(up_bytes)
        self.rounds += 1

    @property
    def total_gb(self) -> float:
        return (self.up_bytes + self.down_bytes) / 1e9
