"""FL strategies: FedAvg, FedProx, SCAFFOLD, FedDyn, FedAdam.

Each strategy contributes (a) an optional client-side loss modifier /
gradient correction and (b) a server aggregation rule. The paper shows
FedPara composes with all of them (Table 3) because it only changes the
layer parameterization.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp


def tree_mean(trees: List[Any], weights: Optional[List[float]] = None) -> Any:
    if weights is None:
        weights = [1.0 / len(trees)] * len(trees)
    total = sum(weights)
    weights = [w / total for w in weights]
    return jax.tree.map(lambda *xs: sum(w * x for w, x in zip(weights, xs)), *trees)


# ------------------------------------------------- stacked (client-axis) ops

def tree_stack(trees: List[Any]) -> Any:
    """Stack a list of identically-structured pytrees along a new leading
    client axis: leaves (..,) -> (C, ..)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def tree_unstack(tree: Any) -> List[Any]:
    """Inverse of ``tree_stack``: split the leading axis back into a list."""
    n = jax.tree.leaves(tree)[0].shape[0]
    return [tree_index(tree, i) for i in range(n)]


def tree_index(tree: Any, i) -> Any:
    """Slice client ``i`` out of a stacked tree (lazy: one gather per leaf)."""
    return jax.tree.map(lambda x: x[i], tree)


def tree_take(tree: Any, idx) -> Any:
    """Vectorized row gather out of a stacked tree: leaves ``(R, ..)``
    -> ``(len(idx), ..)``. The arena's cohort-gather primitive — one
    ``jnp.take`` per leaf instead of ``len(idx)`` ``tree_index`` calls."""
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), tree)


def tree_broadcast(tree: Any, n: int) -> Any:
    """Replicate a tree along a new leading client axis of size ``n``."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + jnp.shape(x)), tree)


def tree_wmean_stacked(stacked: Any, weights: jax.Array) -> Any:
    """Masked weighted mean over the leading client axis.

    ``weights`` is a (C,) float vector; masked-out clients carry weight 0,
    so this is the jit-safe replacement for aggregating an ``arrived``
    list — the mask IS the participation decision."""
    total = jnp.maximum(weights.sum(), 1e-12)
    wn = (weights / total).astype(jnp.float32)
    return jax.tree.map(
        lambda x: jnp.tensordot(wn, x.astype(jnp.float32), axes=1).astype(x.dtype),
        stacked)


def tree_hetero_wmean_stacked(stacked: Any, weights: jax.Array,
                              col_masks: Any, fallback: Any) -> Any:
    """Per-element arrival-weighted mean over the client axis with
    per-client rank masks (heterogeneous-capacity aggregation).

    Args:
        stacked: client-stacked upload tree, leaves ``(C, ...)``.
        weights: ``(C,)`` mask·weight vector (dropped clients carry 0).
        col_masks: per-client broadcastable 0/1 masks (leaves
            ``(C, 1, r)`` / ``(C, r, r, 1, 1)`` / ``(C, 1, ...)`` — see
            ``repro.core.parameterization.rank_mask_tree``): a client's
            columns beyond its tier rank get zero WEIGHT, not zero
            value.
        fallback: unstacked payload-structure tree supplying the value
            for elements no arrived client covers (the current global
            slice, so uncovered trailing columns simply persist).

    Returns:
        The element-wise weighted mean ``Σ_c w_c·m_c·x_c / Σ_c w_c·m_c``
        where covered, ``fallback`` elsewhere; leaf dtypes preserved.
        With all-ones masks this reduces to :func:`tree_wmean_stacked`
        to fp32 round-off.
    """
    wf = weights.astype(jnp.float32)

    def one(x, m, tgt):
        w = wf.reshape((-1,) + (1,) * (x.ndim - 1))
        mf = m.astype(jnp.float32)
        num = jnp.sum(w * mf * x.astype(jnp.float32), axis=0)
        den = jnp.sum(w * mf, axis=0)
        mean = jnp.where(den > 0, num / jnp.maximum(den, 1e-12),
                         tgt.astype(jnp.float32))
        return mean.astype(x.dtype)

    return jax.tree.map(one, stacked, col_masks, fallback)


def tree_trimmed_wmean_stacked(stacked: Any, weights: jax.Array,
                               col_masks: Any, fallback: Any,
                               trim: float) -> Any:
    """Coordinate-wise trimmed weighted mean over the client axis
    (robust aggregation, ``ServerConfig.defense='trimmed'``).

    Per coordinate, the ``floor(trim * n_members)`` highest and lowest
    values among member clients (positive weight, covered column) are
    dropped and the remainder weighted-averaged; coordinates with no
    surviving member fall back to ``fallback`` — the same uncovered-
    column semantics as :func:`tree_hetero_wmean_stacked`. Needs every
    upload resident along the client axis, which is why the streaming
    engine statically rejects this defense (see docs/robustness.md).

    Args:
        stacked: client-stacked upload tree, leaves ``(C, ...)``.
        weights: ``(C,)`` mask-weight vector (rejected clients carry 0).
        col_masks: per-client broadcastable 0/1 rank masks, or ``None``
            (homogeneous: every client covers every coordinate).
        fallback: unstacked payload-structure tree (current global).
        trim: fraction trimmed from EACH side, in [0, 0.5).
    """
    wf = weights.astype(jnp.float32)

    def one(x, m, tgt):
        w = wf.reshape((-1,) + (1,) * (x.ndim - 1))
        member = ((w > 0)
                  & (jnp.broadcast_to(m, x.shape) > 0)).astype(jnp.float32)
        n = member.sum(axis=0)
        k = jnp.floor(trim * n)
        xf = x.astype(jnp.float32)
        # per-coordinate rank among members: non-members sort to +inf
        # (never into the kept low band), argsort-of-argsort gives each
        # element its rank along the client axis
        keyed = jnp.where(member > 0, xf, jnp.inf)
        order = jnp.argsort(keyed, axis=0)
        rank = jnp.argsort(order, axis=0).astype(jnp.float32)
        keep = member * (rank >= k) * (rank < n - k)
        num = jnp.sum(w * keep * xf, axis=0)
        den = jnp.sum(w * keep, axis=0)
        mean = jnp.where(den > 0, num / jnp.maximum(den, 1e-12),
                         tgt.astype(jnp.float32))
        return mean.astype(x.dtype)

    if col_masks is None:
        col_masks = jax.tree.map(lambda x: jnp.ones((1,) * x.ndim,
                                                    jnp.float32), stacked)
    return jax.tree.map(one, stacked, col_masks, fallback)


def tree_sub(a: Any, b: Any) -> Any:
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_add(a: Any, b: Any, scale: float = 1.0) -> Any:
    return jax.tree.map(lambda x, y: x + scale * y, a, b)


def tree_zeros(a: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, a)


def tree_sqnorm(a: Any) -> jax.Array:
    return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(a))


def tree_dot(a: Any, b: Any) -> jax.Array:
    return sum(jnp.sum(x * y) for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@dataclass
class Strategy:
    """FL strategy. Server-side aggregation is expressed as
    ``server_update(server_state, global_params, mean_w)`` — a pure,
    jit-safe transform of the (already weighted/masked) client mean —
    so the sequential engine (list mean) and the batched engine
    (masked stacked weighted mean over the client axis) share the
    exact same server math. ``aggregate`` is the legacy list-based
    entry point, derived from ``server_update``."""

    name: str = "fedavg"
    # client loss modifier: fn(params, global_params, client_state) -> penalty
    client_penalty: Optional[Callable] = None
    # gradient correction: fn(grads, client_state) -> grads
    grad_correction: Optional[Callable] = None
    # server state init / aggregation
    server_init: Optional[Callable] = None
    # (server_state, global_params, mean_w) -> (new_global, new_server_state)
    server_update: Optional[Callable] = None
    aggregate: Optional[Callable] = None

    def __post_init__(self):
        if self.server_update is None:
            self.server_update = lambda st, gp, mean_w: (mean_w, st)
        if self.aggregate is None:
            def agg(server_state, global_params, client_params, weights):
                return self.server_update(server_state, global_params,
                                          tree_mean(client_params, weights))
            self.aggregate = agg


def fedavg() -> Strategy:
    return Strategy(name="fedavg")


def fedprox(mu: float = 0.1) -> Strategy:
    def penalty(params, global_params, _state):
        return 0.5 * mu * tree_sqnorm(tree_sub(params, global_params))

    return Strategy(name="fedprox", client_penalty=penalty)


def scaffold(lr_local: float = 0.1, local_steps_hint: int = 1) -> Strategy:
    """Option II control variates. client_state: {'c_i': tree, 'c': tree}
    (c broadcast from the server at download). Correction: g - c_i + c;
    the c_i update (Option II) happens client-side after local steps."""

    def correction(grads, client_state):
        return jax.tree.map(lambda g, ci, c: g - ci + c,
                            grads, client_state["c_i"], client_state["c"])

    return Strategy(name="scaffold", grad_correction=correction)


def feddyn(alpha: float = 0.1) -> Strategy:
    """Client: L(w) - <lambda_i, w> + alpha/2 ||w - w_g||^2 with
    lambda_i updated post-round; server keeps running h."""

    def penalty(params, global_params, client_state):
        lam = client_state["lambda_i"]
        return (-tree_dot(lam, params)
                + 0.5 * alpha * tree_sqnorm(tree_sub(params, global_params)))

    def server_init(params):
        return {"h": tree_zeros(params)}

    def update(server_state, global_params, mean_w):
        delta = tree_sub(mean_w, global_params)
        h = tree_add(server_state["h"], delta, scale=-alpha)
        new_global = tree_add(mean_w, h, scale=-1.0 / alpha)
        return new_global, {"h": h}

    return Strategy(name="feddyn", client_penalty=penalty,
                    server_init=server_init, server_update=update)


def fedadam(eta_g: float = 0.01, b1: float = 0.9, b2: float = 0.99,
            tau: float = 1e-3) -> Strategy:
    def server_init(params):
        return {"m": tree_zeros(params), "v": tree_zeros(params),
                "t": jnp.zeros((), jnp.int32)}

    def update(server_state, global_params, mean_w):
        delta = tree_sub(mean_w, global_params)
        m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d,
                         server_state["m"], delta)
        v = jax.tree.map(lambda v_, d: b2 * v_ + (1 - b2) * d * d,
                         server_state["v"], delta)
        new_global = jax.tree.map(
            lambda w, m_, v_: w + eta_g * m_ / (jnp.sqrt(v_) + tau),
            global_params, m, v)
        return new_global, {"m": m, "v": v, "t": server_state["t"] + 1}

    return Strategy(name="fedadam", server_init=server_init,
                    server_update=update)


def make_strategy(name: str, **kw) -> Strategy:
    """Build a named strategy: ``fedavg`` | ``fedprox`` (``mu``) |
    ``scaffold`` | ``feddyn`` (``alpha``) | ``fedadam`` (``eta_g``,
    ``b1``, ``b2``, ``tau``); ``kw`` forwards to its constructor."""
    return {
        "fedavg": fedavg,
        "fedprox": fedprox,
        "scaffold": scaffold,
        "feddyn": feddyn,
        "fedadam": fedadam,
    }[name](**kw)
