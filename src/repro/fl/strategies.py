"""FL strategies: FedAvg, FedProx, SCAFFOLD, FedDyn, FedAdam.

Each strategy contributes (a) an optional client-side loss modifier /
gradient correction and (b) a server aggregation rule. The paper shows
FedPara composes with all of them (Table 3) because it only changes the
layer parameterization.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


def tree_mean(trees: List[Any], weights: Optional[List[float]] = None) -> Any:
    if weights is None:
        weights = [1.0 / len(trees)] * len(trees)
    total = sum(weights)
    weights = [w / total for w in weights]
    return jax.tree.map(lambda *xs: sum(w * x for w, x in zip(weights, xs)), *trees)


def tree_sub(a: Any, b: Any) -> Any:
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_add(a: Any, b: Any, scale: float = 1.0) -> Any:
    return jax.tree.map(lambda x, y: x + scale * y, a, b)


def tree_zeros(a: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, a)


def tree_sqnorm(a: Any) -> jax.Array:
    return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(a))


def tree_dot(a: Any, b: Any) -> jax.Array:
    return sum(jnp.sum(x * y) for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@dataclass
class Strategy:
    name: str = "fedavg"
    # client loss modifier: fn(params, global_params, client_state) -> penalty
    client_penalty: Optional[Callable] = None
    # gradient correction: fn(grads, client_state) -> grads
    grad_correction: Optional[Callable] = None
    # server state init / aggregation
    server_init: Optional[Callable] = None
    aggregate: Optional[Callable] = None


def fedavg() -> Strategy:
    def agg(server_state, global_params, client_params, weights):
        return tree_mean(client_params, weights), server_state

    return Strategy(name="fedavg", aggregate=agg)


def fedprox(mu: float = 0.1) -> Strategy:
    def penalty(params, global_params, _state):
        return 0.5 * mu * tree_sqnorm(tree_sub(params, global_params))

    def agg(server_state, global_params, client_params, weights):
        return tree_mean(client_params, weights), server_state

    return Strategy(name="fedprox", client_penalty=penalty, aggregate=agg)


def scaffold(lr_local: float = 0.1, local_steps_hint: int = 1) -> Strategy:
    """Option II control variates. client_state: {'c_i': tree, 'c': tree}
    (c broadcast from the server at download). Correction: g - c_i + c;
    the c_i update (Option II) happens client-side after local steps."""

    def correction(grads, client_state):
        return jax.tree.map(lambda g, ci, c: g - ci + c,
                            grads, client_state["c_i"], client_state["c"])

    def agg(server_state, global_params, client_params, weights):
        return tree_mean(client_params, weights), server_state

    return Strategy(name="scaffold", grad_correction=correction, aggregate=agg)


def feddyn(alpha: float = 0.1) -> Strategy:
    """Client: L(w) - <lambda_i, w> + alpha/2 ||w - w_g||^2 with
    lambda_i updated post-round; server keeps running h."""

    def penalty(params, global_params, client_state):
        lam = client_state["lambda_i"]
        return (-tree_dot(lam, params)
                + 0.5 * alpha * tree_sqnorm(tree_sub(params, global_params)))

    def server_init(params):
        return {"h": tree_zeros(params)}

    def agg(server_state, global_params, client_params, weights):
        mean_w = tree_mean(client_params, weights)
        delta = tree_sub(mean_w, global_params)
        h = tree_add(server_state["h"], delta, scale=-alpha)
        new_global = tree_add(mean_w, h, scale=-1.0 / alpha)
        return new_global, {"h": h}

    return Strategy(name="feddyn", client_penalty=penalty,
                    server_init=server_init, aggregate=agg)


def fedadam(eta_g: float = 0.01, b1: float = 0.9, b2: float = 0.99,
            tau: float = 1e-3) -> Strategy:
    def server_init(params):
        return {"m": tree_zeros(params), "v": tree_zeros(params),
                "t": jnp.zeros((), jnp.int32)}

    def agg(server_state, global_params, client_params, weights):
        delta = tree_sub(tree_mean(client_params, weights), global_params)
        m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d,
                         server_state["m"], delta)
        v = jax.tree.map(lambda v_, d: b2 * v_ + (1 - b2) * d * d,
                         server_state["v"], delta)
        new_global = jax.tree.map(
            lambda w, m_, v_: w + eta_g * m_ / (jnp.sqrt(v_) + tau),
            global_params, m, v)
        return new_global, {"m": m, "v": v, "t": server_state["t"] + 1}

    return Strategy(name="fedadam", server_init=server_init, aggregate=agg)


def make_strategy(name: str, **kw) -> Strategy:
    return {
        "fedavg": fedavg,
        "fedprox": fedprox,
        "scaffold": scaffold,
        "feddyn": feddyn,
        "fedadam": fedadam,
    }[name](**kw)
