"""Device-resident per-client state arena: gather-at-sample,
scatter-at-arrival.

The dict-based server keeps every client's strategy state (SCAFFOLD
``c_i``, FedDyn ``lambda_i``), codec error-feedback accumulators
(``"_ef_up"``) and personalization residents in host-side Python dicts
(``FLServer.client_states`` / ``local_trees``), and writes arrivals
back with a per-client ``tree_index`` loop — O(C) host objects and
O(cohort) Python-loop dispatches per round. :class:`ClientArena`
replaces both with **index-addressed stacked device arrays**:

  * every per-client tree lives once, stacked along a leading row axis
    of ``R = clients + 1`` rows (row ``clients`` is a scratch row that
    absorbs the streaming engine's pad-slot writebacks, so duplicate
    pad indices scatter the same value and stay deterministic);
  * round start is ONE vectorized ``jnp.take`` over the cohort's rows
    (:meth:`gather`), round end is ONE masked ``.at[rows].set``
    (:meth:`scatter`) — non-arrived clients keep their previous rows
    bit-exactly because the scatter writes ``where(mask, new, old)``;
  * the scatter donates the arena buffers (``donate_argnums``), so XLA
    updates the fleet state in place instead of double-buffering the
    O(C)-sized arrays;
  * on a ``("clients",)`` mesh the row axis is sharded across devices
    (:meth:`shard_rows`), putting each device in charge of a fleet
    shard.

Rows are initialized from a single template (strategy init state is
zeros / constants; residents start at the global init), which matches
the dict engines' lazy first-participation init exactly — a client's
row is bit-identical to what ``FLServer._prep_client_state`` would have
built the first time it was sampled. Participation counts ride along as
an int32 row vector bumped by the same arrival mask.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def _gather_rows(tree: Any, rows: jax.Array) -> Any:
    from repro.fl.strategies import tree_take

    return tree_take(tree, rows)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(tree: Any, rows: jax.Array, new: Any,
                  mask: jax.Array) -> Any:
    def one(a, n):
        keep = (mask > 0).reshape((-1,) + (1,) * (n.ndim - 1))
        return a.at[rows].set(jnp.where(keep, n.astype(a.dtype), a[rows]))

    return jax.tree.map(one, tree, new)


@functools.partial(jax.jit, donate_argnums=(0,))
def _bump_rows(counts: jax.Array, rows: jax.Array,
               mask: jax.Array) -> jax.Array:
    return counts.at[rows].add((mask > 0).astype(counts.dtype))


@functools.partial(jax.jit, donate_argnums=(0,))
def _pin_rows(versions: jax.Array, rows: jax.Array, mask: jax.Array,
              version: jax.Array) -> jax.Array:
    keep = mask > 0
    return versions.at[rows].set(
        jnp.where(keep, jnp.asarray(version, versions.dtype),
                  versions[rows]))


class ClientArena:
    """Stacked device-resident per-client state (see module docstring).

    Build with :meth:`create`; address with :meth:`rows_for` (appends
    the scratch row for streaming pad slots); move cohorts on and off
    with :meth:`gather` / :meth:`scatter`.
    """

    def __init__(self, n_clients: int, state: Any, residents: Any,
                 participation: jax.Array, versions: Any = None):
        self.n_clients = int(n_clients)
        self.scratch_row = int(n_clients)   # absorbs pad-slot scatters
        self.state = state                  # dict tree, leaves (R, ...)
        self.residents = residents          # tree or None, leaves (R, ...)
        self.participation = participation  # (R,) int32
        # broadcast-version pinning (async engine, docs/async.md): the
        # global version each row's state was produced against — the
        # row's EF accumulator / delta reference / strategy state are
        # KEYED by this version; -1 = never dispatched
        self.versions = (versions if versions is not None
                         else jnp.full(participation.shape, -1, jnp.int32))

    @classmethod
    def create(cls, n_clients: int, state_template: Any,
               resident_template: Any = None) -> "ClientArena":
        """Allocate ``n_clients + 1`` rows, every row a copy of the
        templates (strategy-init state / global-init residents): the
        vectorized equivalent of the dict engines' lazy per-client
        first-participation init."""
        rows = int(n_clients) + 1

        def stackify(tree):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(
                    jnp.asarray(x)[None], (rows,) + jnp.shape(x)) + 0,
                tree)

        return cls(n_clients,
                   stackify(state_template if state_template else {}),
                   stackify(resident_template)
                   if resident_template is not None else None,
                   jnp.zeros((rows,), jnp.int32))

    # ---------------------------------------------------------- addressing
    def rows_for(self, cids, pad: int = 0) -> jax.Array:
        """Row indices for a cohort, with ``pad`` trailing scratch-row
        slots (the streaming engine's chunk padding): every pad slot
        maps to the SAME scratch row, so the masked scatter writes it
        one identical value — duplicate-index order never matters."""
        rows = np.asarray(cids, np.int32)
        if pad:
            rows = np.concatenate(
                [rows, np.full(pad, self.scratch_row, np.int32)])
        return jnp.asarray(rows)

    # ------------------------------------------------------ gather/scatter
    def gather(self, rows: jax.Array) -> Tuple[Any, Any]:
        """One vectorized row gather: ``(state_chunk, resident_chunk)``
        stacked along the cohort axis (resident half ``None`` when the
        arena holds no residents)."""
        state = _gather_rows(self.state, rows)
        residents = (_gather_rows(self.residents, rows)
                     if self.residents is not None else None)
        return state, residents

    def scatter(self, rows: jax.Array, new_state: Any, new_residents: Any,
                arrived_mask) -> None:
        """One masked row scatter: arrived rows take the new values,
        everyone else (including the scratch row's pad slots) keeps the
        old row bit-exactly. Donates the arena buffers — the fleet
        arrays update in place. Also bumps the participation counters."""
        mask = jnp.asarray(arrived_mask, jnp.float32)
        if new_state:
            new_state = {k: v for k, v in new_state.items()
                         if k in self.state}
            self.state = {**self.state,
                          **_scatter_rows(
                              {k: self.state[k] for k in new_state},
                              rows, new_state, mask)}
        if new_residents is not None and self.residents is not None:
            self.residents = _scatter_rows(self.residents, rows,
                                           new_residents, mask)
        self.participation = _bump_rows(self.participation, rows, mask)

    def pin_versions(self, rows: jax.Array, version: int,
                     arrived_mask) -> None:
        """Record the broadcast version the masked rows' new state was
        trained against (one masked ``.at[].set`` — the async engine
        calls this alongside :meth:`scatter` at dispatch writeback)."""
        self.versions = _pin_rows(self.versions, rows,
                                  jnp.asarray(arrived_mask, jnp.float32),
                                  jnp.int32(int(version)))

    # ------------------------------------------------------------ sharding
    def shard_rows(self, mesh, axis: str = "clients") -> None:
        """Shard every arena leaf's row axis over ``mesh[axis]`` (no-op
        unless the row count divides evenly — the scratch row makes
        ``clients + 1`` rows, so pick fleets accordingly when sharding)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        if mesh is None or axis not in mesh.axis_names:
            return
        if (self.n_clients + 1) % mesh.shape[axis]:
            return
        sharding = NamedSharding(mesh, P(axis))

        def put(tree):
            return jax.tree.map(lambda a: jax.device_put(a, sharding), tree)

        self.state = put(self.state)
        if self.residents is not None:
            self.residents = put(self.residents)
        self.participation = jax.device_put(self.participation, sharding)
        self.versions = jax.device_put(self.versions, sharding)

    # ------------------------------------------------------------- readout
    def client_state(self, cid: int) -> Any:
        """One client's state row as host arrays (test/debug readout —
        the training path never unstacks rows)."""
        return jax.tree.map(lambda a: np.asarray(a[int(cid)]), self.state)

    def client_resident(self, cid: int) -> Any:
        """One client's personalization-resident row as host arrays
        (``None`` when the mode keeps no residents)."""
        if self.residents is None:
            return None
        return jax.tree.map(lambda a: np.asarray(a[int(cid)]),
                            self.residents)

    def participation_counts(self) -> np.ndarray:
        """(clients,) int array: rounds each client arrived in (the
        scratch row is excluded)."""
        return np.asarray(self.participation)[: self.n_clients]

    def client_versions(self) -> np.ndarray:
        """(clients,) int array: the pinned broadcast version of each
        row's state (-1 = never dispatched; scratch row excluded)."""
        return np.asarray(self.versions)[: self.n_clients]
