"""Client-batched FL round engine: vmap/shard_map over a client axis.

The sequential reference in ``repro.fl.server`` runs each sampled
client's local epochs in a Python loop — round wall-clock scales
linearly with participation and every local step pays a dispatch.
This engine stacks the sampled clients' params / optimizer / strategy
state along a leading **client axis** and runs the whole round as ONE
jit-compiled program:

  1. ``lax.scan`` over local steps (per client), with a float step mask
     turning padded steps into no-ops (params carried through
     unchanged), so clients with different local-step counts share one
     fixed-shape program;
  2. ``jax.vmap`` over the client axis (single host), or
     ``shard_map`` over a named mesh axis (multi-device) with the vmap
     applied to each device's client shard;
  3. payload selection (none / pfedpara / fedper / local) as pure tree
     restructuring on the stacked tree;
  4. per-client uplink codec encode/decode (``repro.fl.codecs``: delta
     vs the round's decoded broadcast, top-k with client-stacked
     error-feedback accumulators riding in ``stacked_state["_ef_up"]``,
     low-rank delta truncation, int8/fp16 quantization) vmapped over
     the client axis with per-client RNG keys;
  5. masked weighted tree-reduce over the client axis (the
     arrived-mask replaces the sequential engine's ``arrived`` list)
     followed by the strategy's ``server_update``.

Numerical contract: with the same round selection (mask, seeds, keys)
the engine matches the sequential reference to fp32 tolerance; the
aggregation mask itself is bitwise identical because both engines
derive it from the same host-side RNG draws (``FLServer._select_round``).

Pallas interplay: when the model's loss runs the fused differentiable
fedpara_matmul (``ParamCfg(use_pallas=True)``), the client-axis
``jax.vmap`` here batches its custom-VJP forward/backward Pallas calls
through Pallas' batching rule — the mapped client axis folds into a
leading grid dimension, so each layer's compose+matmul (and each of its
three backward kernels) is ONE kernel launch for the whole client
batch, not C sequential launches.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.fl import comm
from repro.fl.codecs import Codec, make_codec
from repro.fl.client import ClientConfig, _step_math, strategy_post
from repro.fl.strategies import (
    Strategy,
    tree_index,
    tree_stack,
    tree_wmean_stacked,
    tree_zeros,
)


def _tree_where(cond, a, b):
    return jax.tree.map(lambda x, y: jnp.where(cond, x, y), a, b)


def batched_local_update(
    stacked_params: Any,
    stacked_state: Dict,
    batches: Dict[str, jax.Array],
    step_mask: jax.Array,
    loss_fn: Callable,
    cfg: ClientConfig,
    strategy_name: str,
    lr,
    mesh: Optional[Mesh] = None,
    axis: str = "clients",
):
    """Run every stacked client's local epochs at once.

    ``stacked_params`` / ``stacked_state`` leaves are ``(C, ...)``;
    ``batches`` leaves are ``(C, S, B, ...)``; ``step_mask`` is
    ``(C, S)`` float32. Returns ``(new_params, new_state, last_loss,
    n_steps)`` all stacked along the client axis. A masked step feeds a
    padding batch through the exact same step math and then discards
    the result, so real steps are bit-identical to the unmasked case.
    """

    def one_client(params0, state, cbatches, cmask):
        mu0 = tree_zeros(params0)

        def step(carry, xs):
            p, mu, last = carry
            b, m = xs
            new_p, new_mu, loss = _step_math(
                p, mu, b, params0, state, loss_fn, strategy_name,
                lr, cfg.momentum, cfg.weight_decay)
            on = m > 0
            p = _tree_where(on, new_p, p)
            mu = _tree_where(on, new_mu, mu)
            last = jnp.where(on, loss.astype(jnp.float32), last)
            return (p, mu, last), None

        (p, _, last), _ = jax.lax.scan(
            step, (params0, mu0, jnp.zeros((), jnp.float32)),
            (cbatches, cmask))
        n = cmask.sum()
        state = strategy_post(strategy_name, state, params0, p, n, lr)
        return p, state, last, n

    f = jax.vmap(one_client)
    if mesh is not None and axis in mesh.axis_names:
        C = step_mask.shape[0]
        if C % mesh.shape[axis] == 0:
            from repro.distributed.collectives import shard_map

            spec = P(axis)
            f = shard_map(
                jax.vmap(one_client), mesh=mesh,
                in_specs=(spec, spec, spec, spec),
                out_specs=(spec, spec, spec, spec),
                check_rep=False)
        else:
            import warnings

            warnings.warn(
                f"client batch of {C} not divisible by mesh axis "
                f"'{axis}' ({mesh.shape[axis]} devices); falling back "
                "to single-device vmap for this round")
    return f(stacked_params, stacked_state, batches, step_mask)


def batched_personalized_eval(stacked_params: Any, eval_data: Dict,
                              metric_fn: Callable) -> jax.Array:
    """Batched replacement for the per-client eval sweep: vmap
    ``metric_fn(params, batch) -> scalar`` over the client axis.
    ``eval_data`` leaves are ``(C, n, ...)`` per-client eval batches."""
    return jax.vmap(metric_fn)(stacked_params, eval_data)


@dataclass
class ClientBatch:
    """The jit-compiled round program, configured once per server.

    ``run`` executes local updates, payload selection, per-client
    quantization, masked aggregation, and the strategy server update as
    a single XLA program. Recompiles only when the (C, S, B) shape
    signature changes.
    """

    loss_fn: Callable
    strategy: Strategy
    client_cfg: ClientConfig
    personalization: str = "none"
    uplink_codec: Optional[Codec] = None
    fedper_local_keys: Tuple[str, ...] = ()
    mesh: Optional[Mesh] = None
    mesh_axis: str = "clients"

    def __post_init__(self):
        if self.uplink_codec is None:
            self.uplink_codec = make_codec("")
        self._program = jax.jit(self._round_program)

    # ----------------------------------------------------- payload select
    def _select_upload(self, stacked_params):
        """(upload, local) stacked trees per personalization mode."""
        mode = self.personalization
        if mode == "pfedpara":
            return comm.split_pfedpara(stacked_params)
        if mode == "fedper":
            up = {k: v for k, v in stacked_params.items()
                  if k not in self.fedper_local_keys}
            loc = {k: v for k, v in stacked_params.items()
                   if k in self.fedper_local_keys}
            return up, loc
        if mode == "local":
            return None, stacked_params
        return stacked_params, None

    # ------------------------------------------------------- the program
    def _round_program(self, stacked_params, stacked_state, batches,
                       step_mask, arrived_mask, sizes, lr, quant_keys,
                       server_state, agg_target, down_payload):
        new_p, new_state, last_loss, n_steps = batched_local_update(
            stacked_params, stacked_state, batches, step_mask,
            self.loss_fn, self.client_cfg, self.strategy.name, lr,
            mesh=self.mesh, axis=self.mesh_axis)

        upload, local = self._select_upload(new_p)
        codec = self.uplink_codec
        if upload is not None and not codec.is_identity:
            # per-client encode/decode: delta against the round's decoded
            # broadcast (closure => broadcast under vmap), error feedback
            # threaded through the stacked client state
            if codec.has_ef:
                upload, new_ef = jax.vmap(
                    lambda u, e, k: codec.encode_decode(
                        u, ref=down_payload, ef=e, key=k)
                )(upload, new_state["_ef_up"], quant_keys)
                new_state = {**new_state, "_ef_up": new_ef}
            else:
                upload, _ = jax.vmap(
                    lambda u, k: codec.encode_decode(
                        u, ref=down_payload, key=k)
                )(upload, quant_keys)

        if upload is not None:
            w = arrived_mask * sizes
            mean_w = tree_wmean_stacked(upload, w)
            new_global, new_server_state = self.strategy.server_update(
                server_state, agg_target, mean_w)
        else:
            new_global, new_server_state = agg_target, server_state
        return (new_p, new_state, upload, local, last_loss, n_steps,
                new_global, new_server_state)

    def run(self, stacked_params, stacked_state, batches, step_mask,
            arrived_mask, sizes, lr, quant_keys, server_state, agg_target,
            down_payload):
        return self._program(
            stacked_params, stacked_state,
            jax.tree.map(jnp.asarray, batches), jnp.asarray(step_mask),
            jnp.asarray(arrived_mask, jnp.float32),
            jnp.asarray(sizes, jnp.float32),
            jnp.asarray(lr, jnp.float32), quant_keys,
            server_state, agg_target, down_payload)
