"""Client-batched FL round engine: vmap/shard_map over a client axis.

The sequential reference in ``repro.fl.server`` runs each sampled
client's local epochs in a Python loop — round wall-clock scales
linearly with participation and every local step pays a dispatch.
This engine stacks the sampled clients' params / optimizer / strategy
state along a leading **client axis** and runs the whole round as ONE
jit-compiled program:

  1. ``lax.scan`` over local steps (per client), with a float step mask
     turning padded steps into no-ops (params carried through
     unchanged), so clients with different local-step counts share one
     fixed-shape program;
  2. ``jax.vmap`` over the client axis (single host), or
     ``shard_map`` over a named mesh axis (multi-device) with the vmap
     applied to each device's client shard;
  3. payload selection (none / pfedpara / fedper / local) as pure tree
     restructuring on the stacked tree;
  4. per-client uplink codec encode/decode (``repro.fl.codecs``: delta
     vs the round's decoded broadcast, top-k with client-stacked
     error-feedback accumulators riding in ``stacked_state["_ef_up"]``,
     low-rank delta truncation, int8/fp16 quantization) vmapped over
     the client axis with per-client RNG keys;
  5. masked weighted tree-reduce over the client axis (the
     arrived-mask replaces the sequential engine's ``arrived`` list)
     followed by the strategy's ``server_update``.

Numerical contract: with the same round selection (mask, seeds, keys)
the engine matches the sequential reference to fp32 tolerance; the
aggregation mask itself is bitwise identical because both engines
derive it from the same host-side RNG draws (``FLServer._select_round``).

Pallas interplay: when the model's loss runs the fused differentiable
fedpara_matmul (``ParamCfg(use_pallas=True)``), the client-axis
``jax.vmap`` here batches its custom-VJP forward/backward Pallas calls
through Pallas' batching rule — the mapped client axis folds into a
leading grid dimension, so each layer's compose+matmul (and each of its
three backward kernels) is ONE kernel launch for the whole client
batch, not C sequential launches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.parameterization import apply_rank_mask
from repro.fl import comm
from repro.fl import faults as faults_lib
from repro.fl.codecs import Codec, make_codec
from repro.fl.client import ClientConfig, _step_math, strategy_post
from repro.fl.strategies import (
    Strategy, tree_hetero_wmean_stacked, tree_trimmed_wmean_stacked,
    tree_wmean_stacked, tree_zeros)


def _tree_where(cond, a, b):
    return jax.tree.map(lambda x, y: jnp.where(cond, x, y), a, b)


def batched_local_update(
    stacked_params: Any,
    stacked_state: Dict,
    batches: Dict[str, jax.Array],
    step_mask: jax.Array,
    loss_fn: Callable,
    cfg: ClientConfig,
    strategy_name: str,
    lr,
    mesh: Optional[Mesh] = None,
    axis: str = "clients",
):
    """Run every stacked client's local epochs at once.

    ``stacked_params`` / ``stacked_state`` leaves are ``(C, ...)``;
    ``batches`` leaves are ``(C, S, B, ...)``; ``step_mask`` is
    ``(C, S)`` float32. Returns ``(new_params, new_state, last_loss,
    n_steps)`` all stacked along the client axis. A masked step feeds a
    padding batch through the exact same step math and then discards
    the result, so real steps are bit-identical to the unmasked case.
    """

    def one_client(params0, state, cbatches, cmask):
        mu0 = tree_zeros(params0)

        def step(carry, xs):
            p, mu, last = carry
            b, m = xs
            new_p, new_mu, loss = _step_math(
                p, mu, b, params0, state, loss_fn, strategy_name,
                lr, cfg.momentum, cfg.weight_decay)
            on = m > 0
            p = _tree_where(on, new_p, p)
            mu = _tree_where(on, new_mu, mu)
            last = jnp.where(on, loss.astype(jnp.float32), last)
            return (p, mu, last), None

        (p, _, last), _ = jax.lax.scan(
            step, (params0, mu0, jnp.zeros((), jnp.float32)),
            (cbatches, cmask))
        n = cmask.sum()
        state = strategy_post(strategy_name, state, params0, p, n, lr)
        return p, state, last, n

    f = jax.vmap(one_client)
    if mesh is not None and axis in mesh.axis_names:
        from repro.distributed.collectives import shard_map

        C = step_mask.shape[0]
        ndev = mesh.shape[axis]
        pad = -C % ndev
        spec = P(axis)
        f_sharded = shard_map(
            jax.vmap(one_client), mesh=mesh,
            in_specs=(spec, spec, spec, spec),
            out_specs=(spec, spec, spec, spec),
            check_rep=False)
        if pad == 0:
            f = f_sharded
        else:
            # Keep the shard_map path for any C: pad the client batch
            # with masked dummies (client 0 replicated, step_mask 0 so
            # every padded step is a discarded no-op) and slice the
            # results back. The pad rows go through the identical step
            # math, so real clients stay bit-identical to the unpadded
            # run.
            def pad_tree(t):
                return jax.tree.map(
                    lambda x: jnp.concatenate(
                        [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])], 0),
                    t)

            def f(sp, ss, bt, sm):
                sm_p = jnp.concatenate(
                    [sm, jnp.zeros((pad,) + sm.shape[1:], sm.dtype)], 0)
                outs = f_sharded(pad_tree(sp), pad_tree(ss), pad_tree(bt),
                                 sm_p)
                return jax.tree.map(lambda x: x[:C], outs)
    return f(stacked_params, stacked_state, batches, step_mask)


def batched_personalized_eval(stacked_params: Any, eval_data: Dict,
                              metric_fn: Callable) -> jax.Array:
    """Batched replacement for the per-client eval sweep: vmap
    ``metric_fn(params, batch) -> scalar`` over the client axis.
    ``eval_data`` leaves are ``(C, n, ...)`` per-client eval batches."""
    return jax.vmap(metric_fn)(stacked_params, eval_data)


def assemble_client_params(down_payload: Any, residents: Any, n: int,
                           personalization: str,
                           fedper_local_keys: Tuple[str, ...] = ()):
    """Stacked ``(n, model)`` client params from the round's single
    decoded broadcast plus client-stacked personalization residents —
    the inverse of :func:`select_upload`, vectorized over the client
    axis. Shared by the streaming scan step (chunk assembly) and the
    arena server path (cohort assembly from gathered resident rows);
    with ``personalization="none"`` it is a pure broadcast and the
    residents argument is ignored."""
    from repro.fl.strategies import tree_broadcast

    if personalization == "none":
        return tree_broadcast(down_payload, n)
    if personalization == "pfedpara":
        return comm.merge_pfedpara(tree_broadcast(down_payload, n),
                                   residents)
    if personalization == "fedper":
        merged = dict(tree_broadcast(down_payload, n))
        merged.update(residents)
        return merged
    # "local": residents are the full per-client params
    return residents


def select_upload(stacked_params: Any, personalization: str,
                  fedper_local_keys: Tuple[str, ...] = ()):
    """(upload, local) stacked trees per personalization mode."""
    if personalization == "pfedpara":
        return comm.split_pfedpara(stacked_params)
    if personalization == "fedper":
        up = {k: v for k, v in stacked_params.items()
              if k not in fedper_local_keys}
        loc = {k: v for k, v in stacked_params.items()
               if k in fedper_local_keys}
        return up, loc
    if personalization == "local":
        return None, stacked_params
    return stacked_params, None


def chunk_round_program(
    stacked_params: Any,
    stacked_state: Dict,
    batches: Dict[str, jax.Array],
    step_mask: jax.Array,
    quant_keys: jax.Array,
    down_payload: Any,
    *,
    loss_fn: Callable,
    client_cfg: ClientConfig,
    strategy_name: str,
    personalization: str,
    fedper_local_keys: Tuple[str, ...],
    uplink_codec: Codec,
    lr,
    mesh: Optional[Mesh] = None,
    axis: str = "clients",
    encoded_upload: bool = False,
    col_masks: Any = None,
    fault: Any = None,
    stale_ref: Any = None,
    flip_bits: int = 4,
):
    """One chunk of clients: local epochs, payload selection, per-client
    uplink encoding. The shared core of the batched engine's round
    program (chunk = the whole sampled cohort) and of every streaming
    scan step (chunk = ``ServerConfig.client_chunk`` clients).

    With ``encoded_upload=False`` uploads come back DECODED (the batched
    engine weighted-means them densely). With ``encoded_upload=True``
    uploads stay in the codec's encoded-for-aggregation form
    (``Codec.encode_for_agg``: int8 ``{"q", "scale"}`` nodes / dense
    linear carriers, delta offset left to the aggregator) so the
    streaming accumulator can fold them in with the fused
    dequant-accumulate kernel without ever materializing the dense
    stack.

    ``col_masks`` (heterogeneous rank tiers): a client-stacked
    payload-structure tree of broadcastable 0/1 rank masks. When given,
    each client's upload is column-masked to its tier rank BEFORE the
    codec sees it, and the codec's delta reference becomes the
    equally-masked broadcast — exactly what a client that only ever
    received the leading tier-rank factor columns would transmit. With
    ``col_masks=None`` the homogeneous path below is byte-identical to
    before.

    ``fault`` (chaos injection, see ``repro.fl.faults``): the traced
    per-client arrays of :func:`repro.fl.faults.device_fault_args`.
    Stale-replay / byzantine-scaling / NaN-poison corruption hits the
    payload BEFORE the codec (exactly what a faulty client would
    transmit); bit-flips hit the ENCODED int8 wire between encode and
    decode. ``stale_ref`` is the server's previous decoded broadcast
    (the model a stale client replays). With ``fault=None`` the clean
    path below is byte-identical to before.

    Returns ``(new_params, new_state, upload, local, last_loss,
    n_steps)``, all stacked along the chunk's client axis.
    """
    new_p, new_state, last_loss, n_steps = batched_local_update(
        stacked_params, stacked_state, batches, step_mask,
        loss_fn, client_cfg, strategy_name, lr, mesh=mesh, axis=axis)

    upload, local = select_upload(new_p, personalization, fedper_local_keys)
    codec = uplink_codec
    if upload is not None and col_masks is not None:
        # tier-sliced uplink: zero columns stand in for absent ones
        # (they carry zero aggregation WEIGHT downstream, not zero value)
        upload = apply_rank_mask(upload, col_masks)
    if upload is not None and fault is not None:
        # pre-codec corruption: stale replay / byzantine deviation
        # scaling / NaN poisoning, per client (the wire then carries the
        # corrupted factors exactly as a faulty client would send them)
        sref = down_payload if stale_ref is None else stale_ref

        def poison_one(u, nan_on, pv, byz, st, m=None):
            r, s = down_payload, sref
            if m is not None:
                r = apply_rank_mask(r, m)
                s = apply_rank_mask(s, m)
            return faults_lib.poison_upload_one(u, r, s, nan_on, pv, byz, st)

        if col_masks is not None:
            upload = jax.vmap(poison_one)(
                upload, fault["nan"], fault["poison"], fault["byz"],
                fault["stale"], col_masks)
        else:
            upload = jax.vmap(
                lambda u, a, p, b, s: poison_one(u, a, p, b, s)
            )(upload, fault["nan"], fault["poison"], fault["byz"],
              fault["stale"])
    if upload is not None and not codec.is_identity:
        # per-client encode: delta against the round's decoded broadcast
        # (closure => broadcast under vmap), error feedback threaded
        # through the stacked client state
        if fault is None:
            enc = (codec.encode_for_agg if encoded_upload
                   else codec.encode_decode)
            if col_masks is not None:
                def enc_masked(u, m, e, k):
                    return enc(u, ref=apply_rank_mask(down_payload, m),
                               ef=e, key=k)

                if codec.has_ef:
                    upload, new_ef = jax.vmap(enc_masked)(
                        upload, col_masks, new_state["_ef_up"], quant_keys)
                    new_state = {**new_state, "_ef_up": new_ef}
                else:
                    upload, _ = jax.vmap(
                        lambda u, m, k: enc_masked(u, m, None, k)
                    )(upload, col_masks, quant_keys)
            elif codec.has_ef:
                upload, new_ef = jax.vmap(
                    lambda u, e, k: enc(u, ref=down_payload, ef=e, key=k)
                )(upload, new_state["_ef_up"], quant_keys)
                new_state = {**new_state, "_ef_up": new_ef}
            else:
                upload, _ = jax.vmap(
                    lambda u, k: enc(u, ref=down_payload, key=k)
                )(upload, quant_keys)
        else:
            # faulted path: the round trip is opened up so wire bit-flips
            # land on the ENCODED int8 payload, then the usual decode /
            # agg-form recovery runs on the corrupted wire. EF state is
            # taken from encode (client-side, before the wire corrupts).
            def enc_faulted(u, ref, e, k, fl, fk):
                wire, new_e = codec.encode(u, ref=ref, ef=e, key=k)
                wire = faults_lib.flip_wire_bits(wire, fl, fk, flip_bits)
                if encoded_upload:
                    if not codec.agg_linear:
                        wire = faults_lib.linear_decode(codec, wire)
                    return wire, new_e
                return codec.decode(wire, ref=ref), new_e

            fl, fk = fault["flip"], fault["flip_keys"]
            if col_masks is not None:
                def enc_fm(u, m, e, k, fl_, fk_):
                    return enc_faulted(u, apply_rank_mask(down_payload, m),
                                       e, k, fl_, fk_)

                if codec.has_ef:
                    upload, new_ef = jax.vmap(enc_fm)(
                        upload, col_masks, new_state["_ef_up"], quant_keys,
                        fl, fk)
                    new_state = {**new_state, "_ef_up": new_ef}
                else:
                    upload, _ = jax.vmap(
                        lambda u, m, k, fl_, fk_:
                            enc_fm(u, m, None, k, fl_, fk_)
                    )(upload, col_masks, quant_keys, fl, fk)
            elif codec.has_ef:
                upload, new_ef = jax.vmap(
                    lambda u, e, k, fl_, fk_:
                        enc_faulted(u, down_payload, e, k, fl_, fk_)
                )(upload, new_state["_ef_up"], quant_keys, fl, fk)
                new_state = {**new_state, "_ef_up": new_ef}
            else:
                upload, _ = jax.vmap(
                    lambda u, k, fl_, fk_:
                        enc_faulted(u, down_payload, None, k, fl_, fk_)
                )(upload, quant_keys, fl, fk)
    return new_p, new_state, upload, local, last_loss, n_steps


@dataclass
class ClientBatch:
    """The jit-compiled round program, configured once per server.

    ``run`` executes local updates, payload selection, per-client
    quantization, masked aggregation, and the strategy server update as
    a single XLA program. Recompiles only when the (C, S, B) shape
    signature changes.
    """

    loss_fn: Callable
    strategy: Strategy
    client_cfg: ClientConfig
    personalization: str = "none"
    uplink_codec: Optional[Codec] = None
    fedper_local_keys: Tuple[str, ...] = ()
    mesh: Optional[Mesh] = None
    mesh_axis: str = "clients"
    # upload defenses (repro.fl.faults): "none" | "clip" | "trimmed";
    # all static => baked into the one compiled program, no per-round
    # recompiles when fault draws change
    defense: str = "none"
    defense_z: float = 3.0
    defense_clip: float = 1.0
    defense_trim: float = 0.1
    flip_bits: int = 4

    def __post_init__(self):
        if self.uplink_codec is None:
            self.uplink_codec = make_codec("")
        self._program = jax.jit(self._round_program)

    # ------------------------------------------------------- the program
    def _round_program(self, stacked_params, stacked_state, batches,
                       step_mask, arrived_mask, sizes, lr, quant_keys,
                       server_state, agg_target, down_payload,
                       tier_idx, tier_masks, fault=None, stale_ref=None):
        col_masks = None
        if tier_masks is not None:
            # per-client rank masks gathered from the (T, ...) tier table
            col_masks = jax.tree.map(
                lambda m: jnp.take(m, tier_idx, axis=0), tier_masks)
        new_p, new_state, upload, local, last_loss, n_steps = \
            chunk_round_program(
                stacked_params, stacked_state, batches, step_mask,
                quant_keys, down_payload,
                loss_fn=self.loss_fn, client_cfg=self.client_cfg,
                strategy_name=self.strategy.name,
                personalization=self.personalization,
                fedper_local_keys=self.fedper_local_keys,
                uplink_codec=self.uplink_codec, lr=lr,
                mesh=self.mesh, axis=self.mesh_axis,
                col_masks=col_masks, fault=fault, stale_ref=stale_ref,
                flip_bits=self.flip_bits)

        valid = jnp.ones_like(arrived_mask)
        if upload is not None:
            w = arrived_mask * sizes
            if self.defense != "none":
                # compiled upload screening: finite + per-layer norm
                # z-score vs the cohort; rejected clients fold into the
                # arrival weighting as zero WEIGHT with sanitized (zero)
                # values so 0 * NaN never reaches the fp32 accumulators
                cand = (arrived_mask > 0).astype(jnp.float32)
                dev = faults_lib.deviation_tree(upload, down_payload, False)
                if col_masks is not None:
                    dev = apply_rank_mask(dev, col_masks)
                norms, finite = faults_lib.upload_stats(dev)
                valid = faults_lib.validity_gate(norms, finite, cand,
                                                 self.defense_z)
                upload = faults_lib.sanitize_stacked(upload, valid)
                w = w * valid
                if self.defense == "clip":
                    s = faults_lib.clip_scales(norms, valid, cand,
                                               self.defense_clip)
                    upload = faults_lib.apply_clip_stacked(
                        upload, down_payload, s)
                    if col_masks is not None:
                        # the clip re-centers on the full broadcast;
                        # re-mask so tier-absent columns stay zero-valued
                        upload = apply_rank_mask(upload, col_masks)
            if self.defense == "trimmed":
                # coordinate-wise trimmed mean: needs all uploads
                # resident along the client axis (batched engine only)
                mean_w = tree_trimmed_wmean_stacked(
                    upload, w, col_masks, agg_target, self.defense_trim)
            elif col_masks is not None:
                # per-column arrival weighting: a column only averages
                # over clients whose tier covers it; columns nobody
                # covers keep the current global value (agg_target)
                mean_w = tree_hetero_wmean_stacked(upload, w, col_masks,
                                                   agg_target)
            else:
                mean_w = tree_wmean_stacked(upload, w)
                if self.defense != "none":
                    # a fully-rejected round keeps the current global
                    # (zero accepted weight must not zero the model)
                    wsum = w.sum()
                    mean_w = jax.tree.map(
                        lambda mn, tgt: jnp.where(wsum > 0, mn,
                                                  tgt.astype(mn.dtype)),
                        mean_w, agg_target)
            new_global, new_server_state = self.strategy.server_update(
                server_state, agg_target, mean_w)
        else:
            new_global, new_server_state = agg_target, server_state
        return (new_p, new_state, upload, local, last_loss, n_steps,
                new_global, new_server_state, valid)

    def run(self, stacked_params, stacked_state, batches, step_mask,
            arrived_mask, sizes, lr, quant_keys, server_state, agg_target,
            down_payload, tier_idx=None, tier_masks=None, fault=None,
            stale_ref=None):
        """Execute one round. ``tier_idx`` (``(C,)`` int) and
        ``tier_masks`` (``(T, ...)``-leading payload-structure mask tree)
        switch on heterogeneous-rank aggregation; both ``None`` (the
        default) runs the homogeneous program unchanged. ``fault`` is a
        :func:`repro.fl.faults.device_fault_args` dict (or ``None``) and
        ``stale_ref`` the previous decoded broadcast for stale-replay
        injection."""
        return self._program(
            stacked_params, stacked_state,
            jax.tree.map(jnp.asarray, batches), jnp.asarray(step_mask),
            jnp.asarray(arrived_mask, jnp.float32),
            jnp.asarray(sizes, jnp.float32),
            jnp.asarray(lr, jnp.float32), quant_keys,
            server_state, agg_target, down_payload,
            None if tier_idx is None else jnp.asarray(tier_idx, jnp.int32),
            tier_masks, fault, stale_ref)
