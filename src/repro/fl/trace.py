"""Fleet availability traces: O(cohort) client sampling at any scale.

``FLServer._select_round`` historically drew the round cohort with
``np.random.RandomState.choice`` over the whole fleet plus a lognormal
latency draw per sampled client — both O(C) in the *fleet* size, which
is exactly the host-side cost ROADMAP item 5 calls out as the blocker
for million-client rounds. :class:`FleetTrace` replaces that path with
a streamed availability service whose per-round cost is proportional to
the **cohort**, never the fleet:

  * **Sampling** — rejection-sampling of distinct client ids from
    ``[0, clients)`` (O(k) expected for k ≪ C, falling back to a
    permutation when the cohort is a large fraction of the fleet), so a
    1%-participation round over 1M clients touches ~10k ids, not 1M.
  * **Seeding** — every round gets its own ``np.random.SeedSequence``
    keyed on ``(trace seed, round)``; per-client local-epoch seeds are
    ``SeedSequence.spawn``-derived 64-bit values (see
    :func:`spawn_seeds`), so distinct clients cannot birthday-collide
    into identical data shuffles the way 2^30-range draws do at fleet
    scale.
  * **Availability** — a diurnal participation curve: client ``i`` is
    up with probability ``(1 - dropout) * (1 + amplitude * sin(2π(t /
    period + phase_i)))`` clipped to [0, 1], where ``phase_i`` is a
    deterministic low-discrepancy hash of the client id scaled by
    ``phase_spread`` (0 = the whole fleet shares one day/night cycle,
    1 = time zones spread uniformly around the clock).
  * **Tier mix** — ``tiers_of`` hashes ids onto capacity tiers with
    fixed proportions (``tier_mix``), replacing the O(C)
    ``TierSchedule.assign`` table for fleets too large to enumerate.

Everything is a pure function of ``(seed, round, client id)`` — no
per-client host state exists anywhere, which is what lets the arena
engine (``repro.fl.arena``) keep the *device* as the only O(C) store.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

# low-discrepancy multipliers for the id hashes: the golden ratio
# conjugate for diurnal phases, sqrt(2)-1 for tier assignment — two
# irrationals whose Weyl sequences are equidistributed and mutually
# uncorrelated, so a client's time zone says nothing about its tier
_PHI = 0.6180339887498949
_SQRT2M1 = 0.41421356237309515

# domain-separation tag mixed into every SeedSequence entropy tuple so
# trace streams never collide with other RandomState(seed) consumers
_TRACE_TAG = 0x5EEDF1EE


def spawn_seeds(seed: int, round_idx: int, n: int) -> np.ndarray:
    """``n`` collision-free 64-bit data seeds for one round.

    One ``np.random.SeedSequence`` keyed on ``(seed, round)`` is spawned
    into ``n`` children (the documented fork-safe derivation) and each
    child contributes one ``uint64`` word. Replaces the legacy
    ``rng.randint(1 << 30, size=n)`` draw whose 2^30 space
    birthday-collides near ~32k clients — two colliding clients would
    shuffle their local epochs identically every round.
    """
    root = np.random.SeedSequence((int(seed), _TRACE_TAG, int(round_idx)))
    return np.array(
        [child.generate_state(1, np.uint64)[0] for child in root.spawn(n)],
        dtype=np.uint64)


def _id_hash(cids: np.ndarray, mult: float, seed: int) -> np.ndarray:
    """Deterministic uniform-ish hash of client ids into [0, 1): the Weyl
    sequence ``frac((cid + seed·offset) · mult)`` — O(cohort), no table."""
    c = np.asarray(cids, np.float64)
    return np.mod((c + 1.0 + 977.0 * seed) * mult, 1.0)


@dataclass
class FleetTrace:
    """Deterministic fleet availability model (see module docstring).

    Attributes:
        clients: fleet size C (ids are ``[0, C)``).
        tier_mix: capacity-tier proportions, e.g. ``(0.5, 0.3, 0.2)``;
            pairs positionally with ``ServerConfig.gamma_tiers``. Empty
            = homogeneous fleet, ``tiers_of`` returns all zeros.
        dropout: baseline per-round unavailability (peak-hour failure
            rate); the diurnal curve modulates around ``1 - dropout``.
        diurnal_amplitude: participation swing in [0, 1); 0 disables
            the day/night cycle.
        diurnal_period: rounds per simulated day.
        phase_spread: how far client time zones spread around the clock
            (0 = one global cycle, 1 = uniform around the full day).
        seed: trace seed; every derived stream is keyed on it.
    """

    clients: int
    tier_mix: Tuple[float, ...] = ()
    dropout: float = 0.0
    diurnal_amplitude: float = 0.0
    diurnal_period: int = 24
    phase_spread: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.clients <= 0:
            raise ValueError("FleetTrace.clients must be positive")
        if self.tier_mix:
            s = float(sum(self.tier_mix))
            if not np.isclose(s, 1.0, atol=1e-6):
                raise ValueError(
                    f"tier_mix must sum to 1, got {self.tier_mix} (sum {s})")

    # ------------------------------------------------------------ streams
    def round_rng(self, round_idx: int, salt: int = 0) -> np.random.Generator:
        """The round's private generator — every round re-keys from the
        trace seed, so round r's draws never depend on how many draws
        earlier rounds made (replayable at any round in isolation).
        ``salt`` (recovery retries, see docs/robustness.md) opens a
        fresh stream per attempt; ``salt=0`` keys exactly as before, so
        existing runs are bitwise untouched."""
        entropy = (int(self.seed), _TRACE_TAG, int(round_idx))
        if salt:
            entropy = entropy + (int(salt),)
        return np.random.Generator(np.random.PCG64(
            np.random.SeedSequence(entropy)))

    def local_seeds(self, round_idx: int, n: int) -> np.ndarray:
        """Per-client 64-bit local-epoch data seeds for the round's
        cohort (``spawn_seeds`` keyed on the trace seed)."""
        return spawn_seeds(self.seed, round_idx, n)

    # ----------------------------------------------------------- sampling
    def sample_cohort(self, rng: np.random.Generator, k: int) -> np.ndarray:
        """``k`` distinct client ids, cost O(k) expected — not O(C).

        For cohorts up to half the fleet, rejection-sample batches of
        ids until ``k`` distinct ones accumulate (expected < 2 batches
        at 1% participation); larger cohorts fall back to a fleet
        permutation, where O(C) is within a constant of the answer size.
        """
        n, k = int(self.clients), int(k)
        if k >= n:
            return rng.permutation(n)
        if k > n // 2:   # dense cohort: rejection would thrash
            return rng.permutation(n)[:k]
        got = np.unique(rng.integers(0, n, size=int(k * 1.25) + 16))
        while len(got) < k:
            got = np.unique(np.concatenate(
                [got, rng.integers(0, n, size=k)]))
        # np.unique sorts — shuffle so cohort order carries no id bias
        rng.shuffle(got)
        return got[:k].astype(np.int64)

    # ------------------------------------------------------- availability
    def client_phase(self, cids: np.ndarray) -> np.ndarray:
        """Each client's diurnal phase offset in [0, 1): a deterministic
        low-discrepancy hash of the id, scaled by ``phase_spread``."""
        return self.phase_spread * _id_hash(cids, _PHI, self.seed)

    def availability(self, cids: np.ndarray, round_idx: int) -> np.ndarray:
        """Per-client up-probability at round ``round_idx`` (the diurnal
        participation curve; O(cohort))."""
        base = 1.0 - float(self.dropout)
        cids = np.asarray(cids)
        if self.diurnal_amplitude <= 0:
            return np.full(len(cids), base)
        t = float(round_idx) / max(1, int(self.diurnal_period))
        wave = np.sin(2.0 * np.pi * (t + self.client_phase(cids)))
        return np.clip(base * (1.0 + self.diurnal_amplitude * wave), 0.0, 1.0)

    # --------------------------------------------------------------- tiers
    def tiers_of(self, cids: np.ndarray) -> np.ndarray:
        """Capacity-tier index per client (O(cohort) hash, proportions
        ``tier_mix``); all zeros when no mix is configured."""
        cids = np.asarray(cids)
        if not self.tier_mix:
            return np.zeros(len(cids), np.int32)
        edges = np.cumsum(np.asarray(self.tier_mix, np.float64))[:-1]
        u = _id_hash(cids, _SQRT2M1, self.seed)
        return np.searchsorted(edges, u, side="right").astype(np.int32)

    def tier_counts(self) -> np.ndarray:
        """Expected clients per tier (``round(mix * C)``) — the fleet is
        never enumerated, so exact counts would cost O(C) on purpose."""
        if not self.tier_mix:
            return np.array([self.clients], np.int64)
        return np.round(np.asarray(self.tier_mix, np.float64)
                        * self.clients).astype(np.int64)

    # ------------------------------------------------------------ latency
    def latency(self, rng: np.random.Generator, payload_bytes,
                n: int, sigma: float, bandwidth_mbps: float) -> np.ndarray:
        """Simulated arrival latency for ``n`` cohort clients: lognormal
        compute plus payload/bandwidth transfer — the server's straggler
        model, drawn from the round's private generator."""
        comp = rng.lognormal(mean=0.0, sigma=sigma, size=n)
        comm_s = 8.0 * np.asarray(payload_bytes, np.float64) / (
            bandwidth_mbps * 1e6)
        return comp + comm_s

    def arrival_stream(self, round_idx: int, k: int, payload_bytes,
                       sigma: float, bandwidth_mbps: float,
                       t0: float = 0.0, salt: int = 0):
        """One dispatch's deterministic arrival stream, in arrival order:
        ``(cohort_ids, [(absolute_time, position), ...])`` where a
        position indexes the returned cohort. Everything replays from
        ``(trace seed, round_idx, salt)`` alone — the same re-keying
        contract as :meth:`round_rng` — so two servers (or a crashed and
        a resumed one) asking for the same round's stream get identical
        cohorts AND identical event timing regardless of what either
        drew before. This is the async engine's dispatch draw
        (``repro.fl.arrivals.arrival_events`` orders the admitted
        subset); the sync engines consume the same draws as a
        round-scoped arrival mask."""
        from repro.fl.arrivals import arrival_events

        rng = self.round_rng(round_idx, salt=salt)
        cohort = self.sample_cohort(rng, k)
        lat = self.latency(rng, payload_bytes, len(cohort), sigma,
                           bandwidth_mbps)
        alive = rng.random(len(cohort)) < self.availability(cohort,
                                                            round_idx)
        return cohort, arrival_events(alive, lat, t0=t0)
