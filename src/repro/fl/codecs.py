"""Composable up/down-link codecs for the FL simulator.

A codec is a pipeline of stages selected by a spec string, e.g.
``"delta|topk0.1|int8"``:

  delta        encode the payload as a difference against a reference
               tree (the last decoded broadcast for the downlink, the
               round's decoded broadcast for the uplink) — Konecny et
               al. 2016, structured updates.
  topk{f}      per-leaf magnitude top-k sparsification keeping a
               fraction ``f`` of the entries, with an error-feedback
               accumulator (Seide et al. / EF-SGD): the discarded
               residual is added back into the next round's input, so
               the long-run compression bias vanishes.
  lowrank{r}   dual-side low-rank delta compression (Qiao et al. 2021):
               SVD-truncate each 2-D leaf of the *update* to rank ``r``
               (integer) or to ``round(r * min_dim)`` when ``r`` < 1 —
               the wire carries the two factors, never the dense delta.
  int8 / fp16  the FedPAQ-style quantizers from ``repro.fl.comm``
               (per-tensor symmetric int8 with stochastic rounding /
               half-precision cast).

Stage order is canonical and validated: ``delta`` first, then at most
one of ``topk``/``lowrank`` (they are alternative sparsifiers — their
wire formats do not compose), then at most one quantizer. ``""``,
``"fp32"``, ``"none"`` and ``"identity"`` all name the identity codec.

Every method that touches array data (``encode`` / ``decode`` /
``encode_decode``) is jit-safe and vmap-compatible: all
shape-dependent decisions (top-k counts, SVD ranks, eligibility) are
made from static leaf shapes, so the batched engine can vmap one
client's codec over a client-stacked payload. The in-memory wire tree
is *value-faithful*: the arrays a decoder sees are exactly what a real
implementation would reconstruct (top-k keeps a dense masked carrier;
low-rank and int8 carry compact factors / ``{"q", "scale"}`` nodes).

Byte accounting is exact and data-independent: ``Codec.wire_bytes``
replays the stage algebra over the payload's leaf shapes (k values +
4-byte indices for top-k, ``r * (m + n)`` factor entries for low-rank,
per-chunk itemsize + 4-byte scales for int8), so both engines charge
identical integers to ``CommLog``. ``measured_bytes`` walks an actual
encoded wire tree and must agree with ``wire_bytes`` — the regression
tests hold the two to each other.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import comm
from repro.fl.strategies import tree_sub, tree_zeros

_IDENTITY_SPECS = ("", "fp32", "none", "identity")
_LR_KEYS = frozenset(("lr_u", "lr_v"))

# stage kind -> pipeline category (must be strictly increasing in a spec)
_CATEGORY = {"delta": 0, "topk": 1, "lowrank": 1, "int8": 2, "fp16": 2}


@dataclass(frozen=True)
class Stage:
    kind: str                 # delta | topk | lowrank | int8 | fp16
    param: float = 0.0        # topk fraction / lowrank rank


def _topk_count(shape, frac: float) -> int:
    n = int(np.prod(shape)) if shape else 1
    return max(1, min(n, int(math.ceil(frac * n))))


def _lowrank_rank(shape, param: float) -> int:
    m, n = int(shape[0]), int(shape[1])
    r = int(param) if param >= 1 else max(1, int(round(param * min(m, n))))
    return r


def _lowrank_eligible(shape, param: float) -> bool:
    if len(shape) != 2:
        return False
    m, n = int(shape[0]), int(shape[1])
    r = _lowrank_rank(shape, param)
    return r < min(m, n) and r * (m + n) < m * n


def _is_lr_node(node: Any) -> bool:
    return isinstance(node, dict) and set(node) == _LR_KEYS


# ----------------------------------------------------------- stage encoders

# Top-k selection backend: None = auto (approx_max_k on accelerator
# backends, where it maps to the fast partial-reduction TPU/GPU
# lowering; exact lax.top_k on CPU), True/False = forced. approx_max_k
# with recall_target < 1.0 may keep a slightly different index set than
# exact top-k — the parity-tolerance test bounds the decoded error.
_APPROX_TOPK: Optional[bool] = None
_APPROX_RECALL = 0.95


def set_approx_topk(enabled: Optional[bool]) -> None:
    """Force (True/False) or restore auto-selection (None) of the
    ``jax.lax.approx_max_k`` top-k backend.

    The flag is read at TRACE time: it applies to codec programs traced
    after the call (fresh servers / first-round compiles). Round
    programs that were already jit-compiled keep whichever backend was
    baked in — set the flag before building the server."""
    global _APPROX_TOPK
    _APPROX_TOPK = enabled


def use_approx_topk() -> bool:
    if _APPROX_TOPK is not None:
        return _APPROX_TOPK
    env = os.environ.get("REPRO_APPROX_TOPK", "").lower()
    if env in ("1", "true", "yes"):
        return True
    if env in ("0", "false", "no"):
        return False
    return jax.default_backend() in ("tpu", "gpu")


def _topk_leaf(x: jax.Array, frac: float) -> jax.Array:
    """Dense masked carrier: top-k |entries| kept, the rest zeroed."""
    k = _topk_count(x.shape, frac)
    flat = x.reshape(-1)
    if use_approx_topk():
        _, idx = jax.lax.approx_max_k(jnp.abs(flat), k,
                                      recall_target=_APPROX_RECALL)
    else:
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return kept.reshape(x.shape)


def _lowrank_encode_leaf(x: jax.Array, param: float) -> Any:
    if not _lowrank_eligible(x.shape, param):
        return x
    r = _lowrank_rank(x.shape, param)
    u, s, vt = jnp.linalg.svd(x, full_matrices=False)
    return {"lr_u": u[:, :r] * s[:r], "lr_v": vt[:r, :]}


def _lowrank_decode(tree: Any) -> Any:
    def walk(n):
        if _is_lr_node(n):
            return n["lr_u"] @ n["lr_v"]
        if isinstance(n, dict):
            return {k: walk(v) for k, v in n.items()}
        if isinstance(n, (list, tuple)):
            return type(n)(walk(v) for v in n)
        return n

    return walk(tree)


# ------------------------------------------------------------------- codec

@dataclass(frozen=True)
class Codec:
    spec: str
    stages: Tuple[Stage, ...] = ()

    @property
    def is_identity(self) -> bool:
        return not self.stages

    @property
    def has_ef(self) -> bool:
        return any(s.kind == "topk" for s in self.stages)

    @property
    def has_delta(self) -> bool:
        return any(s.kind == "delta" for s in self.stages)

    def ef_init(self, payload: Any) -> Optional[Any]:
        """Zero error-feedback accumulator (payload structure), or None.

        The accumulator is PER-CLIENT residual state: dict engines keep
        it under ``client_states[cid]["_ef_up"]``, the arena stores it
        as one stacked ``(clients + 1, ..)`` row block (a fleet's whole
        EF memory is ``clients * ef_nbytes(payload)`` on device)."""
        return tree_zeros(payload) if self.has_ef else None

    def ef_nbytes(self, payload: Any) -> int:
        """Bytes one client's error-feedback accumulator occupies (0
        when the codec keeps none) — the per-row cost the arena pays to
        make EF fleet-resident; see docs/fleet.md."""
        if not self.has_ef:
            return 0
        return int(sum(np.prod(jnp.shape(x) or (1,))
                       * np.dtype(jnp.asarray(x).dtype).itemsize
                       for x in jax.tree.leaves(payload)))

    # -------------------------------------------------------------- encode
    def encode(self, payload: Any, *, ref: Any = None, ef: Any = None,
               key: Optional[jax.Array] = None) -> Tuple[Any, Optional[Any]]:
        """Returns ``(wire, new_ef)``. jit-safe; vmap over a client axis
        by vmapping this method with per-client ``payload``/``ef``/``key``
        (the ``ref`` closure broadcasts)."""
        x = payload
        new_ef = ef
        for st in self.stages:
            if st.kind == "delta":
                if ref is None:
                    raise ValueError("delta stage requires a reference tree")
                x = tree_sub(x, ref)
            elif st.kind == "topk":
                if ef is not None:
                    x = jax.tree.map(lambda a, e: a + e, x, ef)
                kept = jax.tree.map(lambda a: _topk_leaf(a, st.param), x)
                new_ef = tree_sub(x, kept)
                x = kept
            elif st.kind == "lowrank":
                x = jax.tree.map(lambda a: _lowrank_encode_leaf(a, st.param), x)
            elif st.kind == "fp16":
                x = comm.quantize_fp16(x)
            elif st.kind == "int8":
                x = comm.quantize_int8(
                    x, key if key is not None else jax.random.PRNGKey(0))
        return x, new_ef

    def decode(self, wire: Any, *, ref: Any = None) -> Any:
        """Invert :meth:`encode`: map a wire tree back to payload space.

        Args:
            wire: the encoded tree (``{"q", "scale"}`` int8 nodes,
                ``{"lr_u", "lr_v"}`` factor nodes, dense carriers).
            ref: reference tree for the delta stage (required iff the
                spec contains ``delta``).

        Returns:
            The decoded payload tree — exactly what a receiver would
            reconstruct (top-k carriers are already dense, so that
            stage decodes as identity). jit/vmap-safe.
        """
        x = wire
        for st in reversed(self.stages):
            if st.kind == "int8":
                x = comm.dequantize_int8(x)
            elif st.kind == "fp16":
                x = comm.dequantize_fp16(x)
            elif st.kind == "lowrank":
                x = _lowrank_decode(x)
            elif st.kind == "delta":
                if ref is None:
                    raise ValueError("delta stage requires a reference tree")
                x = jax.tree.map(lambda d, r: d + r, x, ref)
            # topk: identity (dense masked carrier)
        return x

    def encode_decode(self, payload: Any, *, ref: Any = None, ef: Any = None,
                      key: Optional[jax.Array] = None
                      ) -> Tuple[Any, Optional[Any]]:
        """One simulated wire round trip: ``(decoded, new_ef)``."""
        if self.is_identity:
            return payload, ef
        wire, new_ef = self.encode(payload, ref=ref, ef=ef, key=key)
        return self.decode(wire, ref=ref), new_ef

    # ------------------------------------------- encoded-form aggregation
    #
    # The streaming engine never decodes uplinks to a dense (C, model)
    # stack; it accumulates  Σ_c w_c · dequant(wire_c)  directly (the
    # fused kernel in ``repro.kernels.agg``). That only works when the
    # remaining decode is LINEAR per leaf: int8 dequant (q·scale), fp16
    # widening and the top-k dense carrier all are; the low-rank factor
    # product is bilinear, and the delta reference is a constant the
    # mean absorbs:  mean(decode(wire_c)) = mean(lin(wire_c)) + ref.

    @property
    def agg_linear(self) -> bool:
        """True when decode(wire) = linear-dequant(wire) [+ delta ref]
        leaf-wise, i.e. encoded wires can be weighted-summed without a
        per-client decode (no low-rank factor stage)."""
        return not any(s.kind == "lowrank" for s in self.stages)

    def encode_for_agg(self, payload: Any, *, ref: Any = None, ef: Any = None,
                       key: Optional[jax.Array] = None
                       ) -> Tuple[Any, Optional[Any]]:
        """Encode for a streaming (encoded-form) aggregator.

        Returns ``(agg_wire, new_ef)`` where ``agg_wire`` leaves are
        ``{"q", "scale"}`` int8 nodes or dense arrays satisfying
        ``decode(wire) = linear(agg_wire) + (ref if has_delta)``. For
        codecs with a low-rank stage the bilinear factor product is
        composed back per client here (still O(client) at a time under
        the chunk vmap), leaving the delta offset to the aggregator.
        """
        if self.is_identity:
            return payload, ef
        wire, new_ef = self.encode(payload, ref=ref, ef=ef, key=key)
        if not self.agg_linear:
            # undo the nonlinear stages per client via the one decode
            # implementation, minus the delta stage (left to the mean)
            stripped = Codec(spec=self.spec, stages=tuple(
                s for s in self.stages if s.kind != "delta"))
            wire = stripped.decode(wire)
        return wire, new_ef

    def agg_finalize(self, mean: Any, *, ref: Any = None) -> Any:
        """Map the weighted mean of ``encode_for_agg`` wires back to
        payload space (adds the delta reference back in)."""
        if self.has_delta:
            if ref is None:
                raise ValueError("delta stage requires a reference tree")
            return jax.tree.map(lambda d, r: d + r.astype(d.dtype), mean, ref)
        return mean

    @staticmethod
    def agg_finalize_pinned(mean: Any, refs: Dict[int, Any],
                            coefs: Dict[int, float]) -> Any:
        """Multi-reference :meth:`agg_finalize` for version-pinned
        asynchronous folds (docs/async.md): arrivals in one buffer may
        decode against DIFFERENT pinned broadcasts, so the mean
        re-attaches ``sum_d coefs[d] * refs[d]`` where ``d`` ranges
        over live dispatch ids and ``coefs[d]`` is that dispatch's
        accumulated fold weight over the total (host floats — with a
        single live dispatch the ratio is exactly 1.0 and this
        reproduces ``agg_finalize`` bitwise). The clip defense's
        non-delta slack — the clipped-away ``(1-clip)`` remainder of
        each pinned broadcast — rides the same linear re-attachment."""
        out = mean
        for d in sorted(coefs):
            c = float(coefs[d])
            if c == 0.0:
                continue
            cc = jnp.float32(c)
            out = jax.tree.map(lambda a, r: a + cc * r.astype(a.dtype),
                               out, refs[d])
        return out

    # ---------------------------------------------------------- accounting
    def wire_bytes(self, payload: Any) -> int:
        """Exact wire size of ``payload`` under this codec, from leaf
        shapes alone (data-independent, so every engine charges the same
        integers). Per original leaf the stage algebra tracks a list of
        value chunks ``(count, bytes_per_value)`` plus an index/scale
        overhead in plain bytes.

        Heterogeneous rank tiers price each tier by passing the
        PHYSICALLY SLICED payload
        (``repro.core.parameterization.slice_factor_tree``) — smaller
        factor column counts flow through the same exact algebra, so
        per-tier bytes need no special cases here."""
        total = 0
        for leaf in jax.tree.leaves(payload):
            if not hasattr(leaf, "shape"):
                continue
            shape = tuple(int(d) for d in jnp.shape(leaf))
            itemsize = int(np.dtype(leaf.dtype).itemsize)
            chunks: List[Tuple[int, int]] = [(int(np.prod(shape)) if shape
                                              else 1, itemsize)]
            overhead = 0
            for st in self.stages:
                if st.kind == "topk":
                    k = _topk_count(shape, st.param)
                    chunks = [(k, bpv) for _, bpv in chunks]
                    overhead += 4 * k                     # int32 indices
                elif st.kind == "lowrank":
                    if _lowrank_eligible(shape, st.param):
                        r = _lowrank_rank(shape, st.param)
                        bpv = chunks[0][1]
                        chunks = [(r * shape[0], bpv), (r * shape[1], bpv)]
                elif st.kind == "fp16":
                    chunks = [(c, 2) for c, _ in chunks]
                elif st.kind == "int8":
                    chunks = [(c, 1) for c, _ in chunks]
                    overhead += 4 * len(chunks)           # per-tensor scales
            total += sum(c * b for c, b in chunks) + overhead
        return int(total)


def measured_bytes(wire: Any, *, topk_frac: Optional[float] = None) -> int:
    """Bytes of an actual encoded wire tree, by inspection: ``{"q",
    "scale"}`` nodes at stored itemsize + 4B/scale, ``{"lr_u", "lr_v"}``
    factor nodes recursed, dense leaves at ``size * itemsize``. When the
    codec used top-k, pass ``topk_frac`` so dense masked carriers are
    priced at k values + 4-byte indices. Must agree with
    ``Codec.wire_bytes`` — the unit tests pin the two together."""
    def walk(n) -> int:
        if comm._is_qnode(n):
            q, s = n["q"], n["scale"]
            nq = int(q.size)
            if topk_frac is not None:
                nq = _topk_count(tuple(int(d) for d in jnp.shape(q)), topk_frac)
            return (nq * int(np.dtype(q.dtype).itemsize)
                    + (4 * nq if topk_frac is not None else 0)
                    + 4 * max(int(getattr(s, "size", 1)), 1))
        if _is_lr_node(n):
            return walk(n["lr_u"]) + walk(n["lr_v"])
        if isinstance(n, dict):
            return sum(walk(v) for v in n.values())
        if isinstance(n, (list, tuple)):
            return sum(walk(v) for v in n)
        if hasattr(n, "size"):
            nv = int(n.size)
            if topk_frac is not None:
                nv = _topk_count(tuple(int(d) for d in jnp.shape(n)), topk_frac)
                return nv * int(np.dtype(n.dtype).itemsize) + 4 * nv
            return nv * int(np.dtype(n.dtype).itemsize)
        return 0

    return int(walk(wire))


# ------------------------------------------------------------------ parser

def make_codec(spec: Optional[str]) -> Codec:
    """Parse a codec spec like ``"delta|topk0.1|int8"``."""
    raw = (spec or "").strip()
    if raw in _IDENTITY_SPECS:
        return Codec(spec="fp32")
    stages: List[Stage] = []
    last_cat = -1
    for tok in raw.split("|"):
        tok = tok.strip()
        if tok in ("", "fp32"):
            continue
        if tok == "delta":
            st = Stage("delta")
        elif tok.startswith("topk"):
            frac = float(tok[len("topk"):])
            if not 0.0 < frac <= 1.0:
                raise ValueError(f"topk fraction must be in (0, 1]: {tok!r}")
            st = Stage("topk", frac)
        elif tok.startswith("lowrank"):
            val = float(tok[len("lowrank"):])
            if val <= 0:
                raise ValueError(f"lowrank rank must be positive: {tok!r}")
            st = Stage("lowrank", val)
        elif tok in ("int8", "fp16"):
            st = Stage(tok)
        else:
            raise ValueError(
                f"unknown codec stage {tok!r} in {raw!r} "
                "(expected delta | topk<f> | lowrank<r> | int8 | fp16)")
        cat = _CATEGORY[st.kind]
        if cat <= last_cat:
            raise ValueError(
                f"codec {raw!r}: stages must follow delta -> "
                "topk|lowrank -> int8|fp16, each at most once "
                "(topk and lowrank are mutually exclusive)")
        last_cat = cat
        stages.append(st)
    return Codec(spec=raw, stages=tuple(stages))
