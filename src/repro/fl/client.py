"""FL client: local SGD epochs on private data (paper Algorithm 1/2).

``local_update`` is strategy-aware (FedProx penalty, SCAFFOLD gradient
correction, FedDyn dynamic regularizer) and parameterization-agnostic —
FedPara factors are just the params pytree. Optionally applies the
Jacobian-correction regularizer (supplementary Eq. 9) for matrix-
parameterized models.

The ``jax.value_and_grad`` in ``_step_math`` traces whatever the model's
``loss_fn`` contains — including the fused Pallas fedpara_matmul, which
is a ``jax.custom_vjp`` (``repro.kernels.fedpara_grad``): with
``ParamCfg(use_pallas=True)`` every local step's forward AND backward
run dense-W-free, the local-training cost drops from O(mn) to
O(r(m+n)) HBM bytes per layer, and no engine code changes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.fl.strategies import Strategy, tree_sub, tree_zeros


@dataclass
class ClientConfig:
    """Local-SGD settings shared by every simulated client: base
    learning rate (decayed per round by ``ServerConfig.lr_decay``),
    SGD momentum, minibatch size, local epochs per round, and weight
    decay."""

    lr: float = 0.1
    momentum: float = 0.0
    batch: int = 64
    epochs: int = 10
    weight_decay: float = 0.0


def _step_math(params, opt_mu, batch, global_params, client_state,
               loss_fn, strategy_name: str, lr, momentum: float, wd: float):
    """One strategy-aware local SGD step. Pure math shared verbatim by the
    per-batch jitted sequential path (`_local_step`) and the batched
    scan-over-steps path (`repro.fl.batch_engine`), so the two engines
    stay numerically aligned. ``momentum``/``wd`` are static python
    floats; ``lr`` may be traced."""

    def total_loss(p):
        base = loss_fn(p, batch)
        if strategy_name == "fedprox":
            from repro.fl.strategies import tree_sqnorm
            base = base + 0.5 * client_state["mu_prox"] * tree_sqnorm(
                tree_sub(p, global_params))
        if strategy_name == "feddyn":
            from repro.fl.strategies import tree_dot, tree_sqnorm
            base = base + (-tree_dot(client_state["lambda_i"], p)
                           + 0.5 * client_state["alpha"] * tree_sqnorm(
                               tree_sub(p, global_params)))
        return base

    loss, grads = jax.value_and_grad(total_loss)(params)
    if strategy_name == "scaffold":
        grads = jax.tree.map(lambda g, ci, c: g - ci + c, grads,
                             client_state["c_i"], client_state["c"])
    if wd:
        grads = jax.tree.map(lambda g, p: g + wd * p, grads, params)
    if momentum:
        opt_mu = jax.tree.map(lambda m, g: momentum * m + g, opt_mu, grads)
        step_dir = opt_mu
    else:
        step_dir = grads
    params = jax.tree.map(lambda p, g: p - lr * g, params, step_dir)
    return params, opt_mu, loss


@functools.partial(jax.jit, static_argnames=("loss_fn", "strategy_name", "mom_wd"))
def _local_step(params, opt_mu, batch, global_params, client_state, lr,
                loss_fn, strategy_name: str, mom_wd: Tuple[float, float]):
    # lr is TRACED: the server decays it every round (lr * decay**round),
    # so baking it static would recompile this program each round. The
    # momentum/wd pair stays static — it selects the step-math branch and
    # never changes within a run.
    momentum, wd = mom_wd
    return _step_math(params, opt_mu, batch, global_params, client_state,
                      loss_fn, strategy_name, lr, momentum, wd)


def strategy_post(strategy_name: str, state: Dict, global_params: Any,
                  params: Any, n_steps, lr) -> Dict:
    """Per-client post-round state update (SCAFFOLD Option II c_i, FedDyn
    lambda_i). jit-safe: ``n_steps`` may be a traced per-client step count
    (the batched engine passes ``step_mask.sum()``); a zero count leaves
    the state unchanged."""
    state = dict(state)
    if strategy_name == "scaffold":
        n = jnp.maximum(jnp.asarray(n_steps, jnp.float32), 1.0)
        scale = 1.0 / (n * lr)
        live = jnp.asarray(n_steps, jnp.float32) > 0
        state["c_i"] = jax.tree.map(
            lambda ci, c, wg, wl: jnp.where(live, ci - c + scale * (wg - wl), ci),
            state["c_i"], state["c"], global_params, params)
    if strategy_name == "feddyn":
        state["lambda_i"] = jax.tree.map(
            lambda lam, wl, wg: lam - state["alpha"] * (wl - wg),
            state["lambda_i"], params, global_params)
    return state


def local_update(
    global_params: Any,
    batches: Iterator[Dict],
    loss_fn: Callable,
    cfg: ClientConfig,
    strategy: Strategy,
    client_state: Optional[Dict] = None,
    lr: Optional[float] = None,
) -> Tuple[Any, Dict, Dict]:
    """Run local epochs; returns (new_params, new_client_state, metrics)."""
    params = global_params
    state = dict(client_state or {})
    mu = tree_zeros(params)
    lr = cfg.lr if lr is None else lr
    n_steps, last_loss = 0, 0.0
    for batch in batches:
        params, mu, loss = _local_step(
            params, mu, batch, global_params, state,
            jnp.asarray(lr, jnp.float32), loss_fn,
            strategy.name, (cfg.momentum, cfg.weight_decay))
        n_steps += 1
        last_loss = loss
    # ---- strategy post-processing (shared with the batched engine)
    state = strategy_post(strategy.name, state, global_params, params,
                          n_steps, lr)
    metrics = {"steps": n_steps, "loss": float(last_loss)}
    return params, state, metrics


def init_client_state(strategy: Strategy, params: Any, **kw) -> Dict:
    """Strategy-owned client state. The key ``"_ef_up"`` is reserved:
    the server attaches the uplink codec's error-feedback accumulator
    there (``FLServer._ensure_ef``); step math and ``strategy_post``
    carry it through untouched."""
    if strategy.name == "scaffold":
        return {"c_i": tree_zeros(params), "c": tree_zeros(params)}
    if strategy.name == "feddyn":
        return {"lambda_i": tree_zeros(params),
                "alpha": jnp.asarray(kw.get("alpha", 0.1), jnp.float32)}
    if strategy.name == "fedprox":
        return {"mu_prox": jnp.asarray(kw.get("mu", 0.1), jnp.float32)}
    return {}
