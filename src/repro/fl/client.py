"""FL client: local SGD epochs on private data (paper Algorithm 1/2).

``local_update`` is strategy-aware (FedProx penalty, SCAFFOLD gradient
correction, FedDyn dynamic regularizer) and parameterization-agnostic —
FedPara factors are just the params pytree. Optionally applies the
Jacobian-correction regularizer (supplementary Eq. 9) for matrix-
parameterized models.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.fl.strategies import Strategy, tree_add, tree_sub, tree_zeros
from repro.optim import apply_updates, sgd


@dataclass
class ClientConfig:
    lr: float = 0.1
    momentum: float = 0.0
    batch: int = 64
    epochs: int = 10
    weight_decay: float = 0.0


@functools.partial(jax.jit, static_argnames=("loss_fn", "strategy_name", "lr_mom"))
def _local_step(params, opt_mu, batch, global_params, client_state,
                loss_fn, strategy_name: str, lr_mom: Tuple[float, float, float]):
    lr, momentum, wd = lr_mom

    def total_loss(p):
        base = loss_fn(p, batch)
        if strategy_name == "fedprox":
            from repro.fl.strategies import tree_sqnorm
            base = base + 0.5 * client_state["mu_prox"] * tree_sqnorm(
                tree_sub(p, global_params))
        if strategy_name == "feddyn":
            from repro.fl.strategies import tree_dot, tree_sqnorm
            base = base + (-tree_dot(client_state["lambda_i"], p)
                           + 0.5 * client_state["alpha"] * tree_sqnorm(
                               tree_sub(p, global_params)))
        return base

    loss, grads = jax.value_and_grad(total_loss)(params)
    if strategy_name == "scaffold":
        grads = jax.tree.map(lambda g, ci, c: g - ci + c, grads,
                             client_state["c_i"], client_state["c"])
    if wd:
        grads = jax.tree.map(lambda g, p: g + wd * p, grads, params)
    if momentum:
        opt_mu = jax.tree.map(lambda m, g: momentum * m + g, opt_mu, grads)
        step_dir = opt_mu
    else:
        step_dir = grads
    params = jax.tree.map(lambda p, g: p - lr * g, params, step_dir)
    return params, opt_mu, loss


def local_update(
    global_params: Any,
    batches: Iterator[Dict],
    loss_fn: Callable,
    cfg: ClientConfig,
    strategy: Strategy,
    client_state: Optional[Dict] = None,
    lr: Optional[float] = None,
) -> Tuple[Any, Dict, Dict]:
    """Run local epochs; returns (new_params, new_client_state, metrics)."""
    params = global_params
    state = dict(client_state or {})
    mu = tree_zeros(params)
    lr = cfg.lr if lr is None else lr
    n_steps, last_loss = 0, 0.0
    for batch in batches:
        params, mu, loss = _local_step(
            params, mu, batch, global_params, state, loss_fn,
            strategy.name, (lr, cfg.momentum, cfg.weight_decay))
        n_steps += 1
        last_loss = loss
    # ---- strategy post-processing
    if strategy.name == "scaffold" and n_steps > 0:
        # Option II: c_i' = c_i - c + (w_global - w_local)/(K * lr)
        scale = 1.0 / (n_steps * lr)
        state["c_i"] = jax.tree.map(
            lambda ci, c, wg, wl: ci - c + scale * (wg - wl),
            state["c_i"], state["c"], global_params, params)
    if strategy.name == "feddyn":
        # lambda_i' = lambda_i - alpha (w_local - w_global)
        state["lambda_i"] = jax.tree.map(
            lambda lam, wl, wg: lam - state["alpha"] * (wl - wg),
            state["lambda_i"], params, global_params)
    metrics = {"steps": n_steps, "loss": float(last_loss)}
    return params, state, metrics


def init_client_state(strategy: Strategy, params: Any, **kw) -> Dict:
    if strategy.name == "scaffold":
        return {"c_i": tree_zeros(params), "c": tree_zeros(params)}
    if strategy.name == "feddyn":
        return {"lambda_i": tree_zeros(params),
                "alpha": jnp.asarray(kw.get("alpha", 0.1), jnp.float32)}
    if strategy.name == "fedprox":
        return {"mu_prox": jnp.asarray(kw.get("mu", 0.1), jnp.float32)}
    return {}
