"""Shared arrival-ordering model: one code path prices latency for
every engine.

The synchronous engines need a *round-scoped* answer — "which of the
sampled clients are among the first ``n_target`` arrivals?" — while the
async engine (``repro.fl.async_engine``) needs the *stream* itself:
each admitted client's absolute arrival time on the virtual clock, in
arrival order. Both derive from the same latency vector drawn in
``FLServer._select_round`` (legacy RNG or ``FleetTrace``), and both
MUST sort it the same way: a stable argsort on latency, so ties break
by sampling position identically everywhere. Before this module the
mask sort lived in ``server.py`` and the fault crash-fold reimplemented
its own arrival assumptions inline; an engine that priced latency
differently could silently diverge from the recorded
``arrived_mask``/byte charges.

Helpers:
  ``arrival_order``   stable latency sort (ties: sampling order),
  ``arrival_mask``    first-``n_target``-arrivals boolean mask over the
                      sampled order (the sync engines' participation
                      record),
  ``arrival_events``  the async arrival stream: ``(time, position)``
                      pairs in arrival order on the virtual clock,
  ``fold_crashes``    crash-before-upload folding into the effective
                      mask (shared by the sync round loop and the async
                      event queue — a crashed client never arrives).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np


def arrival_order(lat: np.ndarray) -> np.ndarray:
    """Sampling-order positions sorted by simulated latency. The sort is
    STABLE: equal latencies (e.g. ``straggler_sigma=0`` instant
    arrivals) keep sampling order, which is what makes the async
    engine's arrival stream bitwise-reproducible against the sync
    engines' masks."""
    return np.argsort(np.asarray(lat), kind="stable")


def arrival_mask(ok: np.ndarray, lat: np.ndarray, n_target: int) -> np.ndarray:
    """Keep the first ``n_target`` *arrivals*: among clients that
    survived dropout and the deadline (``ok``), the ``n_target`` with
    the smallest simulated latency — not the first in sampling order.
    Returned in sampling order (boolean mask over the sampled array)."""
    order = arrival_order(lat)
    keep_sorted = ok[order] & (np.cumsum(ok[order]) <= n_target)
    mask = np.zeros_like(ok)
    mask[order] = keep_sorted
    return mask


def arrival_events(mask: np.ndarray, lat: np.ndarray,
                   t0: float = 0.0) -> List[Tuple[float, int]]:
    """The arrival stream of one dispatch: ``(absolute_time, position)``
    pairs for every admitted client (``mask``), in arrival order.
    ``t0`` is the dispatch instant on the virtual clock; a client's
    upload lands at ``t0 + lat[position]``. Ordering matches
    :func:`arrival_order` exactly (stable on ties), so the first
    ``n_target`` events of a full dispatch are precisely the clients
    :func:`arrival_mask` selects."""
    lat = np.asarray(lat, np.float64)
    mask = np.asarray(mask, bool)
    return [(float(t0 + lat[p]), int(p))
            for p in arrival_order(lat) if mask[p]]


def fold_crashes(mask: np.ndarray,
                 crash: Optional[np.ndarray]) -> np.ndarray:
    """Effective arrival mask after crash-before-upload faults: the
    client trained and vanished — no upload, no state writeback, zero
    aggregation weight. ``crash=None`` (fault-free) returns ``mask``
    unchanged. Sync engines fold this into the round's aggregation
    weights; the async engine never enqueues the arrival at all — the
    same helper guarantees both price the crash identically."""
    if crash is None:
        return mask
    return mask & ~np.asarray(crash, bool)
