"""Federated learning: server/simulator, client, strategies, wire
codecs, byte accounting, the batched/streaming/async round engines, and
the fleet-scale substrate (device-resident client-state arena +
availability traces).

Start at :class:`FLServer` + :class:`ServerConfig`; see docs/engines.md
for the engine decision table, docs/codecs.md for the codec grammar,
docs/hetero.md for heterogeneous-capacity rank tiers, docs/fleet.md
for the arena / trace / streamed-data fleet substrate,
docs/robustness.md for fault injection, upload defenses and
crash/resume, and docs/async.md for the event-driven buffered
(FedBuff-style) engine with staleness weighting and broadcast-version
pinning.
"""
from repro.fl import (
    arena,
    arrivals,
    async_engine,
    batch_engine,
    client,
    codecs,
    comm,
    faults,
    server,
    strategies,
    stream_engine,
    trace,
)
from repro.fl.arena import ClientArena
from repro.fl.arrivals import (
    arrival_events,
    arrival_mask,
    arrival_order,
    fold_crashes,
)
from repro.fl.async_engine import (
    ArrivalEvent,
    AsyncDispatch,
    AsyncState,
    finalize_buffer,
    fold_arrival,
    make_staleness,
)
from repro.fl.batch_engine import (
    ClientBatch,
    assemble_client_params,
    batched_local_update,
    batched_personalized_eval,
    chunk_round_program,
    select_upload,
)
from repro.fl.client import ClientConfig, init_client_state, local_update
from repro.fl.codecs import Codec, make_codec
from repro.fl.comm import CommLog, merge_pfedpara, split_pfedpara
from repro.fl.faults import FaultPlan
from repro.fl.server import FLServer, ServerConfig
from repro.fl.strategies import (
    Strategy,
    make_strategy,
    tree_hetero_wmean_stacked,
    tree_take,
    tree_wmean_stacked,
)
from repro.fl.stream_engine import StreamingRound
from repro.fl.trace import FleetTrace, spawn_seeds

__all__ = [
    "arena", "arrivals", "async_engine", "batch_engine", "client", "codecs",
    "comm", "faults", "server", "strategies", "stream_engine", "trace",
    "arrival_events", "arrival_mask", "arrival_order", "fold_crashes",
    "ArrivalEvent", "AsyncDispatch", "AsyncState", "finalize_buffer",
    "fold_arrival", "make_staleness", "ClientArena", "ClientBatch",
    "assemble_client_params", "batched_local_update",
    "batched_personalized_eval", "chunk_round_program", "select_upload",
    "ClientConfig", "init_client_state", "local_update", "Codec",
    "make_codec", "CommLog", "merge_pfedpara", "split_pfedpara", "FaultPlan",
    "FLServer", "ServerConfig", "Strategy", "make_strategy", "FleetTrace",
    "spawn_seeds", "StreamingRound", "tree_hetero_wmean_stacked",
    "tree_take", "tree_wmean_stacked",
]
