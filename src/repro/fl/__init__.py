"""Federated learning: server/simulator, client, strategies, wire
codecs, byte accounting, and the batched/streaming round engines.

Start at :class:`FLServer` + :class:`ServerConfig`; see docs/engines.md
for the engine decision table, docs/codecs.md for the codec grammar and
docs/hetero.md for heterogeneous-capacity rank tiers.
"""
from repro.fl import (
    batch_engine,
    client,
    codecs,
    comm,
    server,
    strategies,
    stream_engine,
)
from repro.fl.batch_engine import (
    ClientBatch,
    batched_local_update,
    batched_personalized_eval,
    chunk_round_program,
    select_upload,
)
from repro.fl.client import ClientConfig, init_client_state, local_update
from repro.fl.codecs import Codec, make_codec
from repro.fl.comm import CommLog, merge_pfedpara, split_pfedpara
from repro.fl.server import FLServer, ServerConfig
from repro.fl.strategies import (
    Strategy,
    make_strategy,
    tree_hetero_wmean_stacked,
    tree_wmean_stacked,
)
from repro.fl.stream_engine import StreamingRound

__all__ = [
    "batch_engine", "client", "codecs", "comm", "server", "strategies",
    "stream_engine", "ClientBatch", "batched_local_update",
    "batched_personalized_eval", "chunk_round_program", "select_upload",
    "ClientConfig", "init_client_state", "local_update", "Codec",
    "make_codec", "CommLog", "merge_pfedpara", "split_pfedpara", "FLServer",
    "ServerConfig", "Strategy", "make_strategy", "StreamingRound",
    "tree_hetero_wmean_stacked", "tree_wmean_stacked",
]
