from repro.fl import client, comm, server, strategies
from repro.fl.client import ClientConfig, init_client_state, local_update
from repro.fl.comm import CommLog, merge_pfedpara, split_pfedpara
from repro.fl.server import FLServer, ServerConfig
from repro.fl.strategies import Strategy, make_strategy

__all__ = [
    "client", "comm", "server", "strategies", "ClientConfig",
    "init_client_state", "local_update", "CommLog", "merge_pfedpara",
    "split_pfedpara", "FLServer", "ServerConfig", "Strategy", "make_strategy",
]
