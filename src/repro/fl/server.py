"""FL server: sampling, straggler-aware aggregation, personalization.

Fault-tolerance / straggler model: per-round client latencies are drawn
from a lognormal compute + payload/bandwidth communication model; the
server over-samples by ``oversample`` and aggregates whoever arrives
before the deadline (quantile of expected latency). Clients that miss
the deadline are dropped from the round — a dropped pod costs a round
of its data, never a crash. Async (staleness-weighted) aggregation is
available as ``staleness_mix``.

Personalization modes:
  none      — vanilla FL (upload/download everything)
  pfedpara  — paper §2.3: only x1/y1 (the global halves) transferred;
              x2/y2 persist per client
  fedper    — Arivazhagan et al.: last layer stays local
  local     — FedPAQ-style local-only baseline (no aggregation)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.loader import client_epochs
from repro.fl import comm
from repro.fl.client import ClientConfig, init_client_state, local_update
from repro.fl.strategies import Strategy, tree_mean

FEDPER_LOCAL_KEYS = ("head", "fc2", "b2")   # model-specific last layers


@dataclass
class ServerConfig:
    clients: int = 100
    participation: float = 0.16
    rounds: int = 20
    lr_decay: float = 0.992
    personalization: str = "none"      # none | pfedpara | fedper | local
    uplink_quant: str = "fp32"         # fp32 | fp16 | int8  (FedPAQ-style)
    downlink_quant: str = "fp32"
    oversample: float = 0.0            # straggler over-sampling fraction
    deadline_quantile: float = 0.9
    straggler_sigma: float = 0.5       # lognormal sigma of compute time
    bandwidth_mbps: float = 10.0
    dropout_prob: float = 0.0          # random client failure per round
    staleness_mix: float = 0.0         # >0: async staleness-weighted mixing
    seed: int = 0


class FLServer:
    def __init__(
        self,
        loss_fn: Callable,
        global_params: Any,
        data: Dict[str, np.ndarray],
        partitions: List[np.ndarray],
        strategy: Strategy,
        client_cfg: ClientConfig,
        server_cfg: ServerConfig,
        eval_fn: Optional[Callable] = None,
    ):
        self.loss_fn = loss_fn
        self.global_params = global_params
        self.data = data
        self.partitions = partitions
        self.strategy = strategy
        self.ccfg = client_cfg
        self.scfg = server_cfg
        self.eval_fn = eval_fn
        self.rng = np.random.RandomState(server_cfg.seed)
        self.round_idx = 0
        self.comm_log = comm.CommLog()
        self.server_state = (strategy.server_init(global_params)
                             if strategy.server_init else {})
        self.client_states: Dict[int, Dict] = {}
        self.local_trees: Dict[int, Any] = {}   # personalization residents
        self.history: List[Dict] = []

    # ------------------------------------------------------------ payload
    def _download_payload(self, cid: int) -> Any:
        p = self.global_params
        mode = self.scfg.personalization
        if mode == "pfedpara":
            glob, _ = comm.split_pfedpara(p)
            return glob
        if mode == "fedper":
            return {k: v for k, v in p.items() if k not in FEDPER_LOCAL_KEYS}
        return p

    def _client_full_params(self, cid: int, download: Any) -> Any:
        mode = self.scfg.personalization
        if mode == "none":
            return download
        resident = self.local_trees.get(cid)
        if resident is None:  # first participation: start from global
            return self.global_params
        if mode == "pfedpara":
            return comm.merge_pfedpara(download, resident)
        if mode == "fedper":
            merged = dict(download)
            merged.update(resident)
            return merged
        if mode == "local":
            return resident
        return download

    def _split_upload(self, cid: int, trained: Any):
        mode = self.scfg.personalization
        if mode == "pfedpara":
            glob, loc = comm.split_pfedpara(trained)
            self.local_trees[cid] = loc
            return glob
        if mode == "fedper":
            self.local_trees[cid] = {k: trained[k] for k in FEDPER_LOCAL_KEYS
                                     if k in trained}
            return {k: v for k, v in trained.items() if k not in FEDPER_LOCAL_KEYS}
        if mode == "local":
            self.local_trees[cid] = trained
            return None
        return trained

    # ------------------------------------------------------------- round
    def _simulate_latency(self, payload_bytes: int, n: int) -> np.ndarray:
        comp = self.rng.lognormal(mean=0.0, sigma=self.scfg.straggler_sigma, size=n)
        comm_s = 8.0 * payload_bytes / (self.scfg.bandwidth_mbps * 1e6)
        return comp + comm_s

    def run_round(self) -> Dict:
        scfg = self.scfg
        n_target = max(1, int(round(scfg.participation * scfg.clients)))
        n_sample = max(n_target, int(round(n_target * (1 + scfg.oversample))))
        sampled = self.rng.choice(scfg.clients, size=min(n_sample, scfg.clients),
                                  replace=False)
        lr = self.ccfg.lr * (scfg.lr_decay ** self.round_idx)

        # straggler & dropout simulation
        probe_payload = self._download_payload(int(sampled[0]))
        payload_bytes = comm.tree_bytes(probe_payload)
        lat = self._simulate_latency(payload_bytes, len(sampled))
        alive = self.rng.rand(len(sampled)) >= scfg.dropout_prob
        deadline = np.quantile(lat, scfg.deadline_quantile) if scfg.oversample else np.inf
        arrived = [int(c) for c, l, a in zip(sampled, lat, alive)
                   if a and l <= deadline]
        arrived = arrived[:n_target] if len(arrived) > n_target else arrived
        if not arrived:   # everyone failed: skip round (fault tolerance)
            self.round_idx += 1
            return {"round": self.round_idx, "participants": 0, "skipped": True}

        uploads, weights, losses = [], [], []
        for cid in arrived:
            download = self._download_payload(cid)
            params = self._client_full_params(cid, download)
            state = self.client_states.get(cid)
            if state is None:
                state = init_client_state(self.strategy, params)
            if self.strategy.name == "scaffold" and "c" in state:
                state["c"] = jax.tree.map(jnp.zeros_like, params) \
                    if not self.server_state else self.server_state.get(
                        "c", jax.tree.map(jnp.zeros_like, params))
            batches = client_epochs(self.data, self.partitions[cid],
                                    self.ccfg.batch, self.ccfg.epochs,
                                    seed=self.rng.randint(1 << 30))
            trained, state, m = local_update(
                params, batches, self.loss_fn, self.ccfg, self.strategy,
                client_state=state, lr=lr)
            self.client_states[cid] = state
            up = self._split_upload(cid, trained)
            if up is not None:
                if scfg.uplink_quant == "int8":
                    up = comm.dequantize_int8(
                        comm.quantize_int8(up, jax.random.PRNGKey(self.round_idx)))
                elif scfg.uplink_quant == "fp16":
                    up = comm.dequantize_fp16(comm.quantize_fp16(up))
                uploads.append(up)
                weights.append(float(len(self.partitions[cid])))
            losses.append(m["loss"])
            self.comm_log.log_round(download, up if up is not None else {},
                                    1, up_scheme=scfg.uplink_quant,
                                    down_scheme=scfg.downlink_quant)

        # ---------------------------------------------------- aggregation
        if uploads and scfg.personalization != "local":
            agg_target = (self.global_params if scfg.personalization == "none"
                          else self._download_payload(-1))
            new_global_part, self.server_state = self.strategy.aggregate(
                self.server_state, agg_target, uploads, weights)
            if scfg.staleness_mix > 0:
                a = scfg.staleness_mix
                new_global_part = jax.tree.map(
                    lambda old, new: (1 - a) * old + a * new,
                    agg_target, new_global_part)
            if scfg.personalization == "none":
                self.global_params = new_global_part
            else:  # write the aggregated global slice back into params
                self.global_params = comm.merge_pfedpara(
                    new_global_part,
                    comm.split_pfedpara(self.global_params)[1],
                ) if scfg.personalization == "pfedpara" else {
                    **self.global_params, **new_global_part}

        self.round_idx += 1
        rec = {
            "round": self.round_idx,
            "participants": len(arrived),
            "sampled": len(sampled),
            "mean_loss": float(np.mean(losses)) if losses else float("nan"),
            "comm_gb": self.comm_log.total_gb,
            "lr": lr,
        }
        if self.eval_fn is not None:
            rec["eval"] = self.eval_fn(self.global_params)
        self.history.append(rec)
        return rec

    def run(self, rounds: Optional[int] = None, log_every: int = 0) -> List[Dict]:
        for r in range(rounds or self.scfg.rounds):
            rec = self.run_round()
            if log_every and (r % log_every == 0):
                print(rec)
        return self.history

    # --------------------------------------------- personalization eval
    def personalized_eval(self, eval_fn: Callable) -> List[float]:
        """Evaluate each client's merged (global + resident local) model."""
        scores = []
        for cid in range(self.scfg.clients):
            params = self._client_full_params(cid, self._download_payload(cid))
            scores.append(float(eval_fn(params, cid)))
        return scores
