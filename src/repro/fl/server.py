"""FL server: sampling, straggler-aware aggregation, personalization.

Fault-tolerance / straggler model: per-round client latencies are drawn
from a lognormal compute + payload/bandwidth communication model; the
server over-samples by ``oversample`` and aggregates whoever arrives
before the deadline (quantile of expected latency). Clients that miss
the deadline are dropped from the round — a dropped pod costs a round
of its data, never a crash. ``staleness_mix`` is a legacy sync mixing
knob; true event-driven asynchrony is ``engine="async"`` below.

Execution engines (``ServerConfig.engine``):
  sequential  — reference implementation: a Python loop over arrived
                clients, one jitted step per local minibatch.
  batched     — ``repro.fl.batch_engine.ClientBatch``: all sampled
                clients' params/state are stacked along a leading
                client axis and the whole round (local epochs, payload
                selection, quantization, aggregation) runs as one
                jit-compiled vmap/shard_map program. Round memory is
                O(C · model).
  streaming   — ``repro.fl.stream_engine.StreamingRound``: one
                jit-compiled ``lax.scan`` over fixed-size client chunks
                (``ServerConfig.client_chunk``) threading a running
                fp32 weighted-sum accumulator; uploads stay in encoded
                wire form and are folded in by the fused
                dequant-accumulate Pallas kernel. Round memory is
                O(chunk · model + model) — participation becomes a
                time axis, so cohorts the stacked engine cannot hold
                (1024+ simulated clients on one host) stream through.
  async       — ``repro.fl.async_engine``: event-driven FedBuff-style
                buffered federation. A virtual clock drains an arrival
                queue (the same latency model the sync engines mask
                on); each upload folds into the streaming accumulator
                AT ARRIVAL, weighted by a staleness function ``s(tau)``
                (``ServerConfig.staleness``), and ``buffer_k`` folded
                arrivals trigger a version bump + re-broadcast. With
                ``buffer_k`` = participation target and every arrival
                landing before the next dispatch, it reproduces the
                streaming engine to fp32 tolerance with bitwise masks
                (see docs/async.md).

Masked-aggregation semantics: both engines derive the SAME boolean
arrived-mask over the sampled clients from host-side RNG draws
(``_select_round``): a client participates iff it survived random
dropout, beat the straggler deadline, and falls within the first
``n_target`` arrivals in simulated-latency order (earliest arrivals
win, not earliest sampling positions). The sequential engine
materializes the mask as the ``arrived`` list it loops over; the
batched engine keeps every sampled client in the stacked program and
multiplies the mask into the aggregation weights, so dropped clients
contribute exactly zero to the weighted tree-reduce and their
state/resident updates are discarded at unstack time. The mask is
bitwise identical between engines (it is recorded per round in
``history[i]["arrived_mask"]``), and the aggregated global params
match to fp32 tolerance.

Personalization modes:
  none      — vanilla FL (upload/download everything)
  pfedpara  — paper §2.3: only x1/y1 (the global halves) transferred;
              x2/y2 persist per client
  fedper    — Arivazhagan et al.: last layer stays local
  local     — FedPAQ-style local-only baseline (no aggregation)

Communication codecs (``ServerConfig.uplink_codec`` /
``downlink_codec``, specs like ``"delta|topk0.1|int8"`` — see
``repro.fl.codecs``): the downlink payload is encoded/decoded ONCE per
round host-side (the broadcast is identical for every client; delta
reference and server-side error feedback are broadcast state shared by
all clients, the standard sync-FL simulation assumption), and clients
train on the DECODED payload. Uplinks are encoded per client against
the round's decoded broadcast, with client-resident error-feedback
accumulators threaded through ``client_states["_ef_up"]``. The legacy
``uplink_quant`` / ``downlink_quant`` fields map to single-stage
quantizer codecs when no codec spec is given. ``CommLog`` charges the
codecs' exact ``wire_bytes``.

Heterogeneous capacity tiers (``ServerConfig.gamma_tiers`` /
``tier_assignment`` — see ``docs/hetero.md``): each client belongs to a
capacity tier with its own rank gamma; it receives, trains and uploads
only the leading tier-rank columns of every FedPara factor. The
sequential engine masks host-side per client; the batched/streaming
engines keep ONE compiled program by gathering per-client column masks
from a ``(T, ...)`` tier table instead of using ragged shapes. The
server aggregates rank-sliced uploads into the full-rank global factors
with per-column arrival-weighted averaging: columns beyond a client's
tier contribute zero WEIGHT (not zero value), and columns no arrived
client covers keep their current global value. Wire bytes are priced at
each tier's physically sliced payload shapes on both links, including
the straggler latency model. ``gamma_tiers=()`` (default) is exactly
the homogeneous path; a single tier at the model's own gamma reproduces
it to fp32 tolerance with bitwise-identical arrival masks.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import parameterization as param_lib
from repro.core import rank_policy
from repro.data.loader import client_epochs, stack_client_epochs
from repro.fl import codecs, comm
from repro.fl import faults as faults_lib
from repro.fl.arrivals import arrival_events, arrival_mask, fold_crashes
from repro.fl.client import ClientConfig, init_client_state, local_update
from repro.fl.strategies import (
    Strategy, tree_broadcast, tree_hetero_wmean_stacked,
    tree_trimmed_wmean_stacked, tree_index, tree_mean, tree_stack,
    tree_wmean_stacked)
from repro.fl.trace import spawn_seeds

FEDPER_LOCAL_KEYS = ("head", "fc2", "b2")   # model-specific last layers


def _loss_stats(losses) -> tuple:
    """``(mean, nonfinite_count)`` over per-client round losses: the
    mean ignores non-finite entries (one NaN/Inf client must not poison
    the whole round's ``mean_loss``) and the count keeps fault rounds
    diagnosable. All-finite rounds reproduce the plain mean bitwise."""
    arr = np.asarray(losses).reshape(-1)
    if arr.size == 0:
        return float("nan"), 0
    fin = np.isfinite(arr)
    mean = float(arr[fin].mean()) if fin.any() else float("nan")
    return mean, int((~fin).sum())


def _to_plain(obj):
    """Recursively convert numpy scalars/arrays to plain Python so the
    checkpoint's msgpack ``extra`` blob can serialize history records."""
    if isinstance(obj, dict):
        return {k: _to_plain(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_to_plain(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, (np.ndarray, jnp.ndarray)):
        return _to_plain(np.asarray(obj).tolist())
    return obj


# ``arrival_mask`` now lives in ``repro.fl.arrivals`` (one arrival-
# ordering code path shared with the async engine's event queue); it is
# re-imported above so existing ``from repro.fl.server import
# arrival_mask`` call sites keep working.
assert arrival_mask is not None


@dataclass
class ServerConfig:
    """Round/selection/wire/engine settings for :class:`FLServer`.

    Groups: fleet + participation (``clients``, ``participation``,
    ``rounds``, ``lr_decay``); personalization mode; wire codecs
    (``uplink_codec``/``downlink_codec`` specs — see docs/codecs.md —
    with the legacy ``*_quant`` single-stage fields as fallback);
    straggler/fault model (``oversample``, ``deadline_quantile``,
    ``straggler_sigma``, ``bandwidth_mbps``, ``dropout_prob``,
    ``staleness_mix``); execution engine (``engine``, ``client_chunk``
    — see docs/engines.md); fleet substrate (``state_store``,
    ``data_stream``, ``trace`` — see docs/fleet.md); heterogeneous
    capacity tiers (``gamma_tiers``, ``tier_assignment`` — see
    docs/hetero.md).
    """

    clients: int = 100
    participation: float = 0.16
    rounds: int = 20
    lr_decay: float = 0.992
    personalization: str = "none"      # none | pfedpara | fedper | local
    uplink_quant: str = "fp32"         # legacy: fp32 | fp16 | int8
    downlink_quant: str = "fp32"       # legacy: fp32 | fp16 | int8
    uplink_codec: str = ""             # codec spec, e.g. "delta|topk0.1|int8"
    downlink_codec: str = ""           # overrides *_quant when non-empty
    oversample: float = 0.0            # straggler over-sampling fraction
    deadline_quantile: float = 0.9
    straggler_sigma: float = 0.5       # lognormal sigma of compute time
    bandwidth_mbps: float = 10.0
    dropout_prob: float = 0.0          # random client failure per round
    staleness_mix: float = 0.0         # >0: async staleness-weighted mixing
    engine: str = "sequential"         # sequential | batched | streaming
                                       # | async (event-driven buffered
                                       # federation — docs/async.md)
    client_chunk: int = 16             # streaming/async: clients per scan step
    buffer_k: int = 0                  # async: folded arrivals per version
                                       # bump; 0 = the participation target
                                       # (K = cohort, the sync-parity limit)
    staleness: str = "constant"        # async staleness weight s(tau):
                                       # constant | poly[:a] | hinge[:b]
    max_staleness: int = -1            # async: drop arrivals staler than
                                       # this many versions; -1 = never
    state_store: str = "dict"          # dict | arena: host dicts (the
                                       # reference) or the device-resident
                                       # index-addressed fleet arena
                                       # (repro.fl.arena, docs/fleet.md)
    data_stream: str = "eager"         # eager | chunked: cohort batch
                                       # stack up front, or lazy per-chunk
                                       # host-callback materialization
                                       # (streaming engine only)
    trace: Optional[Any] = None        # repro.fl.trace.FleetTrace: O(cohort)
                                       # trace-driven sampling/availability;
                                       # None = legacy O(fleet) RNG path
    gamma_tiers: tuple = ()            # heterogeneous capacity tiers: one
                                       # rank-gamma per tier; () = uniform
                                       # full-rank clients (today's path)
    tier_assignment: str = "round_robin"   # round_robin | random | size
    defense: str = "none"              # upload screening + robust agg:
                                       # none | clip | trimmed (trimmed is
                                       # batched-only — docs/robustness.md)
    defense_z: float = 3.0             # validity-gate norm z-score bound
    defense_clip: float = 1.0          # clip: tau = clip * median norm
    defense_trim: float = 0.1          # trimmed: fraction cut per side
    faults: Optional[Any] = None       # repro.fl.faults.FaultPlan: chaos
                                       # injection; None = fault-free
    recover_frac: float = 0.5          # re-sample the round when more than
                                       # this fraction of participants
                                       # crashed or were gate-rejected ...
    recover_retries: int = 0           # ... up to this many retries
    seed: int = 0


class FLServer:
    """The federated-learning server/simulator (see module docstring).

    Args:
        loss_fn: ``loss_fn(params, batch) -> scalar`` traced inside each
            client's local step.
        global_params: initial global model pytree (FedPara factors are
            just leaves of this tree).
        data: dataset dict of arrays; clients index it via
            ``partitions``.
        partitions: per-client index arrays into ``data``.
        strategy: a ``repro.fl.strategies.Strategy``.
        client_cfg: local-SGD settings (lr, batch, epochs, ...).
        server_cfg: round/selection/codec/engine/tier settings.
        eval_fn: optional ``eval_fn(global_params) -> metric`` recorded
            per round in ``history[i]["eval"]``.
        mesh / mesh_axis: optional jax mesh for the batched/streaming
            engines' shard_map path.

    After ``run()``: ``global_params`` holds the trained model,
    ``history`` the per-round records (participants, ``arrived_mask``,
    mean loss, exact ``down_bytes``/``up_bytes``), ``comm_log`` the
    cumulative wire-byte totals, ``client_states``/``local_trees`` the
    per-client strategy state and personalization residents.
    """

    def __init__(
        self,
        loss_fn: Callable,
        global_params: Any,
        data: Dict[str, np.ndarray],
        partitions: List[np.ndarray],
        strategy: Strategy,
        client_cfg: ClientConfig,
        server_cfg: ServerConfig,
        eval_fn: Optional[Callable] = None,
        mesh: Optional[Any] = None,
        mesh_axis: str = "clients",
    ):
        self.loss_fn = loss_fn
        self.global_params = global_params
        self.data = data
        self.partitions = partitions
        self.strategy = strategy
        self.ccfg = client_cfg
        self.scfg = server_cfg
        self.eval_fn = eval_fn
        self.rng = np.random.RandomState(server_cfg.seed)
        self.round_idx = 0
        self.comm_log = comm.CommLog()
        self.server_state = (strategy.server_init(global_params)
                             if strategy.server_init else {})
        self.client_states: Dict[int, Dict] = {}
        self.local_trees: Dict[int, Any] = {}   # personalization residents
        self.history: List[Dict] = []
        self.uplink_codec = codecs.make_codec(
            server_cfg.uplink_codec or server_cfg.uplink_quant)
        self.downlink_codec = codecs.make_codec(
            server_cfg.downlink_codec or server_cfg.downlink_quant)
        self._down_ref: Any = None   # last decoded broadcast (delta ref)
        self._down_ef: Any = None    # server-side downlink error feedback
        self.tiers: Optional[rank_policy.TierSchedule] = None
        self.tier_of: Optional[np.ndarray] = None
        self._tier_cache: Optional[Dict] = None
        trace = server_cfg.trace
        if trace is not None and int(trace.clients) != int(server_cfg.clients):
            raise ValueError(
                f"trace.clients={trace.clients} != "
                f"ServerConfig.clients={server_cfg.clients}")
        if server_cfg.gamma_tiers:
            self.tiers = rank_policy.TierSchedule(
                tuple(float(g) for g in server_cfg.gamma_tiers),
                server_cfg.tier_assignment)
            if trace is not None and getattr(trace, "tier_mix", ()):
                # trace-hashed tiers: no O(fleet) assignment table
                if len(trace.tier_mix) != len(server_cfg.gamma_tiers):
                    raise ValueError(
                        "trace.tier_mix must pair one proportion with "
                        "each gamma tier")
            else:
                self.tier_of = self.tiers.assign(
                    server_cfg.clients,
                    sizes=[len(p) for p in partitions],
                    seed=server_cfg.seed)
        if server_cfg.state_store not in ("dict", "arena"):
            raise ValueError(
                f"unknown state_store {server_cfg.state_store!r} "
                "(expected dict | arena)")
        if (server_cfg.state_store == "arena"
                and server_cfg.engine == "sequential"):
            raise ValueError(
                "state_store='arena' requires the batched or streaming "
                "engine (the sequential reference keeps host dicts)")
        if server_cfg.data_stream not in ("eager", "chunked"):
            raise ValueError(
                f"unknown data_stream {server_cfg.data_stream!r} "
                "(expected eager | chunked)")
        if (server_cfg.data_stream == "chunked"
                and server_cfg.engine != "streaming"):
            raise ValueError(
                "data_stream='chunked' requires the streaming engine")
        if server_cfg.defense not in ("none", "clip", "trimmed"):
            raise ValueError(
                f"unknown defense {server_cfg.defense!r} "
                "(expected none | clip | trimmed)")
        if (server_cfg.defense == "trimmed"
                and server_cfg.engine != "batched"):
            raise ValueError(
                "defense='trimmed' requires the batched engine: the "
                "coordinate-wise trim needs every upload resident along "
                "the client axis (see docs/robustness.md); the streaming "
                "fold, the async event loop and the sequential reference "
                "use defense='clip'")
        if server_cfg.engine == "async":
            if server_cfg.staleness_mix > 0:
                raise ValueError(
                    "staleness_mix is the legacy sync mixing knob; the "
                    "async engine weights every arrival by its real "
                    "staleness s(tau) — use ServerConfig.staleness")
            if server_cfg.recover_retries > 0:
                raise ValueError(
                    "recover_retries (round-level cohort re-sampling) is "
                    "a synchronous-round notion; the async engine "
                    "recovers by dispatching fresh cohorts whenever the "
                    "arrival queue runs dry before buffer_k")
            if server_cfg.buffer_k < 0:
                raise ValueError("buffer_k must be >= 0")
        plan = server_cfg.faults
        if plan is not None and not isinstance(plan, faults_lib.FaultPlan):
            raise ValueError(
                "ServerConfig.faults must be a repro.fl.faults.FaultPlan")
        if server_cfg.recover_retries < 0:
            raise ValueError("recover_retries must be >= 0")
        self._stale_ref: Any = None   # previous decoded broadcast (what a
                                      # stale-replay fault re-uploads)
        self.arena = None   # created lazily at the first arena-mode round
        self._mesh, self._mesh_axis = mesh, mesh_axis
        self._engine = None
        self._stream = None
        self._adispatch = None
        self._async = None            # async engine event-loop state
        self._staleness_fn = None
        self._client_versions: Dict[int, int] = {}   # dict-mode pinning
        if server_cfg.engine == "batched":
            from repro.fl.batch_engine import ClientBatch

            self._engine = ClientBatch(
                loss_fn=loss_fn, strategy=strategy, client_cfg=client_cfg,
                personalization=server_cfg.personalization,
                uplink_codec=self.uplink_codec,
                fedper_local_keys=FEDPER_LOCAL_KEYS,
                mesh=mesh, mesh_axis=mesh_axis,
                defense=server_cfg.defense,
                defense_z=server_cfg.defense_z,
                defense_clip=server_cfg.defense_clip,
                defense_trim=server_cfg.defense_trim,
                flip_bits=plan.flip_bits if plan is not None else 4)
        elif server_cfg.engine == "streaming":
            from repro.fl.stream_engine import StreamingRound

            self._stream = StreamingRound(
                loss_fn=loss_fn, strategy=strategy, client_cfg=client_cfg,
                personalization=server_cfg.personalization,
                uplink_codec=self.uplink_codec,
                fedper_local_keys=FEDPER_LOCAL_KEYS,
                chunk=max(1, int(server_cfg.client_chunk)),
                mesh=mesh, mesh_axis=mesh_axis,
                defense=server_cfg.defense,
                defense_z=server_cfg.defense_z,
                defense_clip=server_cfg.defense_clip,
                flip_bits=plan.flip_bits if plan is not None else 4)
        elif server_cfg.engine == "async":
            from repro.fl.async_engine import AsyncDispatch, make_staleness

            self._staleness_fn = make_staleness(server_cfg.staleness)
            self._adispatch = AsyncDispatch(
                loss_fn=loss_fn, strategy=strategy, client_cfg=client_cfg,
                personalization=server_cfg.personalization,
                uplink_codec=self.uplink_codec,
                fedper_local_keys=FEDPER_LOCAL_KEYS,
                chunk=max(1, int(server_cfg.client_chunk)),
                mesh=mesh, mesh_axis=mesh_axis,
                defense=server_cfg.defense,
                defense_z=server_cfg.defense_z,
                defense_clip=server_cfg.defense_clip,
                flip_bits=plan.flip_bits if plan is not None else 4)
        elif server_cfg.engine != "sequential":
            raise ValueError(
                f"unknown engine {server_cfg.engine!r} "
                "(expected sequential | batched | streaming | async)")

    # ------------------------------------------------------------ payload
    def _download_payload(self, cid: int) -> Any:
        p = self.global_params
        mode = self.scfg.personalization
        if mode == "pfedpara":
            glob, _ = comm.split_pfedpara(p)
            return glob
        if mode == "fedper":
            return {k: v for k, v in p.items() if k not in FEDPER_LOCAL_KEYS}
        return p

    def _client_full_params(self, cid: int, download: Any) -> Any:
        """Client-side model assembly from the (decoded) downlink payload
        plus personalization residents. First-time participants take
        their resident half from the global init, so they too train on
        the decoded broadcast — not on uncompressed global params."""
        mode = self.scfg.personalization
        if mode == "none":
            return download
        resident = self.resident_of(cid)
        if mode == "pfedpara":
            if resident is None:
                resident = comm.split_pfedpara(self.global_params)[1]
            return comm.merge_pfedpara(download, resident)
        if mode == "fedper":
            if resident is None:
                resident = {k: v for k, v in self.global_params.items()
                            if k in FEDPER_LOCAL_KEYS}
            merged = dict(download)
            merged.update(resident)
            return merged
        if mode == "local":
            return resident if resident is not None else download
        return download

    def resident_of(self, cid: int) -> Any:
        """One client's personalization resident, wherever it lives:
        the arena row (``state_store='arena'``) or the ``local_trees``
        dict (``None`` if the client never participated — callers fall
        back to the global init, which is exactly what an arena row
        still holds before its first scatter)."""
        if self.arena is not None and self.arena.residents is not None:
            return self.arena.client_resident(cid)
        return self.local_trees.get(cid)

    def client_state_of(self, cid: int) -> Dict:
        """One client's strategy/EF state, wherever it lives: the arena
        row (``state_store='arena'``) or the ``client_states`` dict
        (``{}`` if the client never participated)."""
        if self.arena is not None:
            return self.arena.client_state(cid)
        return self.client_states.get(cid, {})

    def participation_counts(self) -> np.ndarray:
        """(clients,) per-client arrival counts. Arena mode reads the
        device-resident counter row (one masked ``.at[].add`` per
        round); dict mode tallies the recorded per-round cohorts."""
        if self.arena is not None:
            return self.arena.participation_counts()
        counts = np.zeros(self.scfg.clients, np.int64)
        for r in self.history:
            for cid, hit in zip(r.get("sampled", ()),
                                r.get("arrived_mask", ())):
                counts[cid] += int(hit)
        return counts

    def _split_upload(self, cid: int, trained: Any, into: Optional[Dict] = None):
        """Split a trained tree into (upload, resident); the resident
        lands in ``into`` (default ``self.local_trees`` — pass a pending
        dict to defer the writeback until the round commits)."""
        target = self.local_trees if into is None else into
        mode = self.scfg.personalization
        if mode == "pfedpara":
            glob, loc = comm.split_pfedpara(trained)
            target[cid] = loc
            return glob
        if mode == "fedper":
            target[cid] = {k: trained[k] for k in FEDPER_LOCAL_KEYS
                           if k in trained}
            return {k: v for k, v in trained.items() if k not in FEDPER_LOCAL_KEYS}
        if mode == "local":
            target[cid] = trained
            return None
        return trained

    def _apply_aggregated(self, new_global_part: Any, agg_target: Any):
        """Write the aggregated global slice back, with optional
        staleness-weighted async mixing. Shared by both engines."""
        scfg = self.scfg
        if scfg.staleness_mix > 0:
            a = scfg.staleness_mix
            new_global_part = jax.tree.map(
                lambda old, new: (1 - a) * old + a * new,
                agg_target, new_global_part)
        if scfg.personalization == "none":
            self.global_params = new_global_part
        elif scfg.personalization == "pfedpara":
            self.global_params = comm.merge_pfedpara(
                new_global_part, comm.split_pfedpara(self.global_params)[1])
        else:
            self.global_params = {**self.global_params, **new_global_part}

    # ------------------------------------------------ heterogeneous tiers
    def _tier_state(self, probe: Any) -> Dict:
        """Round-invariant tier tables, built once from the downlink
        payload structure (lazily, since the payload structure depends
        on the personalization mode):

          payload_masks  (T, ...)-leading rank-mask tree over the
                         payload structure (uploads + aggregation),
          full_masks     same over the full global-param structure
                         (client assembly + strategy state),
          down_bytes /   exact per-tier wire bytes, priced by each link's
          up_bytes       codec on the PHYSICALLY SLICED payload shapes —
                         the shape algebra of ``Codec.wire_bytes`` stays
                         exact, it just sees tier-rank column counts.
        """
        if self._tier_cache is None:
            gammas = self.tiers.gammas
            sliced = [param_lib.slice_factor_tree(probe, g) for g in gammas]
            self._tier_cache = {
                "payload_masks": param_lib.tier_rank_masks(probe, gammas),
                "full_masks": param_lib.tier_rank_masks(
                    self.global_params, gammas),
                "down_bytes": tuple(
                    self.downlink_codec.wire_bytes(s) for s in sliced),
                "up_bytes": tuple(
                    self.uplink_codec.wire_bytes(s) for s in sliced),
            }
        return self._tier_cache

    def tier_bytes(self) -> List[Dict]:
        """Public per-tier wire pricing (heterogeneous mode only).

        Returns one dict per tier, in ``gamma_tiers`` order:
        ``{"gamma", "up_bytes", "down_bytes", "clients"}`` — the exact
        per-round per-client wire bytes of the tier's sliced payload on
        each link, and how many clients the assignment mapped to it.
        Raises if ``gamma_tiers`` is unset or no round has run yet (the
        payload structure, hence the pricing, is known after the first
        round's broadcast).
        """
        if self.tiers is None:
            raise ValueError("tier_bytes() requires ServerConfig.gamma_tiers")
        if self._tier_cache is None:
            raise ValueError("tier_bytes() is available after the first "
                             "round (run_round() fixes the payload shapes)")
        tc = self._tier_cache
        if self.tier_of is not None:
            counts = [int((self.tier_of == t).sum())
                      for t in range(len(self.tiers.gammas))]
        else:   # trace-hashed tiers: expected counts, fleet never walked
            counts = [int(c) for c in self.scfg.trace.tier_counts()]
        return [{"gamma": g,
                 "up_bytes": tc["up_bytes"][t],
                 "down_bytes": tc["down_bytes"][t],
                 "clients": counts[t]}
                for t, g in enumerate(self.tiers.gammas)]

    def _cohort_tiers(self, cids) -> Optional[np.ndarray]:
        """Tier index per cohort client: the assignment table when one
        exists, otherwise the trace's O(cohort) id hash. ``None`` in
        homogeneous mode."""
        if self.tiers is None:
            return None
        cids = np.asarray(cids, np.int64)
        if self.tier_of is not None:
            return self.tier_of[cids].astype(np.int32)
        return self.scfg.trace.tiers_of(cids)

    def _round_bytes(self, sampled, mask, down_bytes: int, down_dec: Any,
                     up_mask=None) -> tuple:
        """Exact (down, up) wire bytes for the round's arrived clients.
        Homogeneous: participants × full payload bytes (as before).
        Heterogeneous: each arrived client is charged its TIER's sliced
        payload bytes on both links. ``up_mask`` (fault injection) lets
        crash-before-upload clients charge the downlink only — they
        received the broadcast, trained, and vanished."""
        if up_mask is None:
            up_mask = mask
        n_arrived = int(mask.sum())
        local = self.scfg.personalization == "local"
        if self.tiers is None:
            up = 0 if local else self.uplink_codec.wire_bytes(down_dec)
            return n_arrived * down_bytes, int(up_mask.sum()) * up
        tc = self._tier_cache
        down_tiers = self._cohort_tiers(
            np.asarray(sampled)[mask.astype(bool)])
        up_tiers = self._cohort_tiers(
            np.asarray(sampled)[up_mask.astype(bool)])
        down = sum(tc["down_bytes"][int(t)] for t in down_tiers)
        up = 0 if local else sum(tc["up_bytes"][int(t)] for t in up_tiers)
        return down, up

    # ------------------------------------------------------------- round
    def _simulate_latency(self, payload_bytes, n: int) -> np.ndarray:
        comp = self.rng.lognormal(mean=0.0, sigma=self.scfg.straggler_sigma, size=n)
        comm_s = 8.0 * payload_bytes / (self.scfg.bandwidth_mbps * 1e6)
        return comp + comm_s

    def _select_round(self, attempt: int = 0):
        """Host-side RNG for one round, shared verbatim by both engines:
        sample clients, simulate stragglers/dropout, derive the boolean
        arrived-mask over the sampled order (truncated to the first
        ``n_target`` ARRIVALS — earliest simulated latency first), and
        derive every sampled client's data seed. The mask — not a
        filtered list — is the round's participation record, so the two
        engines agree bitwise. Download latency is priced at the active
        downlink codec's wire bytes, not the raw fp32 tree.

        With a :class:`repro.fl.trace.FleetTrace` configured, sampling,
        availability and latency come from the trace's per-round
        generator at O(cohort) cost — ``dropout_prob`` defers to the
        trace's own dropout/diurnal model. Per-client data seeds are
        ``SeedSequence.spawn``-derived 64-bit values on BOTH paths
        (collision-free at fleet scale, unlike the legacy 2^30 draws).

        ``attempt > 0`` (round-level fault recovery) re-samples a
        replacement cohort from a fresh salted stream: the trace path
        salts its per-round generator, the legacy path switches to the
        stateless :func:`repro.fl.faults.recovery_rng` so retries never
        disturb the stateful ``self.rng`` sequence the clean rounds
        replay from.
        """
        scfg = self.scfg
        trace = scfg.trace
        n_target = max(1, int(round(scfg.participation * scfg.clients)))
        n_sample = max(n_target, int(round(n_target * (1 + scfg.oversample))))
        n_sample = min(n_sample, scfg.clients)
        rrng = (faults_lib.recovery_rng(scfg.seed, self.round_idx, attempt)
                if attempt and trace is None else None)
        if trace is not None:
            trng = trace.round_rng(self.round_idx, salt=attempt)
            sampled = trace.sample_cohort(trng, n_sample)
        elif rrng is not None:
            sampled = rrng.choice(scfg.clients, size=n_sample, replace=False)
        else:
            sampled = self.rng.choice(scfg.clients, size=n_sample,
                                      replace=False)
        lr = self.ccfg.lr * (scfg.lr_decay ** self.round_idx)

        probe_payload = self._download_payload(int(sampled[0]))
        if self.tiers is not None:
            # per-tier sliced broadcast: each sampled client's download
            # latency is priced at ITS tier's wire bytes
            tc = self._tier_state(probe_payload)
            payload_bytes = np.asarray(tc["down_bytes"])[
                self._cohort_tiers(sampled)]
        else:
            payload_bytes = self.downlink_codec.wire_bytes(probe_payload)
        if trace is not None:
            lat = trace.latency(trng, payload_bytes, len(sampled),
                                scfg.straggler_sigma, scfg.bandwidth_mbps)
            alive = (trng.random(len(sampled))
                     < trace.availability(sampled, self.round_idx))
        elif rrng is not None:
            lat = (rrng.lognormal(mean=0.0, sigma=scfg.straggler_sigma,
                                  size=len(sampled))
                   + 8.0 * np.asarray(payload_bytes, np.float64)
                   / (scfg.bandwidth_mbps * 1e6))
            alive = rrng.random(len(sampled)) >= scfg.dropout_prob
        else:
            lat = self._simulate_latency(payload_bytes, len(sampled))
            alive = self.rng.rand(len(sampled)) >= scfg.dropout_prob
        deadline = (np.quantile(lat, scfg.deadline_quantile)
                    if scfg.oversample else np.inf)
        ok = alive & (lat <= deadline)
        mask = arrival_mask(ok, lat, n_target)
        seeds = spawn_seeds(scfg.seed, self.round_idx, len(sampled))
        return sampled, mask, seeds, lr, probe_payload, lat

    def _quant_keys(self, n: int) -> jax.Array:
        """Per-client quantization keys: ``fold_in(key(round), i)`` for
        every cohort position — vectorized with one ``vmap`` dispatch
        (value-identical to the historical per-client fold_in loop,
        which cost O(cohort) dispatches per round)."""
        base = jax.random.PRNGKey(self.round_idx)
        return jax.vmap(lambda i: jax.random.fold_in(base, i))(
            jnp.arange(n, dtype=jnp.uint32))

    def _encode_downlink(self, payload: Any):
        """One broadcast encode/decode per round (the downlink payload
        is identical for every sampled client). Returns the DECODED
        payload clients actually train on plus its exact per-client
        wire bytes; advances the server-side delta reference / error
        feedback. Identity codecs short-circuit so legacy runs are
        numerically untouched."""
        codec = self.downlink_codec
        if codec.is_identity:
            return payload, codec.wire_bytes(payload)
        if codec.has_delta and self._down_ref is None:
            self._down_ref = jax.tree.map(jnp.zeros_like, payload)
        if codec.has_ef and self._down_ef is None:
            self._down_ef = codec.ef_init(payload)
        key = jax.random.fold_in(jax.random.PRNGKey(self.round_idx),
                                 0x7FFFFFFF)   # distinct from client keys
        wire, self._down_ef = codec.encode(
            payload, ref=self._down_ref, ef=self._down_ef, key=key)
        decoded = codec.decode(wire, ref=self._down_ref)
        if codec.has_delta:
            self._down_ref = decoded   # clients cache the last broadcast
        return decoded, codec.wire_bytes(payload)

    def run_round(self) -> Dict:
        """Execute one federated round end-to-end (selection, broadcast
        encode, fault injection, the configured engine, defense gating,
        round-level recovery, bookkeeping) and return (and append to
        ``history``) its record dict.

        With ``ServerConfig.faults`` set, each attempt draws the round's
        deterministic fault schedule, folds crash-before-upload clients
        out of the effective arrival mask, and runs the engine WITHOUT
        committing state; when crashed + gate-rejected clients exceed
        ``recover_frac`` of the participants and retries remain, a
        replacement cohort is re-sampled from a salted stream and the
        attempt's results are discarded. Only the accepted attempt's
        writebacks, aggregation and wire charges commit."""
        scfg = self.scfg
        plan = scfg.faults
        if scfg.engine == "async":
            return self._run_async_round()
        sampled, mask, seeds, lr, probe, lat = self._select_round()
        if not mask.any():   # everyone failed: skip round (fault tolerance)
            self.round_idx += 1
            return {"round": self.round_idx, "participants": 0, "skipped": True}
        down_dec, down_bytes = self._encode_downlink(probe)
        attempt = 0
        while True:
            fault = (plan.draw(self.round_idx, len(sampled), attempt)
                     if plan is not None else None)
            # crash-before-upload folds into the EFFECTIVE arrival mask
            # host-side: the client trained and vanished — no upload, no
            # state writeback, zero aggregation weight
            eff = fold_crashes(
                mask, fault["crash"] if fault is not None else None)
            if eff.any():
                if self._stream is not None:
                    runner = self._run_round_streaming
                elif self._engine is not None:
                    runner = self._run_round_batched
                else:
                    runner = self._run_round_sequential
                rec, commit, valid = runner(sampled, eff, seeds, lr,
                                            down_dec, down_bytes,
                                            sel_mask=mask, fault=fault)
            else:
                # every participant crashed before upload: a
                # downlink-only round, nothing arrives to aggregate
                valid = np.zeros(len(sampled), np.float32)
                rd, ru = self._round_bytes(sampled, mask, down_bytes,
                                           down_dec, up_mask=eff)
                rec = {"participants": int(mask.sum()),
                       "sampled": len(sampled),
                       "mean_loss": float("nan"), "nonfinite_losses": 0,
                       "down_bytes": rd, "up_bytes": ru, "lr": lr}

                def commit(rd=rd, ru=ru):
                    self.comm_log.log_round(rd, ru)
            participants = int(mask.sum())
            ok = (int(np.round(np.asarray(valid, np.float64)[
                np.asarray(eff, bool)].sum())) if eff.any() else 0)
            rejected = participants - ok
            if (fault is not None and attempt < scfg.recover_retries
                    and rejected > scfg.recover_frac * participants):
                nxt = self._select_round(attempt + 1)
                if nxt[1].any():
                    # discard the attempt (nothing committed) and rerun
                    # the round on the replacement cohort
                    attempt += 1
                    sampled, mask, seeds, lr, _, lat = nxt
                    continue
            break
        commit()
        # virtual seconds the sync barrier costs: the round completes
        # when its LAST arrival lands (the async engine's benchmark
        # baseline — see benchmarks/fl_async.py)
        rec["round_latency"] = float(
            np.max(np.asarray(lat)[mask.astype(bool)]))
        rec["comm_gb"] = self.comm_log.total_gb
        self.round_idx += 1
        rec["round"] = self.round_idx
        rec["arrived_mask"] = mask.astype(int).tolist()
        rec["sampled"] = [int(c) for c in sampled]
        if plan is not None:
            rec["rejected"] = rejected
            rec["retries"] = attempt
            rec["fault_kinds"] = plan.kind_counts(fault, mask)
        if self.eval_fn is not None:
            rec["eval"] = self.eval_fn(self.global_params)
        self.history.append(rec)
        # next round's stale-replay faults re-upload THIS broadcast
        self._stale_ref = down_dec
        return rec

    def _ensure_ef(self, state: Dict, payload: Any) -> Dict:
        """Attach a zero uplink error-feedback accumulator (payload
        structure) to a client state that does not have one yet."""
        if self.uplink_codec.has_ef and "_ef_up" not in state:
            state = {**state, "_ef_up": self.uplink_codec.ef_init(payload)}
        return state

    # ------------------------------------------- sequential reference
    def _run_round_sequential(self, sampled, mask, seeds, lr, down_dec,
                              down_bytes, sel_mask=None, fault=None):
        """Reference round. ``mask`` is the EFFECTIVE arrival mask
        (crash faults removed); ``sel_mask`` the selection mask used for
        participant counts and downlink charges. Returns ``(rec, commit,
        valid)``: nothing is written back until ``commit()`` runs, so a
        recovery retry can discard the whole attempt."""
        scfg = self.scfg
        if sel_mask is None:
            sel_mask = mask
        up_codec = self.uplink_codec
        plan = scfg.faults
        quant_keys = self._quant_keys(len(sampled))
        hetero = self.tiers is not None
        tc = self._tier_state(down_dec) if hetero else None
        cohort_tiers = self._cohort_tiers(sampled) if hetero else None
        pend_states: Dict[int, Dict] = {}
        pend_locals: Dict[int, Any] = {}
        uploads, up_masks, weights, losses, up_pos = [], [], [], [], []
        for i, cid in enumerate(int(c) for c in sampled):
            if not mask[i]:
                continue
            tier = int(cohort_tiers[i]) if hetero else -1
            params = self._client_full_params(cid, down_dec)
            if hetero:
                # the client only receives (and trains) the leading
                # tier-rank factor columns of the broadcast
                params = param_lib.apply_rank_mask(
                    params, tree_index(tc["full_masks"], tier))
            state = self._prep_client_state(cid, params, down_dec, tier=tier)
            batches = client_epochs(self.data, self.partitions[cid],
                                    self.ccfg.batch, self.ccfg.epochs,
                                    seed=int(seeds[i]))
            trained, state, m = local_update(
                params, batches, self.loss_fn, self.ccfg, self.strategy,
                client_state=state, lr=lr)
            up = self._split_upload(cid, trained, into=pend_locals)
            if up is not None:
                ref = down_dec
                pmask = None
                if hetero:
                    pmask = tree_index(tc["payload_masks"], tier)
                    up = param_lib.apply_rank_mask(up, pmask)
                    ref = param_lib.apply_rank_mask(down_dec, pmask)
                    up_masks.append(pmask)
                if fault is not None:
                    # same per-client injection helpers the compiled
                    # engines vmap — identical inputs, bitwise-identical
                    # faulted uploads
                    sref = (self._stale_ref if self._stale_ref is not None
                            else down_dec)
                    if pmask is not None:
                        sref = param_lib.apply_rank_mask(sref, pmask)
                    up = faults_lib.poison_upload_one(
                        up, ref, sref,
                        jnp.float32(fault["nan"][i]),
                        jnp.float32(fault["poison"][i]),
                        jnp.float32(fault["byz"][i]),
                        jnp.float32(fault["stale"][i]))
                    if up_codec.is_identity:
                        new_ef = state.get("_ef_up")
                    else:
                        wire, new_ef = up_codec.encode(
                            up, ref=ref, ef=state.get("_ef_up"),
                            key=quant_keys[i])
                        wire = faults_lib.flip_wire_bits(
                            wire, jnp.float32(fault["flip"][i]),
                            jnp.asarray(fault["flip_keys"][i], jnp.uint32),
                            plan.flip_bits)
                        up = up_codec.decode(wire, ref=ref)
                else:
                    up, new_ef = up_codec.encode_decode(
                        up, ref=ref, ef=state.get("_ef_up"),
                        key=quant_keys[i])
                if new_ef is not None:
                    state = {**state, "_ef_up": new_ef}
                uploads.append(up)
                weights.append(float(len(self.partitions[cid])))
                up_pos.append(i)
            pend_states[cid] = state
            losses.append(m["loss"])

        # ---------------------------------------------------- aggregation
        valid = np.ones(len(sampled), np.float32)
        agg_state = None
        if uploads and scfg.personalization != "local":
            agg_target = (self.global_params if scfg.personalization == "none"
                          else self._download_payload(-1))
            if scfg.defense != "none":
                # same gate/clip primitives the batched program runs,
                # over the same statistics block (the arrived cohort)
                stacked = tree_stack(uploads)
                masks_st = tree_stack(up_masks) if hetero else None
                w = jnp.asarray(weights, jnp.float32)
                cand = jnp.ones(len(uploads), jnp.float32)
                dev = faults_lib.deviation_tree(stacked, down_dec, False)
                if hetero:
                    dev = param_lib.apply_rank_mask(dev, masks_st)
                norms, finite = faults_lib.upload_stats(dev)
                v = faults_lib.validity_gate(norms, finite, cand,
                                             scfg.defense_z)
                stacked = faults_lib.sanitize_stacked(stacked, v)
                w = w * v
                if scfg.defense == "clip":
                    s = faults_lib.clip_scales(norms, v, cand,
                                               scfg.defense_clip)
                    stacked = faults_lib.apply_clip_stacked(
                        stacked, down_dec, s)
                    if hetero:
                        stacked = param_lib.apply_rank_mask(stacked,
                                                            masks_st)
                valid[np.asarray(up_pos)] = np.asarray(v, np.float32)
                if hetero:
                    mean_w = tree_hetero_wmean_stacked(stacked, w, masks_st,
                                                       agg_target)
                else:
                    mean_w = tree_wmean_stacked(stacked, w)
                    wsum = w.sum()
                    # a fully-rejected round keeps the current global
                    # (zero accepted weight must not zero the model)
                    mean_w = jax.tree.map(
                        lambda mn, tgt: jnp.where(wsum > 0, mn,
                                                  tgt.astype(mn.dtype)),
                        mean_w, agg_target)
            elif hetero:
                mean_w = tree_hetero_wmean_stacked(
                    tree_stack(uploads), jnp.asarray(weights, jnp.float32),
                    tree_stack(up_masks), agg_target)
            else:
                mean_w = tree_mean(uploads, weights)
            new_global_part, new_server_state = self.strategy.server_update(
                self.server_state, agg_target, mean_w)
            agg_state = (new_global_part, new_server_state, agg_target)

        rd, ru = self._round_bytes(sampled, sel_mask, down_bytes, down_dec,
                                   up_mask=mask)
        mean_loss, nonfinite = _loss_stats(losses)

        def commit():
            self.client_states.update(pend_states)
            self.local_trees.update(pend_locals)
            if agg_state is not None:
                new_gp, new_ss, tgt = agg_state
                self.server_state = new_ss
                self._apply_aggregated(new_gp, tgt)
            self.comm_log.log_round(rd, ru)

        rec = {
            "participants": int(sel_mask.sum()),
            "sampled": len(sampled),
            "mean_loss": mean_loss,
            "nonfinite_losses": nonfinite,
            "down_bytes": rd,
            "up_bytes": ru,
            "lr": lr,
        }
        return rec, commit, valid

    def _prep_client_state(self, cid: int, params: Any, down_dec: Any,
                           tier: int = -1) -> Dict:
        """Round-start client state: stored state or strategy init, with
        the uplink EF accumulator (payload structure) attached and the
        SCAFFOLD server control variate broadcast in. Shared by all
        three engines. ``tier >= 0`` (heterogeneous mode) column-masks
        every payload/param-structured state tree to the client's tier
        rank, so masked factor columns see exactly-zero strategy signals
        and stay zero through local training."""
        state = self.client_states.get(cid)
        if state is None:
            state = init_client_state(self.strategy, params)
        if self.scfg.personalization != "local":
            state = self._ensure_ef(state, down_dec)
        if self.strategy.name == "scaffold" and "c" in state:
            c = (jax.tree.map(jnp.zeros_like, params)
                 if not self.server_state else self.server_state.get(
                     "c", jax.tree.map(jnp.zeros_like, params)))
            state = {**state, "c": c}
        if tier >= 0:
            tc = self._tier_cache
            fmask = tree_index(tc["full_masks"], tier)
            pmask = tree_index(tc["payload_masks"], tier)
            state = dict(state)
            for k in ("c", "c_i", "lambda_i"):
                if k in state:
                    state[k] = param_lib.apply_rank_mask(state[k], fmask)
            if "_ef_up" in state:
                state["_ef_up"] = param_lib.apply_rank_mask(
                    state["_ef_up"], pmask)
        return state

    # ------------------------------------------------- fleet arena
    def _ensure_arena(self):
        """Create the device-resident client arena on first use (its EF
        template needs the payload structure, which depends on the
        personalization mode — same laziness as ``_tier_cache``). Rows
        replicate the strategy-init state / global-init residents, so a
        never-sampled row equals what ``_prep_client_state`` would build
        at first participation."""
        if self.arena is not None or self.scfg.state_store != "arena":
            return
        from repro.fl.arena import ClientArena

        scfg = self.scfg
        tmpl = init_client_state(self.strategy, self.global_params)
        if scfg.personalization != "local" and self.uplink_codec.has_ef:
            tmpl = {**tmpl, "_ef_up": self.uplink_codec.ef_init(
                self._download_payload(-1))}
        mode = scfg.personalization
        if mode == "pfedpara":
            res = comm.split_pfedpara(self.global_params)[1]
        elif mode == "fedper":
            res = {k: v for k, v in self.global_params.items()
                   if k in FEDPER_LOCAL_KEYS}
        elif mode == "local":
            res = self.global_params
        else:
            res = None
        self.arena = ClientArena.create(scfg.clients, tmpl, res)
        self.arena.shard_rows(self._mesh, self._mesh_axis)

    def _stacked_state_fixups(self, state: Dict, n: int,
                              tiers: Optional[np.ndarray]) -> Dict:
        """Round-start fixups on arena-gathered stacked state — the
        vectorized mirror of ``_prep_client_state``: broadcast the
        SCAFFOLD server control variate into every row, column-mask
        state trees to each client's tier rank in heterogeneous mode."""
        if self.strategy.name == "scaffold" and "c" in state:
            c = (self.server_state or {}).get("c")
            if c is None:
                c = jax.tree.map(lambda x: jnp.zeros(x.shape[1:], x.dtype),
                                 state["c"])
            state = {**state, "c": tree_broadcast(c, n)}
        if tiers is not None:
            tc = self._tier_cache
            ti = jnp.asarray(tiers, jnp.int32)
            fmask = jax.tree.map(lambda m: jnp.take(m, ti, axis=0),
                                 tc["full_masks"])
            pmask = jax.tree.map(lambda m: jnp.take(m, ti, axis=0),
                                 tc["payload_masks"])
            state = dict(state)
            for k in ("c", "c_i", "lambda_i"):
                if k in state:
                    state[k] = param_lib.apply_rank_mask(state[k], fmask)
            if "_ef_up" in state:
                state["_ef_up"] = param_lib.apply_rank_mask(
                    state["_ef_up"], pmask)
        return state

    # ------------------------------------------------ batched engine
    def _run_round_batched(self, sampled, mask, seeds, lr, down_dec,
                           down_bytes, sel_mask=None, fault=None):
        scfg = self.scfg
        if sel_mask is None:
            sel_mask = mask
        cids = [int(c) for c in sampled]
        C = len(cids)
        hetero = self.tiers is not None
        tc = self._tier_state(down_dec) if hetero else None
        tier_idx = self._cohort_tiers(cids) if hetero else None
        arena = scfg.state_store == "arena"

        if arena:
            # ONE vectorized gather for the whole cohort: state and
            # resident rows come off the device arena, params assemble
            # from the broadcast — no per-client Python loop exists
            self._ensure_arena()
            rows = self.arena.rows_for(cids)
            stacked_state, stacked_res = self.arena.gather(rows)
            stacked_state = self._stacked_state_fixups(stacked_state, C,
                                                       tier_idx)
            from repro.fl.batch_engine import assemble_client_params

            stacked_params = assemble_client_params(
                down_dec, stacked_res, C, scfg.personalization,
                FEDPER_LOCAL_KEYS)
            if hetero:
                fmask = jax.tree.map(
                    lambda m: jnp.take(m, jnp.asarray(tier_idx, jnp.int32),
                                       axis=0), tc["full_masks"])
                stacked_params = param_lib.apply_rank_mask(stacked_params,
                                                           fmask)
        else:
            full, states = [], []
            for pos, cid in enumerate(cids):
                params = self._client_full_params(cid, down_dec)
                tier = int(tier_idx[pos]) if hetero else -1
                if hetero:
                    params = param_lib.apply_rank_mask(
                        params, tree_index(tc["full_masks"], tier))
                full.append(params)
                states.append(self._prep_client_state(cid, params, down_dec,
                                                      tier=tier))
            stacked_params = tree_stack(full)
            stacked_state = tree_stack(states) if states and states[0] else {}

        batches, step_mask = stack_client_epochs(
            self.data, self.partitions, cids, self.ccfg.batch,
            self.ccfg.epochs, seeds)
        sizes = np.array([len(self.partitions[c]) for c in cids], np.float32)
        agg_target = (self.global_params if scfg.personalization == "none"
                      else self._download_payload(-1))

        (new_p, new_state, upload, local, last_loss, n_steps, new_global,
         new_server_state, valid_dev) = self._engine.run(
            stacked_params, stacked_state, batches, step_mask,
            mask, sizes, lr, self._quant_keys(C),
            self.server_state, agg_target, down_dec,
            tier_idx=tier_idx,
            tier_masks=tc["payload_masks"] if hetero else None,
            fault=faults_lib.device_fault_args(fault),
            stale_ref=(None if fault is None else
                       (self._stale_ref if self._stale_ref is not None
                        else down_dec)))

        arrived = np.nonzero(mask)[0]
        valid = np.asarray(valid_dev, np.float32)

        def commit():
            if arena:
                # ONE masked scatter writes the arrivals back;
                # non-arrived (and crashed) rows keep their previous
                # values bit-exactly
                self.arena.scatter(rows, new_state if new_state else {},
                                   local, mask)
            else:
                for pos in arrived:
                    cid = cids[pos]
                    if new_state:
                        self.client_states[cid] = tree_index(new_state, pos)
                    else:
                        self.client_states[cid] = {}
                    if local is not None:
                        self.local_trees[cid] = tree_index(local, pos)
            if upload is not None and scfg.personalization != "local":
                self.server_state = new_server_state
                self._apply_aggregated(new_global, agg_target)
            self.comm_log.log_round(rd, ru)

        losses = np.asarray(last_loss)[arrived]
        rd, ru = self._round_bytes(sampled, sel_mask, down_bytes, down_dec,
                                   up_mask=mask)
        mean_loss, nonfinite = _loss_stats(losses)

        rec = {
            "participants": int(sel_mask.sum()),
            "sampled": len(sampled),
            "mean_loss": mean_loss,
            "nonfinite_losses": nonfinite,
            "down_bytes": rd,
            "up_bytes": ru,
            "lr": lr,
        }
        return rec, commit, valid

    # ---------------------------------------------- streaming engine
    def _run_round_streaming(self, sampled, mask, seeds, lr, down_dec,
                             down_bytes, sel_mask=None, fault=None):
        """Chunked round: identical selection/bookkeeping contract as the
        batched engine, but clients are fed to the jitted scan program
        ``client_chunk`` at a time and the aggregate is a streamed fp32
        accumulator — no (C, model) tree is ever stacked."""
        from repro.data.loader import ChunkBatchSource, client_step_count
        from repro.fl.stream_engine import chunk_layout, from_chunks, to_chunks

        scfg = self.scfg
        if sel_mask is None:
            sel_mask = mask
        mode = scfg.personalization
        cids = [int(c) for c in sampled]
        C = len(cids)
        chunk, n_chunks, pad = chunk_layout(C, scfg.client_chunk)
        cids_pad = cids + cids[:1] * pad   # pad slots reuse client 0's
        # (small) state/resident trees; their batches are zeros below
        # (arena mode maps pad slots to the scratch row instead)
        hetero = self.tiers is not None
        tc = self._tier_state(down_dec) if hetero else None
        tier_pad = self._cohort_tiers(cids_pad) if hetero else None
        arena = scfg.state_store == "arena"

        if arena:
            # ONE vectorized cohort gather off the device arena (pad
            # slots address the scratch row); params assemble inside
            # the scan step from the broadcast + gathered residents
            self._ensure_arena()
            rows = self.arena.rows_for(cids, pad=pad)
            stacked_state, stacked_res = self.arena.gather(rows)
            stacked_state = self._stacked_state_fixups(
                stacked_state, C + pad, tier_pad)
        else:
            states, residents = [], []
            for pos, cid in enumerate(cids_pad):
                params = self._client_full_params(cid, down_dec)
                states.append(self._prep_client_state(
                    cid, params, down_dec,
                    tier=int(tier_pad[pos]) if hetero else -1))
                if mode == "pfedpara":
                    residents.append(comm.split_pfedpara(params)[1])
                elif mode == "fedper":
                    residents.append({k: params[k] for k in FEDPER_LOCAL_KEYS
                                      if k in params})
                elif mode == "local":
                    residents.append(params)
            stacked_state = tree_stack(states) if states and states[0] else {}
            stacked_res = tree_stack(residents) if residents else None

        # one round-wide step axis so every chunk (and every later round
        # with the same cohort shape) shares a compiled program
        S = max(client_step_count(len(self.partitions[c]), self.ccfg.batch,
                                  self.ccfg.epochs) for c in cids)
        data_source = None
        if scfg.data_stream == "chunked":
            # lazy per-chunk data: the scan step's host callback
            # materializes one chunk's batches at a time — the cohort's
            # (C, S, B, ...) stack never exists on the host
            data_source = ChunkBatchSource(
                self.data, self.partitions, cids, self.ccfg.batch,
                self.ccfg.epochs, [int(s) for s in seeds],
                chunk=chunk, n_chunks=n_chunks, pad_steps=max(S, 1))
            batches_xs = None
            step_mask = data_source.step_mask()
        else:
            # pad slots are pre-sized into the stacked allocation
            # (zero batches, fully masked) — never concatenated in
            batches, step_mask = stack_client_epochs(
                self.data, self.partitions, cids, self.ccfg.batch,
                self.ccfg.epochs, [int(s) for s in seeds],
                pad_steps=max(S, 1), pad_clients=pad)
            batches_xs = to_chunks(jax.tree.map(jnp.asarray, batches),
                                   n_chunks, chunk)
        mask_pad = np.zeros(C + pad, np.float32)
        mask_pad[:C] = mask
        sizes_pad = np.zeros(C + pad, np.float32)
        sizes_pad[:C] = [len(self.partitions[c]) for c in cids]
        agg_target = (self.global_params if mode == "none"
                      else self._download_payload(-1))

        fault_xs = None
        stale_ref = None
        if fault is not None:
            # pad slots are drawn-clean (byz scale 1, everything else 0)
            # so the injection math inside the scan is a no-op for them
            def _pad1(a, fill, dtype):
                out = np.full((C + pad,) + np.shape(a)[1:], fill, dtype)
                out[:C] = a
                return out
            fault_pad = {
                "nan": _pad1(fault["nan"], 0.0, np.float32),
                "poison": _pad1(fault["poison"], 0.0, np.float32),
                "byz": _pad1(fault["byz"], 1.0, np.float32),
                "stale": _pad1(fault["stale"], 0.0, np.float32),
                "flip": _pad1(fault["flip"], 0.0, np.float32),
                "flip_keys": _pad1(fault["flip_keys"], 0, np.uint32),
            }
            fault_xs = jax.tree.map(
                lambda a: to_chunks(a, n_chunks, chunk),
                faults_lib.device_fault_args(fault_pad))
            stale_ref = (self._stale_ref if self._stale_ref is not None
                         else down_dec)

        (state_ys, local_ys, loss_ys, _steps, new_global,
         new_server_state, valid_ys) = self._stream.run(
            to_chunks(stacked_state, n_chunks, chunk),
            to_chunks(stacked_res, n_chunks, chunk)
            if stacked_res is not None else None,
            batches_xs,
            to_chunks(jnp.asarray(step_mask, jnp.float32), n_chunks, chunk),
            to_chunks(jnp.asarray(mask_pad), n_chunks, chunk),
            to_chunks(jnp.asarray(sizes_pad), n_chunks, chunk),
            to_chunks(self._quant_keys(C + pad), n_chunks, chunk),
            lr, self.server_state, agg_target, down_dec,
            tier_xs=(to_chunks(jnp.asarray(tier_pad), n_chunks, chunk)
                     if hetero else None),
            tier_payload_masks=tc["payload_masks"] if hetero else None,
            tier_full_masks=tc["full_masks"] if hetero else None,
            data_source=data_source,
            fault_xs=fault_xs, stale_ref=stale_ref)

        new_state = from_chunks(state_ys) if state_ys else {}
        local = from_chunks(local_ys) if local_ys is not None else None
        arrived = np.nonzero(mask)[0]
        valid = np.asarray(from_chunks(valid_ys), np.float32)[:C]

        def commit():
            if arena:
                # ONE masked scatter: arrivals land in their rows, the
                # pad slots all write the scratch row's unchanged value
                self.arena.scatter(rows, new_state, local, mask_pad)
            else:
                for pos in arrived:
                    cid = cids[pos]
                    self.client_states[cid] = (
                        tree_index(new_state, int(pos)) if new_state else {})
                    if local is not None:
                        self.local_trees[cid] = tree_index(local, int(pos))
            if mode != "local":
                self.server_state = new_server_state
                self._apply_aggregated(new_global, agg_target)
            self.comm_log.log_round(rd, ru)

        losses = np.asarray(from_chunks(loss_ys))[arrived]
        mean_loss, nonfinite = _loss_stats(losses)
        rd, ru = self._round_bytes(sampled, sel_mask, down_bytes, down_dec,
                                   up_mask=mask)

        rec = {
            "participants": int(sel_mask.sum()),
            "sampled": len(sampled),
            "chunks": n_chunks,
            "client_chunk": chunk,
            "mean_loss": mean_loss,
            "nonfinite_losses": nonfinite,
            "down_bytes": rd,
            "up_bytes": ru,
            "lr": lr,
        }
        return rec, commit, valid

    # ------------------------------------------------- async event loop
    def _ensure_async(self):
        """Lazily create the async event-loop state (docs/async.md)."""
        if self._async is None:
            from repro.fl.async_engine import AsyncState

            n_tiers = len(self.tiers.gammas) if self.tiers is not None else 1
            self._async = AsyncState(self.scfg.clients, n_tiers=n_tiers)

    def client_versions(self) -> np.ndarray:
        """(clients,) pinned broadcast version per client (-1 = never
        dispatched): the version whose decoded broadcast the client's
        current state (EF accumulator, strategy state, residents) was
        produced against. Arena mode reads the device-resident row;
        dict mode the host-side pinning map."""
        if self.arena is not None:
            return self.arena.client_versions()
        out = np.full(self.scfg.clients, -1, np.int64)
        for c, v in self._client_versions.items():
            out[int(c)] = int(v)
        return out

    def _async_dispatch(self) -> int:
        """One broadcast + training dispatch at the current version:
        sample a cohort (same host RNG / trace draws as the sync
        engines, salted by the dispatch index within the version),
        exclude clients still in flight, encode ONE downlink, run the
        jitted :class:`repro.fl.async_engine.AsyncDispatch` program,
        commit the trained state immediately (dispatch-atomic: the
        client HAS trained — only its upload is in flight), pin the
        cohort's broadcast version, and enqueue one arrival event per
        admitted client at ``clock + latency``. Returns the number of
        events enqueued (0 = nothing admitted / everyone crashed)."""
        from repro.data.loader import client_step_count
        from repro.fl import async_engine as async_lib
        from repro.fl.stream_engine import chunk_layout, from_chunks, to_chunks

        scfg = self.scfg
        st = self._async
        plan = scfg.faults
        mode = scfg.personalization
        attempt = st.n_dispatches
        st.n_dispatches += 1
        sampled, mask, seeds, _lr, probe, lat = self._select_round(attempt)
        # an in-flight client keeps training against its pinned version;
        # it is only re-admissible once its upload lands (or is dropped)
        mask = mask & ~st.in_flight[np.asarray(sampled, np.int64)]
        if not mask.any():
            return 0
        if st.window is None:
            # the version's first ADMITTING dispatch is its participation
            # record (the parity analogue of a sync round's sampled/mask)
            st.window = {"sampled": [int(c) for c in sampled],
                         "mask": [int(v) for v in mask.astype(int)]}
        version = self.round_idx
        did = st.total_dispatches
        st.total_dispatches += 1
        down_dec, down_bytes = self._encode_downlink(probe)
        fault = (plan.draw(version, len(sampled), attempt)
                 if plan is not None else None)
        # a crashed client trained and vanished: downlink is charged,
        # no state writeback, and NO arrival event is ever enqueued
        eff = fold_crashes(mask,
                           fault["crash"] if fault is not None else None)

        cids = [int(c) for c in sampled]
        C = len(cids)
        chunk, n_chunks, pad = chunk_layout(C, scfg.client_chunk)
        cids_pad = cids + cids[:1] * pad
        hetero = self.tiers is not None
        tc = self._tier_state(down_dec) if hetero else None
        tier_pad = self._cohort_tiers(cids_pad) if hetero else None
        arena = scfg.state_store == "arena"

        if arena:
            self._ensure_arena()
            rows = self.arena.rows_for(cids, pad=pad)
            stacked_state, stacked_res = self.arena.gather(rows)
            stacked_state = self._stacked_state_fixups(
                stacked_state, C + pad, tier_pad)
        else:
            states, residents = [], []
            for pos, cid in enumerate(cids_pad):
                params = self._client_full_params(cid, down_dec)
                states.append(self._prep_client_state(
                    cid, params, down_dec,
                    tier=int(tier_pad[pos]) if hetero else -1))
                if mode == "pfedpara":
                    residents.append(comm.split_pfedpara(params)[1])
                elif mode == "fedper":
                    residents.append({k: params[k] for k in FEDPER_LOCAL_KEYS
                                      if k in params})
                elif mode == "local":
                    residents.append(params)
            stacked_state = tree_stack(states) if states and states[0] else {}
            stacked_res = tree_stack(residents) if residents else None

        S = max(client_step_count(len(self.partitions[c]), self.ccfg.batch,
                                  self.ccfg.epochs) for c in cids)
        batches, step_mask = stack_client_epochs(
            self.data, self.partitions, cids, self.ccfg.batch,
            self.ccfg.epochs, [int(s) for s in seeds],
            pad_steps=max(S, 1), pad_clients=pad)
        batches_xs = to_chunks(jax.tree.map(jnp.asarray, batches),
                               n_chunks, chunk)
        eff_pad = np.zeros(C + pad, np.float32)
        eff_pad[:C] = eff
        sizes = np.asarray([len(self.partitions[c]) for c in cids],
                           np.float32)
        sizes_pad = np.zeros(C + pad, np.float32)
        sizes_pad[:C] = sizes

        fault_xs = None
        stale_ref = None
        if fault is not None:
            def _pad1(a, fill, dtype):
                out = np.full((C + pad,) + np.shape(a)[1:], fill, dtype)
                out[:C] = a
                return out
            fault_pad = {
                "nan": _pad1(fault["nan"], 0.0, np.float32),
                "poison": _pad1(fault["poison"], 0.0, np.float32),
                "byz": _pad1(fault["byz"], 1.0, np.float32),
                "stale": _pad1(fault["stale"], 0.0, np.float32),
                "flip": _pad1(fault["flip"], 0.0, np.float32),
                "flip_keys": _pad1(fault["flip_keys"], 0, np.uint32),
            }
            fault_xs = jax.tree.map(
                lambda a: to_chunks(a, n_chunks, chunk),
                faults_lib.device_fault_args(fault_pad))
            stale_ref = (self._stale_ref if self._stale_ref is not None
                         else down_dec)

        lr = self.ccfg.lr * (scfg.lr_decay ** version)
        (state_ys, local_ys, loss_ys, _steps, valid_ys, clip_ys,
         upload_ys) = self._adispatch.run(
            to_chunks(stacked_state, n_chunks, chunk),
            to_chunks(stacked_res, n_chunks, chunk)
            if stacked_res is not None else None,
            batches_xs,
            to_chunks(jnp.asarray(step_mask, jnp.float32), n_chunks, chunk),
            to_chunks(jnp.asarray(eff_pad), n_chunks, chunk),
            to_chunks(jnp.asarray(sizes_pad), n_chunks, chunk),
            to_chunks(self._quant_keys(C + pad), n_chunks, chunk),
            lr, down_dec,
            tier_xs=(to_chunks(jnp.asarray(tier_pad), n_chunks, chunk)
                     if hetero else None),
            tier_payload_masks=tc["payload_masks"] if hetero else None,
            tier_full_masks=tc["full_masks"] if hetero else None,
            fault_xs=fault_xs, stale_ref=stale_ref)

        new_state = from_chunks(state_ys) if state_ys else {}
        local = from_chunks(local_ys) if local_ys is not None else None

        # dispatch-atomic writeback: trained state/EF/residents commit
        # now, pinned to this version — the upload is what stays in
        # flight. A crashed client keeps its PREVIOUS row/pin.
        if arena:
            self.arena.scatter(rows, new_state, local, eff_pad)
            self.arena.pin_versions(rows, version, eff_pad)
        else:
            for pos in np.nonzero(eff)[0]:
                cid = cids[int(pos)]
                self.client_states[cid] = (
                    tree_index(new_state, int(pos)) if new_state else {})
                if local is not None:
                    self.local_trees[cid] = tree_index(local, int(pos))
                self._client_versions[cid] = version

        losses = np.asarray(from_chunks(loss_ys), np.float64)
        valid = np.asarray(from_chunks(valid_ys), np.float32)
        clips = np.asarray(from_chunks(clip_ys), np.float32)

        if mode != "local" and upload_ys is not None:
            st.wires[did] = from_chunks(upload_ys)
            st.refs[did] = down_dec
            if st.accs is None:
                st.accs = [jax.tree.map(
                    lambda x: jnp.zeros(jnp.shape(x), jnp.float32),
                    down_dec) for _ in range(st.n_tiers)]

        # downlink charged at dispatch time, uplink at each arrival
        rd, _ = self._round_bytes(sampled, mask, down_bytes, down_dec,
                                  up_mask=np.zeros(len(sampled), bool))
        st.down_bytes += int(rd)
        cohort_tiers = tier_pad[:C] if hetero else None
        if mode == "local":
            up_cost = np.zeros(C, np.int64)
        elif hetero:
            up_cost = np.asarray(tc["up_bytes"], np.int64)[cohort_tiers]
        else:
            up_cost = np.full(C, int(self.uplink_codec.wire_bytes(down_dec)),
                              np.int64)

        n_events = 0
        for t_abs, pos in arrival_events(eff, lat, t0=st.clock):
            ev = async_lib.ArrivalEvent(
                t=float(t_abs), seq=st.seq, cid=cids[pos], version=version,
                did=did, pos=int(pos),
                tier=int(cohort_tiers[pos]) if hetero else 0,
                weight=float(sizes[pos]), valid=float(valid[pos]),
                clip=float(clips[pos]), loss=float(losses[pos]),
                up_cost=int(up_cost[pos]))
            st.pending[ev.seq] = ev
            heapq.heappush(st.events, (ev.t, ev.seq))
            st.in_flight[ev.cid] = True
            st.seq += 1
            n_events += 1
        if mode != "local" and upload_ys is not None:
            if n_events:
                st.wire_left[did] = n_events
            else:
                # every admitted client crashed: nothing will ever
                # consume this dispatch's wires or pin its ref
                st.wires.pop(did, None)
                st.refs.pop(did, None)
        # the NEXT dispatch's stale-replay faults re-upload THIS broadcast
        self._stale_ref = down_dec
        return n_events

    def _async_step(self) -> bool:
        """Consume the earliest arrival: advance the virtual clock,
        charge its uplink bytes, record its staleness, and — unless it
        is past ``max_staleness`` — fold its wire row into the
        accumulator with weight ``s(tau) * n_samples * valid * clip``.
        Returns True iff the arrival counted toward the buffer."""
        from repro.fl import async_engine as async_lib

        scfg = self.scfg
        st = self._async
        t, seq = heapq.heappop(st.events)
        ev = st.pending.pop(seq)
        st.clock = max(st.clock, float(t))
        st.in_flight[ev.cid] = False
        tau = self.round_idx - ev.version
        st.up_bytes += int(ev.up_cost)
        st.stale_hist[tau] = st.stale_hist.get(tau, 0) + 1
        folded = False
        if scfg.max_staleness >= 0 and tau > scfg.max_staleness:
            st.dropped_stale += 1
        elif scfg.personalization == "local":
            # no uploads to aggregate: the arrival only paces the loop
            st.losses.append(ev.loss)
            st.buffer += 1
            folded = True
        else:
            s = float(self._staleness_fn(tau))
            base = s * ev.weight * ev.valid
            wf = base * ev.clip
            st.accs[ev.tier] = async_lib.fold_arrival(
                st.accs[ev.tier], st.wires[ev.did], ev.pos, wf)
            st.wtot[ev.tier] += base
            if self.uplink_codec.has_delta:
                # delta wires decode as linear + ref: the pinned
                # broadcast re-attaches at finalize with this weight
                st.refw[ev.tier][ev.did] = (
                    st.refw[ev.tier].get(ev.did, 0.0) + base)
            elif scfg.defense == "clip":
                # clipped non-delta upload: the clipped-away remainder
                # is (1-clip) of the client's pinned broadcast
                st.refw[ev.tier][ev.did] = (
                    st.refw[ev.tier].get(ev.did, 0.0)
                    + base * (1.0 - ev.clip))
            st.losses.append(ev.loss)
            st.buffer += 1
            folded = True
        st.release_wire(ev.did)
        return folded

    def _async_flush(self) -> Dict:
        """Buffer threshold reached: finalize the staleness-weighted
        mean, apply the strategy's server update, bump the global
        version, record the version's history row (staleness histogram
        + exact per-version wire bytes), and reset the buffer. Pending
        arrivals survive — they fold into future buffers at tau >= 1."""
        from repro.fl import async_engine as async_lib

        scfg = self.scfg
        st = self._async
        mode = scfg.personalization
        version = self.round_idx
        if mode != "local" and st.buffer > 0:
            agg_target = (self.global_params if mode == "none"
                          else self._download_payload(-1))
            hetero = self.tiers is not None
            mean = async_lib.finalize_buffer(
                st.accs, st.wtot, st.refw, st.refs,
                codec=self.uplink_codec, agg_target=agg_target,
                tier_payload_masks=(
                    self._tier_state(self._download_payload(-1))
                    ["payload_masks"] if hetero else None),
                defense=scfg.defense)
            new_global, self.server_state = self.strategy.server_update(
                self.server_state, agg_target, mean)
            self._apply_aggregated(new_global, agg_target)
        self.comm_log.log_round(st.down_bytes, st.up_bytes)
        mean_loss, nonfinite = _loss_stats(st.losses)
        window = st.window or {}
        rec = {
            "participants": int(np.sum(window.get("mask", [0]))),
            "mean_loss": mean_loss,
            "nonfinite_losses": nonfinite,
            "down_bytes": int(st.down_bytes),
            "up_bytes": int(st.up_bytes),
            "lr": float(self.ccfg.lr * (scfg.lr_decay ** version)),
            "version": int(version),
            "folded": int(st.buffer),
            "dispatches": int(st.n_dispatches),
            "virtual_time": float(st.clock),
            "round_latency": float(st.clock - st.flush_t0),
            "staleness_hist": {str(k): int(v)
                               for k, v in sorted(st.stale_hist.items())},
            "dropped_stale": int(st.dropped_stale),
            "in_flight": int(len(st.pending)),
        }
        rec["comm_gb"] = self.comm_log.total_gb
        st.flush_t0 = float(st.clock)
        self.round_idx += 1
        rec["round"] = self.round_idx
        rec["arrived_mask"] = [int(v) for v in window.get("mask", [])]
        rec["sampled"] = [int(c) for c in window.get("sampled", [])]
        if self.eval_fn is not None:
            rec["eval"] = self.eval_fn(self.global_params)
        self.history.append(rec)
        st.reset_buffer(None if mode == "local"
                        else self._download_payload(-1))
        st.prune_refs()
        return rec

    def _run_async_round(self) -> Dict:
        """One async 'round' = one buffer window: dispatch at the
        current version (re-admission broadcast), drain arrivals until
        ``buffer_k`` of them folded (dispatching fresh cohorts whenever
        the queue runs dry first), then flush. ``buffer_k=0`` defaults
        K to the sync participation target, which is what makes the
        instant-arrival regime a bitwise parity reference."""
        scfg = self.scfg
        self._ensure_async()
        st = self._async
        K = int(scfg.buffer_k) or max(
            1, int(round(scfg.participation * scfg.clients)))
        self._async_dispatch()
        dry = 0
        while st.buffer < K:
            if st.events:
                self._async_step()
                continue
            admitted = self._async_dispatch()
            if admitted == 0:
                dry += 1
                if not st.events:
                    break        # arrival stream exhausted: partial flush
                if dry >= 16:
                    break        # admission starved: flush what we have
            else:
                dry = 0
        if st.buffer == 0 and st.dropped_stale == 0 and st.window is None:
            # nothing admitted, nothing arrived: skip the round
            # (mirrors the sync engines' everyone-failed skip)
            st.n_dispatches = 0
            self.round_idx += 1
            return {"round": self.round_idx, "participants": 0,
                    "skipped": True}
        return self._async_flush()

    # --------------------------------------------------- crash / resume
    def _checkpoint_tree(self) -> Dict:
        """Every array-valued piece of server state, as one dict tree
        (client dicts keyed by stringified cid — the checkpoint's
        "/"-joined paths restore them without a target structure)."""
        tree: Dict[str, Any] = {"global_params": self.global_params,
                                "server_state": self.server_state}
        if self._down_ref is not None:
            tree["down_ref"] = self._down_ref
        if self._down_ef is not None:
            tree["down_ef"] = self._down_ef
        if self._stale_ref is not None:
            tree["stale_ref"] = self._stale_ref
        if self.client_states:
            tree["client_states"] = {str(c): s for c, s
                                     in self.client_states.items()}
        if self.local_trees:
            tree["local_trees"] = {str(c): t for c, t
                                   in self.local_trees.items()}
        if self.arena is not None:
            ar = {"state": self.arena.state,
                  "participation": self.arena.participation,
                  "versions": self.arena.versions}
            if self.arena.residents is not None:
                ar["residents"] = self.arena.residents
            tree["arena"] = ar
        if self._async is not None:
            # mid-buffer async state: the accumulator, every live
            # dispatch's stacked wires and pinned broadcast ref — the
            # array half of a bitwise event-loop resume (host half in
            # save_checkpoint's extra)
            st = self._async
            az: Dict[str, Any] = {}
            if st.accs is not None:
                az["acc"] = {str(t): a for t, a in enumerate(st.accs)}
            if st.wires:
                az["wires"] = {str(d): w for d, w in st.wires.items()}
            if st.refs:
                az["refs"] = {str(d): r for d, r in st.refs.items()}
            if az:
                tree["async"] = az
        return tree

    def save_checkpoint(self, manager) -> str:
        """Checkpoint the COMPLETE server state at a round boundary
        (arrays + host bookkeeping: round index, legacy RNG stream,
        wire-byte totals, history). A restore from the written step is
        bitwise: continuing reproduces an uninterrupted run exactly."""
        st = self.rng.get_state()
        extra = {
            "round_idx": int(self.round_idx),
            "rng": [st[0], [int(v) for v in st[1]], int(st[2]),
                    int(st[3]), float(st[4])],
            "comm": [int(self.comm_log.down_bytes),
                     int(self.comm_log.up_bytes),
                     int(self.comm_log.rounds)],
            "history": _to_plain(self.history),
        }
        if self._async is not None:
            ast = self._async
            extra["async"] = {
                "clock": float(ast.clock),
                "flush_t0": float(ast.flush_t0),
                "seq": int(ast.seq),
                "buffer": int(ast.buffer),
                "total_dispatches": int(ast.total_dispatches),
                "n_dispatches": int(ast.n_dispatches),
                "wtot": [float(w) for w in ast.wtot],
                "refw": [{str(d): float(w) for d, w in rw.items()}
                         for rw in ast.refw],
                "events": [ast.pending[seq].as_list()
                           for _, seq in sorted(ast.events)],
                "up_bytes": int(ast.up_bytes),
                "down_bytes": int(ast.down_bytes),
                "stale_hist": {str(k): int(v)
                               for k, v in ast.stale_hist.items()},
                "dropped_stale": int(ast.dropped_stale),
                "losses": [float(v) for v in ast.losses],
                "window": _to_plain(ast.window),
            }
        if self._client_versions:
            extra["client_versions"] = {str(c): int(v) for c, v
                                        in self._client_versions.items()}
        return manager.save(self.round_idx, self._checkpoint_tree(),
                            extra=extra)

    def restore_checkpoint(self, manager, step: Optional[int] = None) -> int:
        """Restore from ``manager`` (latest step by default) and return
        the restored round index. Structure-free: the checkpoint's
        "/"-joined paths rebuild the nested dict trees, so per-client
        state dicts restore without knowing which clients ever
        participated. Continuing the run reproduces the uninterrupted
        history bitwise (see docs/robustness.md)."""
        by_path, extra, step = manager.restore_items(step)
        root: Dict[str, Any] = {}
        for path, arr in by_path.items():
            parts = path.split("/")
            node = root
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = jnp.asarray(arr)
        self.global_params = root["global_params"]
        self.server_state = root.get("server_state", {})
        self._down_ref = root.get("down_ref")
        self._down_ef = root.get("down_ef")
        self._stale_ref = root.get("stale_ref")
        self.client_states = {int(c): s for c, s
                              in root.get("client_states", {}).items()}
        self.local_trees = {int(c): t for c, t
                            in root.get("local_trees", {}).items()}
        ar = root.get("arena")
        if ar is not None:
            self._ensure_arena()
            # fedavg-without-EF arenas have an EMPTY state dict — only
            # the sections that produced leaves exist in the checkpoint
            if "state" in ar:
                self.arena.state = ar["state"]
            self.arena.participation = ar["participation"]
            if "versions" in ar:
                self.arena.versions = ar["versions"]
            if "residents" in ar:
                self.arena.residents = ar["residents"]
        self.round_idx = int(extra["round_idx"])
        self._client_versions = {int(c): int(v) for c, v
                                 in extra.get("client_versions",
                                              {}).items()}
        ext_async = extra.get("async")
        if ext_async is not None:
            from repro.fl.async_engine import ArrivalEvent

            self._async = None
            self._ensure_async()
            ast = self._async
            ast.clock = float(ext_async["clock"])
            ast.flush_t0 = float(ext_async["flush_t0"])
            ast.seq = int(ext_async["seq"])
            ast.buffer = int(ext_async["buffer"])
            ast.total_dispatches = int(ext_async["total_dispatches"])
            ast.n_dispatches = int(ext_async["n_dispatches"])
            ast.wtot = [float(w) for w in ext_async["wtot"]]
            ast.refw = [{int(d): float(w) for d, w in rw.items()}
                        for rw in ext_async["refw"]]
            ast.up_bytes = int(ext_async["up_bytes"])
            ast.down_bytes = int(ext_async["down_bytes"])
            ast.stale_hist = {int(k): int(v) for k, v
                              in ext_async["stale_hist"].items()}
            ast.dropped_stale = int(ext_async["dropped_stale"])
            ast.losses = [float(v) for v in ext_async["losses"]]
            ast.window = ext_async["window"]
            evs = [ArrivalEvent.from_list(r) for r in ext_async["events"]]
            ast.pending = {ev.seq: ev for ev in evs}
            ast.events = [(ev.t, ev.seq) for ev in evs]
            heapq.heapify(ast.events)
            # in_flight and the wire refcounts are derived, not stored
            ast.in_flight = np.zeros(self.scfg.clients, bool)
            ast.wire_left = {}
            for ev in evs:
                ast.in_flight[ev.cid] = True
                ast.wire_left[ev.did] = ast.wire_left.get(ev.did, 0) + 1
            az = root.get("async", {})
            acc = az.get("acc")
            if acc is not None:
                ast.accs = [acc[str(t)] for t in range(ast.n_tiers)]
            ast.wires = {int(d): w for d, w in az.get("wires", {}).items()}
            ast.refs = {int(d): r for d, r in az.get("refs", {}).items()}
        r = extra["rng"]
        self.rng.set_state((r[0], np.asarray(r[1], np.uint32), int(r[2]),
                            int(r[3]), float(r[4])))
        (self.comm_log.down_bytes, self.comm_log.up_bytes,
         self.comm_log.rounds) = (int(v) for v in extra["comm"])
        self.history = list(extra["history"])
        return step

    def run(self, rounds: Optional[int] = None, log_every: int = 0,
            ckpt: Optional[Any] = None, ckpt_every: int = 1) -> List[Dict]:
        """Run ``rounds`` federated rounds (default:
        ``ServerConfig.rounds``) and return the full ``history`` list.

        With ``ckpt`` (a :class:`repro.checkpoint.CheckpointManager`),
        ``rounds`` is the TOTAL round target: a server restored via
        :meth:`restore_checkpoint` runs only the remaining rounds, and
        the full state checkpoints every ``ckpt_every`` completed
        rounds (plus at the end)."""
        target = rounds or self.scfg.rounds
        if ckpt is None:
            for r in range(target):
                rec = self.run_round()
                if log_every and (r % log_every == 0):
                    print(rec)
            return self.history
        while self.round_idx < target:
            rec = self.run_round()
            if log_every and ((self.round_idx - 1) % log_every == 0):
                print(rec)
            if (self.round_idx % ckpt_every == 0
                    or self.round_idx >= target):
                self.save_checkpoint(ckpt)
        ckpt.wait()
        return self.history

    # --------------------------------------------- personalization eval
    def personalized_eval(self, eval_fn: Optional[Callable] = None,
                          batch_eval_fn: Optional[Callable] = None) -> List[float]:
        """Evaluate each client's merged (global + resident local) model.

        ``eval_fn(params, cid)`` runs the sequential per-client sweep.
        ``batch_eval_fn(stacked_params, cids)`` replaces the sweep with
        one batched call over all clients' stacked params (see
        ``repro.fl.batch_engine.batched_personalized_eval``)."""
        if batch_eval_fn is not None:
            full = [self._client_full_params(cid, self._download_payload(cid))
                    for cid in range(self.scfg.clients)]
            scores = batch_eval_fn(tree_stack(full),
                                   np.arange(self.scfg.clients))
            return [float(s) for s in np.asarray(scores)]
        scores = []
        for cid in range(self.scfg.clients):
            params = self._client_full_params(cid, self._download_payload(cid))
            scores.append(float(eval_fn(params, cid)))
        return scores
