"""Event-driven asynchronous buffered federation (FedBuff-style).

The synchronous engines make a round a BARRIER: sample a cohort, wait
(straggler-masked) for its arrivals, aggregate, advance. Real fleets of
millions never synchronize — each client trains against whatever
broadcast version it last received and reports whenever it finishes.
``ServerConfig.engine="async"`` models exactly that regime on top of
the streaming substrate:

  1. A **dispatch** broadcasts the current global version to an
     admitted cohort (``FLServer._select_round`` — the same host RNG /
     ``FleetTrace`` draws as the sync engines) and runs their local
     training as ONE jitted chunk-scan program (:class:`AsyncDispatch`,
     the streaming engine's chunk program minus the aggregation carry).
     The encoded uploads come back as a stacked wire tree; each
     client's arrival time is its simulated latency on the virtual
     clock (``repro.fl.arrivals.arrival_events``).
  2. The server drains the arrival queue ONE event at a time: each
     upload folds into the streaming fp32 accumulator via the fused
     dequant-aggregate kernel (:func:`fold_arrival`), weighted by
     ``s(tau) * n_samples * valid * clip`` where ``tau`` is the
     client's staleness in versions and ``s`` the configured staleness
     function (:func:`make_staleness`).
  3. When the buffer reaches ``K`` folded arrivals
     (``ServerConfig.buffer_k``), the server finalizes the weighted
     mean (:func:`finalize_buffer`), applies the strategy's
     ``server_update``, bumps the global version, and re-admits drained
     clients at the next dispatch. Clients still in flight keep
     training against their pinned version; their uploads fold later
     with ``tau >= 1`` (or are dropped past ``max_staleness``).

Version pinning: a delta-codec upload decodes as
``linear(wire) + ref_d`` where ``ref_d`` is the decoded broadcast of
the client's pinned dispatch ``d`` (each dispatch broadcasts exactly
one version). The fold accumulates only the linear part; the server
keeps per-tier, per-dispatch host-float ref weights and re-attaches
``sum_d (refw[t][d] / W) * ref_d`` at
finalize. With a single live dispatch the ratio is EXACTLY 1.0 (the
same host-float additions build numerator and denominator), which is
what makes ``K = cohort`` instant-arrival async reproduce the
streaming engine's ``Codec.agg_finalize`` bit-for-bit on the ref-add
step — the staleness->0 parity contract of ``tests/test_fl_async.py``.

Why ``defense="trimmed"`` cannot run here: a coordinate-wise trimmed
mean is an order statistic over the FULL client axis — it needs every
upload resident simultaneously, but the whole point of the async fold
is that an upload is consumed (and freed) the moment it arrives.
``clip`` survives because it stays linear: the per-client scale folds
into the arrival's scalar weight and the clipped-away broadcast
remainder rides in the same per-version ref weights.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.parameterization import apply_rank_mask
from repro.fl import faults as faults_lib
from repro.fl.batch_engine import assemble_client_params, chunk_round_program
from repro.fl.client import ClientConfig
from repro.fl.codecs import Codec, make_codec
from repro.fl.strategies import Strategy
from repro.kernels import agg as agg_kernels


# ------------------------------------------------------------ staleness
def make_staleness(spec: str) -> Callable[[int], float]:
    """Parse a staleness-weight spec into ``s(tau) -> float``:

      ``constant``     s(tau) = 1 (FedAsync's alpha-only limit),
      ``poly[:a]``     s(tau) = (1 + tau)^-a (FedBuff's polynomial;
                       default a = 0.5),
      ``hinge[:b]``    s(tau) = 1 for tau <= b, else 1 / (1 + tau - b)
                       (flat grace window, hyperbolic decay past it;
                       default b = 4).

    Every function returns exactly 1.0 at ``tau = 0``, so the
    staleness->0 parity regime is weight-identical to the sync engines
    for ANY spec.
    """
    name, _, arg = str(spec).partition(":")
    name = name.strip().lower()
    if name == "constant":
        return lambda tau: 1.0
    if name == "poly":
        a = float(arg) if arg else 0.5
        return lambda tau: float((1.0 + tau) ** (-a))
    if name == "hinge":
        b = float(arg) if arg else 4.0
        return lambda tau: 1.0 if tau <= b else float(1.0 / (1.0 + tau - b))
    raise ValueError(
        f"unknown staleness spec {spec!r} "
        "(expected constant | poly[:a] | hinge[:b])")


# ------------------------------------------------------ dispatch program
@dataclass
class AsyncDispatch:
    """The jitted dispatch program: local training + uplink encoding for
    one admitted cohort, WITHOUT aggregation.

    Structurally this is ``repro.fl.stream_engine.StreamingRound`` with
    the accumulator carry removed: the same ``chunk_round_program``
    scan step (local epochs, payload selection, per-client encoding,
    fault injection, chunk-block defense gating), so a dispatched
    client's trained state, EF accumulator and encoded wire are
    bitwise-identical to what the streaming engine would produce from
    the same inputs. The encoded uploads and per-client defense
    verdicts (validity gate + clip scale) return as scan ys; the SERVER
    folds each wire row at its arrival time — training cost is paid at
    dispatch, aggregation cost at arrival, exactly the async split.
    """

    loss_fn: Callable
    strategy: Strategy
    client_cfg: ClientConfig
    personalization: str = "none"
    uplink_codec: Optional[Codec] = None
    fedper_local_keys: Tuple[str, ...] = ()
    chunk: int = 16
    mesh: Optional[Mesh] = None
    mesh_axis: str = "clients"
    defense: str = "none"
    defense_z: float = 3.0
    defense_clip: float = 1.0
    flip_bits: int = 4

    def __post_init__(self):
        if self.defense not in ("none", "clip"):
            raise ValueError(
                f"async engine supports defense 'none' | 'clip', got "
                f"{self.defense!r} (coordinate-wise trimming needs all "
                "uploads resident along the client axis — an order "
                "statistic cannot fold one arrival at a time; see "
                "docs/async.md)")
        if self.uplink_codec is None:
            self.uplink_codec = make_codec("")
        self._program = jax.jit(self._dispatch_program,
                                donate_argnums=(0, 1))

    def _assemble(self, resident_chunk, down_payload, chunk: int):
        return assemble_client_params(down_payload, resident_chunk, chunk,
                                      self.personalization,
                                      self.fedper_local_keys)

    def _dispatch_program(self, state_xs, resident_xs, batches_xs,
                          step_mask_xs, mask_xs, sizes_xs, quant_keys_xs,
                          lr, down_payload, tier_xs, tier_payload_masks,
                          tier_full_masks, fault_xs=None, stale_ref=None):
        codec = self.uplink_codec
        mode = self.personalization
        mesh, axis = self.mesh, self.mesh_axis
        chunk = step_mask_xs.shape[1]
        hetero = tier_payload_masks is not None

        def chunk_step(carry, xs):
            (state_c, resident_c, batches_c, smask_c, mask_c, sizes_c,
             keys_c, tier_c, fault_c) = xs
            params_c = self._assemble(resident_c, down_payload, chunk)
            col_masks = None
            if hetero:
                full_m = jax.tree.map(
                    lambda m: jnp.take(m, tier_c, axis=0), tier_full_masks)
                params_c = apply_rank_mask(params_c, full_m)
                col_masks = jax.tree.map(
                    lambda m: jnp.take(m, tier_c, axis=0),
                    tier_payload_masks)
            new_p, new_state, upload, local, last_loss, n_steps = \
                chunk_round_program(
                    params_c, state_c, batches_c, smask_c, keys_c,
                    down_payload,
                    loss_fn=self.loss_fn, client_cfg=self.client_cfg,
                    strategy_name=self.strategy.name, personalization=mode,
                    fedper_local_keys=self.fedper_local_keys,
                    uplink_codec=codec, lr=lr, mesh=mesh, axis=axis,
                    encoded_upload=True, col_masks=col_masks,
                    fault=fault_c, stale_ref=stale_ref,
                    flip_bits=self.flip_bits)
            valid_c = jnp.ones_like(mask_c)
            clip_c = jnp.ones_like(mask_c)
            if upload is not None and self.defense != "none":
                # same chunk-block screening as the streaming engine
                # (the statistics block is the dispatch chunk): rejected
                # clients carry zero fold weight and a sanitized wire
                lin = jax.vmap(
                    lambda u: faults_lib.linear_decode(codec, u))(upload)
                dev = faults_lib.deviation_tree(lin, down_payload,
                                                codec.has_delta)
                if hetero:
                    dev = apply_rank_mask(dev, col_masks)
                cand = (mask_c > 0).astype(jnp.float32)
                norms, finite = faults_lib.upload_stats(dev)
                valid_c = faults_lib.validity_gate(norms, finite, cand,
                                                   self.defense_z)
                upload = faults_lib.sanitize_stacked(upload, valid_c)
                if self.defense == "clip":
                    clip_c = faults_lib.clip_scales(norms, valid_c, cand,
                                                    self.defense_clip)
            del new_p   # reassembled from the broadcast at next dispatch
            ys = (new_state, local, last_loss, n_steps, valid_c, clip_c,
                  upload)
            return carry, ys

        xs = (state_xs, resident_xs, batches_xs, step_mask_xs, mask_xs,
              sizes_xs, quant_keys_xs, tier_xs, fault_xs)
        _, (state_ys, local_ys, loss_ys, steps_ys, valid_ys, clip_ys,
            upload_ys) = jax.lax.scan(chunk_step, (), xs)
        return (state_ys, local_ys, loss_ys, steps_ys, valid_ys, clip_ys,
                upload_ys)

    def run(self, state_xs, resident_xs, batches_xs, step_mask_xs, mask_xs,
            sizes_xs, quant_keys_xs, lr, down_payload, tier_xs=None,
            tier_payload_masks=None, tier_full_masks=None, fault_xs=None,
            stale_ref=None):
        """Execute one dispatch over chunk-stacked xs (the same layout
        as ``StreamingRound.run``). Returns ``(state_ys, local_ys,
        loss_ys, steps_ys, valid_ys, clip_ys, upload_ys)`` with leading
        ``(n_chunks, chunk)`` axes; ``upload_ys`` is the stacked
        encoded-for-aggregation wire tree (``None`` in
        ``personalization='local'`` mode)."""
        return self._program(
            state_xs, resident_xs,
            None if batches_xs is None
            else jax.tree.map(jnp.asarray, batches_xs),
            jnp.asarray(step_mask_xs, jnp.float32),
            jnp.asarray(mask_xs, jnp.float32),
            jnp.asarray(sizes_xs, jnp.float32),
            quant_keys_xs, jnp.asarray(lr, jnp.float32),
            down_payload,
            None if tier_xs is None else jnp.asarray(tier_xs, jnp.int32),
            tier_payload_masks, tier_full_masks, fault_xs, stale_ref)


# -------------------------------------------------------- arrival folds
@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("use_pallas",))
def fold_arrival(acc_tree, wires, pos, weight, *, use_pallas=True):
    """Fold ONE arrival into the running fp32 accumulator: gather row
    ``pos`` of the dispatch's stacked wire tree and dequant-accumulate
    it with scalar ``weight`` via the fused kernel. ``pos`` and
    ``weight`` are traced, so every arrival of every dispatch with the
    same cohort shape reuses ONE compiled program — the zero-recompile
    contract across version bumps (``repro.analysis.program_check``).
    The accumulator is donated: XLA updates it in place."""
    row = jax.tree.map(
        lambda x: jax.lax.dynamic_index_in_dim(x, pos, 0, keepdims=True),
        wires)
    w = jnp.reshape(jnp.asarray(weight, jnp.float32), (1,))
    return agg_kernels.tree_dequant_acc(acc_tree, row, w,
                                        use_pallas=use_pallas)


def finalize_buffer(accs, wtots, refws, refs, *, codec, agg_target,
                    tier_payload_masks=None, defense="none"):
    """Weighted mean of the buffered folds, with per-version delta
    references re-attached.

    ``accs``/``wtots``/``refws`` are per-tier: fp32 accumulator trees,
    host-float weight totals, and ``{dispatch_id: host-float}`` ref
    weights (a dispatch belongs to exactly one version, but a version
    can re-broadcast mid-drain, so the delta reference is pinned per
    DISPATCH — ``refs`` maps each live dispatch id to the decoded
    broadcast its clients trained against). Homogeneous
    (``tier_payload_masks=None``)::

        mean = acc / max(W, eps) + sum_d (refw[d] / max(W, eps)) * ref_d

    Heterogeneous: per-column num/den reduction over the tier masks
    exactly as the streaming finalize, with the ref coefficient a
    per-column array ``sum_t M_t * refw[t][d] / max(den, eps)``;
    columns no fold covered keep ``agg_target``. The single-live-
    dispatch ratios are exactly 1.0 (numerator and denominator are the
    same host-float sums), reproducing ``Codec.agg_finalize``.
    """
    if tier_payload_masks is None:
        wtot = float(wtots[0])
        if wtot <= 0.0:
            # a fully-rejected (or empty) buffer keeps the current
            # global — zero accepted weight must not zero the model
            return jax.tree.map(lambda t: t.astype(jnp.float32), agg_target)
        denom = max(wtot, 1e-12)
        mean = jax.tree.map(lambda a: a / jnp.float32(denom), accs[0])
        return codec.agg_finalize_pinned(
            mean, refs, {d: float(w) / denom for d, w in refws[0].items()})

    n_tiers = len(accs)
    masks_t = [jax.tree.map(lambda m: m[t], tier_payload_masks)
               for t in range(n_tiers)]
    num = functools.reduce(
        lambda a, b: jax.tree.map(jnp.add, a, b),
        [jax.tree.map(lambda m, a: m * a, masks_t[t], accs[t])
         for t in range(n_tiers)])
    den = functools.reduce(
        lambda a, b: jax.tree.map(jnp.add, a, b),
        [jax.tree.map(lambda m: m * jnp.float32(float(wtots[t])), masks_t[t])
         for t in range(n_tiers)])
    mean = jax.tree.map(lambda nm, d: nm / jnp.maximum(d, 1e-12), num, den)
    versions = sorted(set().union(*[set(r) for r in refws]))
    for v in versions:
        if all(refws[t].get(v, 0.0) == 0.0 for t in range(n_tiers)):
            continue
        coef = functools.reduce(
            lambda a, b: jax.tree.map(jnp.add, a, b),
            [jax.tree.map(
                lambda m: m * jnp.float32(float(refws[t].get(v, 0.0))),
                masks_t[t]) for t in range(n_tiers)])
        mean = jax.tree.map(
            lambda a, cf, d, r: a + cf / jnp.maximum(d, 1e-12)
            * r.astype(a.dtype), mean, coef, den, refs[v])
    # columns no folded arrival covers keep the current global value
    return jax.tree.map(
        lambda d, mn, tgt: jnp.where(d > 0, mn, tgt.astype(mn.dtype)),
        den, mean, agg_target)


# ------------------------------------------------------- event machinery
@dataclass
class ArrivalEvent:
    """One in-flight upload: everything the fold needs, host-side.
    ``valid``/``clip`` are the dispatch program's defense verdicts for
    this client; ``up_cost`` its tier-priced uplink wire bytes, charged
    at arrival (a crash never creates an event, so a crashed client is
    never charged uplink bytes)."""

    t: float          # absolute arrival time on the virtual clock
    seq: int          # global tie-break: equal times pop in enqueue order
    cid: int          # fleet client id
    version: int      # pinned broadcast version the client trained from
    did: int          # dispatch id (keys the stacked wire tree)
    pos: int          # row in the dispatch's stacked cohort
    tier: int         # capacity tier (-1 = homogeneous)
    weight: float     # n_samples aggregation weight
    valid: float      # defense validity gate (1.0 = accepted)
    clip: float       # defense clip scale (1.0 = unclipped)
    loss: float       # client's last local loss (flush bookkeeping)
    up_cost: int      # exact uplink wire bytes for this arrival

    def as_list(self) -> list:
        """Flatten to a plain numeric row (checkpoint wire format)."""
        return [self.t, self.seq, self.cid, self.version, self.did,
                self.pos, self.tier, self.weight, self.valid, self.clip,
                self.loss, self.up_cost]

    @classmethod
    def from_list(cls, row) -> "ArrivalEvent":
        """Rebuild from an ``as_list`` row, restoring field dtypes."""
        return cls(t=float(row[0]), seq=int(row[1]), cid=int(row[2]),
                   version=int(row[3]), did=int(row[4]), pos=int(row[5]),
                   tier=int(row[6]), weight=float(row[7]),
                   valid=float(row[8]), clip=float(row[9]),
                   loss=float(row[10]), up_cost=int(row[11]))


@dataclass
class AsyncState:
    """The async server's mutable event-loop state: the virtual clock,
    the arrival heap, per-dispatch wire stacks, per-version broadcast
    refs, and the streaming accumulator with its host-float weight
    bookkeeping. Everything here round-trips through the checkpoint
    (``FLServer.save_checkpoint``) so a mid-buffer crash/resume is
    bitwise; ``in_flight`` and the wire/ref refcounts are derived from
    the pending events on restore rather than serialized."""

    n_clients: int
    n_tiers: int = 1
    clock: float = 0.0
    flush_t0: float = 0.0          # clock at the previous version bump
    seq: int = 0
    buffer: int = 0
    total_dispatches: int = 0
    n_dispatches: int = 0          # dispatches within the current version
    accs: Optional[List[Any]] = None      # per-tier fp32 payload trees
    wtot: List[float] = field(default_factory=list)
    refw: List[Dict[int, float]] = field(default_factory=list)
    events: List[Tuple[float, int]] = field(default_factory=list)  # heap
    pending: Dict[int, ArrivalEvent] = field(default_factory=dict)
    wires: Dict[int, Any] = field(default_factory=dict)
    wire_left: Dict[int, int] = field(default_factory=dict)
    refs: Dict[int, Any] = field(default_factory=dict)
    in_flight: Optional[np.ndarray] = None
    up_bytes: int = 0              # charged since the last flush
    down_bytes: int = 0
    stale_hist: Dict[int, int] = field(default_factory=dict)
    dropped_stale: int = 0
    losses: List[float] = field(default_factory=list)
    window: Optional[Dict[str, Any]] = None  # current version's first
    #                                          dispatch (sampled, mask, ...)

    def __post_init__(self):
        if not self.wtot:
            self.wtot = [0.0] * self.n_tiers
        if not self.refw:
            self.refw = [dict() for _ in range(self.n_tiers)]
        if self.in_flight is None:
            self.in_flight = np.zeros(self.n_clients, bool)

    def reset_buffer(self, payload_template: Any) -> None:
        """Flush epilogue: zero the accumulator (reallocated — the fold
        donates it), the weight totals, ref weights and per-flush
        bookkeeping. Pending events, wires and still-pinned refs
        survive — they belong to future buffers. ``payload_template=
        None`` (personalization='local': nothing aggregates) keeps the
        accumulator unallocated."""
        self.accs = None if payload_template is None else [jax.tree.map(
            lambda x: jnp.zeros(jnp.shape(x), jnp.float32),
            payload_template) for _ in range(self.n_tiers)]
        self.wtot = [0.0] * self.n_tiers
        self.refw = [dict() for _ in range(self.n_tiers)]
        self.buffer = 0
        self.n_dispatches = 0
        self.up_bytes = 0
        self.down_bytes = 0
        self.stale_hist = {}
        self.dropped_stale = 0
        self.losses = []
        self.window = None

    def prune_refs(self) -> None:
        """Drop broadcast refs no pending event is pinned to (the flush
        already consumed their ref weights). Refs are keyed by dispatch
        id — a version that re-broadcasts mid-drain has one ref per
        dispatch."""
        live = {ev.did for ev in self.pending.values()}
        for d in [d for d in self.refs if d not in live]:
            del self.refs[d]

    def release_wire(self, did: int) -> None:
        """One event of dispatch ``did`` was consumed; free the stacked
        wire tree once its last in-flight row is gone."""
        if did not in self.wire_left:
            return
        self.wire_left[did] -= 1
        if self.wire_left[did] <= 0:
            del self.wire_left[did]
            self.wires.pop(did, None)
